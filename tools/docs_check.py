"""CI docs gate: DESIGN.md section pointers resolve, README examples run.

Two checks, both cheap enough for the lint job:

1. **Pointer integrity** — module docstrings, tests, benchmarks, and the
   READMEs refer to design sections as ``DESIGN.md §N`` (often just
   ``§N`` after a nearby mention).  Every ``§N`` token anywhere in the
   repo's Python and Markdown sources must resolve to a ``## §N``
   heading in DESIGN.md — a renumbering or a deleted section fails the
   gate instead of silently pointing readers at the wrong subsystem.
   (§1 is valid by declaration: DESIGN.md's preamble documents it as
   living in the ``repro.core`` module docstrings.)

2. **README examples execute** — every ```` ```python ```` block in
   README.md runs, in order, in one shared namespace (later blocks may
   use names the earlier ones defined, exactly as a reader would paste
   them).  A block whose text contains ``docs-check: skip`` is exempt
   (e.g. the sharded example needs 8 simulated devices, which requires
   an XLA flag set before jax imports).

Run as ``make docs-check`` (wired into the CI lint job)::

    PYTHONPATH=src python tools/docs_check.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DESIGN = ROOT / "DESIGN.md"
README = ROOT / "README.md"

#: directories whose .py/.md files carry §N pointers worth checking
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")

#: sections documented outside DESIGN.md by declaration (its preamble)
EXTERNAL_SECTIONS = {1}


def design_sections() -> set[int]:
    text = DESIGN.read_text(encoding="utf-8")
    return {int(m) for m in re.findall(r"^## §(\d+)\b", text, re.M)}


def check_pointers() -> list[str]:
    valid = design_sections() | EXTERNAL_SECTIONS
    errors = []
    files = [DESIGN, README]
    for d in SCAN_DIRS:
        files += sorted((ROOT / d).rglob("*.py"))
        files += sorted((ROOT / d).rglob("*.md"))
    for path in files:
        if not path.is_file():
            continue
        for lineno, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            for m in re.finditer(r"§(\d+)", line):
                n = int(m.group(1))
                if n not in valid:
                    errors.append(
                        f"{path.relative_to(ROOT)}:{lineno}: §{n} does "
                        f"not resolve to a DESIGN.md section "
                        f"(have: {sorted(valid)})")
    return errors


def readme_blocks() -> list[tuple[int, str]]:
    """(start_line, code) for each ```python fence in README.md."""
    blocks, code, start = [], None, 0
    for lineno, line in enumerate(
            README.read_text(encoding="utf-8").splitlines(), 1):
        if code is None:
            if line.strip() == "```python":
                code, start = [], lineno
        elif line.strip() == "```":
            blocks.append((start, "\n".join(code)))
            code = None
        else:
            code.append(line)
    return blocks


def check_readme() -> list[str]:
    sys.path.insert(0, str(ROOT / "src"))
    ns: dict = {"__name__": "__docs_check__"}
    errors = []
    for start, code in readme_blocks():
        if "docs-check: skip" in code:
            print(f"README.md:{start}: skipped (marked)")
            continue
        print(f"README.md:{start}: running ``````python block")
        try:
            exec(compile(code, f"README.md:{start}", "exec"), ns)
        except Exception as e:  # noqa: BLE001 — report, keep checking
            errors.append(f"README.md:{start}: block raised "
                          f"{type(e).__name__}: {e}")
    return errors


def main() -> int:
    errors = check_pointers()
    errors += check_readme()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    n = len(design_sections())
    if not errors:
        print(f"docs-check: OK — {n} DESIGN.md sections, all §N "
              f"pointers resolve, all README blocks ran")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
