"""Kernel throughput benches → ``BENCH_kernels.json``.

Two sections:

**matmul** — the original semiring-matmul engine rows: (∨,∧)/(min,+)/
(+,×) dense contraction throughput of the execution layer (CPU path
here; the Pallas kernels are the TPU target, correctness-validated in
interpret mode).

**spmm** — the fused batched COO semiring SpMM (DESIGN.md §9,
``kernels/coo_spmm.py``) vs the traceable jnp gather→⊗→segment-⊕
composition, swept across semiring × B ∈ {1, 8, 64} × edge density at
the 50k-vertex serving shape.  Each cell times ONE hot-loop advance
(``d ⊗ E`` with dst-sorted edges) — the unit the planner's
``SpmmKernelModel`` prices — on whatever backend
:func:`repro.core.planner.spmm_exec_backend` resolves on this host
(packed-𝔹 / host-fused on CPU, the Pallas kernel on TPU), checks it
bit-exact against the jnp oracle, and reports the speedup.  A small
interpret-mode Pallas parity cell runs per semiring so the kernel path
itself is exercised even on CPU.

Acceptance gate (``gate=True``): boolean B=64 at the serve shape must
hold ≥ 1.5× the jnp round throughput — the committed
``BENCH_kernels.json`` then pins every speedup via
``benchmarks/check_regression.py`` (``make bench-check``).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import semiring as sr_mod
from repro.datalog import datasets
from repro.kernels import coo_spmm, ops
from repro.sparse import contract

#: the acceptance cell: (semiring, B, avg_deg at the 50k serve shape)
GATE_CELL = ("bool", 64, 4)
GATE_MIN_SPEEDUP = 1.5


def run_matmul(sizes=(256, 512), semirings=("bool", "trop", "nat")):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        for name in semirings:
            sr = sr_mod.get(name)
            if name == "bool":
                a = jnp.asarray(rng.random((n, n)) < 0.1)
                b = a
            else:
                a = jnp.asarray(rng.integers(0, 9, (n, n)).astype(np.float32))
                b = a
            t = timeit(lambda: ops.semiring_matmul(sr, a, b), iters=3)
            gflops = 2 * n ** 3 / t / 1e9
            emit(f"kernel/semiring_matmul/{name}/n{n}", t,
                 f"{gflops:.2f} GOP/s")
            rows.append({"semiring": name, "n": n, "t_s": t,
                         "gops": gflops})
    return rows


# --------------------------------------------------------------------------
# fused SpMM sweep
# --------------------------------------------------------------------------


def _graph(n: int, avg_deg: int, seed: int) -> datasets.Graph:
    """The serving shape: power-law at the serve bench's attachment
    degree; denser sweeps re-attach at higher m."""
    g0 = datasets.powerlaw(n, avg_deg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    return datasets.Graph(g0.n, g0.edges,
                          rng.integers(1, 5, len(g0.edges)))


def _frontier(n: int, b: int, sr_name: str, seed: int) -> np.ndarray:
    """A mid-fixpoint-looking (n, B) delta pack: ~5 % live entries."""
    rng = np.random.default_rng(seed)
    live = rng.random((n, b)) < 0.05
    srn = sr_mod.get(sr_name, lib="np")
    if sr_name == "bool":
        return live
    x = np.full((n, b), srn.zero, srn.dtype)
    x[live] = rng.integers(0, 8, int(live.sum())).astype(srn.dtype)
    return x


def _time_jnp_round(rel, x):
    f = jax.jit(lambda v: contract.spmm(rel, v, transpose=True))
    return timeit(lambda: f(x), iters=3)


def _time_backend_round(backend, plan, x):
    """One fused advance on the resolved backend — the serve loop's
    actual per-round unit (packed words for 𝔹 on the host)."""
    if backend == "pallas":
        return timeit(lambda: coo_spmm.spmm_pallas(
            plan, x, interpret=jax.default_backend() != "tpu"), iters=3)
    if plan.sr_name == "bool":
        words = coo_spmm.pack_lanes(np.asarray(x).T)
        return timeit(lambda: coo_spmm.bool_round_packed(plan, words),
                      iters=3)
    xh = np.asarray(x)
    return timeit(lambda: coo_spmm.spmm_host(plan, xh), iters=3)


def _interpret_parity(sr_name: str, seed: int, n: int = 384,
                      b: int = 8) -> bool:
    """Small interpret-mode Pallas cell vs the jnp oracle, so the kernel
    path compiles-and-matches even on a CPU bench host."""
    g = _graph(n, 3, seed)
    rel = g.sparse_adjacency(
        semiring=sr_name if sr_name in ("bool", "trop", "maxplus")
        else "trop")
    if sr_name not in ("bool", "trop", "maxplus"):
        from repro.sparse.coo import SparseRelation
        eh = rel.as_np()
        k = int(eh.nnz)
        rel = SparseRelation.from_coo(eh.coords[:k], eh.values[:k],
                                      rel.shape, sr_name)
    x = jnp.asarray(_frontier(n, b, sr_name, seed + 7))
    plan = coo_spmm.plan_geometry(rel, transpose=True)
    got = np.asarray(coo_spmm.spmm_pallas(plan, x, interpret=True))
    want = np.asarray(contract.spmm(rel, x, transpose=True))
    return np.array_equal(got, want)


def run_spmm(n=50_000, batches=(1, 8, 64), avg_degs=(4, 16),
             semirings=("bool", "trop", "nat", "maxplus"), seed=1,
             interpret_parity=True):
    rows = []
    for deg in avg_degs:
        g = _graph(n, deg, seed)
        for sr_name in semirings:
            rel = g.sparse_adjacency(
                semiring="bool" if sr_name == "bool" else "trop")
            if sr_name not in ("bool", "trop"):
                from repro.sparse.coo import SparseRelation
                eh = rel.as_np()
                k = int(eh.nnz)
                rel = SparseRelation.from_coo(eh.coords[:k],
                                              eh.values[:k], rel.shape,
                                              sr_name)
            rel_j = rel.as_jnp()
            plan = coo_spmm.plan_geometry(rel_j, transpose=True)
            # the *hardware* backend, never interpret mode: under
            # REPRO_PALLAS_INTERPRET (the CI flag) spmm_exec_backend
            # resolves "pallas", but timing the interpreter would make
            # every speedup a fiction — interpret parity is the
            # separate cells below
            backend = ("pallas" if jax.default_backend() == "tpu"
                       else "fused")
            for b in batches:
                x = _frontier(n, b, sr_name, seed + b)
                xj = jnp.asarray(x)
                t_jnp = _time_jnp_round(rel_j, xj)
                t_fused = _time_backend_round(backend, plan, xj)
                # bit-exact parity of the timed unit vs the jnp oracle
                want = np.asarray(contract.spmm(rel_j, xj,
                                                transpose=True))
                if plan.sr_name == "bool" and backend != "pallas":
                    words = coo_spmm.pack_lanes(x.T)
                    got = coo_spmm.unpack_lanes(
                        coo_spmm.bool_round_packed(plan, words), b).T
                elif backend == "pallas":
                    got = np.asarray(coo_spmm.spmm_pallas(
                        plan, xj,
                        interpret=jax.default_backend() != "tpu"))
                else:
                    got = coo_spmm.spmm_host(plan, x)
                assert np.array_equal(np.asarray(got), want), \
                    (sr_name, b, deg)
                nnz = int(plan.nnz)
                speedup = t_jnp / t_fused
                rows.append({
                    "semiring": sr_name, "B": b, "avg_deg": deg,
                    "nnz": nnz, "density": nnz / (n * n),
                    "backend": backend, "t_jnp_s": t_jnp,
                    "t_fused_s": t_fused, "speedup": speedup,
                })
                emit(f"kernel/coo_spmm/{sr_name}/B{b}/deg{deg}", t_fused,
                     f"jnp={t_jnp*1e3:.2f}ms fused={t_fused*1e3:.2f}ms "
                     f"speedup={speedup:.2f}x [{backend}]")
    parity = {}
    if interpret_parity:
        for sr_name in semirings:
            parity[sr_name] = _interpret_parity(sr_name, seed)
            emit(f"kernel/coo_spmm_pallas_parity/{sr_name}", 0.0,
                 "exact" if parity[sr_name] else "MISMATCH")
        assert all(parity.values()), \
            f"interpret-mode Pallas parity failed: {parity}"
    return rows, parity


def run(sizes=(256, 512), semirings=("bool", "trop", "nat"),
        n=50_000, batches=(1, 8, 64), avg_degs=(4, 16),
        spmm_semirings=("bool", "trop", "nat", "maxplus"), seed=1,
        out="BENCH_kernels.json", gate=True):
    matmul_rows = run_matmul(sizes, semirings)
    spmm_rows, parity = run_spmm(n, batches, avg_degs, spmm_semirings,
                                 seed)
    result = {"bench": "kernels", "n": n, "seed": seed,
              "backend": ("pallas" if jax.default_backend() == "tpu"
                          else "fused"),
              "pallas_interpret_parity": parity,
              "matmul": matmul_rows, "spmm": spmm_rows}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")
    if gate:
        sname, gb, gdeg = GATE_CELL
        cell = [r for r in spmm_rows
                if (r["semiring"], r["B"], r["avg_deg"])
                == (sname, gb, gdeg)]
        assert cell, f"gate cell {GATE_CELL} not swept"
        assert cell[0]["speedup"] >= GATE_MIN_SPEEDUP, (
            f"fused {sname} B={gb} round speedup "
            f"{cell[0]['speedup']:.2f}x < {GATE_MIN_SPEEDUP}x at the "
            f"serve shape — the planner's measured-crossover constants "
            f"(SpmmKernelModel) no longer hold on this host")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--batches", default="1,8,64")
    ap.add_argument("--degs", default="4,16")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--no-gate", action="store_true")
    args = ap.parse_args()
    run(n=args.n,
        batches=tuple(int(s) for s in args.batches.split(",") if s),
        avg_degs=tuple(int(s) for s in args.degs.split(",") if s),
        seed=args.seed, out=args.out, gate=not args.no_gate)


if __name__ == "__main__":
    main()
