"""Semiring-matmul engine bench: (∨,∧)/(min,+)/(+,×) contraction
throughput of the execution layer (CPU path here; the Pallas kernels are
the TPU target and are correctness-validated in interpret mode)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import semiring as sr_mod
from repro.kernels import ops


def run(sizes=(256, 512), semirings=("bool", "trop", "nat")):
    rng = np.random.default_rng(0)
    for n in sizes:
        for name in semirings:
            sr = sr_mod.get(name)
            if name == "bool":
                a = jnp.asarray(rng.random((n, n)) < 0.1)
                b = a
            else:
                a = jnp.asarray(rng.integers(0, 9, (n, n)).astype(np.float32))
                b = a
            t = timeit(lambda: ops.semiring_matmul(sr, a, b), iters=3)
            gflops = 2 * n ** 3 / t / 1e9
            emit(f"kernel/semiring_matmul/{name}/n{n}", t,
                 f"{gflops:.2f} GOP/s")


if __name__ == "__main__":
    run()
