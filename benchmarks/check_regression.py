"""Benchmark regression gate: freshly produced ``BENCH_*.json`` vs the
committed baselines.

The CI bench job used to only *upload* the reports — a 10× throughput
regression sailed through green.  This gate walks each fresh report next
to its committed baseline (``git show HEAD:<file>`` by default, or a
``--baseline-dir`` snapshot) and fails when any matched metric regressed
by more than ``--threshold`` (default 25 %):

* **lower-is-better** metrics: numeric leaves whose key ends in ``_s``
  or ``_ms`` or contains ``latency`` or a tail percentile (``p50``/
  ``p95``/``p99``);
* **higher-is-better** metrics: keys containing ``qps``, ``speedup``,
  or ``throughput``.

Thresholds are per-metric: ``--metric-threshold fragment=value``
(repeatable) overrides the global ``--threshold`` for any metric whose
key contains the fragment — the longest matching fragment wins.  Tail
percentiles default to a looser 50 % bound (they are order statistics
of a handful of requests, far noisier than a mean), overridable the
same way (``--metric-threshold p99=0.3``).

Non-metric leaves (sizes, seeds, iteration counts, booleans, picks) are
ignored; a metric present on only one side is reported but never fails
the gate (suites are allowed to grow/shrink rows).  Improvements are
never gated.

Wall-clock baselines are machine-relative: committing a fresh
``BENCH_*.json`` *is* the re-baselining act, so when the bench hardware
changes (or the gate pages on a known-benign shift), regenerate the
report there and commit it — the unitless ``speedup`` columns carry
across machines; the ``*_s`` columns deliberately pin the current
hardware so slow drift on one box cannot hide.

Usage:
  python -m benchmarks.check_regression                  # all BENCH_*.json
  python -m benchmarks.check_regression BENCH_serve.json --threshold 0.4
"""

from __future__ import annotations

import argparse
import glob
import json
import pathlib
import subprocess
import sys

#: key fragments → metric direction
LOWER_BETTER = ("latency", "p50", "p95", "p99")
LOWER_SUFFIXES = ("_s", "_ms")
HIGHER_BETTER = ("qps", "speedup", "throughput", "reduction")

#: per-fragment default thresholds (overridable via --metric-threshold);
#: tail percentiles are order statistics over a few hundred requests —
#: far noisier run-to-run than means, so they get a looser gate
DEFAULT_METRIC_THRESHOLDS = {"p50": 0.5, "p95": 0.5, "p99": 0.5}


def metric_direction(key: str) -> str | None:
    """"lower" | "higher" | None (not a gated metric)."""
    k = key.lower()
    if any(f in k for f in HIGHER_BETTER):
        return "higher"
    if any(f in k for f in LOWER_BETTER) or k.endswith(LOWER_SUFFIXES):
        return "lower"
    return None


def metrics_of(doc, path: str = "") -> dict[str, float]:
    """Flatten a report to {json-path: value} over gated numeric leaves.

    List elements are keyed by a stable row identity when one exists
    (``update``/``semiring``/``mode``/``name`` fields) so reordered rows
    still line up across the two reports.
    """
    out: dict[str, float] = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            if isinstance(v, (dict, list)):
                out.update(metrics_of(v, f"{path}/{k}"))
            elif isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and metric_direction(k):
                out[f"{path}/{k}"] = float(v)
    elif isinstance(doc, list):
        for i, item in enumerate(doc):
            ident = i
            if isinstance(item, dict):
                ident = "|".join(
                    str(item[f]) for f in ("update", "semiring", "mode",
                                           "name", "family")
                    if f in item) or i
            out.update(metrics_of(item, f"{path}[{ident}]"))
    return out


def baseline_text(name: str, baseline_dir: str | None) -> str | None:
    if baseline_dir is not None:
        p = pathlib.Path(baseline_dir) / pathlib.Path(name).name
        return p.read_text() if p.exists() else None
    try:
        return subprocess.run(
            ["git", "show", f"HEAD:{name}"], capture_output=True,
            text=True, check=True).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None


def threshold_for(key: str, default: float,
                  per_metric: dict[str, float]) -> float:
    """The gate for one metric: the longest ``per_metric`` fragment
    contained in the key wins; otherwise the global default."""
    k = key.lower()
    best = None
    for frag, th in per_metric.items():
        if frag in k and (best is None or len(frag) > len(best)):
            best, out = frag, th
    return out if best is not None else default


def check_file(name: str, threshold: float, baseline_dir: str | None,
               per_metric: dict[str, float] | None = None) -> list[str]:
    """Compare one fresh report against its baseline; returns the list
    of regression messages (empty = pass)."""
    per_metric = dict(DEFAULT_METRIC_THRESHOLDS,
                      **(per_metric or {}))
    fresh_path = pathlib.Path(name)
    if not fresh_path.exists():
        print(f"{name}: no fresh report (suite not run here) — skipped")
        return []
    base_text = baseline_text(name, baseline_dir)
    if base_text is None:
        print(f"{name}: no committed baseline — skipped (will gate once "
              f"committed)")
        return []
    fresh = metrics_of(json.loads(fresh_path.read_text()))
    base = metrics_of(json.loads(base_text))
    failures = []
    for key in sorted(base):
        if key not in fresh:
            print(f"{name}{key}: dropped from fresh report — not gated")
            continue
        b, f = base[key], fresh[key]
        if b <= 0:
            continue
        direction = metric_direction(key.rsplit("/", 1)[-1])
        gate = threshold_for(key, threshold, per_metric)
        ratio = f / b
        worse = ratio - 1.0 if direction == "lower" else 1.0 - ratio
        mark = "REGRESSED" if worse > gate else "ok"
        print(f"{name}{key}: base={b:.6g} fresh={f:.6g} "
              f"({'+' if ratio >= 1 else ''}{(ratio - 1) * 100:.1f}%, "
              f"{direction}-is-better, gate {gate * 100:.0f}%) {mark}")
        if worse > gate:
            failures.append(
                f"{name}{key}: {b:.6g} → {f:.6g} "
                f"({worse * 100:.0f}% worse than baseline, "
                f"threshold {gate * 100:.0f}%)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="fresh reports to gate (default: BENCH_*.json)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--baseline-dir", default=None,
                    help="directory of baseline reports (default: the "
                         "committed versions via `git show HEAD:<file>`)")
    ap.add_argument("--metric-threshold", action="append", default=[],
                    metavar="FRAGMENT=VALUE",
                    help="per-metric override, e.g. p99=0.3 (repeatable; "
                         "longest matching fragment wins)")
    args = ap.parse_args()
    per_metric = {}
    for spec in args.metric_threshold:
        frag, _, val = spec.partition("=")
        if not frag or not val:
            ap.error(f"--metric-threshold needs FRAGMENT=VALUE, "
                     f"got {spec!r}")
        per_metric[frag.lower()] = float(val)
    files = args.files or sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("no BENCH_*.json reports found — nothing to gate")
        return
    failures: list[str] = []
    for name in files:
        failures += check_file(name, args.threshold, args.baseline_dir,
                               per_metric)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print(f"\nregression gate passed for {len(files)} report(s)")


if __name__ == "__main__":
    main()
