# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark entry point:

  fig11  — FGH speedups, rule-based group (BM/CC/SSSP + GSN)
  fig12  — FGH speedups, CEGIS group (WS/BC/R/MLM) vs data size
  fig13  — synthesis/invariant-inference time + search-space size
  kernel — semiring matmul engine throughput
  (roofline runs separately on dry-run output: benchmarks/roofline.py)

``python -m benchmarks.run [--quick] [--only fig11,...]``
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="fig13,fig11,fig12,kernel")
    ap.add_argument("--sizes", default="256,1024",
                    help="fig11 graph sizes (rule-based group)")
    ap.add_argument("--sizes12", default="48,96",
                    help="fig12 sizes (CEGIS group; BC's original program "
                         "is O(n³·d²)-ish dense — keep modest on CPU)")
    args = ap.parse_args()
    only = set(args.only.split(","))
    sizes = tuple(int(s) for s in args.sizes.split(","))
    sizes12 = tuple(int(s) for s in args.sizes12.split(","))

    print("name,us_per_call,derived")
    if "fig13" in only:
        from benchmarks import synthesis_stats
        synthesis_stats.run()
    if "fig11" in only:
        from benchmarks import fgh_speedups
        fgh_speedups.run(sizes=sizes)
    if "fig12" in only:
        from benchmarks import fgh_scaling
        fgh_scaling.run(sizes=sizes12)
    if "kernel" in only:
        from benchmarks import kernel_bench
        kernel_bench.run()


if __name__ == '__main__':
    main()
