# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark entry point:

  fig13  — synthesis/invariant-inference time + search-space size
  fig11  — FGH speedups, rule-based group (BM/CC/SSSP + GSN)
  fig12  — FGH speedups, CEGIS group (WS/BC/R/MLM) vs data size
  kernel — semiring matmul + fused SpMM throughput (BENCH_kernels.json)
  sparse — dense-vs-sparse scaling (BM/TC family)
  serve  — batched multi-source serving throughput (BENCH_serve.json)
  plan   — planner-vs-empirical crossover checks
  incremental — streaming-update maintenance (BENCH_incremental.json)
  sharded — graph-axis sharded fixpoints (BENCH_sharded.json)
  roofline — measured peaks + achieved bytes/s of the SpMM hot loop
  replan — mid-fixpoint adaptive re-planning (BENCH_replan.json)
  (regression gating against committed BENCH_*.json baselines:
  benchmarks/check_regression.py)

Suites are discovered lazily: one suite failing to import (a missing
optional dependency, e.g. no networkx for the graph generators or a
container without jax) is reported as skipped instead of killing the
whole run.

``python -m benchmarks.run [--only fig11,...] [--quick]``
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

#: name -> (module, runner attr, default kwargs, quick kwargs)
SUITES: dict[str, tuple[str, str, dict, dict]] = {
    "fig13": ("benchmarks.synthesis_stats", "run", {}, {}),
    "fig11": ("benchmarks.fgh_speedups", "run",
              {"sizes": (256, 1024)}, {"sizes": (128,)}),
    "fig12": ("benchmarks.fgh_scaling", "run",
              {"sizes": (48, 96)}, {"sizes": (32,)}),
    "kernel": ("benchmarks.kernel_bench", "run", {},
               {"sizes": (128,), "semirings": ("bool", "trop"),
                "n": 2000, "batches": (1, 8), "avg_degs": (4,),
                "spmm_semirings": ("bool", "trop"), "out": None,
                "gate": False}),
    "sparse": ("benchmarks.sparse_scaling", "run",
               {}, {"sizes": (256,), "big": 2000}),
    "serve": ("benchmarks.serve_batch", "run",
              {}, {"n": 2000, "batch_sizes": (1, 8), "out": None}),
    "plan": ("benchmarks.plan_crossover", "run", {}, {"quick": True}),
    # quick mode keeps exactness + planner-pick assertions but waives the
    # ≥10× latency gate: at toy sizes both paths run in ~1 ms of noise
    "incremental": ("benchmarks.incremental_update", "run", {},
                    {"n": 2000, "trials": 1, "out": None, "gate": False}),
    # graph-axis sharded fixpoints; the planner-pick gate needs ≥ 2
    # devices (CI: XLA_FLAGS=--xla_force_host_platform_device_count=8)
    "sharded": ("benchmarks.sharded_scaling", "run", {},
                {"n": 2000, "out": None}),
    # measured-peak roofline of the SpMM hot loop (fused vs jnp)
    "roofline": ("benchmarks.roofline", "run", {},
                 {"n": 2000, "batches": (8,), "out": None}),
    # mid-fixpoint adaptive re-planning vs static plans; quick mode
    # keeps the exactness + switch assertions but waives the speedup
    # gates (toy sizes put both paths inside chunk-overhead noise)
    "replan": ("benchmarks.replan_adaptive", "run", {},
               {"n_hub": 3000, "deg": 10, "chain": 60, "batch": 16,
                "deep": 2, "chunk_iters": 8, "trials": 1, "out": None,
                "gate": False}),
}


def run_suite(name: str, overrides: dict | None = None,
              quick: bool = False) -> str:
    """Run one suite; returns "ok", "skipped" (missing optional import —
    tolerated), or "failed" (the runner raised — reported but the
    remaining suites still run; main exits nonzero)."""
    module, attr, kwargs, quick_kwargs = SUITES[name]
    kwargs = dict(quick_kwargs if quick else kwargs)
    kwargs.update(overrides or {})
    try:
        mod = importlib.import_module(module)
    except ImportError as e:
        # only a *third-party* module going missing is a tolerable skip;
        # a repo-internal module failing to resolve is a broken import
        missing = (getattr(e, "name", "") or "").split(".")[0]
        if isinstance(e, ModuleNotFoundError) \
                and missing not in ("repro", "benchmarks"):
            print(f"{name},skipped,import failed: {e}", flush=True)
            return "skipped"
        traceback.print_exc()
        print(f"{name},failed,broken import: {e}", flush=True)
        return "failed"
    try:
        getattr(mod, attr)(**kwargs)
        return "ok"
    except KeyboardInterrupt:
        raise
    except BaseException as e:  # keep the remaining suites running.
        # BaseException, not Exception: a suite gate that calls
        # ``sys.exit(0)`` raises SystemExit, which previously sailed
        # straight through main() and terminated the whole run with
        # exit code 0 — a green CI bench job with suites never run.
        traceback.print_exc()
        print(f"{name},FAILED,{type(e).__name__}: {e}", flush=True)
        return "failed"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(SUITES),
                    help=f"comma-separated subset of {sorted(SUITES)}")
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for a smoke pass")
    ap.add_argument("--sizes", default=None,
                    help="fig11 graph sizes (rule-based group)")
    ap.add_argument("--sizes12", default=None,
                    help="fig12 sizes (CEGIS group; BC's original program "
                         "is O(n³·d²)-ish dense — keep modest on CPU)")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    unknown = set(only) - set(SUITES)
    if unknown:
        raise SystemExit(f"unknown suites {sorted(unknown)}; "
                         f"have {sorted(SUITES)}")
    overrides: dict[str, dict] = {}
    if args.sizes:
        overrides["fig11"] = {
            "sizes": tuple(int(s) for s in args.sizes.split(","))}
    if args.sizes12:
        overrides["fig12"] = {
            "sizes": tuple(int(s) for s in args.sizes12.split(","))}

    print("name,us_per_call,derived")
    failed = [name for name in only
              if run_suite(name, overrides.get(name),
                           quick=args.quick) == "failed"]
    if failed:
        print(f"FAILED: {','.join(failed)}", file=sys.stderr, flush=True)
        sys.exit(1)


if __name__ == '__main__':
    main()
