"""Graph-axis sharded fixpoint acceptance → ``BENCH_sharded.json``.

The ISSUE-5 acceptance run (DESIGN.md §6): a 100k-vertex power-law
graph, solved on a D-way ``("graph",)`` mesh of simulated host devices
(CI: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and
checked three ways:

* **exactness** — the sharded fixpoint must agree bit-for-bit (values
  *and* per-source iteration counts) with whatever single-device runner
  the planner picks for the same workload, for the 𝔹 (reachability) and
  Trop (shortest-distance) lattices, plus a sharded-vs-single-device
  ℕ∞ contraction probe (ℕ∞ lacks ⊖, so the fixpoint runners are
  rightly out of its reach — the SpMM exchange itself is what's
  checked);
* **planning** — given the mesh, ``plan_program`` must select
  ``sparse_sharded`` and ``explain()`` must render the partition line;
* **reporting** — per-mode wall times land in ``BENCH_sharded.json``
  for the CI regression gate (``benchmarks/check_regression.py``).

Simulated host devices share one physical CPU, so no wall-clock speedup
is gated — the point is exact distributed semantics plus the planner's
device-dimension routing; real scaling comes with real devices.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.sharded_scaling
  PYTHONPATH=src python -m benchmarks.sharded_scaling --n 2000
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys


def _ensure_devices(d: int) -> None:
    """Best-effort: force ``d`` simulated host devices when jax has not
    been initialized yet (the Makefile/CI set XLA_FLAGS explicitly)."""
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={d}"
            ).strip()


def run(n: int = 100_000, seed: int = 1, source: int = 0,
        out: str | None = "BENCH_sharded.json", iters: int = 2,
        gate: bool | None = None):
    import jax
    import numpy as np

    from benchmarks.common import emit, timeit
    from repro.core import engine, planner
    from repro.datalog import datasets, programs
    from repro.distributed import datalog as dd
    from repro.launch.mesh import make_graph_mesh
    from repro.sparse import contract
    from repro.sparse.fixpoint import sparse_seminaive_fixpoint

    ndev = len(jax.devices())
    d = 1
    while d * 2 <= ndev:
        d *= 2
    if gate is None:
        gate = d >= 2
    mesh = make_graph_mesh(d)
    g = datasets.powerlaw(n, 4, seed=seed)
    rng = np.random.default_rng(seed)
    g.weights = rng.integers(1, 8, len(g.edges))
    problems: list[str] = []
    rows = []

    def check(label, cond, msg):
        if not cond:
            problems.append(f"{label}: {msg}")

    # -- bool / trop: full sharded fixpoints vs the planner's own pick ----
    for semiring in ("bool", "trop"):
        rel = g.sparse_adjacency(semiring=semiring)
        nnz = int(np.asarray(rel.as_np().nnz))
        if semiring == "bool":
            init = np.zeros(n, bool)
            init[source] = True
        else:
            init = np.full(n, np.inf, np.float32)
            init[source] = 0.0

        # plan the *matching* workload per semiring: BM reachability over
        # the stored bool adjacency, SSSP over the weighted COO operator
        # (its schema-level E3 would be a dense (n, n, w) tensor at this
        # scale — the edges= override routes the adjacency, exactly as
        # the serve loop does)
        if semiring == "bool":
            b = programs.bm(a=source)
            db = engine.Database(b.original.schema, {"id": n},
                                 {"E": g.sparse_adjacency(),
                                  "V": np.ones((n,), bool)})
            plan_kwargs = {}
        else:
            b = programs.sssp(a=source, wmax=8, dmax=64)
            db = engine.Database(b.original.schema,
                                 {"id": n, "w": 8, "d": 64}, {})
            plan_kwargs = {"edges": rel}
        plan0 = planner.plan_program(b.optimized, db, **plan_kwargs)
        pick0 = plan0.strata[0].runner
        y0, it0 = sparse_seminaive_fixpoint(
            rel, init,
            mode="frontier" if pick0 == "sparse_frontier" else "jit")
        t0 = timeit(lambda: sparse_seminaive_fixpoint(
            rel, init,
            mode="frontier" if pick0 == "sparse_frontier" else "jit")[0],
            iters=iters)

        sharded = dd.shard_relation(rel, mesh)
        run_fn = jax.jit(lambda e, i: dd.sharded_seminaive_fixpoint(
            e, i, mesh=mesh))
        ys, its = run_fn(sharded, init)
        ts = timeit(lambda: run_fn(sharded, init)[0], iters=iters)
        exact = bool(np.array_equal(np.asarray(ys), np.asarray(y0))
                     and int(its) == int(it0))
        check(semiring, exact,
              f"sharded D={d} diverged from single-device {pick0}")
        emit(f"sharded_scaling/{semiring}/n{n}", ts,
             f"D={d} nnz={nnz} iters={int(its)} single={t0 * 1e3:.1f}ms "
             f"({pick0}) exact={exact}")
        rows.append({"semiring": semiring, "mode": "fixpoint", "D": d,
                     "nnz": nnz, "iters": int(its), "exact": exact,
                     "t_sharded_s": ts, "t_single_s": t0,
                     "single_runner": pick0})

        plan_m = planner.plan_program(b.optimized, db, mesh=mesh,
                                      **plan_kwargs)
        pick_m = plan_m.strata[0].runner
        text = planner.explain(plan_m)
        if gate:
            check(f"planner/{semiring}", pick_m == "sparse_sharded",
                  f"picked {pick_m!r} with the mesh attached")
            check(f"planner/{semiring}",
                  "partition   graph axis" in text,
                  "explain() did not render the partition")
        emit(f"sharded_scaling/planner/{semiring}/n{n}", float("nan"),
             f"pick={pick_m} D={d}")
        rows.append({"semiring": semiring, "mode": "planner",
                     "D": d, "pick": pick_m})

    # -- nat: no ⊖, so no GSN fixpoint — probe the sharded exchange -------
    reln = g.sparse_adjacency(semiring="nat")
    x = rng.random(n).astype(np.float32)
    a = np.asarray(contract.vspm(x, reln.as_jnp()))
    contract_fn = jax.jit(lambda e, v: dd.sharded_contract(e, v,
                                                           mesh=mesh))
    sharded_n = dd.shard_relation(reln, mesh)
    bshard = np.asarray(contract_fn(sharded_n, x))
    exact = bool(np.allclose(a, bshard, rtol=1e-6, atol=1e-4))
    check("nat", exact, "sharded contraction diverged from vspm")
    tn = timeit(lambda: contract_fn(sharded_n, x), iters=iters)
    emit(f"sharded_scaling/nat/n{n}", tn, f"D={d} exact={exact}")
    rows.append({"semiring": "nat", "mode": "contract", "D": d,
                 "exact": exact, "t_sharded_s": tn})

    result = {"bench": "sharded_scaling", "n": n, "seed": seed, "D": d,
              "devices": ndev, "gate": gate, "rows": rows}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")
    if problems:
        raise RuntimeError("sharded_scaling gate failed: "
                           + "; ".join(problems))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--devices", type=int, default=8,
                    help="simulated host devices to request when jax is "
                         "not yet initialized (CI sets XLA_FLAGS itself)")
    ap.add_argument("--out", default="BENCH_sharded.json")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the planner-pick gate "
                         "(exactness is still checked)")
    args = ap.parse_args()
    _ensure_devices(args.devices)
    try:
        run(n=args.n, seed=args.seed, out=args.out,
            gate=False if args.no_gate else None)
    except RuntimeError as e:
        print(e, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
