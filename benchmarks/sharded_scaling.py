"""Graph-axis sharded fixpoint acceptance → ``BENCH_sharded.json``.

The ISSUE-7 crossover run (DESIGN.md §6/§8): power-law graphs at sizes
straddling the sharding crossover, solved on a D-way ``("graph",)``
mesh of simulated host devices (CI: ``XLA_FLAGS=--xla_force_host_
platform_device_count=8``) and checked four ways:

* **exactness** — the sharded Δ-sparse-exchange fixpoint must agree
  bit-for-bit (values *and* per-source iteration counts) with the
  single-device runner the planner picks for the same batched
  workload, for the 𝔹 and Trop lattices, plus a sharded ℕ∞
  contraction probe (ℕ∞ lacks ⊖ — the exchange itself is checked);
* **speed** — at the largest size, D devices must genuinely beat one:
  ``speedup = t_single_s / t_sharded_s ≥ 1`` on the batched rows, with
  per-iteration exchanged bytes reduced ≥ 5× vs the dense all-gather
  baseline on the bit-packed 𝔹 row.  Below the crossover no speedup
  is demanded — that regime is *supposed* to stay single-device;
* **planning** — on every row decisively off the crossover (measured
  speedup outside ±10% of 1) the planner's mesh-offered pick must
  match the empirical winner: ``sparse_sharded`` exactly where the
  measured speedup clears 1 (the PR-5 model picked sharding where the
  single device was 30–50× faster, and the old gate waved it through);
* **reporting** — wall times, speedups, and exchanged-byte reductions
  land in ``BENCH_sharded.json`` for ``benchmarks/check_regression.py``
  (``speedup``/``reduction`` are gated higher-is-better metrics).

Gate failures print a ``sharded_scaling,FAILED,...`` line (the
``benchmarks/run.py`` convention) and exit non-zero.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.sharded_scaling
  PYTHONPATH=src python -m benchmarks.sharded_scaling --sizes 2000
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

#: the batched-serving crossover sweep: one size well below the
#: measured crossover (single device must win) and one well above
#: (D=8 must win) — ISSUE 7 acceptance.  With the Δ-sparse exchange
#: the measured crossover sits low: D=8 already wins ~1.4× at 100k
#: vertices, so the single-device side has to be a genuinely small
#: graph
SIZES = (5_000, 2_000_000)


def _ensure_devices(d: int) -> None:
    """Best-effort: force ``d`` simulated host devices when jax has not
    been initialized yet (the Makefile/CI set XLA_FLAGS explicitly)."""
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={d}"
            ).strip()


def run(sizes: tuple[int, ...] = SIZES, n: int | None = None,
        seed: int = 1, batch: int = 8,
        out: str | None = "BENCH_sharded.json", iters: int = 1,
        gate: bool | None = None):
    import jax
    import numpy as np

    from benchmarks.common import emit, timeit
    from repro.core import engine, planner
    from repro.datalog import datasets, programs
    from repro.distributed import datalog as dd
    from repro.launch.mesh import make_graph_mesh
    from repro.sparse import contract
    from repro.sparse.fixpoint import sparse_seminaive_fixpoint

    if n is not None:           # quick mode: one small size, no gates
        sizes = (n,)
    sizes = tuple(sorted(sizes))
    ndev = len(jax.devices())
    d = 1
    while d * 2 <= ndev:
        d *= 2
    if gate is None:
        gate = d >= 2 and max(sizes) >= 1_000_000
    mesh = make_graph_mesh(d)
    rng = np.random.default_rng(seed)
    problems: list[str] = []
    rows = []

    def check(label, cond, msg):
        if not cond:
            problems.append(f"{label}: {msg}")

    for size in sizes:
        largest = size == max(sizes)
        g = datasets.powerlaw(size, 4, seed=seed)
        # wide weights → many light-edge detours → deep trop fixpoints:
        # the regime where per-iteration exchange cost dominates
        g.weights = rng.integers(1, 256, len(g.edges))
        sources = rng.choice(size, size=batch, replace=False)

        for semiring in ("bool", "trop"):
            rel = g.sparse_adjacency(semiring=semiring)
            nnz = int(np.asarray(rel.as_np().nnz))
            zero = False if semiring == "bool" else np.inf
            one = True if semiring == "bool" else 0.0
            init = np.full((batch, size), zero,
                           bool if semiring == "bool" else np.float32)
            init[np.arange(batch), sources] = one

            # plan the matching workload per semiring: BM reachability
            # over the stored bool adjacency, SSSP over the weighted COO
            # operator via the edges= override (its schema-level E3
            # would be dense at this scale), batched ⇒ throughput
            if semiring == "bool":
                b = programs.bm(a=int(sources[0]))
                db = engine.Database(b.original.schema, {"id": size},
                                     {"E": rel,
                                      "V": np.ones((size,), bool)})
                plan_kwargs = {}
            else:
                b = programs.sssp(a=int(sources[0]), wmax=256, dmax=64)
                db = engine.Database(b.original.schema,
                                     {"id": size, "w": 256, "d": 64}, {})
                plan_kwargs = {"edges": rel}
            plan0 = planner.plan_program(b.optimized, db,
                                         objective="throughput",
                                         **plan_kwargs)
            pick0 = plan0.strata[0].runner
            single_fn = jax.jit(lambda e, i: sparse_seminaive_fixpoint(
                e, i, mode="jit"))
            y0, it0 = single_fn(rel, init)
            t0 = timeit(lambda: single_fn(rel, init)[0], iters=iters)

            sharded = dd.shard_relation(rel, mesh)
            run_fn = jax.jit(
                lambda e, i: dd.sharded_seminaive_fixpoint_stats(
                    e, i, mesh=mesh))
            ys, its, rounds = run_fn(sharded, init)
            ts = timeit(lambda: run_fn(sharded, init)[0], iters=iters)
            exact = bool(np.array_equal(np.asarray(ys), np.asarray(y0))
                         and np.array_equal(np.asarray(its),
                                            np.asarray(it0)))
            check(f"{semiring}/n{size}", exact,
                  f"sharded D={d} diverged from single-device {pick0}")
            speedup = t0 / ts
            xb = dd.exchange_byte_report(sharded, rounds, batch=batch)
            emit(f"sharded_scaling/{semiring}/n{size}", ts,
                 f"D={d} B={batch} nnz={nnz} "
                 f"iters={int(np.max(np.asarray(its)))} "
                 f"single={t0:.2f}s ({pick0}) speedup={speedup:.2f}x "
                 f"bytes {xb['byte_reduction']:.1f}x under dense "
                 f"exact={exact}")
            rows.append({
                "semiring": semiring, "mode": "throughput",
                "name": f"n{size}", "D": d, "B": batch, "nnz": nnz,
                "iters": int(np.max(np.asarray(its))), "exact": exact,
                "t_sharded_s": ts, "t_single_s": t0, "speedup": speedup,
                "single_runner": pick0,
                "exchange_rounds": xb["rounds"],
                "bytes_per_iter": xb["bytes_per_iter"],
                "dense_bytes_per_iter": xb["dense_bytes_per_iter"],
                "byte_reduction": xb["byte_reduction"]})

            plan_m = planner.plan_program(b.optimized, db, mesh=mesh,
                                          objective="throughput",
                                          **plan_kwargs)
            pick_m = plan_m.strata[0].runner
            text = planner.explain(plan_m)
            picked_sharded = pick_m == "sparse_sharded"
            if gate and abs(speedup - 1.0) >= 0.1:
                # the pick must match the measured winner on *this* side
                # of the crossover — the PR-5 mispick regression gate.
                # Rows inside the ±10% dead-band sit *on* the crossover:
                # either pick is defensible there and one-repetition
                # timings are too noisy to gate on
                check(f"planner/{semiring}/n{size}",
                      picked_sharded == (speedup > 1.0),
                      f"picked {pick_m!r} where measured speedup is "
                      f"{speedup:.2f}x")
                if picked_sharded:
                    check(f"planner/{semiring}/n{size}",
                          "partition   graph axis" in text,
                          "explain() did not render the partition")
                else:
                    check(f"planner/{semiring}/n{size}",
                          "crossover" in plan_m.strata[0].rejected.get(
                              "sparse_sharded", ""),
                          "sharded was skipped without the crossover "
                          "rejection")
            emit(f"sharded_scaling/planner/{semiring}/n{size}",
                 float("nan"), f"pick={pick_m} D={d}")
            rows.append({"semiring": semiring, "mode": "planner",
                         "name": f"n{size}", "D": d, "pick": pick_m})

            if gate and largest:
                check(f"speed/{semiring}/n{size}", speedup >= 1.0,
                      f"D={d} lost to one device: speedup "
                      f"{speedup:.2f}x < 1 (t_sharded={ts:.2f}s, "
                      f"t_single={t0:.2f}s)")
                if semiring == "bool":
                    check(f"bytes/{semiring}/n{size}",
                          xb["byte_reduction"] >= 5.0,
                          f"exchanged bytes only "
                          f"{xb['byte_reduction']:.1f}x under the dense "
                          f"all-gather (< 5x)")

    # -- nat: no ⊖, so no GSN fixpoint — probe the sharded exchange -------
    size = min(sizes)
    g = datasets.powerlaw(size, 4, seed=seed)
    reln = g.sparse_adjacency(semiring="nat")
    x = rng.random(size).astype(np.float32)
    a = np.asarray(contract.vspm(x, reln.as_jnp()))
    contract_fn = jax.jit(lambda e, v: dd.sharded_contract(e, v,
                                                           mesh=mesh))
    sharded_n = dd.shard_relation(reln, mesh)
    bshard = np.asarray(contract_fn(sharded_n, x))
    exact = bool(np.allclose(a, bshard, rtol=1e-6, atol=1e-4))
    check("nat", exact, "sharded contraction diverged from vspm")
    tn = timeit(lambda: contract_fn(sharded_n, x), iters=iters)
    emit(f"sharded_scaling/nat/n{size}", tn, f"D={d} exact={exact}")
    rows.append({"semiring": "nat", "mode": "contract",
                 "name": f"n{size}", "D": d, "exact": exact,
                 "t_sharded_s": tn})

    result = {"bench": "sharded_scaling", "sizes": list(sizes),
              "seed": seed, "B": batch, "D": d, "devices": ndev,
              "gate": gate, "rows": rows}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")
    if problems:
        raise RuntimeError("sharded_scaling gate failed: "
                           + "; ".join(problems))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=list(SIZES))
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8,
                    help="simulated host devices to request when jax is "
                         "not yet initialized (CI sets XLA_FLAGS itself)")
    ap.add_argument("--out", default="BENCH_sharded.json")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the speedup/planner gates "
                         "(exactness is still checked)")
    args = ap.parse_args()
    _ensure_devices(args.devices)
    try:
        run(sizes=tuple(args.sizes), seed=args.seed, batch=args.batch,
            out=args.out, gate=False if args.no_gate else None)
    except RuntimeError as e:
        print(f"sharded_scaling,FAILED,{type(e).__name__}: {e}",
              flush=True)
        print(e, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
