"""Mid-fixpoint adaptive re-planning vs static plans (DESIGN.md §10).

The drifting workload is the serve shape that motivates the adaptive
executor: a (B, n) batch of reachability queries over a hub-and-chain
graph where most rows are short hub explorations and a few are deep
chain walks.  Early rounds have every row live with wide frontiers —
the nnz-bound fused backend (``sparse_frontier_pallas``) wins because
the host worklist pays per-row expansion of the whole hub.  Once the
hub rows converge, the surviving chain rows have one-vertex frontiers
for hundreds of rounds — the worklist wins because the staged runners
keep paying O(nnz(E)) per round for a handful of live rows.  Neither
static plan is right for the whole fixpoint; the adaptive executor
starts on the fused backend and hands the carry to the frontier runner
at the chunk boundary where the live-row collapse shows up in
:class:`~repro.sparse.fixpoint.FrontierStats`.

The control workload (every source in the hub) has no drift: the
fixpoint converges inside the first chunk and the adaptive path must
price-out to the static choice with no switch and negligible overhead.

Gates (BENCH_replan.json, checked by benchmarks/check_regression.py):

* ``speedup_adaptive``  — adaptive vs the *best* static plan on the
  drifting workload, must be ≥ 1.0 (measured ~2.5-3×);
* ``speedup_control``   — adaptive vs the best static plan on the
  static-friendly control, must be ≥ 0.95 (no-drift overhead bound);
* exactness — the adaptive answer is bit-identical to every static
  runner's answer on both workloads;
* the drifting run must actually switch runners (the trace is the
  ``explain(plan)`` switch history).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import runners as runners_mod
from repro.sparse import fixpoint as fx
from repro.sparse.adaptive import ReplanPolicy
from repro.sparse.coo import SparseRelation

#: static rivals timed against the adaptive executor.  ``sparse_jit``
#: is priced as a candidate but not timed end-to-end: its XLA scatter
#: rounds are ~60× slower than the fused backend on CPU at these sizes
#: (BENCH_kernels.json), which would dominate the suite's runtime
#: without changing the best-static baseline.
STATICS = (("sparse_frontier", dict(mode="frontier")),
           ("sparse_frontier_pallas", dict(mode="jit", backend="fused")))


def hub_chain(n_hub: int, deg: int, n_chain: int, seed: int = 0):
    """A random hub (n_hub vertices, ~deg out-edges each) plus a
    disjoint chain of n_chain vertices: hub queries converge in
    O(diameter) wide rounds, chain queries walk one vertex per round."""
    rng = np.random.default_rng(seed)
    n = n_hub + n_chain
    m = n_hub * deg
    src = np.concatenate([rng.integers(0, n_hub, m),
                          np.arange(n_hub, n - 1)])
    dst = np.concatenate([rng.integers(0, n_hub, m),
                          np.arange(n_hub + 1, n)])
    coords = np.stack([src, dst], 1)
    rel = SparseRelation.from_coo(coords, np.ones(len(coords), bool),
                                  (n, n), "bool")
    return rel.as_jnp(), n


def _sources(n_hub: int, n: int, batch: int, deep: int, seed: int = 1):
    """(B, n) one-hot init: ``batch - deep`` hub sources plus ``deep``
    chain-head sources (the long-tail rows that drive the drift)."""
    rng = np.random.default_rng(seed)
    init = np.zeros((batch, n), bool)
    init[np.arange(batch - deep), rng.integers(0, n_hub, batch - deep)] = True
    init[np.arange(batch - deep, batch), n_hub] = True
    return jnp.asarray(init)


def _measure(rel, init, *, chunk_iters: int, trials: int):
    """Time the static runners and the adaptive executor on one init
    pack; returns (times, answers, trace)."""
    times, answers = {}, {}
    for name, kw in STATICS:
        fn = lambda kw=kw: np.asarray(fx.fixpoint(rel, init, **kw)[0])
        times[name] = timeit(fn, iters=trials)
        answers[name] = fn()

    policy = ReplanPolicy(chunk_iters=chunk_iters)
    ctx = runners_mod.make_context(rel, init, "bool", 10_000)
    trace_box = []

    def adaptive():
        y, _, tr = runners_mod.adaptive_fixpoint(
            ctx, start="sparse_frontier_pallas",
            candidates=("sparse_frontier", "sparse_jit"), policy=policy)
        trace_box.append(tr)
        return np.asarray(y)

    times["adaptive"] = timeit(adaptive, iters=trials)
    answers["adaptive"] = adaptive()
    return times, answers, trace_box[-1]


def run(n_hub: int = 50_000, deg: int = 18, chain: int = 260,
        batch: int = 64, deep: int = 4, chunk_iters: int = 32,
        trials: int = 3, out: str | None = "BENCH_replan.json",
        gate: bool = True):
    rel, n = hub_chain(n_hub, deg, chain)
    problems: list[str] = []
    rows = []

    # -- drifting workload: hub explosion → long live-row tail -------------
    init = _sources(n_hub, n, batch, deep)
    times, answers, trace = _measure(rel, init, chunk_iters=chunk_iters,
                                     trials=trials)
    best_static = min(t for k, t in times.items() if k != "adaptive")
    speedup = best_static / times["adaptive"]
    for name, t in sorted(times.items()):
        emit(f"replan/drift/{name}", t, f"B={batch} n={n}")
    emit("replan/drift/speedup_adaptive", times["adaptive"],
         f"{speedup:.2f}x_vs_best_static")
    exact = all(np.array_equal(answers["adaptive"], v)
                for v in answers.values())
    if not exact:
        problems.append("drift: adaptive answer differs from a static "
                        "runner's")
    if not trace.switches:
        problems.append("drift: adaptive executor never switched runners")
    if gate and speedup < 1.0:
        problems.append(f"drift: adaptive {speedup:.2f}x vs best static "
                        f"(gate ≥ 1.0)")
    rows.append({
        "name": "replan/drift", "batch": batch, "n": n,
        "nnz": int(rel.nnz), "deep_rows": deep,
        "adaptive_s": times["adaptive"], "best_static_s": best_static,
        "static_s": {k: v for k, v in times.items() if k != "adaptive"},
        "speedup_adaptive": speedup, "exact": exact,
        "n_switches": len(trace.switches),
        "final_runner": trace.final_runner,
        "switches": [{"chunk": e.chunk, "iteration": e.iteration,
                      "from": e.from_runner, "to": e.to_runner}
                     for e in trace.switches],
    })

    # -- control: all-hub sources, no drift --------------------------------
    init2 = _sources(n_hub, n, batch, deep=0, seed=2)
    times2, answers2, trace2 = _measure(rel, init2,
                                        chunk_iters=chunk_iters,
                                        trials=trials)
    best2 = min(t for k, t in times2.items() if k != "adaptive")
    ratio = best2 / times2["adaptive"]
    for name, t in sorted(times2.items()):
        emit(f"replan/control/{name}", t, f"B={batch} n={n}")
    emit("replan/control/speedup_control", times2["adaptive"],
         f"{ratio:.2f}x_vs_best_static")
    exact2 = all(np.array_equal(answers2["adaptive"], v)
                 for v in answers2.values())
    if not exact2:
        problems.append("control: adaptive answer differs from a static "
                        "runner's")
    if gate and ratio < 0.95:
        problems.append(f"control: adaptive {ratio:.2f}x vs best static "
                        f"(gate ≥ 0.95)")
    rows.append({
        "name": "replan/control", "batch": batch, "n": n,
        "nnz": int(rel.nnz),
        "adaptive_s": times2["adaptive"], "best_static_s": best2,
        "speedup_control": ratio, "exact": exact2,
        "n_switches": len(trace2.switches),
    })

    if out:
        path = pathlib.Path(__file__).resolve().parent.parent / out
        path.write_text(json.dumps(rows, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}", flush=True)
    if problems:
        raise RuntimeError("replan_adaptive gate failed:\n  "
                           + "\n  ".join(problems))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-hub", type=int, default=50_000)
    ap.add_argument("--chain", type=int, default=260)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--no-gate", action="store_true")
    ap.add_argument("--out", default="BENCH_replan.json")
    args = ap.parse_args()
    try:
        run(n_hub=args.n_hub, chain=args.chain, batch=args.batch,
            trials=args.trials, out=args.out, gate=not args.no_gate)
    except RuntimeError as e:
        print(e, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
