"""Paper Fig. 13: optimization time, invariant-inference time, and search
space size for every benchmark program."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import fgh, verify
from repro.datalog import programs

CASES = [
    ("BM", programs.bm, ["E", "V"]),
    ("CC", programs.cc, ["E", "V"]),
    ("SSSP", programs.sssp, ["E3"]),
    ("WS", programs.ws, ["A2"]),
    ("R", programs.radius, ["E", "V"]),
    ("MLM", programs.mlm, ["E", "V"]),
    ("APSP100", programs.apsp100, ["Ew"]),
]


def run():
    rows = []
    for name, mk, edbs in CASES:
        b = mk()
        task = verify.task_from_program(b.original, edbs,
                                        constraint=b.constraint)
        rep = fgh.optimize(task, rng=np.random.default_rng(0))
        inv_t = rep.stats["invariant_inference"]["time_s"]
        cg = rep.stats.get("cegis", {})
        synth_t = rep.stats["total_time_s"] - inv_t
        space = cg.get("candidates_tested", 0)
        pool = cg.get("pool_terms", 0)
        emit(f"fig13/{name}", rep.stats["total_time_s"],
             f"method={rep.method} ok={rep.ok} inv_s={inv_t:.3f} "
             f"synth_s={synth_t:.3f} search_space={space} pool={pool} "
             f"invariants={len(rep.invariants)}")
        rows.append((name, rep.method, rep.ok, inv_t, synth_t, space, pool))
    # BC: synthesis deviation — verified rewrite (Brandes needs an invented
    # IDB, which the paper also lists as out of scope for its synthesizer)
    emit("fig13/BC", 0.0, "method=verified-rewrite (see EXPERIMENTS.md)")
    return rows


if __name__ == "__main__":
    run()
