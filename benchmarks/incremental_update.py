"""Incremental-maintenance acceptance: update-to-fresh-answer latency
under streaming edge updates → ``BENCH_incremental.json``.

Single-source shortest distances (trop) over a weighted 50k-vertex
power-law graph, solved once from scratch; then the graph mutates and
the fresh answer is produced two ways:

* ``full``  — the pre-PR-4 shape: merge the delta with the coalescing
  ``SparseRelation.union`` (the only mutation API that existed), then
  recompute the fixpoint from ⊥ — every mutation throws away the old
  solution, the old adjacency index, and the old relation layout;
* ``delta`` — ``SparseRelation.apply_delta`` (O(nnz(Δ)) append that
  *extends* the cached CSR adjacency instead of re-sorting it) and
  *delta-restart* from the old solution
  (:func:`repro.incremental.delta_restart_fixpoint`, DESIGN.md §5): an
  O(nnz(Δ)) seed ``d₀ = (y* ⊗ ΔE) ⊖ y*`` plus re-convergence over only
  the affected region.

Two update sizes per the ISSUE-4 acceptance line: a single random edge
and a 1 %-of-nnz batch.  The gate (CI: ``make bench-incremental``):

* median update-to-answer speedup ≥ 10× at **both** sizes,
* exact agreement with the from-scratch answer on every trial,
* the cost-based planner, asked with ``objective="incremental"``, picks
  the ``delta_restart`` strategy for this workload.

Usage:
  PYTHONPATH=src python -m benchmarks.incremental_update
  PYTHONPATH=src python -m benchmarks.incremental_update --n 2000
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import engine, planner
from repro.datalog import datasets, programs
from repro.incremental import delta_restart_fixpoint
from repro.sparse import SparseRelation, sparse_seminaive_fixpoint

GATE_SPEEDUP = 10.0
WMAX = 8


def _weighted_powerlaw(n: int, seed: int) -> datasets.Graph:
    g = datasets.powerlaw(n, 4, seed=seed)
    rng = np.random.default_rng(seed)
    g.weights = rng.integers(1, WMAX, len(g.edges))
    return g


def _trop_init(n: int, source: int) -> np.ndarray:
    init = np.full(n, np.inf, np.float32)
    init[source] = 0.0
    return init


def _rand_delta(rng, n: int, k: int):
    coords = np.stack([rng.integers(0, n, k), rng.integers(0, n, k)],
                      axis=1)
    values = rng.integers(1, WMAX, k).astype(np.float32)
    return coords, values


def _one_trial(rel, init, y_star, coords, values, *, max_iters=10_000):
    """Apply one delta both ways; returns (t_full, t_delta, exact,
    resumed_iters)."""
    dr = SparseRelation.from_coo(coords, values, rel.shape, rel.semiring,
                                 lib="np")
    # -- full recompute: coalescing union + from-scratch frontier fixpoint
    t0 = time.perf_counter()
    rel_full = rel.union(dr)
    y_full, _ = sparse_seminaive_fixpoint(rel_full, init, mode="frontier",
                                          max_iters=max_iters)
    t_full = time.perf_counter() - t0
    y_full = np.asarray(y_full)

    # -- delta restart: O(nnz(Δ)) append + seed + affected-region rounds
    t0 = time.perf_counter()
    rel_delta = rel.apply_delta(coords, values)
    y_delta, it = delta_restart_fixpoint(rel_delta, dr, y_star,
                                         mode="frontier",
                                         max_iters=max_iters)
    t_delta = time.perf_counter() - t0
    return t_full, t_delta, np.array_equal(np.asarray(y_delta), y_full), \
        int(np.asarray(it))


def _planner_pick(n: int, rel: SparseRelation, delta_nnz: int) -> str:
    """What the cost-based planner chooses for this workload under
    ``objective="incremental"`` (SSSP's schema-level E3 would be a dense
    (n, n, w) tensor at 50k — the edges override routes the weighted COO
    adjacency, exactly as the serve loop does)."""
    b = programs.sssp(a=0, wmax=WMAX, dmax=64)
    db = engine.Database(b.original.schema, {"id": n, "w": WMAX, "d": 64},
                        {})
    plan = planner.plan_program(b.optimized, db, objective="incremental",
                                edges=rel, delta_nnz=delta_nnz)
    return plan.strata[0].runner


def run(n: int = 50_000, seed: int = 1, trials: int = 3,
        out: str = "BENCH_incremental.json", source: int = 0,
        gate: bool = True):
    g = _weighted_powerlaw(n, seed)
    rel = g.sparse_adjacency(semiring="trop")
    nnz = int(np.asarray(rel.as_np().nnz))
    init = _trop_init(n, source)

    t0 = time.perf_counter()
    y_star, iters0 = sparse_seminaive_fixpoint(rel, init, mode="frontier")
    t_scratch = time.perf_counter() - t0
    y_star = np.asarray(y_star)
    emit("incremental/scratch", t_scratch,
         f"n={n} nnz={nnz} iters={int(np.asarray(iters0))}")

    rng = np.random.default_rng(seed + 1)
    sizes = {"single": 1, "batch1pct": max(1, nnz // 100)}
    rows, ok_exact = [], True
    for label, k in sizes.items():
        t_fulls, t_deltas, resumed = [], [], []
        for _ in range(trials):
            coords, values = _rand_delta(rng, n, k)
            tf, td, exact, it = _one_trial(rel, init, y_star, coords,
                                           values)
            ok_exact &= exact
            t_fulls.append(tf)
            t_deltas.append(td)
            resumed.append(it)
        tf, td = float(np.median(t_fulls)), float(np.median(t_deltas))
        speedup = tf / td
        pick = _planner_pick(n, rel, k)
        rows.append({"update": label, "nnz_delta": k,
                     "t_full_s": tf, "t_delta_s": td, "speedup": speedup,
                     "resumed_iters": resumed, "planner_pick": pick})
        emit(f"incremental/{label}", td,
             f"nnz(Δ)={k} full={tf * 1e3:.1f}ms delta={td * 1e3:.1f}ms "
             f"speedup={speedup:.1f}x pick={pick}")

    result = {"bench": "incremental_update", "family": "SSSP/trop",
              "n": n, "nnz": nnz, "seed": seed, "trials": trials,
              "scratch_s": t_scratch, "agreement": ok_exact,
              "gate_speedup": GATE_SPEEDUP, "rows": rows}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")

    problems = []
    if not ok_exact:
        problems.append("delta-restart diverged from from-scratch answers")
    for r in rows:
        if gate and r["speedup"] < GATE_SPEEDUP:
            problems.append(f"{r['update']}: speedup {r['speedup']:.1f}x "
                            f"< {GATE_SPEEDUP:.0f}x")
        if r["planner_pick"] != "delta_restart":
            problems.append(f"{r['update']}: planner picked "
                            f"{r['planner_pick']!r}, not delta_restart")
    if problems:
        raise RuntimeError("incremental_update gate failed: "
                           + "; ".join(problems))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--out", default="BENCH_incremental.json")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the ≥10× latency gate "
                         "(exactness + planner-pick still checked)")
    args = ap.parse_args()
    try:
        run(n=args.n, seed=args.seed, trials=args.trials, out=args.out,
            gate=not args.no_gate)
    except RuntimeError as e:
        print(e, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
