"""Incremental-maintenance acceptance: update-to-fresh-answer latency
under streaming edge updates → ``BENCH_incremental.json``.

Single-source shortest distances (trop) and reachability (𝔹) over a
50k-vertex power-law graph, solved once from scratch; then the graph
mutates and the fresh answer is produced two ways:

* ``full``  — the pre-maintenance shape: rebuild the relation (a
  coalescing ``union`` for inserts, a filtered re-sort for deletes) and
  recompute the fixpoint from ⊥ — every mutation throws away the old
  solution, the old adjacency index, and the old relation layout;
* ``delta`` — the maintained path.  Monotone ⊕-merges take
  ``SparseRelation.apply_delta`` + *delta-restart* from the old
  solution (:func:`repro.incremental.delta_restart_fixpoint`,
  DESIGN.md §5).  Deletes and mixed delete+insert streams take
  ``SparseRelation.delete_keys`` (in-place compaction at unchanged
  capacity; the cached CSR indexes are 0̄-poisoned, not rebuilt) + the
  CEGIS-synthesized ⊖/recount maintenance rule
  (:func:`repro.incremental.maintain_nonmonotone`, DESIGN.md §11).

Update shapes: a single random edge and a 1 %-of-nnz batch for the
monotone merges (the ISSUE-4 acceptance line); a single deleted edge, a
delete-heavy batch, and a mixed delete+insert stream for the
non-monotone path (the ISSUE-10 acceptance line).  The gate
(CI: ``make bench-incremental``):

* median update-to-answer speedup ≥ 10× for both merge sizes and the
  single-edge SSSP delete; the 𝔹 delete rows must beat the full
  recompute (≥ 1×) but are not held to 10× — under 𝔹 every edge
  between reached vertices is tight, so a delete's support cone is
  close to the whole reached set and the recount is inherently a large
  fraction of a scratch solve (§11 discusses the asymmetry),
* exact agreement with the from-scratch answer on every trial,
* the cost-based planner, asked with ``objective="incremental"``, picks
  ``delta_restart`` for the merges and ``synth_maintenance`` (naming
  the verified rule in ``explain()``) for the deletes.

Usage:
  PYTHONPATH=src python -m benchmarks.incremental_update
  PYTHONPATH=src python -m benchmarks.incremental_update --n 2000
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import engine, planner
from repro.datalog import datasets, programs
from repro.incremental import (delta_restart_fixpoint, ensure_rule,
                               maintain_nonmonotone)
from repro.incremental.maintenance import _gather_values
from repro.sparse import SparseRelation
from repro.sparse import fixpoint as fx
from repro.sparse.fixpoint import fixpoint

GATE_SPEEDUP = 10.0
WMAX = 8


def _weighted_powerlaw(n: int, seed: int) -> datasets.Graph:
    g = datasets.powerlaw(n, 4, seed=seed)
    rng = np.random.default_rng(seed)
    g.weights = rng.integers(1, WMAX, len(g.edges))
    return g


def _one_hot(n: int, source: int, semiring: str) -> np.ndarray:
    if semiring == "bool":
        init = np.zeros(n, bool)
        init[source] = True
        return init
    init = np.full(n, np.inf, np.float32)
    init[source] = 0.0
    return init


def _rand_delta(rng, n: int, k: int, semiring: str):
    coords = np.stack([rng.integers(0, n, k), rng.integers(0, n, k)],
                      axis=1)
    values = (np.ones(k, bool) if semiring == "bool"
              else rng.integers(1, WMAX, k).astype(np.float32))
    return coords, values


def _live_coords(rel: SparseRelation) -> np.ndarray:
    h = rel.as_np()
    return np.asarray(h.coords[:int(h.nnz)])


def _scratch_without(rel: SparseRelation, coords: np.ndarray):
    """The pre-maintenance delete shape: filter the COO host-side and
    rebuild the relation (full re-sort, fresh CSR on first use)."""
    h = rel.as_np()
    k = int(h.nnz)
    keys = h._flat_keys(h.coords[:k])
    gone = h._flat_keys(coords)
    keep = ~np.isin(keys, gone)
    return SparseRelation.from_coo(np.asarray(h.coords[:k])[keep],
                                   np.asarray(h.values[:k])[keep],
                                   rel.shape, rel.semiring, lib="np")


def _merge_trial(rel, init, y_star, coords, values, *, max_iters=10_000):
    """Apply one ⊕-merge both ways; returns (t_full, t_delta, exact,
    resumed_iters)."""
    dr = SparseRelation.from_coo(coords, values, rel.shape, rel.semiring,
                                 lib="np")
    # -- full recompute: coalescing union + from-scratch frontier fixpoint
    t0 = time.perf_counter()
    rel_full = rel.union(dr)
    y_full, _ = fixpoint(rel_full, init, mode="frontier",
                         max_iters=max_iters)
    t_full = time.perf_counter() - t0
    y_full = np.asarray(y_full)

    # -- delta restart: O(nnz(Δ)) append + seed + affected-region rounds
    t0 = time.perf_counter()
    rel_delta = rel.apply_delta(coords, values)
    y_delta, it = delta_restart_fixpoint(rel_delta, dr, y_star,
                                         mode="frontier",
                                         max_iters=max_iters)
    t_delta = time.perf_counter() - t0
    return t_full, t_delta, np.array_equal(np.asarray(y_delta), y_full), \
        int(np.asarray(it))


def _delete_trial(rel, init, y_star, rule, coords, *, merge=None,
                  max_iters=10_000):
    """Delete ``coords`` (plus optionally ⊕-merge ``merge``) both ways;
    returns (t_full, t_delta, exact, resumed_iters)."""
    dvals = _gather_values(rel, coords)
    # -- full recompute: filtered rebuild (+ union) + from-scratch solve
    t0 = time.perf_counter()
    rel_full = _scratch_without(rel, coords)
    if merge is not None:
        rel_full = rel_full.union(merge)
    y_full, _ = fixpoint(rel_full, init, mode="frontier",
                         max_iters=max_iters)
    t_full = time.perf_counter() - t0
    y_full = np.asarray(y_full)

    # -- maintained: in-place delete_keys (CSR poisoning) + ⊖/recount rule
    t0 = time.perf_counter()
    rel_new = rel.delete_keys(coords)
    if merge is not None:
        mh = merge.as_np()
        mk = int(mh.nnz)
        rel_new = rel_new.apply_delta(mh.coords[:mk], mh.values[:mk])
    y_new, it = maintain_nonmonotone(rel_new, coords, dvals, y_star,
                                     init, rule, merge_delta=merge,
                                     max_iters=max_iters)
    t_delta = time.perf_counter() - t0
    return t_full, t_delta, np.array_equal(np.asarray(y_new), y_full), \
        int(np.asarray(it))


def _plan_for(n: int, rel: SparseRelation, delta_nnz: int,
              delta_op: str):
    """The cost-based plan for this workload under
    ``objective="incremental"`` (SSSP's schema-level E3 would be a dense
    (n, n, w) tensor at 50k — the edges override routes the weighted COO
    adjacency, exactly as the serve loop does)."""
    if rel.semiring == "bool":
        b = programs.bm(a=0)
        db = engine.Database(b.original.schema, {"id": n}, {})
    else:
        b = programs.sssp(a=0, wmax=WMAX, dmax=64)
        db = engine.Database(b.original.schema,
                             {"id": n, "w": WMAX, "d": 64}, {})
    return planner.plan_program(b.optimized, db, objective="incremental",
                                edges=rel, delta_nnz=delta_nnz,
                                delta_op=delta_op)


def _planner_pick(n: int, rel: SparseRelation, delta_nnz: int,
                  delta_op: str = "merge") -> str:
    plan = _plan_for(n, rel, delta_nnz, delta_op)
    sp = plan.strata[0]
    if delta_op != "merge":
        # planning never synthesizes — ensure the rule is cached (the
        # refresh/serve layers do this once per process) and re-plan
        ensure_rule(sp.vf.signature, sp.vf.semiring, delta_op)
        sp = _plan_for(n, rel, delta_nnz, delta_op).strata[0]
        if sp.runner == "synth_maintenance" \
                and "⊖-recount" not in sp.reason:
            raise RuntimeError("explain() does not name the synthesized "
                               f"rule: {sp.reason}")
    return sp.runner


def _bench_family(rows, problems, *, rel, n, semiring, rule, init,
                  y_star, rng, trials, gate, tag):
    """The non-monotone rows for one semiring family: single delete,
    delete-heavy batch, mixed delete+insert stream."""
    live = _live_coords(rel)
    heavy = max(1, len(live) // 1000)
    shapes = [("delete_single", 1, 0), ("delete_heavy", heavy, 0),
              ("mixed", max(1, heavy // 2), max(1, heavy // 2))]
    for label, kd, ki in shapes:
        t_fulls, t_deltas, resumed, ok = [], [], [], True
        for _ in range(trials):
            dels = live[rng.choice(len(live), kd, replace=False)]
            merge = None
            if ki:
                mc, mv = _rand_delta(rng, n, ki, semiring)
                merge = SparseRelation.from_coo(mc, mv, rel.shape,
                                                semiring, lib="np")
            tf, td, exact, it = _delete_trial(rel, init, y_star, rule,
                                              dels, merge=merge)
            ok &= exact
            t_fulls.append(tf)
            t_deltas.append(td)
            resumed.append(it)
        tf, td = float(np.median(t_fulls)), float(np.median(t_deltas))
        speedup = tf / td
        # a mixed stream plans as its non-monotone part — same
        # delete-rule lookup refresh_program uses (restart.py)
        pick = _planner_pick(n, rel, kd + ki, "delete")
        rows.append({"update": f"{tag}/{label}", "nnz_delta": kd + ki,
                     "t_full_s": tf, "t_delta_s": td, "speedup": speedup,
                     "resumed_iters": resumed, "planner_pick": pick})
        emit(f"incremental/{tag}/{label}", td,
             f"nnz(Δ)={kd + ki} full={tf * 1e3:.1f}ms "
             f"delta={td * 1e3:.1f}ms speedup={speedup:.1f}x pick={pick}")
        if not ok:
            problems.append(f"{tag}/{label}: maintenance diverged from "
                            f"from-scratch answers")
        if gate and tag == "sssp" and label == "delete_single" \
                and speedup < GATE_SPEEDUP:
            problems.append(f"{tag}/{label}: speedup {speedup:.1f}x "
                            f"< {GATE_SPEEDUP:.0f}x")
        if gate and speedup < 1.0:
            problems.append(f"{tag}/{label}: maintenance lost to full "
                            f"recompute ({speedup:.2f}x)")
        if pick != "synth_maintenance":
            problems.append(f"{tag}/{label}: planner picked {pick!r}, "
                            f"not synth_maintenance")


def run(n: int = 50_000, seed: int = 1, trials: int = 3,
        out: str = "BENCH_incremental.json", source: int = 0,
        gate: bool = True):
    g = _weighted_powerlaw(n, seed)
    rel = g.sparse_adjacency(semiring="trop")
    nnz = int(np.asarray(rel.as_np().nnz))
    init = _one_hot(n, source, "trop")

    t0 = time.perf_counter()
    y_star, iters0 = fixpoint(rel, init, mode="frontier")
    t_scratch = time.perf_counter() - t0
    y_star = np.asarray(y_star)
    emit("incremental/scratch", t_scratch,
         f"n={n} nnz={nnz} iters={int(np.asarray(iters0))}")

    rng = np.random.default_rng(seed + 1)
    rows, problems, ok_exact = [], [], True

    # -- monotone ⊕-merges (DESIGN.md §5) -------------------------------
    sizes = {"single": 1, "batch1pct": max(1, nnz // 100)}
    for label, k in sizes.items():
        t_fulls, t_deltas, resumed = [], [], []
        for _ in range(trials):
            coords, values = _rand_delta(rng, n, k, "trop")
            tf, td, exact, it = _merge_trial(rel, init, y_star, coords,
                                             values)
            ok_exact &= exact
            t_fulls.append(tf)
            t_deltas.append(td)
            resumed.append(it)
        tf, td = float(np.median(t_fulls)), float(np.median(t_deltas))
        speedup = tf / td
        pick = _planner_pick(n, rel, k)
        rows.append({"update": label, "nnz_delta": k,
                     "t_full_s": tf, "t_delta_s": td, "speedup": speedup,
                     "resumed_iters": resumed, "planner_pick": pick})
        emit(f"incremental/{label}", td,
             f"nnz(Δ)={k} full={tf * 1e3:.1f}ms delta={td * 1e3:.1f}ms "
             f"speedup={speedup:.1f}x pick={pick}")
        if gate and speedup < GATE_SPEEDUP:
            problems.append(f"{label}: speedup {speedup:.1f}x "
                            f"< {GATE_SPEEDUP:.0f}x")
        if pick != "delta_restart":
            problems.append(f"{label}: planner picked {pick!r}, "
                            f"not delta_restart")

    # -- non-monotone deletes + mixed streams (DESIGN.md §11) -----------
    # prime both CSR orientations outside the timers: scratch and
    # maintained paths each consult the cached forward index, and the
    # delete poisons the transpose too — neither side pays the build
    trop_rule = ensure_rule("bench-sssp", "trop", "delete")
    if not trop_rule.verified:
        raise RuntimeError(f"trop delete rule failed to synthesize: "
                           f"{trop_rule.reason}")
    fx.csr_index(rel)
    fx.csr_index(rel, transpose=True)
    _bench_family(rows, problems, rel=rel, n=n, semiring="trop",
                  rule=trop_rule, init=init, y_star=y_star, rng=rng,
                  trials=trials, gate=gate, tag="sssp")

    brel = g.sparse_adjacency(semiring="bool")
    binit = _one_hot(n, source, "bool")
    by_star, _ = fixpoint(brel, binit, mode="frontier")
    bool_rule = ensure_rule("bench-bm", "bool", "delete")
    if not bool_rule.verified:
        raise RuntimeError(f"bool delete rule failed to synthesize: "
                           f"{bool_rule.reason}")
    fx.csr_index(brel)
    fx.csr_index(brel, transpose=True)
    _bench_family(rows, problems, rel=brel, n=n, semiring="bool",
                  rule=bool_rule, init=binit, y_star=np.asarray(by_star),
                  rng=rng, trials=trials, gate=gate, tag="bm")

    result = {"bench": "incremental_update",
              "family": "SSSP/trop + BM/bool",
              "n": n, "nnz": nnz, "seed": seed, "trials": trials,
              "scratch_s": t_scratch, "agreement": ok_exact,
              "gate_speedup": GATE_SPEEDUP, "rows": rows}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")

    if not ok_exact:
        problems.append("delta-restart diverged from from-scratch "
                        "answers")
    if problems:
        raise RuntimeError("incremental_update gate failed: "
                           + "; ".join(problems))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--out", default="BENCH_incremental.json")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the ≥10× latency gates "
                         "(exactness + planner-pick still checked)")
    args = ap.parse_args()
    try:
        run(n=args.n, seed=args.seed, trials=args.trials, out=args.out,
            gate=not args.no_gate)
    except RuntimeError as e:
        print(e, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
