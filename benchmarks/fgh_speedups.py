"""Paper Fig. 11: speedup of FGH-optimized vs original programs.

Rule-based-synthesis group (BM, CC, SSSP) on power-law SNAP stand-ins,
plus the GSN (generalized semi-naive) variant where the semiring admits it.
Emits: name, runtime_us(original), derived="opt=...x gsn=...x".
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import fgh, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs


def _optimize(bench, edbs):
    task = verify.task_from_program(bench.original, edbs,
                                    constraint=bench.constraint)
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok, bench.name
    if bench.original.post is not None:
        rep.program.post = bench.original.post
    return rep


def run(sizes=(200, 400), seed=0, iters=2):
    graphs = {n: datasets.powerlaw(n, m_attach=4, seed=seed) for n in sizes}
    wgraphs = {n: datasets.erdos_renyi(n, 4.0, seed=seed, weighted=True,
                                       wmax=4) for n in sizes}
    cases = [("BM", programs.bm, ["E", "V"], graphs, {}),
             ("CC", programs.cc, ["E", "V"], graphs, {}),
             ("SSSP", lambda: programs.sssp(a=0, wmax=4, dmax=64),
              ["E3"], wgraphs, {})]
    rows = []
    for name, mk, edbs, data, kw in cases:
        b = mk()
        rep = _optimize(b, edbs)
        for n, g in data.items():
            db = b.make_db(g)
            t_orig = timeit(lambda: run_program(b.original, db)[0],
                            iters=iters)
            t_opt = timeit(lambda: run_program(rep.program, db)[0],
                           iters=iters)
            derived = f"n={n} speedup={t_orig/t_opt:.1f}x"
            try:
                t_gsn = timeit(
                    lambda: run_program(rep.program, db,
                                        mode="seminaive")[0], iters=iters)
                derived += f" gsn={t_orig/t_gsn:.1f}x"
            except ValueError:
                derived += " gsn=n/a"
            emit(f"fig11/{name}/n{n}", t_orig, derived)
            rows.append((name, n, t_orig, t_opt))
    return rows


if __name__ == "__main__":
    run()
