"""Roofline analysis from the dry-run results (assignment §ROOFLINE).

Terms per (arch × shape), single-pod mesh (256 chips of TPU v5e):

  compute    = HLO_FLOPs(per-device)   / 197e12 FLOP/s
  memory     = HLO_bytes(per-device)   / 819e9  B/s
  collective = coll_bytes(per-device)  / 50e9   B/s (per-link ICI)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train — 2·N·D
for single-token decode — and the MODEL/HLO usefulness ratio.
"""

from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2 ** 30
CHIPS = {"single": 256, "multi": 512}


def model_flops(row) -> float:
    tokens = row.get("tokens", 0)
    n_active = row.get("active_params_b", 0)
    if row["shape"].startswith("train"):
        return 6.0 * n_active * tokens
    if row["shape"].startswith("prefill"):
        return 2.0 * n_active * tokens
    # decode: one new token per sequence; tokens field = batch*seq (cache)
    batch = {"decode_32k": 128, "long_500k": 1}.get(row["shape"], 1)
    return 2.0 * n_active * batch


def analyze_row(row) -> dict:
    chips = CHIPS[row["mesh"]]
    t_compute = row["flops"] / PEAK_FLOPS
    t_memory = row["bytes_accessed"] / HBM_BW
    t_coll = row["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(row)
    hlo_global = row["flops"] * chips
    mem = row.get("memory", {})
    hbm_need = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
    return {
        "arch": row["arch"], "shape": row["shape"], "mesh": row["mesh"],
        **{k: f"{v:.4g}" for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": f"{mf:.3g}",
        "useful_ratio": f"{mf / hlo_global:.3f}" if hlo_global else "n/a",
        "roofline_frac": f"{min(1.0, (mf / chips / PEAK_FLOPS) / max(terms.values())):.3f}"
        if max(terms.values()) > 0 else "n/a",
        "hbm_per_chip_gib": f"{hbm_need / 2**30:.1f}",
        "fits_hbm": hbm_need <= HBM_PER_CHIP,
    }


def run(path="results/dryrun_baseline.json", mesh="single"):
    rows = json.load(open(path))
    out = []
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_frac,hbm_gib,fits")
    for r in rows:
        if r.get("status") == "skipped":
            if r["mesh"] == mesh:
                print(f"{r['arch']},{r['shape']},skipped:"
                      f"{r['reason'][:60]}...")
            continue
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        a = analyze_row(r)
        out.append(a)
        print(f"{a['arch']},{a['shape']},{a['compute_s']},{a['memory_s']},"
              f"{a['collective_s']},{a['dominant']},{a['useful_ratio']},"
              f"{a['roofline_frac']},{a['hbm_per_chip_gib']},"
              f"{a['fits_hbm']}")
    return out


if __name__ == "__main__":
    run(*(sys.argv[1:] or []))
