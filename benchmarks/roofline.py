"""Measured roofline for the SpMM hot loop → ``results/roofline.json``.

The seed-era version of this file post-processed a TPU v5e dry-run JSON
(hardcoded 197 TFLOP/s / 819 GB/s pod constants) that no suite in this
repo ever produced — a dead path.  This rewrite measures the machine it
runs on:

1. **Detected peaks** — microbenchmarks, not spec sheets: peak memory
   bandwidth from the best of a numpy copy and a jitted jnp stream over
   a buffer far larger than LLC; peak flop/s from a jitted f32 GEMM.
2. **Achieved rates** — for each (semiring, B, density) cell at the
   serving shape, time one jnp SpMM round and one fused-kernel round
   (the same hot-loop units ``benchmarks/kernel_bench.py`` sweeps),
   convert through a first-order traffic model (index + value reads,
   gather/⊗/segment-⊕ passes over the B-lane payload, output write)
   into bytes/s and semiring-op/s, and report each as a fraction of the
   detected peak.

The point: the fused kernel's speedup must show up as *bandwidth
recovered* (a higher achieved-bytes/s fraction, or strictly fewer bytes
moved for the same advance), so a win is attributable and a regression
diagnosable — not noise.  All model terms are first-order lower bounds
on traffic; fractions above ~1 mean the working set cached, fractions
far below peak mean latency-bound gathers.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline
  PYTHONPATH=src python -m benchmarks.roofline --n 2000 --out ''
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from benchmarks.kernel_bench import (_frontier, _graph,
                                     _time_backend_round,
                                     _time_jnp_round)
from repro.core import semiring as sr_mod
from repro.kernels import coo_spmm
from repro.sparse.coo import SparseRelation


# --------------------------------------------------------------------------
# detected peaks
# --------------------------------------------------------------------------


def detect_peaks(stream_mib: int = 256, gemm_m: int = 1024) -> dict:
    """Microbenchmark this host: peak bytes/s and flop/s.

    Bandwidth is the best of a host numpy copy and a jitted device
    stream (on CPU both hit the same DRAM; on TPU the jnp number is the
    HBM figure that matters).  Flops from a jitted f32 GEMM — the
    highest-intensity kernel XLA will emit here.
    """
    m = stream_mib * (1 << 20) // 4
    xh = np.ones(m, np.float32)
    t_np = timeit(lambda: xh.copy(), iters=3)
    xd = jnp.asarray(xh)
    f = jax.jit(lambda v: v + 1.0)
    t_jnp = timeit(lambda: f(xd), iters=3)
    bw = max(2 * m * 4 / t_np, 2 * m * 4 / t_jnp)

    a = jnp.asarray(np.random.default_rng(0)
                    .random((gemm_m, gemm_m), np.float32))
    g = jax.jit(lambda u, v: u @ v)
    t_mm = timeit(lambda: g(a, a), iters=3)
    flops = 2.0 * gemm_m ** 3 / t_mm
    return {"bytes_per_s": bw, "flop_per_s": flops,
            "stream_copy_s": t_np, "stream_jit_s": t_jnp,
            "gemm_s": t_mm}


# --------------------------------------------------------------------------
# first-order traffic models (bytes per hot-loop round)
# --------------------------------------------------------------------------


def _elem_bytes(sr_name: str) -> int:
    return int(np.dtype(sr_mod.get(sr_name, lib="np").dtype).itemsize)


def jnp_round_bytes(plan, b: int) -> float:
    """gather (read x rows) → ⊗ (write prod) → segment-⊕ (read prod,
    write out), plus the per-edge coordinate + value reads."""
    el = _elem_bytes(plan.sr_name)
    idx = 2 * 4                      # (src, dst) int32 per edge
    val = _elem_bytes(plan.sr_name)
    return (plan.nnz * (idx + val + 3 * b * el)
            + plan.n_out * b * el)


def fused_round_bytes(plan, b: int, backend: str) -> float:
    """One pass over dst-sorted edges.  Packed 𝔹 moves W = ⌈B/64⌉
    words per edge instead of B lanes; the generic fused body keeps the
    lane payload but drops the scatter (segment starts are per unique
    destination, not per edge)."""
    if plan.sr_name == "bool" and backend != "pallas":
        w8 = 8 * ((b + 63) // 64)
        return plan.nnz * (8 + 3 * w8) + plan.n_out * w8
    el = _elem_bytes(plan.sr_name)
    val = _elem_bytes(plan.sr_name)
    return (plan.nnz * (8 + val + 3 * b * el)
            + plan.n_out * b * el)


def round_ops(plan, b: int) -> float:
    """Semiring ops per round: one ⊗ and one ⊕ per (edge, lane)."""
    return 2.0 * plan.nnz * b


# --------------------------------------------------------------------------
# the sweep
# --------------------------------------------------------------------------


def _relation(g, sr_name: str) -> SparseRelation:
    rel = g.sparse_adjacency(
        semiring="bool" if sr_name == "bool" else "trop")
    if sr_name in ("bool", "trop"):
        return rel
    eh = rel.as_np()
    k = int(eh.nnz)
    return SparseRelation.from_coo(eh.coords[:k], eh.values[:k],
                                   rel.shape, sr_name)


def run(n: int = 50_000, batches=(8, 64), avg_degs=(4,),
        semirings=("bool", "trop"), seed: int = 1,
        out: str | None = "results/roofline.json"):
    peaks = detect_peaks()
    emit("roofline/peaks", peaks["gemm_s"],
         f"bw={peaks['bytes_per_s']/1e9:.1f}GB/s "
         f"flops={peaks['flop_per_s']/1e9:.1f}GFLOP/s")
    backend = "pallas" if jax.default_backend() == "tpu" else "fused"
    rows = []
    for deg in avg_degs:
        g = _graph(n, deg, seed)
        for sr_name in semirings:
            rel = _relation(g, sr_name).as_jnp()
            plan = coo_spmm.plan_geometry(rel, transpose=True)
            for b in batches:
                x = jnp.asarray(_frontier(n, b, sr_name, seed + b))
                t_jnp = _time_jnp_round(rel, x)
                t_fused = _time_backend_round(backend, plan, x)
                bj = jnp_round_bytes(plan, b)
                bf = fused_round_bytes(plan, b, backend)
                ops_r = round_ops(plan, b)
                row = {
                    "semiring": sr_name, "B": b, "avg_deg": deg,
                    "nnz": int(plan.nnz),
                    "density": int(plan.nnz) / (n * n),
                    "backend": backend,
                    "t_jnp_s": t_jnp, "t_fused_s": t_fused,
                    "speedup": t_jnp / t_fused,
                    "model_bytes_jnp": bj, "model_bytes_fused": bf,
                    "achieved_gbps_jnp": bj / t_jnp / 1e9,
                    "achieved_gbps_fused": bf / t_fused / 1e9,
                    "bw_frac_jnp": bj / t_jnp / peaks["bytes_per_s"],
                    "bw_frac_fused": bf / t_fused / peaks["bytes_per_s"],
                    "gops_fused": ops_r / t_fused / 1e9,
                    "flop_frac_fused":
                        ops_r / t_fused / peaks["flop_per_s"],
                    "bytes_moved_ratio": bf / bj,
                }
                rows.append(row)
                emit(f"roofline/{sr_name}/B{b}/deg{deg}", t_fused,
                     f"fused={row['achieved_gbps_fused']:.2f}GB/s "
                     f"({row['bw_frac_fused']:.0%} of peak) "
                     f"jnp={row['achieved_gbps_jnp']:.2f}GB/s "
                     f"({row['bw_frac_jnp']:.0%})  "
                     f"bytes x{row['bytes_moved_ratio']:.2f} "
                     f"speedup={row['speedup']:.1f}x")
    result = {"bench": "roofline", "n": n, "seed": seed,
              "backend": backend, "peaks": peaks, "rows": rows}
    if out:
        p = pathlib.Path(out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--batches", default="8,64")
    ap.add_argument("--degs", default="4")
    ap.add_argument("--semirings", default="bool,trop")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    run(n=args.n,
        batches=tuple(int(s) for s in args.batches.split(",") if s),
        avg_degs=tuple(int(s) for s in args.degs.split(",") if s),
        semirings=tuple(s for s in args.semirings.split(",") if s),
        seed=args.seed, out=args.out or None)


if __name__ == "__main__":
    main()
