"""Paper Fig. 12: CEGIS-group benchmarks (WS, BC, R, MLM) vs data size.

R and MLM run on two tree families (random recursive, O(log n) depth;
exponential-decay, O(n) depth) exactly as in the paper — the optimized
form's advantage grows with depth.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import fgh, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs


def run(sizes=(64, 128), seed=0, iters=2):
    rows = []

    # WS — vector sizes (the original is O(n²·w) dense: keep n modest;
    # the n=192 point already shows the 10³× separation)
    b = programs.ws(window=10, vmax=6)
    task = verify.task_from_program(b.original, ["A2"])
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok
    rep.program.post = b.original.post
    for n in [s * 2 for s in sizes]:
        db = b.make_db(datasets.vector_data(n, seed=seed, vmax=6))
        t_o = timeit(lambda: run_program(b.original, db)[0], iters=iters)
        t_p = timeit(lambda: run_program(rep.program, db)[0], iters=iters)
        emit(f"fig12/WS/n{n}", t_o, f"speedup={t_o/t_p:.1f}x")
        rows.append(("WS", n, t_o, t_p))

    # BC — Erdős–Rényi (optimized = Brandes; verified rewrite, see
    # EXPERIMENTS.md §Deviations)
    for n in sizes:
        b = programs.bc(dmax=max(16, n // 4))
        g = datasets.erdos_renyi(n, 2.0, seed=seed)
        db = b.make_db(g)
        t_o = timeit(lambda: run_program(b.original, db)[0], iters=1)
        t_p = timeit(lambda: run_program(b.optimized, db)[0], iters=iters)
        emit(f"fig12/BC/n{n}", t_o, f"speedup={t_o/t_p:.1f}x")
        rows.append(("BC", n, t_o, t_p))

    # R / MLM — two tree families; synthesis runs once per program (the
    # optimized H is size-independent)
    h_cache: dict = {}
    for label, gen in [("rrt", datasets.random_recursive_tree),
                       ("decay", datasets.decay_tree)]:
        for name in ("R", "MLM"):
            for n in sizes:
                g = gen(n, seed=seed)
                depth = datasets.tree_depth(g)
                b = (programs.radius(dmax=depth + 2) if name == "R"
                     else programs.mlm())
                if name not in h_cache:
                    task = verify.task_from_program(
                        b.original, ["E", "V"], constraint="tree")
                    h_cache[name] = fgh.optimize(
                        task, rng=np.random.default_rng(0))
                rep = h_cache[name]
                assert rep.ok, name
                db = b.make_db(g)
                t_o = timeit(lambda: run_program(b.original, db)[0],
                             iters=1)
                t_p = timeit(lambda: run_program(rep.program, db)[0],
                             iters=iters)
                emit(f"fig12/{name}/{label}/n{n}", t_o,
                     f"depth={depth} speedup={t_o/t_p:.1f}x")
                rows.append((name, (label, n), t_o, t_p))
    return rows


if __name__ == "__main__":
    run()
