"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *, warmup: int = 1, iters: int = 3, timeout_s: float = 120.0):
    """Median wall time of fn() in seconds (block_until_ready aware)."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
        if sum(times) > timeout_s:
            break
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
