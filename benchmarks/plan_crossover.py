"""Planner crossover acceptance: sweep density × n and check that the
cost-based planner (DESIGN.md §4) picks the empirically fastest runner at
the extremes.

Each cell builds a benchmark family (BM reachability / CC labels / SSSP
distances), plans it with ``mode="auto"``, then times the forced
alternatives with ``run_program``'s forced-plan modes:

* **sparse extreme** (large n, constant average degree): the plan must
  route to a sparse vector runner (``sparse_frontier``/``sparse_jit``);
  empirically the sparse pick must not lose to the dense GSN engine.
* **dense extreme** (small n, high density): the plan must stay on a
  dense runner (``vector_dense``/``dense_gsn``/``dense_naive``); the
  dense pick must not lose to the forced sparse runner.

Exactness is asserted at every overlap cell: the chosen runner's answer
must equal the dense engine's bit-for-bit.  Exit code 1 on any
planner/empirical disagreement — this is the `make bench-plan` CI gate.

Full (non ``--quick``) runs add the 50k-vertex acceptance cells: BM and
SSSP on sparse 50k-vertex graphs must plan onto the sparse path and
answer in sub-second time.
"""

from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import engine, planner
from repro.core.program import run_program
from repro.datalog import datasets, programs

DENSE_RUNNERS = ("vector_dense", "dense_gsn", "dense_naive")
SPARSE_RUNNERS = ("sparse_frontier", "sparse_jit")

#: empirical slack — "did not lose" means within this factor of the rival
SLACK = 2.0


def _bm_db(n: int, avg_deg: float, *, sparse: bool, seed: int = 0):
    g = (datasets.erdos_renyi_sparse(n, avg_deg, seed=seed) if sparse
         else datasets.erdos_renyi(n, avg_deg, seed=seed))
    schema = programs.bm(a=0).original.schema
    e = g.sparse_adjacency() if sparse else g.adjacency()
    return engine.Database(schema, {"id": n},
                           {"E": e, "V": jnp.ones((n,), bool)})


def _cell(name: str, prog, db, *, expect: tuple[str, ...],
          rival_mode: str, iters: int = 2, time_gate: bool = True) -> dict:
    """Plan one cell, time plan-choice vs the forced rival, check
    exactness against the dense naive engine.

    ``time_gate=False`` (quick/CI mode) keeps the wall-clock comparison
    advisory: at toy sizes the cells run in ~1 ms, where shared-runner
    noise would make a hard 2× gate flaky — the deterministic runner-pick
    and exactness assertions do the gating there.
    """
    plan = planner.plan_program(prog, db, mode="auto")
    runner = plan.strata[0].runner
    ok_pick = runner in expect
    t_pick = timeit(lambda: run_program(prog, db, plan=plan)[0],
                    iters=iters)
    t_rival = timeit(lambda: run_program(prog, db, mode=rival_mode)[0],
                     iters=iters)
    ok_time = (t_pick <= SLACK * t_rival) or not time_gate
    a_pick, _ = run_program(prog, db, plan=plan)
    a_ref, _ = run_program(prog, db, mode="naive")
    ok_exact = np.array_equal(np.asarray(a_pick), np.asarray(a_ref))
    row = dict(cell=name, runner=runner, expect=expect,
               t_pick_ms=round(t_pick * 1e3, 2),
               t_rival_ms=round(t_rival * 1e3, 2),
               pick_ok=ok_pick, time_ok=ok_time, exact=ok_exact)
    print(f"{name:24s} runner={runner:15s} pick={'OK' if ok_pick else 'X'} "
          f"t={t_pick * 1e3:8.2f}ms rival({rival_mode})="
          f"{t_rival * 1e3:8.2f}ms time={'OK' if ok_time else 'X'} "
          f"exact={'OK' if ok_exact else 'X'}", flush=True)
    return row


def run(sizes=(400, 1500), dense_n: int = 160, big: int = 50_000,
        quick: bool = False) -> bool:
    """Raises ``RuntimeError`` on any planner/empirical disagreement so
    the aggregate ``benchmarks.run`` driver reports the failure too."""
    if quick:
        sizes, dense_n, big = (200, 600), 120, 0
    time_gate = not quick
    rows = []

    # -- sparse extreme: BM at growing n, constant degree ------------------
    for n in sizes:
        db = _bm_db(n, 3.0, sparse=True)
        rows.append(_cell(f"bm/sparse/n={n}", programs.bm(a=0).optimized,
                          db, expect=SPARSE_RUNNERS,
                          rival_mode="seminaive", time_gate=time_gate))

    # -- dense extreme: BM + CC on a high-density block --------------------
    db_d = _bm_db(dense_n, 0.4 * dense_n, sparse=False)
    rows.append(_cell(f"bm/dense/n={dense_n}", programs.bm(a=0).optimized,
                      db_d, expect=DENSE_RUNNERS,
                      rival_mode="sparse_jit", time_gate=time_gate))
    bcc = programs.cc()
    g_cc = datasets.erdos_renyi(dense_n, 0.4 * dense_n, seed=1)
    rows.append(_cell(f"cc/dense/n={dense_n}", bcc.optimized,
                      bcc.make_db(g_cc), expect=DENSE_RUNNERS,
                      rival_mode="sparse_jit", time_gate=time_gate))

    # -- 50k acceptance cells (full runs only) -----------------------------
    if big:
        ok_big = _acceptance_50k(big, rows)
    else:
        ok_big = True

    ok = ok_big and all(r["pick_ok"] and r["time_ok"] and r["exact"]
                        for r in rows)
    print(f"plan_crossover: {'PASS' if ok else 'FAIL'} "
          f"({len(rows)} cells)", flush=True)
    if not ok:
        bad = [r["cell"] for r in rows
               if not (r["pick_ok"] and r["time_ok"] and r["exact"])]
        raise RuntimeError(
            f"planner/empirical disagreement at the extremes: {bad}")
    return ok


def _acceptance_50k(n: int, rows: list) -> bool:
    """BM and SSSP at 50k vertices must plan onto the sparse path, and
    match the dense engine exactly at an overlap size."""
    ok = True
    # BM: run_program(mode="auto") end-to-end on the 50k sparse db
    db = _bm_db(n, 8.0, sparse=True)
    prog = programs.bm(a=0).optimized
    plan = planner.plan_program(prog, db, mode="auto")
    runner = plan.strata[0].runner
    t = timeit(lambda: run_program(prog, db, plan=plan)[0], iters=1)
    print(f"bm/sparse/n={n}        runner={runner:15s} "
          f"t={t * 1e3:8.1f}ms", flush=True)
    ok &= runner in SPARSE_RUNNERS

    # SSSP: the schema-level E3 would be a dense (n, n, w) tensor; the
    # plan-level edges override routes a weighted COO adjacency instead
    g = datasets.erdos_renyi_sparse(n, 6.0, seed=3, weighted=True, wmax=6)
    b = programs.sssp(a=0, wmax=6, dmax=48)
    db_s = engine.Database(b.original.schema, {"id": n, "w": 6, "d": 48}, {})
    plan_s = planner.plan_program(b.optimized, db_s, mode="auto",
                                  edges=g.sparse_adjacency(semiring="trop"))
    runner_s = plan_s.strata[0].runner
    t_s = timeit(lambda: run_program(b.optimized, db_s, plan=plan_s)[0],
                 iters=1)
    print(f"sssp/sparse/n={n}      runner={runner_s:15s} "
          f"t={t_s * 1e3:8.1f}ms", flush=True)
    ok &= runner_s in SPARSE_RUNNERS

    # overlap exactness: same programs at a size the dense engine allows
    n_small = 800
    db_small = _bm_db(n_small, 8.0, sparse=True, seed=5)
    a_sp, s_sp = run_program(prog, db_small)
    a_d, _ = run_program(prog, db_small.with_storage("E", "dense"),
                         mode="seminaive")
    exact = np.array_equal(np.asarray(a_sp), np.asarray(a_d))
    print(f"bm/overlap/n={n_small}     runner="
          f"{s_sp.plan.strata[0].runner:15s} exact="
          f"{'OK' if exact else 'X'}", flush=True)
    ok &= exact

    g2 = datasets.erdos_renyi_sparse(n_small, 4.0, seed=6, weighted=True,
                                     wmax=6)
    db2 = b.make_db(g2)
    plan2 = planner.plan_program(b.optimized, db2, mode="auto",
                                 edges=g2.sparse_adjacency(semiring="trop"))
    a_sp2, _ = run_program(b.optimized, db2, plan=plan2)
    a_d2, _ = run_program(b.optimized, db2, mode="seminaive")
    exact2 = np.array_equal(np.asarray(a_sp2), np.asarray(a_d2))
    print(f"sssp/overlap/n={n_small}   runner="
          f"{plan2.strata[0].runner:15s} exact="
          f"{'OK' if exact2 else 'X'}", flush=True)
    ok &= exact2
    rows.append(dict(cell="acceptance50k", pick_ok=ok, time_ok=True,
                     exact=exact and exact2))
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="toy sizes, no 50k acceptance cells (CI smoke)")
    args = ap.parse_args()
    try:
        run(quick=args.quick)
    except RuntimeError as e:
        print(e, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
