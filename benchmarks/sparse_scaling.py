"""Dense vs. sparse scaling over the BM/TC family (DESIGN.md §2).

Single-source reachability (the FGH-optimized BM program) on power-law
graphs, evaluated three ways:

* ``dense``     — the dense engine (`run_program`, semi-naive): O(n)
  state but O(n²) adjacency and per-iteration contraction;
* ``sparse``    — same program with E stored as a COO SparseRelation:
  the engine routes the join through SpMV (O(nnz) per iteration);
* ``frontier``  — the sparse worklist runner
  (`sparse_seminaive_fixpoint`, host mode): total work O(nnz · depth).

At the small sizes all three must agree exactly; beyond
``--dense-limit`` the n×n adjacency is unallocatable and only the sparse
paths run — a 50k-vertex graph completes in seconds on CPU.

Usage:
  PYTHONPATH=src python -m benchmarks.sparse_scaling
  PYTHONPATH=src python -m benchmarks.sparse_scaling --sizes 512,2048 --big 50000
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import engine
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.sparse.fixpoint import sparse_seminaive_fixpoint_stats


def _db(bench, n, edges_rel, dense_e=None):
    rels = {"E": dense_e if dense_e is not None else edges_rel,
            "V": jnp.ones((n,), bool)}
    return engine.Database(bench.original.schema, {"id": n}, rels)


def run(sizes=(512, 2048), big=50_000, dense_limit=8192, seed=1,
        iters=2):
    b = programs.bm(a=0)
    rows = []
    for n in [*sizes, big]:
        g = datasets.powerlaw(n, 4, seed=seed)
        rel = g.sparse_adjacency()
        init = np.zeros(n, bool)
        init[0] = True

        t_fr = timeit(lambda: sparse_seminaive_fixpoint_stats(
            rel, init, mode="frontier")[0], iters=iters)
        y_fr, it_fr, stats = sparse_seminaive_fixpoint_stats(
            rel, init, mode="frontier")
        emit(f"sparse_scaling/frontier/n{n}", t_fr,
             f"iters={it_fr} nnz={int(np.asarray(rel.nnz))} "
             f"edges_expanded={stats.total_edges}")

        db_sp = _db(b, n, rel)
        t_sp = timeit(lambda: run_program(b.optimized, db_sp,
                                          mode="seminaive")[0],
                      iters=iters)
        y_sp, _ = run_program(b.optimized, db_sp, mode="seminaive")
        emit(f"sparse_scaling/sparse/n{n}", t_sp, "")
        assert np.array_equal(np.asarray(y_sp), np.asarray(y_fr)), \
            f"sparse engine vs frontier mismatch at n={n}"

        if n <= dense_limit:
            db_d = _db(b, n, None, dense_e=g.adjacency())
            t_d = timeit(lambda: run_program(b.optimized, db_d,
                                             mode="seminaive")[0],
                         iters=iters)
            y_d, _ = run_program(b.optimized, db_d, mode="seminaive")
            assert np.array_equal(np.asarray(y_d), np.asarray(y_sp)), \
                f"dense vs sparse mismatch at n={n}"
            emit(f"sparse_scaling/dense/n{n}", t_d,
                 f"speedup_sparse={t_d / max(t_sp, 1e-9):.1f}x "
                 f"speedup_frontier={t_d / max(t_fr, 1e-9):.1f}x")
            rows.append((n, t_d, t_sp, t_fr))
        else:
            emit(f"sparse_scaling/dense/n{n}", float("nan"),
                 "skipped: n^2 adjacency unallocatable")
            rows.append((n, None, t_sp, t_fr))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="512,2048",
                    help="comma-separated sizes for the dense-vs-sparse "
                         "agreement points")
    ap.add_argument("--big", type=int, default=50_000,
                    help="sparse-only size (dense cannot allocate)")
    ap.add_argument("--dense-limit", type=int, default=8192)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(",") if s)
    run(sizes=sizes, big=args.big, dense_limit=args.dense_limit,
        seed=args.seed)


if __name__ == "__main__":
    main()
