"""Serving throughput and tail latency → ``BENCH_serve.json``.

Two sections:

**Closed-loop** (the original ISSUE 2 acceptance): single-source
reachability (the FGH-optimized BM program) on a power-law graph, served
at increasing batch sizes B by a Python loop of single-source jitted GSN
fixpoints (``loop``) vs the packed-FIFO serve loop (``batched``,
`launch.datalog_serve`).  At B=64 on 50k vertices the batched path must
reach ≥ 5× the loop's queries/sec; at B=1 the latency route must keep
the server at least at loop parity (it was 0.81× before ISSUE 6).

**Open-loop** (the ISSUE 6 acceptance): a Poisson arrival stream of
mixed traffic — 50 % boolean reachability, 50 % integer-weighted SSSP —
offered at well above either server's capacity, served by the packed
FIFO server and by the continuous-batching scheduler
(`repro.serve.ContinuousServer`) at equal ``max_batch``.  Reports
sustained qps and p50/p95/p99 end-to-end latency for each server; the
continuous scheduler must clear ≥ 5× the FIFO qps, with every answer
identical across the two servers (and spot-checked against single-source
fixpoints).

Usage:
  PYTHONPATH=src python -m benchmarks.serve_batch
  PYTHONPATH=src python -m benchmarks.serve_batch --n 2000 --requests 64
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import engine
from repro.datalog import datasets, programs
from repro.launch.datalog_serve import DatalogServer
from repro.serve import ContinuousServer
from repro.sparse import sparse_seminaive_fixpoint


def _one_hot(n: int, s: int) -> np.ndarray:
    v = np.zeros(n, bool)
    v[s] = True
    return v


def _trop_init(n: int, s: int) -> np.ndarray:
    v = np.full(n, np.inf, np.float32)
    v[s] = 0.0
    return v


def _mk_bm(a):
    return programs.bm(a=a).optimized


def _mk_sssp(a):
    return programs.sssp(a=a, wmax=4, dmax=64).optimized


def _graphs(n: int, seed: int):
    """The serving pair: one unweighted power-law graph for BM, one
    integer-weighted (1..4) for SSSP."""
    g_bm = datasets.powerlaw(n, 4, seed=seed)
    g0 = datasets.powerlaw(n, 4, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)
    g_ss = datasets.Graph(g0.n, g0.edges,
                          rng.integers(1, 5, len(g0.edges)))
    return g_bm, g_ss


def _dbs(n: int, g_bm, g_ss):
    bm_rel = g_bm.sparse_adjacency().as_jnp()
    db_bm = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                            {"E": bm_rel, "V": jnp.ones((n,), bool)})
    # the schema-level E3 is a dense (n, n, w) tensor that must never be
    # materialized at 50k — register with the COO override instead
    ss_rel = g_ss.sparse_adjacency(semiring="trop").as_jnp()
    db_ss = engine.Database(
        programs.sssp(a=0, wmax=4, dmax=64).original.schema,
        {"id": n, "w": 4, "d": 64}, {})
    return bm_rel, db_bm, ss_rel, db_ss


# --------------------------------------------------------------------------
# closed loop: loop vs packed batches (the original BENCH_serve rows)
# --------------------------------------------------------------------------


def run_closed_loop(n, batch_sizes, seed, check):
    g_bm, _ = _graphs(n, seed)
    rel = g_bm.sparse_adjacency().as_jnp()
    db = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                         {"E": rel, "V": jnp.ones((n,), bool)})
    # warm answers off: this section measures *cold* compute throughput
    # (the warm path is benchmarks/incremental_update.py's subject)
    server = DatalogServer(max_batch=max(batch_sizes), warm_answers=0)
    server.register("reach", _mk_bm, db)

    single = jax.jit(lambda e, i: sparse_seminaive_fixpoint(
        e, i, mode="jit"))
    jax.block_until_ready(single(rel, jnp.asarray(_one_hot(n, 0)))[0])

    rng = np.random.default_rng(seed)
    rows = []
    agreement = True
    for b in batch_sizes:
        sources = [int(s) for s in rng.integers(0, n, b)]

        # per-source loop (the jit is already warm: every call shares
        # the single (n,) input shape)
        t0 = time.perf_counter()
        loop_out = []
        for s in sources:
            y, _ = single(rel, jnp.asarray(_one_hot(n, s)))
            loop_out.append(np.asarray(y))
        t_loop = time.perf_counter() - t0
        qps_loop = b / t_loop

        # serve loop (warm the compile cache / frontier index, then
        # timed)
        for timed in (False, True):
            reqs = [server.submit("reach", s) for s in sources]
            t0 = time.perf_counter()
            server.run_until_idle()
            t_batch = time.perf_counter() - t0
        qps_batch = b / t_batch

        if check:
            for req, y in zip(reqs, loop_out):
                if not np.array_equal(req.result, y):
                    agreement = False
        speedup = qps_batch / qps_loop
        rows.append({"B": b, "qps_batched": qps_batch,
                     "qps_loop": qps_loop, "s_batched": t_batch,
                     "s_loop": t_loop, "speedup": speedup})
        emit(f"serve_batch/B{b}", t_batch,
             f"qps_batched={qps_batch:.1f} qps_loop={qps_loop:.1f} "
             f"speedup={speedup:.1f}x")
    return rows, agreement, server.stats


# --------------------------------------------------------------------------
# open loop: Poisson mixed traffic, FIFO vs continuous
# --------------------------------------------------------------------------


def _drive_open_loop(server, schedule, n):
    """Replay a Poisson arrival schedule against a server: requests are
    submitted when their arrival time passes (never early), the server
    steps whenever it has work.  Returns (requests, duration,
    latencies) — latency is measured from *intended arrival*, so time a
    request spends waiting behind a busy server counts against it."""
    t0 = time.perf_counter()
    out = [None] * len(schedule)
    i = 0
    while i < len(schedule) or server.pending():
        now = time.perf_counter() - t0
        while i < len(schedule) and schedule[i][0] <= now:
            _, fam, src = schedule[i]
            out[i] = server.submit(fam, src)
            i += 1
        if server.pending():
            server.step()
        elif i < len(schedule):
            time.sleep(min(schedule[i][0] - now, 1e-3))
    server.run_until_idle()
    duration = time.perf_counter() - t0
    lat = np.array([r.done_s - (t0 + arr)
                    for r, (arr, _, _) in zip(out, schedule)])
    return out, duration, lat


def _pctiles(lat):
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def run_open_loop(n, n_requests, offered_qps, max_batch, seed, check):
    g_bm, g_ss = _graphs(n, seed)
    bm_rel, db_bm, ss_rel, db_ss = _dbs(n, g_bm, g_ss)

    rng = np.random.default_rng(seed + 3)
    # exactly half/half so the FIFO baseline packs only full batches in
    # steady state (its best case), in a random interleaving
    fams = list(rng.permutation(["reach"] * (n_requests // 2)
                                + ["sssp"] * (n_requests // 2)))
    arrivals = np.cumsum(rng.exponential(1.0 / offered_qps, n_requests))
    schedule = [(float(t), str(fam), int(rng.integers(0, n)))
                for t, fam in zip(arrivals, fams)]

    def build(server):
        server.register("reach", _mk_bm, db_bm)
        server.register("sssp", _mk_sssp, db_ss, edges=ss_rel)
        # warm every B-bucket the stream can hit, so neither server
        # pays XLA compiles inside the timed window
        warm_rng = np.random.default_rng(seed + 4)
        for fam in ("reach", "sssp"):
            for b in (1, 2, 4, 8, 16, 32, 64):
                if b > max_batch:
                    continue
                for s in warm_rng.integers(0, n, b):
                    server.submit(fam, int(s))
                server.run_until_idle()
        return server

    fifo = build(DatalogServer(max_batch=max_batch, warm_answers=0))
    cont = build(ContinuousServer(max_batch=max_batch, warm_answers=0,
                                  queue_limit=max(4 * n_requests, 1024)))

    f_reqs, f_dur, f_lat = _drive_open_loop(fifo, schedule, n)
    c_reqs, c_dur, c_lat = _drive_open_loop(cont, schedule, n)

    agreement = True
    if check:
        for rf, rc in zip(f_reqs, c_reqs):
            if rf.error or rc.error or not np.array_equal(
                    np.asarray(rf.result), np.asarray(rc.result)):
                agreement = False
        # spot-check a few against plain single-source fixpoints
        for idx in np.random.default_rng(seed + 5).integers(
                0, n_requests, 6):
            r = c_reqs[idx]
            if r.family == "reach":
                y, _ = sparse_seminaive_fixpoint(
                    bm_rel, jnp.asarray(_one_hot(n, r.source)),
                    mode="jit")
            else:
                y, _ = sparse_seminaive_fixpoint(
                    ss_rel, jnp.asarray(_trop_init(n, r.source)),
                    mode="jit")
            if not np.array_equal(np.asarray(r.result), np.asarray(y)):
                agreement = False

    result = {
        "n": n, "requests": n_requests, "offered_qps": offered_qps,
        "max_batch": max_batch, "mix": "50% BM bool / 50% SSSP trop",
        "fifo": {"qps": n_requests / f_dur, "duration_s": f_dur,
                 **_pctiles(f_lat)},
        "continuous": {"qps": n_requests / c_dur, "duration_s": c_dur,
                       **_pctiles(c_lat)},
        "speedup": f_dur / c_dur,
        "continuous_stats": {
            k: v for k, v in cont.stats().items()
            if not isinstance(v, dict)},
    }
    emit("serve_batch/open_loop", c_dur,
         f"continuous={result['continuous']['qps']:.1f}qps "
         f"p99={result['continuous']['p99_ms']:.0f}ms  "
         f"fifo={result['fifo']['qps']:.1f}qps "
         f"p99={result['fifo']['p99_ms']:.0f}ms  "
         f"speedup={result['speedup']:.1f}x")
    return result, agreement


def run(n: int = 50_000, batch_sizes=(1, 8, 64), seed: int = 1,
        out: str = "BENCH_serve.json", check: bool = True,
        n_requests: int = 512, offered_qps: float = 2000.0):
    rows, agree_closed, fifo_stats = run_closed_loop(
        n, batch_sizes, seed, check)
    open_loop, agree_open = run_open_loop(
        n, n_requests, offered_qps, max(batch_sizes), seed, check)

    agreement = agree_closed and agree_open
    result = {"bench": "serve_batch", "family": "BM", "n": n,
              "seed": seed, "max_batch": max(batch_sizes),
              "agreement": agreement, "rows": rows,
              "open_loop": open_loop, "server_stats": fifo_stats}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")
    assert agreement, "served answers diverged from single-source runs"
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--batches", default="1,8,64",
                    help="comma-separated batch sizes")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--requests", type=int, default=512,
                    help="open-loop request count (even)")
    ap.add_argument("--qps", type=float, default=2000.0,
                    help="open-loop offered load")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.batches.split(",") if s)
    run(n=args.n, batch_sizes=sizes, seed=args.seed, out=args.out,
        check=not args.no_check, n_requests=args.requests,
        offered_qps=args.qps)


if __name__ == "__main__":
    main()
