"""Batched multi-source serving throughput → ``BENCH_serve.json``.

Single-source reachability (the FGH-optimized BM program) served from a
power-law graph two ways, at increasing batch sizes B:

* ``loop``    — the pre-PR-2 shape: a Python loop of B single-source
  jitted GSN fixpoints (each O(nnz)/iteration SpMV);
* ``batched`` — the serve loop (`launch.datalog_serve`): pack B sources
  into one (B, n) frontier, advance them in a single ``lax.while_loop``
  whose step is one SpMM, answer all B at once.

Both paths are warmed (compile cache populated) before timing, and every
batched answer is checked for exact agreement against its single-source
run.  The acceptance line (ISSUE 2): at B=64 on a 50k-vertex power-law
graph the batched path must reach ≥ 5× the loop's queries/sec.

Usage:
  PYTHONPATH=src python -m benchmarks.serve_batch
  PYTHONPATH=src python -m benchmarks.serve_batch --n 2000 --batches 1,8
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import engine
from repro.datalog import datasets, programs
from repro.launch.datalog_serve import DatalogServer
from repro.sparse import sparse_seminaive_fixpoint


def _one_hot(n: int, s: int) -> np.ndarray:
    v = np.zeros(n, bool)
    v[s] = True
    return v


def run(n: int = 50_000, batch_sizes=(1, 8, 64), seed: int = 1,
        out: str = "BENCH_serve.json", check: bool = True):
    g = datasets.powerlaw(n, 4, seed=seed)
    rel = g.sparse_adjacency().as_jnp()
    b0 = programs.bm(a=0)
    db = engine.Database(b0.original.schema, {"id": n},
                         {"E": rel, "V": jnp.ones((n,), bool)})

    # warm answers off: this benchmark measures *cold* compute throughput
    # (the warm path is benchmarks/incremental_update.py's subject)
    server = DatalogServer(max_batch=max(batch_sizes), warm_answers=0)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)

    single = jax.jit(lambda e, i: sparse_seminaive_fixpoint(
        e, i, mode="jit"))
    jax.block_until_ready(single(rel, jnp.asarray(_one_hot(n, 0)))[0])

    rng = np.random.default_rng(seed)
    rows = []
    agreement = True
    for b in batch_sizes:
        sources = [int(s) for s in rng.integers(0, n, b)]

        # per-source loop (the jit is already warm: every call shares the
        # single (n,) input shape)
        t0 = time.perf_counter()
        loop_out = []
        for s in sources:
            y, _ = single(rel, jnp.asarray(_one_hot(n, s)))
            loop_out.append(np.asarray(y))
        t_loop = time.perf_counter() - t0
        qps_loop = b / t_loop

        # serve loop (warm the compile cache, then timed)
        for timed in (False, True):
            reqs = [server.submit("reach", s) for s in sources]
            t0 = time.perf_counter()
            server.run_until_idle()
            t_batch = time.perf_counter() - t0
        qps_batch = b / t_batch

        if check:
            for req, y in zip(reqs, loop_out):
                if not np.array_equal(req.result, y):
                    agreement = False
        speedup = qps_batch / qps_loop
        rows.append({"B": b, "qps_batched": qps_batch,
                     "qps_loop": qps_loop, "s_batched": t_batch,
                     "s_loop": t_loop, "speedup": speedup})
        emit(f"serve_batch/B{b}", t_batch,
             f"qps_batched={qps_batch:.1f} qps_loop={qps_loop:.1f} "
             f"speedup={speedup:.1f}x")

    result = {"bench": "serve_batch", "family": "BM", "n": n,
              "nnz": int(np.asarray(rel.nnz)), "seed": seed,
              "max_batch": max(batch_sizes), "agreement": agreement,
              "rows": rows, "server_stats": server.stats}
    if out:
        pathlib.Path(out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {out}")
    assert agreement, "batched answers diverged from single-source runs"
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--batches", default="1,8,64",
                    help="comma-separated batch sizes")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.batches.split(",") if s)
    run(n=args.n, batch_sizes=sizes, seed=args.seed, out=args.out,
        check=not args.no_check)


if __name__ == "__main__":
    main()
