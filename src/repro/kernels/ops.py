"""Public jit'd wrappers over the Pallas kernels with platform dispatch.

On TPU the Pallas kernels run compiled; elsewhere (this CPU container) the
``ref.py`` oracles execute.  ``force_pallas_interpret()`` lets tests route
through the kernels in interpret mode regardless of platform; setting the
``REPRO_PALLAS_INTERPRET`` environment variable does the same for whole
processes (the CI kernel-parity step and ``make bench-kernel``).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.semiring_matmul import semiring_matmul_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

_FORCE_INTERPRET = bool(os.environ.get("REPRO_PALLAS_INTERPRET"))


def force_pallas_interpret(on: bool = True) -> None:
    """Route ops through the Pallas kernels in interpret mode (tests)."""
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = on


def _use_pallas() -> bool:
    return _FORCE_INTERPRET or jax.default_backend() == "tpu"


def semiring_matmul(sr, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A ⊕.⊗ B over semiring ``sr`` (2-D a, b)."""
    if _use_pallas():
        return semiring_matmul_pallas(a, b, sr_name=sr.name,
                                      interpret=_FORCE_INTERPRET)
    return ref.semiring_matmul_ref(sr, a, b)


def semiring_segment_reduce(sr, vals: jnp.ndarray,
                            segment_ids: jnp.ndarray,
                            num_segments: int) -> jnp.ndarray:
    """``out[s] = ⊕ vals[i]`` over ``segment_ids[i] = s`` (sparse scatter).

    ``vals`` may carry trailing payload axes (batched SpMM rows); the
    Pallas kernel currently handles scalar payloads only, so payload
    shapes route through the jnp reference on every platform.
    """
    if _use_pallas() and vals.ndim == 1:
        from repro.kernels.coo_segment import segment_reduce_pallas
        return segment_reduce_pallas(vals, segment_ids, num_segments,
                                     sr_name=sr.name,
                                     interpret=_FORCE_INTERPRET)
    return ref.segment_reduce_ref(sr, vals, segment_ids, num_segments)


def coo_spmm(rel, x, *, transpose: bool = False):
    """Fused batched COO semiring SpMM with platform dispatch.

    On TPU (or under interpret forcing) the fused Pallas kernel runs;
    elsewhere the host-numpy fused executor does — both via the cached
    geometry of :mod:`repro.kernels.coo_spmm`.  Needs a concrete
    operator; traceable callers use ``sparse.contract.spmm`` directly.
    """
    from repro.kernels import coo_spmm as fused
    plan = fused.plan_geometry(rel, transpose=transpose)
    if _use_pallas():
        return fused.spmm_pallas(plan, x, interpret=_FORCE_INTERPRET)
    return fused.spmm_host(plan, x)


def flash_attention(q, k, v, *, causal=True, window=None, chunk=None,
                    q_offset=0):
    """GQA flash attention (forward); see ref.attention_ref for semantics."""
    if _use_pallas():
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      chunk=chunk, q_offset=q_offset,
                                      interpret=_FORCE_INTERPRET)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             chunk=chunk, q_offset=q_offset)


#: XLA-path scan lowering: "assoc" (full-length associative scan) or
#: "chunked" (blocked GH-form; §Perf hillclimb)
SCAN_IMPL = "assoc"


def set_scan_impl(impl: str):
    global SCAN_IMPL
    assert impl in ("assoc", "chunked")
    SCAN_IMPL = impl


def ssm_scan(a, b):
    """Diagonal linear recurrence h_t = a_t ⊙ h_{t-1} + b_t over axis 1."""
    if _use_pallas():
        t = a.shape[1]
        bt = 256 if t % 256 == 0 else _largest_pow2_divisor(t)
        return ssm_scan_pallas(a, b, bt=bt, interpret=_FORCE_INTERPRET)
    if SCAN_IMPL == "chunked":
        return ref.ssm_scan_chunked(a, b)
    return ref.ssm_scan_ref(a, b)


def _largest_pow2_divisor(t: int, cap: int = 256) -> int:
    d = 1
    while t % (d * 2) == 0 and d * 2 <= cap:
        d *= 2
    return d
