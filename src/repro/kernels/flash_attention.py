"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax tiling: grid (batch, q_heads, q_blocks, kv_blocks) with the
KV axis innermost; running max/denominator/accumulator live in VMEM scratch
that persists across the sequential KV grid steps (TPU grids execute in
order — the same accumulate-in-VMEM pattern as the semiring matmul).

Supports GQA (kv head = q head // group, folded into the BlockSpec index
map), causal masking, sliding windows (StarCoder2) and chunked attention
(Llama 4) via position masks, and ``q_offset`` for decode.

Oracle: ``repro.kernels.ref.attention_ref``.  Training uses the XLA path
(`repro.models.attention`) — this kernel is the serving/prefill fast path on
TPU and is validated in interpret mode here (CPU container).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BKV = 256
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  chunk: int | None, q_offset: int, bq: int, bkv: int,
                  kv_steps: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bkv, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + q_offset
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if chunk is not None:
        mask &= (kpos // chunk) == (qpos // chunk)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom)[None, None].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunk", "q_offset", "bq", "bkv",
                     "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, chunk=None,
                           q_offset=0, bq=DEFAULT_BQ, bkv=DEFAULT_BKV,
                           interpret=False):
    """q: (B, Tq, Hq, D); k/v: (B, Tk, Hkv, D) -> (B, Tq, Hq, D)."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    group = hq // hkv
    bq = min(bq, tq)
    bkv = min(bkv, tk)
    assert tq % bq == 0 and tk % bkv == 0, (tq, bq, tk, bkv)
    scale = 1.0 / np.sqrt(d)

    qt = q.transpose(0, 2, 1, 3)  # (B, Hq, Tq, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, Tk, D)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, hq, tq // bq, tk // bkv)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, chunk=chunk, q_offset=q_offset,
                          bq=bq, bkv=bkv, kv_steps=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
