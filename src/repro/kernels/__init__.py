"""Custom compute kernels: Pallas TPU lowerings + fused host executors.

Every kernel has a jnp/np oracle in ``ref.py`` and platform dispatch in
``ops.py`` (TPU → compiled Pallas, elsewhere → oracle, with
``REPRO_PALLAS_INTERPRET`` / :func:`force_pallas_interpret` routing
through the kernels in interpret mode for CI parity).

* ``coo_spmm.py`` — fused batched COO semiring SpMM (DESIGN.md §9):
  gather → ⊗ → segment-⊕ in one pass over edge tiles.  The serving hot
  loop's ``d ⊗ E`` advance; planned as the ``sparse_frontier_pallas``
  runner and priced by ``planner.SpmmKernelModel``.
* ``semiring_matmul.py`` — dense blocked ⊕.⊗ contraction (engine's
  trop/maxplus matmuls route here via ``ops.semiring_matmul``).
* ``coo_segment.py`` — scalar segment-⊕ scatter (sparse contraction's
  reduce step via ``ops.semiring_segment_reduce``).
* ``ssm_scan.py`` — associative state-space scan; live through
  ``models/ssm.py``.
* ``flash_attention.py`` — GQA flash-attention forward.  Seed-era: no
  in-repo consumer beyond its ``ops.flash_attention`` wrapper and the
  ``test_kernels.py`` parity sweep; kept for the model substrate, not
  the datalog path.
"""

from repro.kernels.coo_spmm import (SpmmPlan, bool_round_packed,
                                    pack_lanes, plan_geometry, spmm_host,
                                    spmm_pallas, unpack_lanes)
from repro.kernels.ops import force_pallas_interpret

__all__ = [
    "SpmmPlan",
    "bool_round_packed",
    "force_pallas_interpret",
    "pack_lanes",
    "plan_geometry",
    "spmm_host",
    "spmm_pallas",
    "unpack_lanes",
]
