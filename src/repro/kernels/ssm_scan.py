"""Blocked linear-recurrence (SSM) scan as a Pallas TPU kernel.

The diagonal recurrence ``h_t = a_t ⊙ h_{t-1} + b_t`` is an FG-program
(DESIGN.md §Arch-applicability): F is the per-token state update, G the
readout.  The FGH-rewritten GH-form used here is the *blocked associative
scan*: within a time block the (a, b) pairs are combined with the
associative monoid ``(a₁,b₁)∘(a₂,b₂) = (a₁a₂, a₂b₁+b₂)`` (O(log T) depth),
and the cross-block carry rides in VMEM scratch across the sequential grid
steps along the time axis — turning an O(T)-depth loop into O(T/bt) grid
steps of O(log bt) depth.

Used by the xLSTM (mLSTM state decay) and Mamba2/Zamba2 blocks
(`repro.models.ssm`).  Oracle: ``repro.kernels.ref.ssm_scan_ref`` (and the
literal sequential loop, ``ssm_scan_sequential``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 256


def _scan_kernel(a_ref, b_ref, h_ref, carry_scr, *, bt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0]  # (bt, d)
    b = b_ref[0]

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=0)
    h = bv + av * carry_scr[...]  # inject cross-block carry
    h_ref[...] = h[None].astype(h_ref.dtype)
    carry_scr[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def ssm_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, *, bt: int = DEFAULT_BT,
                    interpret: bool = False) -> jnp.ndarray:
    """a, b: (B, T, D) -> h: (B, T, D) with h_t = a_t*h_{t-1} + b_t."""
    bsz, t, d = a.shape
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    grid = (bsz, t // bt)
    return pl.pallas_call(
        functools.partial(_scan_kernel, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((1, bt, d), lambda i, ti: (i, ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda i, ti: (i, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(a, b)
