"""Fused batched COO semiring SpMM: gather → ⊗ → segment-⊕ in one pass.

The serving hot loop is ``d' = d ⊗ E`` — a batched semiring SpMM inside
``lax.while_loop`` (DESIGN.md §3).  Composed from generic jnp ops it
makes three memory passes per iteration (gather rows, multiply, scatter
rows); this module fuses them into a single sweep over *edge tiles*, in
two executions sharing one host-planned geometry:

* **Pallas TPU kernel** (:func:`spmm_pallas`) — the scalar-prefetch
  block-mapping pattern of ``kernels/coo_segment.py`` extended to a
  second sparse axis: edges are bucketed by (output block, gather block)
  so each grid step touches one ``(bs, B)`` x-tile and one ``(bn, B)``
  output tile, both resident in VMEM.  ⊕/⊗ bodies are specialized per
  semiring: bool/nat/real lower gather and scatter to one-hot f32
  matmuls on the MXU (bool is or-counted and thresholded on exit);
  trop/maxplus use masked select + min/max reduces on the VPU.
* **Host fused executor** (:func:`spmm_host`, :func:`bool_round_packed`)
  — the CPU serving backend.  For 𝔹 the B query lanes are bit-packed
  into uint64 words (PR 7's payload layout) and one round is a single
  ``np.bitwise_or.reduceat`` over dst-sorted edges: ~64× fewer bytes
  than the (nnz, B) boolean gather/scatter, measured 27× per-iteration
  at the 50k-vertex serve shape (BENCH_kernels.json).  Other semirings
  get a generic dst-sorted ``ufunc.reduceat`` fallback.

Geometry (:func:`plan_geometry`) is host-built from the *concrete*
operator and weakref-cached per (coords, values, transpose) — the same
discipline as the frontier fixpoint's CSR cache.  It is deliberately not
traceable: the chunk capacity depends on the edge distribution, so the
fused backends require a concrete operator (callers under jit close over
it; see ``planner.compile_batched``).

Oracle: ``sparse/contract.py``'s jnp path; parity is tested in interpret
mode across semirings, ragged nnz tails, batching, and transpose.
"""

from __future__ import annotations

import dataclasses
import functools
import weakref

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring as sr_mod

#: ⊕-identity used for pad slots and tile init (f32 compute).
_PAD = {"bool": 0.0, "nat": 0.0, "real": 0.0,
        "trop": float("inf"), "maxplus": float("-inf")}

#: semirings whose ⊕/⊗ lower to (+, ×) on one-hot f32 operands — these
#: run gather and scatter as MXU matmuls; the rest take the VPU
#: select-reduce body (min/max has no matmul form).
_DOT = ("bool", "nat", "real")

#: (bk edges/chunk, bs gather rows, bn output rows).  The dot family
#: amortizes one-hot matmuls over big tiles; the select-reduce family
#: materializes (bk, bs, B) masks so its tiles stay small.
_BLOCKS = {"dot": (256, 256, 128), "minmax": (32, 32, 32)}


def _family(sr_name: str) -> str:
    return "dot" if sr_name in _DOT else "minmax"


@dataclasses.dataclass
class SpmmPlan:
    """Host-planned geometry for one (operator, transpose) orientation.

    The dst-sorted arrays serve the host executors directly; the Pallas
    chunk tiles are built lazily on first kernel use.  ``jit_cache``
    holds per-plan compiled closures (fixpoint/chunk runners) so serving
    families re-enter compiled code across calls.
    """

    sr_name: str
    n_in: int
    n_out: int
    transpose: bool
    nnz: int
    src: np.ndarray    # (nnz,) gather index per edge, dst-sorted
    dst: np.ndarray    # (nnz,) output index per edge, sorted
    udst: np.ndarray   # unique output indices
    seg: np.ndarray    # reduceat segment starts into src/dst
    w: np.ndarray      # (nnz,) edge values, semiring dtype
    bk: int
    bs: int
    bn: int
    chunks: tuple | None = None
    jit_cache: dict = dataclasses.field(default_factory=dict)


_PLANS: dict[tuple[int, int, bool], tuple[object, object, SpmmPlan]] = {}


def plan_geometry(rel, *, transpose: bool = False) -> SpmmPlan:
    """The (cached) fused-SpMM geometry of a binary sparse relation."""
    if isinstance(rel.coords, jax.core.Tracer) or \
            isinstance(rel.values, jax.core.Tracer):
        raise ValueError(
            "fused SpMM needs a concrete operator (its edge-tile geometry "
            "is host-built); keep backend='jnp' under tracing or close "
            "over the operator as a constant")
    key = (id(rel.coords), id(rel.values), bool(transpose))
    ent = _PLANS.get(key)
    if ent is not None and ent[0]() is rel.coords \
            and ent[1]() is rel.values:
        return ent[2]
    plan = _build_plan(rel, transpose)

    def _evict(ref, k=key):
        cur = _PLANS.get(k)
        if cur is not None and ref in (cur[0], cur[1]):
            _PLANS.pop(k, None)

    try:
        _PLANS[key] = (weakref.ref(rel.coords, _evict),
                       weakref.ref(rel.values, _evict), plan)
    except TypeError:  # pragma: no cover — all our buffers are weakrefable
        pass
    return plan


def _build_plan(rel, transpose: bool) -> SpmmPlan:
    h = rel.as_np()
    k = int(h.nnz)
    ci, co = (0, 1) if transpose else (1, 0)
    gidx = np.asarray(h.coords[:k, ci], np.int64)
    oidx = np.asarray(h.coords[:k, co], np.int64)
    vals = np.asarray(h.values[:k])
    order = np.argsort(oidx, kind="stable")
    src, dst, w = gidx[order], oidx[order], vals[order]
    if k:
        udst, seg = np.unique(dst, return_index=True)
    else:
        udst, seg = np.zeros(0, np.int64), np.zeros(0, np.int64)
    bk, bs, bn = _BLOCKS[_family(rel.semiring)]
    return SpmmPlan(rel.semiring, int(h.shape[ci]), int(h.shape[co]),
                    transpose, k, src, dst, udst, seg, w, bk, bs, bn)


# ---------------------------------------------------------------------------
# Pallas kernel


def _chunk_geometry(plan: SpmmPlan) -> tuple:
    if plan.chunks is None:
        plan.chunks = _build_chunks(plan)
    return plan.chunks


def _build_chunks(plan: SpmmPlan) -> tuple:
    """Pack edges into (bk,) chunk rows bucketed by (out block, src block).

    Chunks never straddle a bucket, so each grid step reads exactly one
    x-tile and accumulates into exactly one output tile; buckets are
    out-block-major, so every output tile's chunks are consecutive in
    grid order (the Pallas revisit-accumulate contract).  Every output
    block gets at least one chunk — an all-pad one if no edge lands in
    it — so its tile is still initialized to 0̄.
    """
    bk, bs, bn = plan.bk, plan.bs, plan.bn
    nsb = max(1, -(-plan.n_in // bs))
    ndb = max(1, -(-plan.n_out // bn))
    ob = plan.dst // bn
    gb = plan.src // bs
    order = np.lexsort((gb, ob))
    g_s, o_s = plan.src[order], plan.dst[order]
    v_s = np.asarray(plan.w[order], np.float32)
    key = ob[order] * nsb + gb[order]
    ub, bstart, bcnt = np.unique(key, return_index=True, return_counts=True)
    present = np.zeros(ndb, bool)
    if len(ub):
        present[ub // nsb] = True
    missing = np.flatnonzero(~present).astype(np.int64)
    keys = np.concatenate([ub, missing * nsb])
    cnts = np.concatenate([bcnt, np.zeros(len(missing), np.int64)])
    bord = np.argsort(keys, kind="stable")
    keys, cnts = keys[bord], cnts[bord]
    rank = np.empty(len(bord), np.int64)
    rank[bord] = np.arange(len(bord))
    erank = rank[:len(ub)]                        # ub position → bucket rank
    nchunks = np.maximum(1, -(-cnts // bk))
    cstart = np.concatenate([[0], np.cumsum(nchunks)[:-1]]).astype(np.int64)
    c_total = int(cstart[-1] + nchunks[-1])
    dblk = np.repeat(keys // nsb, nchunks).astype(np.int32)
    sblk = np.repeat(keys % nsb, nchunks).astype(np.int32)
    first = np.ones(c_total, np.int32)
    first[1:] = (dblk[1:] != dblk[:-1]).astype(np.int32)
    # pad slots: loc = block size ⇒ one-hot all-miss on both axes, value
    # = ⊕-identity — they contribute nothing on either kernel body
    locs = np.full((c_total, bk), bs, np.int32)
    locd = np.full((c_total, bk), bn, np.int32)
    vbuf = np.full((c_total, bk), _PAD[plan.sr_name], np.float32)
    if plan.nnz:
        b_of = np.searchsorted(bstart, np.arange(plan.nnz),
                               side="right") - 1
        pos = np.arange(plan.nnz) - bstart[b_of]
        chunk = cstart[erank[b_of]] + pos // bk
        slot = pos % bk
        locs[chunk, slot] = (g_s % bs).astype(np.int32)
        locd[chunk, slot] = (o_s % bn).astype(np.int32)
        vbuf[chunk, slot] = v_s
    # plain numpy on purpose: geometry may be first materialized under an
    # outer trace (the per-operator jitted fixpoints), where jnp.asarray
    # would yield leakable tracers — as np buffers they enter jit as
    # ordinary constants/arguments instead
    return sblk, dblk, first, locs, locd, vbuf, nsb, ndb


def _spmm_kernel(sblk_ref, dblk_ref, first_ref, locs_ref, locd_ref,
                 vals_ref, x_ref, o_ref, *, mode: str, bk: int, bs: int,
                 bn: int):
    c = pl.program_id(0)
    init = _PAD[mode]

    @pl.when(first_ref[c] == 1)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    locs = locs_ref[0, :]                                 # (bk,) int32
    locd = locd_ref[0, :]                                 # (bk,) int32
    w = vals_ref[0, :]                                    # (bk,) f32
    x = x_ref[...]                                        # (bs, bp) f32
    if mode in _DOT:
        # gather and scatter as one-hot matmuls: g = 1[src] · x on the
        # way in, out += 1[dst]ᵀ · (w ⊙ g) on the way out.  Exact for 𝔹
        # (or-counts thresholded on exit) and small-int ℕ — same f32
        # compute contract as the jnp path.
        src_oh = (locs[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (bk, bs), 1)
                  ).astype(jnp.float32)                   # (bk, bs)
        dst_oh = (jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 0) ==
                  locd[None, :]).astype(jnp.float32)      # (bn, bk)
        g = jnp.dot(src_oh, x, preferred_element_type=jnp.float32)
        p = w[:, None] * g                                # (bk, bp)
        o_ref[...] += jnp.dot(dst_oh, p,
                              preferred_element_type=jnp.float32)
    else:
        red, comb = (jnp.min, jnp.minimum) if mode == "trop" else \
            (jnp.max, jnp.maximum)
        src_oh = locs[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (bk, bs), 1)                       # (bk, bs)
        g = red(jnp.where(src_oh[:, :, None], x[None, :, :], init),
                axis=1)                                   # (bk, bp)
        p = w[:, None] + g                                # ⊗ is +
        dst_oh = locd[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (bk, bn), 1)                       # (bk, bn)
        contrib = red(jnp.where(dst_oh[:, :, None], p[:, None, :], init),
                      axis=0)                             # (bn, bp)
        o_ref[...] = comb(o_ref[...], contrib)


@functools.partial(jax.jit,
                   static_argnames=("sr_name", "bk", "bs", "bn", "ndb",
                                    "interpret"))
def _spmm_pallas_call(sblk, dblk, first, locs, locd, vals, xp, *,
                      sr_name: str, bk: int, bs: int, bn: int, ndb: int,
                      interpret: bool):
    c_total, bp = locs.shape[0], xp.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(c_total,),
        in_specs=[
            pl.BlockSpec((1, bk), lambda c, sb, db, fi: (c, 0)),
            pl.BlockSpec((1, bk), lambda c, sb, db, fi: (c, 0)),
            pl.BlockSpec((1, bk), lambda c, sb, db, fi: (c, 0)),
            pl.BlockSpec((bs, bp), lambda c, sb, db, fi: (sb[c], 0)),
        ],
        out_specs=pl.BlockSpec((bn, bp),
                               lambda c, sb, db, fi: (db[c], 0)),
    )
    return pl.pallas_call(
        functools.partial(_spmm_kernel, mode=sr_name, bk=bk, bs=bs, bn=bn),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ndb * bn, bp), jnp.float32),
        interpret=interpret,
    )(sblk, dblk, first, locs, locd, vals, xp)


def spmm_pallas(plan: SpmmPlan, x, *, interpret: bool = False):
    """Fused SpMM via the Pallas kernel: x (n_in, B) or (n_in,) → dense.

    Compute runs in f32 with B padded to the 128-lane register width;
    boolean results are thresholded back on exit, matching the jnp
    oracle bit-for-bit.
    """
    sr = sr_mod.get(plan.sr_name)
    sblk, dblk, first, locs, locd, vals, nsb, ndb = _chunk_geometry(plan)
    x = jnp.asarray(x)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    assert x.shape[0] == plan.n_in, (x.shape, plan.n_in)
    b = x.shape[1]
    bp = max(128, -(-b // 128) * 128)
    xp = jnp.zeros((nsb * plan.bs, bp), jnp.float32)
    xp = xp.at[:plan.n_in, :b].set(x.astype(jnp.float32))
    out = _spmm_pallas_call(sblk, dblk, first, locs, locd, vals, xp,
                            sr_name=plan.sr_name, bk=plan.bk, bs=plan.bs,
                            bn=plan.bn, ndb=ndb, interpret=interpret)
    out = out[:plan.n_out, :b]
    out = out > 0.5 if plan.sr_name == "bool" else out.astype(sr.dtype)
    return out[:, 0] if squeeze else out


# ---------------------------------------------------------------------------
# Host fused executors (the CPU serving backend)


def pack_lanes(x) -> np.ndarray:
    """(B, n) bool → (n, W) uint64 words: lane b lives in bit b (LE)."""
    x = np.ascontiguousarray(np.asarray(x, bool).T)       # (n, B)
    n, b = x.shape
    w = max(1, -(-b // 64))
    bits = np.packbits(x, axis=1, bitorder="little")      # (n, ceil(b/8))
    buf = np.zeros((n, w * 8), np.uint8)
    buf[:, :bits.shape[1]] = bits
    return buf.view(np.uint64)


def unpack_lanes(words: np.ndarray, b: int) -> np.ndarray:
    """(n, W) uint64 → (B, n) bool — inverse of :func:`pack_lanes`."""
    bits = np.unpackbits(words.view(np.uint8), axis=1, bitorder="little")
    return np.ascontiguousarray(bits[:, :b].T).astype(bool)


def bool_round_packed(plan: SpmmPlan, words: np.ndarray) -> np.ndarray:
    """One fused 𝔹 round over packed lanes: (n_in, W) → (n_out, W).

    All live bool edges carry ⊤ (``from_coo`` drops 0̄), so the round is
    pure gather + or-reduce — a single ``bitwise_or.reduceat`` sweep
    over dst-sorted edges, 64 query lanes per word.
    """
    out = np.zeros((plan.n_out, words.shape[1]), np.uint64)
    if plan.nnz:
        out[plan.udst] = np.bitwise_or.reduceat(
            words[plan.src], plan.seg, axis=0)
    return out


def spmm_host(plan: SpmmPlan, x):
    """Host-numpy fused SpMM: gather → ⊗ → ``ufunc.reduceat`` segment-⊕.

    The generic fallback body for non-𝔹 semirings (and the oracle for
    the packed 𝔹 round); one pass over dst-sorted edges, no scatter.
    """
    srn = sr_mod.get(plan.sr_name, lib="np")
    x = np.asarray(x)
    squeeze = x.ndim == 1
    x2 = x[:, None] if squeeze else x
    assert x2.shape[0] == plan.n_in, (x2.shape, plan.n_in)
    out = np.full((plan.n_out, x2.shape[1]), srn.zero, srn.dtype)
    if plan.nnz:
        prod = srn.mul(plan.w[:, None], x2[plan.src])
        out[plan.udst] = sr_mod.NP_COMBINE[plan.sr_name].reduceat(
            prod, plan.seg, axis=0)
    return out[:, 0] if squeeze else out
