"""Blocked semiring matmul as a Pallas TPU kernel.

This is the compute hot-spot of dense Datalog° evaluation (DESIGN.md §2): a
binary-join-and-aggregate rule body is exactly ``C = A ⊕.⊗ B``.  TPU
adaptation of the Datalog hash-join inner loop:

* HBM→VMEM tiling via BlockSpec, (bm, bk) × (bk, bn) tiles, 128-aligned so
  `(∨,∧)`/`(+,×)` hit the MXU (boolean as f32 dot + threshold) and
  `(min,+)`/`(max,+)` vectorize on the 8×128 VPU lanes;
* the K loop is the innermost grid axis; the output tile is revisited and
  accumulated in place (grid iteration on TPU is sequential, so this is the
  canonical accumulate-in-VMEM pattern);
* tropical tiles use a smaller bk so the (bm, bk, bn) broadcast stays in
  VMEM (bm·bk·bn·4B ≤ 2 MiB for the default 128×32×128).

Oracle: ``repro.kernels.ref.semiring_matmul_ref`` — tests sweep shapes and
semirings in interpret mode (CPU container; TPU is the compile target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import semiring as sr_mod

# (bm, bk, bn) per semiring family
_BLOCKS_DOT = (128, 128, 128)
_BLOCKS_TROP = (128, 32, 128)


def _dot_kernel(a_ref, b_ref, o_ref, *, k_steps: int, mode: str):
    """(+,×) and (∨,∧) tiles — MXU path."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    part = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    o_ref[...] = o_ref[...] + part
    # boolean thresholding happens outside (single pass over the output)
    del mode


def _trop_kernel(a_ref, b_ref, o_ref, *, k_steps: int, mode: str):
    """(min,+) / (max,+) tiles — VPU path with in-VMEM broadcast."""
    kk = pl.program_id(2)
    if mode == "trop":
        init, red = jnp.inf, jnp.min
        comb = jnp.minimum
    else:
        init, red = -jnp.inf, jnp.max
        comb = jnp.maximum

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    part = red(a[:, :, None] + b[None, :, :], axis=1)
    o_ref[...] = comb(o_ref[...], part)


def _pad_to(x: jnp.ndarray, m0: int, m1: int, fill) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=fill)
    return x


@functools.partial(jax.jit, static_argnames=("sr_name", "interpret"))
def semiring_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                           sr_name: str, interpret: bool = False) -> jnp.ndarray:
    """C[i,j] = ⊕_k A[i,k] ⊗ B[k,j] via pl.pallas_call."""
    sr = sr_mod.get(sr_name)
    m, k = a.shape
    _, n = b.shape
    dot_path = sr_name in ("bool", "nat", "real")
    bm, bk, bn = _BLOCKS_DOT if dot_path else _BLOCKS_TROP
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    # MXU/VPU want the minor dims 128-aligned; pad up when tiny
    if dot_path:
        a_p = _pad_to(a.astype(jnp.float32), bm, bk, 0.0)
        b_p = _pad_to(b.astype(jnp.float32), bk, bn, 0.0)
        kernel, out_init = _dot_kernel, jnp.float32
    else:
        # pad with ⊗-identity-absorbing values: A rows pad with 0̄ (inf) is
        # wrong for ⊗ (+); pad A with 0̄ on k so padded k never wins the ⊕.
        a_p = _pad_to(a, bm, bk, sr.zero)
        b_p = _pad_to(b, bk, bn, sr.zero)
        kernel, out_init = _trop_kernel, jnp.float32
    mp, kp = a_p.shape
    _, np_ = b_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(kernel, k_steps=grid[2], mode=sr_name),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_init),
        interpret=interpret,
    )(a_p, b_p)
    out = out[:m, :n]
    if sr_name == "bool":
        out = out > 0.5
    return out
