"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

Each function here defines the *semantics* the kernels must match; kernel
tests sweep shapes/dtypes and ``assert_allclose`` against these.  They are
also the CPU execution path (this container is CPU-only; TPU is the target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Semiring matmul
# --------------------------------------------------------------------------


def semiring_matmul_ref(sr, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[i,j] = ⊕_k A[i,k] ⊗ B[k,j] for an arbitrary semiring.

    Fast paths: (∨,∧) and (+,×) use the dot unit; (min,+)/(max,+) use a
    row-chunked broadcast so the materialized intermediate stays bounded.
    """
    name = sr.name
    if name == "bool":
        return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                       preferred_element_type=jnp.float32) > 0.5
    if name in ("nat", "real"):
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    # (min,+) / (max,+): chunk rows to bound the (rows, K, N) intermediate
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    chunk = int(max(1, min(m, (1 << 24) // max(1, k * n))))
    reduce_fn = jnp.min if name == "trop" else jnp.max

    def piece(s):
        blk = jax.lax.dynamic_slice_in_dim(a, s * chunk, chunk, 0)
        return reduce_fn(blk[:, :, None] + b[None, :, :], axis=1)

    if chunk >= m:
        return reduce_fn(a[:, :, None] + b[None, :, :], axis=1)
    npad = (-m) % chunk
    a_p = jnp.pad(a, ((0, npad), (0, 0)), constant_values=sr.zero) if npad else a
    nchunks = (m + npad) // chunk
    out = jax.lax.map(piece, jnp.arange(nchunks))
    return out.reshape(-1, n)[:m]


def segment_reduce_ref(sr, vals: jnp.ndarray, segment_ids: jnp.ndarray,
                       num_segments: int) -> jnp.ndarray:
    """``out[s] = ⊕_{i: ids[i]=s} vals[i]`` with ⊕ from semiring ``sr``.

    The scatter-reduce behind sparse contraction (SpMV destinations).
    Out-of-range ids (the COO padding sentinel) are dropped.  ``vals`` may
    carry trailing payload axes — ``(cap, B)`` rows for batched SpMM — in
    which case each segment row ⊕-combines whole payload slices (the
    scatter window is then a contiguous row, which is what makes the
    batched serving path memory-efficient on every backend).
    """
    from repro.core import semiring as sr_mod
    base = jnp.full((num_segments,) + vals.shape[1:], sr.zero, sr.dtype)
    return sr_mod.scatter_op(sr.name, base.at[segment_ids])(
        vals, mode="drop")


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int | None = None,
                  chunk: int | None = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """Reference GQA attention.

    q: (B, Tq, Hq, D); k/v: (B, Tk, Hkv, D) with Hq % Hkv == 0.
    ``window``: sliding-window size (StarCoder2-style); ``chunk``: chunked
    attention (Llama-4-style, attends within aligned chunks only).
    ``q_offset``: absolute position of q[0] (decode: Tk - Tq).
    """
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(d)
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if chunk is not None:
        mask &= (kpos // chunk) == (qpos // chunk)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vr)


# --------------------------------------------------------------------------
# SSM / linear-recurrence scan
# --------------------------------------------------------------------------


def ssm_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                 h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t along axis 1.  a, b: (B, T, D).

    The sequential FG-loop; the kernel implements the FGH-rewritten
    associative-scan GH-form (DESIGN.md §Arch-applicability).
    """
    if h0 is not None:
        b = b.at[:, 0].set(a[:, 0] * h0 + b[:, 0])
        a = a.at[:, 0].set(0.0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bv


def ssm_scan_chunked(a: jnp.ndarray, b: jnp.ndarray,
                     chunk: int = 256) -> jnp.ndarray:
    """Blocked GH-form on the XLA path: lax.scan over chunks carrying the
    boundary state, associative scan within each chunk — mirrors the Pallas
    kernel's grid structure.  Cuts the O(T·log T) intermediate traffic of a
    full-length associative scan to O(T·log chunk) (§Perf)."""
    bsz, t, d = a.shape
    chunk = min(chunk, t)
    if t % chunk != 0:
        return ssm_scan_ref(a, b)
    n = t // chunk
    ac = a.reshape(bsz, n, chunk, d).transpose(1, 0, 2, 3)
    bc = b.reshape(bsz, n, chunk, d).transpose(1, 0, 2, 3)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def step(carry, xs):
        a_i, b_i = xs
        av, bv = jax.lax.associative_scan(combine, (a_i, b_i), axis=1)
        h = bv + av * carry[:, None, :]
        return h[:, -1], h

    h0 = jnp.zeros((bsz, d), a.dtype)
    _, hs = jax.lax.scan(step, h0, (ac, bc))
    return hs.transpose(1, 0, 2, 3).reshape(bsz, t, d)


def ssm_scan_sequential(a: jnp.ndarray, b: jnp.ndarray,
                        h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """The literal per-token loop (the FG-program): oracle for the oracle."""
    bsz, t, d = a.shape
    h = jnp.zeros((bsz, d), a.dtype) if h0 is None else h0

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
