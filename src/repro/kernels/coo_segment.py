"""Semiring segment-reduce over COO coordinates as a Pallas TPU kernel.

This is the scatter half of sparse S-relation contraction (DESIGN.md §2):
after the XLA-side gather/⊗, each edge carries a value and a destination
key, and the kernel ⊕-reduces values by key — ``out[s] = ⊕ vals[i]`` over
``ids[i] = s``.  TPUs have no efficient scatter, so the kernel recasts the
reduction as a *block-aligned segment sweep*:

1. (XLA prep, static shapes) keys are bucketed into output blocks of
   ``bn`` lanes; edges are stably sorted by block and packed into
   fixed-capacity chunk rows of ``bk`` edges such that no chunk straddles
   an output block (padding slots carry 0̄, the capacity bound
   ``m//bk + nblocks + 1`` is static);
2. a scalar-prefetched chunk→block map drives the output BlockSpec, the
   canonical Pallas sparse pattern: grid iteration is sequential, each
   output tile is revisited by exactly the chunks of its block and
   accumulated in VMEM;
3. inside a chunk the reduction is a (bk, bn) one-hot compare +
   axis-reduce on the VPU (bk·bn·4 B ≤ 128 KiB of VMEM for 256×128).

Oracle: ``repro.kernels.ref.segment_reduce_ref`` (jnp scatter); tests
sweep semirings/sizes in interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INIT = {"bool": 0.0, "nat": 0.0, "real": 0.0,
         "trop": float("inf"), "maxplus": float("-inf")}


def _kernel(blk_ref, first_ref, vals_ref, loc_ref, o_ref, *, mode: str,
            bk: int, bn: int):
    c = pl.program_id(0)
    init = _INIT[mode]
    if mode in ("bool", "maxplus"):
        red, comb = jnp.max, jnp.maximum
    elif mode == "trop":
        red, comb = jnp.min, jnp.minimum
    else:
        red, comb = jnp.sum, jnp.add

    @pl.when(first_ref[c] == 1)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init)

    loc = loc_ref[0, :]                                   # (bk,) int32
    vals = vals_ref[0, :]                                 # (bk,) f32
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 1)
    onehot = loc[:, None] == lanes                        # (bk, bn)
    masked = jnp.where(onehot, vals[:, None], init)
    o_ref[0, :] = comb(o_ref[0, :], red(masked, axis=0))


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "sr_name", "bk", "bn",
                                    "interpret"))
def segment_reduce_pallas(vals: jnp.ndarray, segment_ids: jnp.ndarray,
                          num_segments: int, *, sr_name: str,
                          bk: int = 256, bn: int = 128,
                          interpret: bool = False) -> jnp.ndarray:
    """⊕-reduce ``vals`` by ``segment_ids`` into ``num_segments`` slots.

    Out-of-range ids (COO padding) contribute nothing.  Compute runs in
    f32; boolean inputs are thresholded back on exit.
    """
    n = num_segments
    m = int(vals.shape[0])
    is_bool = sr_name == "bool"
    zero = jnp.float32(_INIT[sr_name])
    v = vals.astype(jnp.float32)
    ids = segment_ids.astype(jnp.int32)

    nblocks = -(-n // bn)
    cap_chunks = m // bk + nblocks + 1
    cap_e = cap_chunks * bk

    valid = (ids >= 0) & (ids < n)
    ids_c = jnp.where(valid, ids, 0)
    v = jnp.where(valid, v, zero)
    blk = ids_c // bn
    loc = ids_c % bn

    order = jnp.argsort(blk, stable=True)
    blk_s, loc_s, v_s = blk[order], loc[order], v[order]
    cnt = jnp.zeros((nblocks,), jnp.int32).at[blk].add(1)
    chunks = jnp.maximum(1, -(-cnt // bk))                 # ≥1 per block
    chunk_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(chunks)[:-1]])
    total_chunks = chunk_start[-1] + chunks[-1]

    # chunk c → owning block; the (monotone) tail of unused capacity maps
    # to the last block with first=0 so it only combines 0̄
    cs = jnp.arange(cap_chunks, dtype=jnp.int32)
    owner = jnp.clip(
        jnp.searchsorted(chunk_start, cs, side="right") - 1, 0, nblocks - 1)
    in_use = cs < total_chunks
    blk_of_chunk = jnp.where(in_use, owner, nblocks - 1).astype(jnp.int32)
    first = (in_use & (cs == chunk_start[owner])).astype(jnp.int32)

    # pack sorted edges into their block's chunk rows
    edge_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(cnt)[:-1]])
    pos = jnp.arange(m, dtype=jnp.int32) - edge_start[blk_s]
    slot = chunk_start[blk_s] * bk + pos
    buf_v = jnp.full((cap_e,), zero, jnp.float32).at[slot].set(
        v_s, mode="drop")
    buf_l = jnp.zeros((cap_e,), jnp.int32).at[slot].set(loc_s, mode="drop")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(cap_chunks,),
        in_specs=[
            pl.BlockSpec((1, bk), lambda c, blk_r, first_r: (c, 0)),
            pl.BlockSpec((1, bk), lambda c, blk_r, first_r: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn),
                               lambda c, blk_r, first_r: (blk_r[c], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, mode=sr_name, bk=bk, bn=bn),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nblocks, bn), jnp.float32),
        interpret=interpret,
    )(blk_of_chunk, first, buf_v.reshape(cap_chunks, bk),
      buf_l.reshape(cap_chunks, bk))
    flat = out.reshape(-1)[:n]
    return flat > 0.5 if is_bool else flat
