"""Fault-tolerant checkpointing for sharded training state.

* **Layout**: one ``.npz`` per host per step + a msgpack manifest holding
  the tree structure, dtypes, global shapes and the *logical* sharding
  spec of every leaf.  Tensors are written as host-local shards
  (`addressable_shards`) keyed by their global slice, so any host count
  can write.
* **Reshard-on-restore**: restore assembles each tensor from whatever
  shard files exist and re-shards onto the *current* mesh (which may have
  a different shape — elastic scaling after losing a pod, or growing one).
* **Async**: `save_checkpoint(..., async_=True)` snapshots to host RAM on
  the caller thread (cheap) and writes to disk on a background thread, so
  the train loop is blocked only for the device→host copy.
* **Atomicity**: writes go to ``step_N.tmp/`` and are renamed onto
  ``step_N/`` only after the manifest fsync — a crash mid-write never
  corrupts the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree):
    return [jax.tree_util.keystr(kp) for kp, _ in
            jax.tree_util.tree_flatten_with_path(tree)[0]]


def save_checkpoint(ckpt_dir: str, step: int, tree, *, async_: bool = False,
                    keep: int = 3):
    """Save a pytree of jax.Arrays / numpy arrays."""
    leaves, treedef = _flatten(tree)
    names = _paths(tree)
    host_shards = {}
    meta = {"step": step, "names": names,
            "treedef": str(treedef),
            "shapes": [], "dtypes": []}
    for name, leaf in zip(names, leaves):
        arr = leaf
        meta["shapes"].append(list(np.shape(arr)))
        meta["dtypes"].append(str(np.asarray(jax.tree.leaves(arr)[0]).dtype)
                              if not hasattr(arr, "dtype") else str(arr.dtype))
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            for sh in arr.addressable_shards:
                key = f"{name}|{_index_key(sh.index)}"
                host_shards[key] = np.asarray(sh.data)
        else:
            host_shards[f"{name}|full"] = np.asarray(arr)

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        host = jax.process_index()
        np.savez(os.path.join(tmp, f"shards_h{host}.npz"), **host_shards)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _index_key(index) -> str:
    parts = []
    for sl in index:
        parts.append(f"{sl.start or 0}:{sl.stop if sl.stop is not None else -1}")
    return ",".join(parts) or "full"


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int, target_tree, *,
                    shardings=None):
    """Restore onto the current mesh (reshard-on-restore).

    ``target_tree`` provides the structure; ``shardings`` (optional pytree
    of NamedSharding) places each tensor — mesh shape may differ from the
    one that wrote the checkpoint.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    shard_files = [np.load(os.path.join(path, fn))
                   for fn in sorted(os.listdir(path)) if fn.endswith(".npz")]

    names = _paths(target_tree)
    leaves, treedef = _flatten(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)

    def assemble(name, like):
        shape = tuple(np.shape(like))
        dtype = like.dtype if hasattr(like, "dtype") else np.float32
        out = np.zeros(shape, dtype)
        found = False
        for zf in shard_files:
            for key in zf.files:
                n, _, idx = key.partition("|")
                if n != name:
                    continue
                found = True
                if idx == "full":
                    out = zf[key]
                else:
                    sls = tuple(
                        slice(int(a), None if int(b) == -1 else int(b))
                        for a, b in (p.split(":") for p in idx.split(",")))
                    out[sls] = zf[key]
        if not found:
            raise KeyError(f"checkpoint missing tensor {name}")
        return out

    new_leaves = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = assemble(name, leaf)
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        new_leaves.append(arr)
    return treedef.unflatten(new_leaves)


class CheckpointManager:
    """Rotation + async handles + restore-latest convenience."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every
        self._pending: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, tree, force: bool = False):
        if not force and (step % self.every != 0):
            return
        self.wait()
        self._pending = save_checkpoint(self.dir, step, tree, async_=True,
                                        keep=self.keep)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, target_tree, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, 0
        return load_checkpoint(self.dir, step, target_tree,
                               shardings=shardings), step
