"""Graph-axis sharded fixpoints: row-partitioned COO SpMM under shard_map.

The serve/incremental layers (DESIGN.md §3–§5) make the recursive matvec

    x[y]  =  init[y] ⊕ ⊕_z x[z] ⊗ E[z, y]

fast on one device, but the graph dimension ``n`` still had to fit that
device.  This module partitions the problem along a ``("graph",)`` mesh
axis instead (DESIGN.md §6): **destination-row blocks**.  Device ``k`` of
``D`` owns rows ``[k·nb, (k+1)·nb)`` of ``x``/``Δ`` (``nb = ⌈n/D⌉``) and
the edge tuples *landing* in that block — exactly the hash-partitioned
rule evaluation of Scaling-Up In-Memory Datalog (Fan et al.) with the
join key being the destination vertex, mapped onto semiring SpMM.

Two things make the partition *fast*, not merely correct (DESIGN.md §8):

* **Balanced destination blocks.**  ``shard_relation`` relabels vertices
  (snake-deal by in-degree) so every block owns ≈ nnz/D edges; without
  it a power-law hub block sets the shared static capacity and every
  shard pays the worst shard's padding.  The relabeling ``perm`` lives
  on the :class:`ShardedRelation`; inits are permuted in and answers
  permuted back out, so callers never see the internal id space.
* **Δ-sparse frontier exchange.**  Instead of all-gathering the dense
  frontier every iteration, each shard compacts its local Δ nonzeros to
  a static-capacity ``(ids, values)`` buffer and exchanges only those
  (bit-packing bool payload lanes).  Receivers expand *only the edges
  out of live frontier vertices* through a per-shard CSR-by-source
  index — per-iteration exchange bytes *and* compute become frontier-
  proportional.  A ladder of static capacities (small tier, large tier,
  dense fallback) keeps every shape static; when the globally-agreed
  frontier density exceeds the last tier the round falls back to the
  dense all-gather, so semantics never change.  All branch predicates
  are ``pmax``/``psum``-reduced, keeping the SPMD programs in lockstep.

The exchange geometry (sorted-by-source edge copy + unique-source CSR
index + the relabeling) is cached on the :class:`ShardedRelation` and
rebuilt by :meth:`ShardedRelation.apply_delta`, which is what
invalidates it under streaming updates.

Convergence is a ``psum``-reduced emptiness check of the new Δ, so
every device leaves the ``lax.while_loop`` on the same iteration and
the iteration count — and every answer bit — matches the single-device
runners exactly, whichever exchange tier each round took (⊕ is an
idempotent lattice wherever the fixpoint is defined, so re-grouping
contributions is exact, not merely close).

The cold, warm-start (:func:`sharded_resume_fixpoint`, the incremental
§5 repair path), and batched ``(B, n)`` multi-source forms all share one
loop body, mirroring :mod:`repro.sparse.fixpoint`.

Sharded storage is a :class:`ShardedRelation`: per-shard padded COO
stacked on a leading device axis, local destination indices, global
source indices.  Padding follows the §2 discipline — source sentinel
``n_pad`` gathers the ⊗-identity fill, destination sentinel ``nb`` is
dropped by the scatter, padded values are 0̄ — so per-shard nnz may be
ragged under one static capacity and ``apply_delta`` can route new
tuples into padding slots without retracing compiled consumers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import semiring as sr_mod
from repro.sparse.coo import SparseRelation

try:  # jax ≥ 0.4.35 exposes shard_map at the top level eventually
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map  # type: ignore[attr-defined]

#: the mesh axis name every sharded fixpoint runs over
GRAPH_AXIS = "graph"


def mesh_size(mesh) -> int:
    """Device count along the graph axis of ``mesh`` (a Mesh with a
    "graph" axis, or a plain int D for planning/host-side partitioning)."""
    if isinstance(mesh, int):
        if mesh < 1:
            raise ValueError(f"device count must be ≥ 1, got {mesh}")
        return mesh
    if isinstance(mesh, Mesh):
        if GRAPH_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no "
                             f"{GRAPH_AXIS!r} axis — build one with "
                             f"launch.mesh.make_graph_mesh")
        return int(mesh.shape[GRAPH_AXIS])
    raise TypeError(f"mesh must be a Mesh or an int device count, "
                    f"got {type(mesh).__name__}")


def _pow2ceil(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def _balance_perm(dst: np.ndarray, n: int, d: int, nb: int) -> np.ndarray:
    """A vertex relabeling ``perm[old] = new`` that snake-deals vertices
    (sorted by in-degree, descending) across the D destination blocks.

    Every block receives ⌈n/D⌉ or ⌊n/D⌋ vertices and — because heavy
    hubs are dealt one per block per round — ≈ nnz/D edges, so the
    shared static capacity is the *mean* shard's nnz instead of the
    worst block's.  On a 1M-vertex power-law graph this cuts per-shard
    padding (and with it every dense round's gather/scatter work) ~2.8×.
    """
    indeg = np.bincount(dst, minlength=n)
    order = np.argsort(-indeg, kind="stable")
    i = np.arange(n)
    rounds, lane = divmod(i, d)
    blk = np.where(rounds % 2 == 0, lane, d - 1 - lane)
    block = np.empty(n, np.int64)
    block[order] = blk
    pos = np.empty(n, np.int64)
    for k in range(d):
        sel = order[blk == k]
        pos[sel] = np.arange(len(sel))
    return (block * nb + pos).astype(np.int32)


def _build_geometry(coords: np.ndarray, values: np.ndarray,
                    nnz: np.ndarray, nb: int, n_pad: int, sr_np):
    """The Δ-exchange receive geometry for one sharded relation: a
    per-shard copy of the edges sorted by global source plus a unique-
    source CSR index over it (host-side, one pass per shard).

    Returns ``(ssrc, sdst, sval, usrc, ustart)``: sorted sources,
    aligned local destinations and values (dead slots keep the padding
    sentinels), the sorted unique sources padded with ``n_pad`` to a
    power-of-two ``ucap``, and the ``(D, ucap+1)`` CSR run starts.  The
    power-of-two ``ucap`` absorbs ragged unique counts and most
    ``apply_delta`` growth without changing any array shape (and so
    without retracing compiled consumers).
    """
    d, cap = values.shape
    ssrc = np.full((d, cap), n_pad, np.int32)
    sdst = np.full((d, cap), nb, np.int32)
    sval = np.full((d, cap), sr_np.zero, sr_np.dtype)
    uniq, starts = [], []
    for k in range(d):
        c = int(nnz[k])
        order = np.argsort(coords[k, :c, 0], kind="stable")
        ssrc[k, :c] = coords[k, :c, 0][order]
        sdst[k, :c] = coords[k, :c, 1][order]
        sval[k, :c] = values[k, :c][order]
        u, st = np.unique(ssrc[k, :c], return_index=True)
        uniq.append(u)
        starts.append((st, c))
    ucap = _pow2ceil(max(1, max((len(u) for u in uniq), default=1)))
    usrc = np.full((d, ucap), n_pad, np.int32)
    ustart = np.zeros((d, ucap + 1), np.int32)
    for k in range(d):
        u, (st, c) = uniq[k], starts[k]
        usrc[k, :len(u)] = u
        ustart[k, :len(u)] = st
        ustart[k, len(u):] = c
    return ssrc, sdst, sval, usrc, ustart


def default_exchange_caps(nb: int, cap: int) -> tuple[tuple[int, int], ...]:
    """The static-capacity ladder for the Δ-sparse exchange: a list of
    ``(frontier_cap, expansion_cap)`` tiers, cheapest first; rounds
    whose (pmax-agreed) frontier exceeds every tier take the dense
    all-gather fallback.  Per-shard frontier caps are fractions of the
    row block ``nb``; expansion caps are fractions of the edge capacity
    ``cap`` — measured on the CI host as the sweet spot between letting
    light rounds stay tiny and not paying worst-case shapes every round
    (DESIGN.md §8)."""
    tiers = []
    for fs, fe in ((32, 16), (4, 2)):
        cs = min(nb, _pow2ceil(max(64, nb // fs)))
        ce = min(cap, _pow2ceil(max(256, cap // fe)))
        if tiers and (cs, ce) == tiers[-1]:
            continue
        tiers.append((cs, ce))
    return tuple(tiers)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedRelation:
    """A binary S-relation partitioned into D destination-row blocks.

    ``coords[(D, cap, 2)]`` holds per-shard tuples as (global source,
    **local** destination); ``values[(D, cap)]`` their semiring values;
    ``nnz[(D,)]`` the ragged live counts.  ``cap`` is one static
    capacity shared by every shard so the type is a pytree whose leaves
    carry a leading device axis ready for ``P("graph")`` in/out specs.

    When built by :func:`shard_relation` the relation also carries the
    Δ-exchange geometry (module docstring): the balance relabeling
    ``perm``/``inv`` (``None`` = identity) and the sorted-by-source
    CSR index ``ssrc``/``sdst``/``sval``/``usrc``/``ustart`` (``None``
    = dense exchange only).  All ride the pytree so compiled fixpoints
    take them as ordinary sharded operands; :meth:`apply_delta`
    rebuilds them, which is what keeps the cache coherent under
    streaming updates.
    """

    coords: jnp.ndarray   # (D, cap, 2) int32 — [:, :, 0] global src,
    #                       [:, :, 1] local dst (block-relative)
    values: jnp.ndarray   # (D, cap) semiring dtype
    nnz: jnp.ndarray      # (D,) int32 live rows per shard
    shape: tuple[int, ...]
    semiring: str
    # -- Δ-exchange geometry (all None when absent) ------------------------
    perm: jnp.ndarray | None = None     # (n,) int32: new padded id of old
    inv: jnp.ndarray | None = None      # (n_pad,) int32: old id of new
    ssrc: jnp.ndarray | None = None     # (D, cap) int32 sorted global src
    sdst: jnp.ndarray | None = None     # (D, cap) int32 aligned local dst
    sval: jnp.ndarray | None = None     # (D, cap) aligned values
    usrc: jnp.ndarray | None = None     # (D, ucap) int32 unique sources
    ustart: jnp.ndarray | None = None   # (D, ucap+1) int32 CSR run starts

    _GEO_FIELDS = ("perm", "inv", "ssrc", "sdst", "sval", "usrc", "ustart")

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        children = (self.coords, self.values, self.nnz) + tuple(
            getattr(self, f) for f in self._GEO_FIELDS)
        return children, (self.shape, self.semiring)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, semiring = aux
        return cls(*children[:3], shape, semiring, *children[3:])

    # -- basics ------------------------------------------------------------
    @property
    def d(self) -> int:
        """Shard count D (the graph-axis mesh size this was built for)."""
        return int(self.coords.shape[0])

    @property
    def capacity(self) -> int:
        """Per-shard static capacity."""
        return int(self.coords.shape[1])

    @property
    def row_block(self) -> int:
        """Destination rows per shard, ``nb = ⌈n/D⌉``."""
        return -(-self.shape[1] // self.d)

    @property
    def n_pad(self) -> int:
        """Padded global row count ``nb · D`` (≥ shape[1])."""
        return self.row_block * self.d

    @property
    def has_exchange_geometry(self) -> bool:
        return self.ssrc is not None

    @property
    def lib(self) -> str:
        return "np" if isinstance(self.values, np.ndarray) else "jnp"

    def total_nnz(self) -> int:
        return int(np.asarray(self.nnz).sum())

    def __repr__(self) -> str:
        return (f"ShardedRelation({self.semiring}{list(self.shape)}, "
                f"D={self.d}×nnz≤{self.capacity}, "
                f"rows/shard={self.row_block})")

    def _convert(self, fn, nnz_dtype) -> "ShardedRelation":
        geo = {f: None if getattr(self, f) is None else fn(getattr(self, f))
               for f in self._GEO_FIELDS}
        return ShardedRelation(fn(self.coords), fn(self.values),
                               fn(np.asarray(self.nnz, nnz_dtype)
                                  if self.lib == "np" else self.nnz),
                               self.shape, self.semiring, **geo)

    def as_jnp(self) -> "ShardedRelation":
        return self._convert(jnp.asarray, np.int32)

    def as_np(self) -> "ShardedRelation":
        return self._convert(np.asarray, np.int32)

    # -- streaming updates -------------------------------------------------
    def apply_delta(self, coords, values=None) -> "ShardedRelation":
        """⊕-merge a batch of global-coordinate tuple updates, routing
        each row to its owning destination shard (DESIGN.md §5/§6).

        The incremental overlay discipline of
        :meth:`repro.sparse.coo.SparseRelation.apply_delta` carries over
        shard-wise: rows land in padding slots while every shard fits
        (static capacity — and therefore the compiled fixpoint's trace —
        unchanged), appended duplicates are left for the ⊕-combining
        consumers to merge, and overflow re-pads **all** shards by
        doubling until the worst shard's live count fits (one uniform
        capacity keeps the stacked pytree rectangular; amortized-O(1),
        one retrace per doubling — the §5 discipline, shard-wise).

        The Δ-exchange geometry is **invalidated and rebuilt** here (a
        host-side re-sort): its array shapes are tied to the capacity
        and the power-of-two unique-source cap, so in-capacity deltas
        keep every compiled consumer's trace alive.
        """
        sr = sr_mod.get(self.semiring, lib="np")
        coords = np.asarray(coords, np.int64).reshape(-1, 2)
        if values is None:
            values = np.full(len(coords), sr.one, sr.dtype)
        values = np.asarray(values, sr.dtype).reshape(-1)
        assert len(coords) == len(values), (coords.shape, values.shape)
        if np.any(coords < 0) or np.any(coords >= np.asarray(self.shape)):
            raise ValueError("delta coordinates out of range for shape "
                             f"{self.shape}")
        live = values if self.semiring == "bool" else values != sr.zero
        coords, values = coords[live], values[live]
        if len(values) == 0:
            return self
        host = self.as_np()
        nb = self.row_block
        if host.perm is not None:
            coords = host.perm[coords]      # old ids → balanced ids
        owner = coords[:, 1] // nb
        k = host.nnz.astype(np.int64)
        add = np.bincount(owner, minlength=self.d)
        need = k + add
        cap = self.capacity
        if int(need.max()) > cap:
            cap = max(1, cap)
            while cap < int(need.max()):
                cap <<= 1
        new_coords = np.empty((self.d, cap, 2), np.int32)
        new_coords[:, :, 0] = self.n_pad
        new_coords[:, :, 1] = nb
        new_values = np.full((self.d, cap), sr.zero, sr.dtype)
        new_coords[:, :self.capacity] = host.coords
        new_values[:, :self.capacity] = host.values
        for s in range(self.d):
            sel = owner == s
            if not sel.any():
                continue
            lo = int(k[s])
            hi = lo + int(sel.sum())
            new_coords[s, lo:hi, 0] = coords[sel, 0]
            new_coords[s, lo:hi, 1] = coords[sel, 1] - s * nb
            new_values[s, lo:hi] = values[sel]
        nnz = need.astype(np.int32)
        geo = {}
        if self.has_exchange_geometry:
            g = _build_geometry(new_coords, new_values, nnz, nb,
                                self.n_pad, sr)
            geo = dict(zip(("ssrc", "sdst", "sval", "usrc", "ustart"), g))
        out = ShardedRelation(new_coords, new_values, nnz, self.shape,
                              self.semiring, perm=host.perm, inv=host.inv,
                              **geo)
        return out if self.lib == "np" else out.as_jnp()


def shard_relation(rel: SparseRelation, mesh, *,
                   balance: bool = True) -> ShardedRelation:
    """Partition a binary :class:`SparseRelation` into per-device
    destination-row blocks for ``mesh`` (host-side, one pass).

    Shard ``k`` receives every live tuple ``(i, j, w)`` whose (balanced)
    destination lands in ``[k·nb, (k+1)·nb)``, stored as block-local.
    All shards share one capacity (the worst shard's nnz, min 1) so the
    stacked buffers stay rectangular; per-shard nnz is ragged.

    ``balance=True`` (default) relabels vertices first so edge counts —
    and with them padding, dense-round work, and exchange buffers — are
    near-uniform across blocks (:func:`_balance_perm`); the relabeling
    is carried on the result and inverted at every public boundary.
    The Δ-exchange geometry (module docstring) is built here too.
    """
    if rel.arity != 2:
        raise ValueError(f"graph sharding needs a binary relation, got "
                         f"arity {rel.arity}")
    d = mesh_size(mesh)
    host = rel.as_np()
    k = int(host.nnz)
    src = host.coords[:k, 0].astype(np.int64)
    dst = host.coords[:k, 1].astype(np.int64)
    w = host.values[:k]
    n = rel.shape[1]
    nb = -(-n // d)
    n_pad = nb * d
    perm = inv = None
    if balance and d > 1 and k and rel.shape[0] == rel.shape[1]:
        perm = _balance_perm(dst, n, d, nb)
        inv = np.full(n_pad, n, np.int32)
        inv[perm] = np.arange(n, dtype=np.int32)
        src = perm[src].astype(np.int64)
        dst = perm[dst].astype(np.int64)
    owner = dst // nb
    counts = np.bincount(owner, minlength=d)
    cap = max(1, int(counts.max()) if k else 1)
    sr = sr_mod.get(rel.semiring, lib="np")
    coords = np.empty((d, cap, 2), np.int32)
    coords[:, :, 0] = n_pad
    coords[:, :, 1] = nb
    values = np.full((d, cap), sr.zero, sr.dtype)
    order = np.argsort(owner, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in range(d):
        sel = order[starts[s]:starts[s + 1]]
        c = len(sel)
        coords[s, :c, 0] = src[sel]
        coords[s, :c, 1] = dst[sel] - s * nb
        values[s, :c] = w[sel]
    nnz = counts.astype(np.int32)
    ssrc, sdst, sval, usrc, ustart = _build_geometry(
        coords, values, nnz, nb, n_pad, sr)
    out = ShardedRelation(coords, values, nnz, rel.shape, rel.semiring,
                          perm=perm, inv=inv, ssrc=ssrc, sdst=sdst,
                          sval=sval, usrc=usrc, ustart=ustart)
    return out if rel.lib == "np" else out.as_jnp()


def unshard(sh: ShardedRelation, *,
            capacity: int | None = None) -> SparseRelation:
    """Reassemble the global COO relation (host-side, coalescing ⊕ at
    duplicate keys and inverting the balance relabeling — the
    round-trip inverse of :func:`shard_relation`)."""
    host = sh.as_np()
    nb = sh.row_block
    coords, values = [], []
    for s in range(sh.d):
        c = int(host.nnz[s])
        blk = host.coords[s, :c].astype(np.int64)
        src, dst = blk[:, 0], blk[:, 1] + s * nb
        if host.inv is not None:
            src, dst = host.inv[src], host.inv[dst]
        coords.append(np.stack([src, dst], axis=1))
        values.append(host.values[s, :c])
    coords = np.concatenate(coords) if coords else np.zeros((0, 2),
                                                            np.int64)
    values = np.concatenate(values) if values else np.zeros(
        0, sr_mod.get(sh.semiring, lib="np").dtype)
    return SparseRelation.from_coo(coords, values, sh.shape, sh.semiring,
                                   capacity=capacity, lib=sh.lib)


# --------------------------------------------------------------------------
# The sharded GSN loop
# --------------------------------------------------------------------------


def _local_derive(sr, coords, values, d_full, nb: int):
    """One shard's δF: gather the gathered frontier at the global source
    coordinates, ⊗ with the local edge values, ⊕-segment-reduce by local
    destination.  ``d_full`` is (n_pad,) or (n_pad, B); the result is
    (nb,) or (nb, B).  The padding discipline (sentinel src → ⊗-identity
    fill, 0̄ values, OOB dst dropped) makes ragged per-shard nnz exact."""
    from repro.kernels import ops as kops
    gathered = jnp.take(d_full, coords[:, 0], axis=0, mode="fill",
                        fill_value=sr.one)
    if d_full.ndim == 1:
        prod = sr.mul(values, gathered)
    else:
        prod = sr.mul(values[:, None], gathered)
    return kops.semiring_segment_reduce(sr, prod, coords[:, 1], nb)


def _pad_rows(x, n_pad: int, fill):
    """Zero-pad the vertex axis (axis 0) of a (n,)/(n, B) array to
    ``n_pad`` phantom rows (0̄ init, never referenced by any edge)."""
    n = x.shape[0]
    if n == n_pad:
        return x
    pad = jnp.full((n_pad - n,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def _payload_codec(sr, batched: bool):
    """(pack, unpack, bytes-per-row) for the exchanged Δ payload.
    Batched bool lanes bit-pack 8-to-a-byte (exact round trip), cutting
    both the dense-fallback all-gather and the sparse buffers 8×."""
    if batched and sr.dtype == jnp.bool_:
        def pack(x):
            return jnp.packbits(x.astype(jnp.uint8), axis=1)

        def unpack(p, b):
            return jnp.unpackbits(p, axis=1, count=b).astype(jnp.bool_)

        return pack, unpack, None  # bytes/row depends on B: ⌈B/8⌉
    return (lambda x: x), (lambda p, b: p), None


def payload_row_bytes(semiring: str, batch: int) -> int:
    """Exchanged bytes per vertex row of Δ payload (after bit-packing)."""
    sr = sr_mod.get(semiring)
    if batch > 1 and sr.dtype == jnp.bool_:
        return -(-batch // 8)
    return batch * np.dtype(sr.dtype).itemsize


def _sparse_exchange_derive(sr, dense_fn, geo, d_loc, *, nb, n_pad, cap,
                            caps, batched, batch):
    """One Δ-sparse derive round under the capacity ladder.

    Returns ``(derived, tier)`` where ``tier`` indexes ``caps`` (or
    ``len(caps)`` for the dense fallback).  Every branch predicate is
    reduced over the graph axis first, so all shards take the same
    branch (collectives inside `lax.cond` stay matched)."""
    ssrc, sdst, sval, usrc, ustart = geo
    zero = jnp.asarray(sr.zero, sr.dtype)
    pack, unpack, _ = _payload_codec(sr, batched)
    dense_tier = jnp.int32(len(caps))

    if batched:
        live = jnp.any(d_loc != zero, axis=1)
    else:
        live = d_loc != zero
    cnt_max = jax.lax.pmax(jnp.sum(live.astype(jnp.int32)), GRAPH_AXIS)

    def expand(V, stt, deg, offs, total, cap_e):
        """Static-shape CSR expansion of the gathered compact frontier:
        edge slot e belongs to gathered entry `row(e)` (scatter + cummax
        instead of a per-edge searchsorted), expanded edges ⊗ their
        source's Δ value, segment-⊕ by local destination.  Slots past
        the *local* total hit the padding sentinels and vanish."""
        starts_ex = offs - deg
        ridx = jnp.zeros((cap_e,), jnp.int32)
        ridx = ridx.at[jnp.where(deg > 0, starts_ex, cap_e)].max(
            jnp.arange(deg.shape[0], dtype=jnp.int32), mode="drop")
        row = jax.lax.cummax(ridx)
        e = jnp.arange(cap_e, dtype=jnp.int32)
        within = e - jnp.take(starts_ex, row, mode="fill", fill_value=0)
        slot = jnp.take(stt, row, mode="fill", fill_value=0) + within
        slot = jnp.where(e < total, slot, cap)
        dsts = jnp.take(sdst, slot, mode="fill", fill_value=nb)
        ws = jnp.take(sval, slot, mode="fill", fill_value=sr.zero)
        srcv = jnp.take(V, row, axis=0, mode="fill", fill_value=sr.zero)
        prod = sr.mul(ws[:, None], srcv) if batched else sr.mul(ws, srcv)
        from repro.kernels import ops as kops
        return kops.semiring_segment_reduce(sr, prod, dsts, nb)

    def sparse_tier(dl, cap_s, cap_e, tier):
        (idx,) = jnp.nonzero(live, size=cap_s, fill_value=nb)
        idx = idx.astype(jnp.int32)
        vals = jnp.take(dl, idx, axis=0, mode="fill", fill_value=sr.zero)
        me = jax.lax.axis_index(GRAPH_AXIS)
        gsrc = jnp.where(idx == nb, n_pad, me * nb + idx)
        # the id gather is issued first so the CSR lookup below can
        # overlap the (larger) payload transfer on async backends
        G = jax.lax.all_gather(gsrc, GRAPH_AXIS, axis=0, tiled=True)
        V = jax.lax.all_gather(pack(vals), GRAPH_AXIS, axis=0, tiled=True)
        pos = jnp.searchsorted(usrc, G).astype(jnp.int32)
        hit = jnp.take(usrc, pos, mode="fill", fill_value=-1) == G
        stt = jnp.take(ustart, pos, mode="fill", fill_value=0)
        en = jnp.take(ustart, pos + 1, mode="fill", fill_value=0)
        deg = jnp.where(hit, en - stt, 0)
        offs = jnp.cumsum(deg)
        total = offs[-1]
        over = jax.lax.pmax(total, GRAPH_AXIS) > cap_e
        return jax.lax.cond(
            over,
            lambda op: (dense_fn(op[0]), dense_tier),
            lambda op: (expand(unpack(op[1], batch), op[2], op[3], op[4],
                               op[5], cap_e), jnp.int32(tier)),
            (dl, V, stt, deg, offs, total))

    def build(i):
        if i == len(caps):
            return lambda dl: (dense_fn(dl), dense_tier)
        cs, ce = caps[i]
        nxt = build(i + 1)
        return lambda dl: jax.lax.cond(
            cnt_max <= cs,
            lambda q: sparse_tier(q, cs, ce, i),
            nxt, dl)

    return build(0)(d_loc)


def sharded_seminaive_fixpoint(edges, init, *, mesh: Mesh,
                               max_iters: int = 10_000,
                               exchange: str = "auto",
                               exchange_caps=None):
    """Least fixpoint of ``x = init ⊕ x ⊗ E`` with the graph axis
    partitioned across ``mesh`` (module docstring).

    ``edges`` is a :class:`ShardedRelation` built for the mesh's D (or a
    plain :class:`SparseRelation`, sharded here).  ``init`` may be
    ``(n,)`` or a batched ``(B, n)`` multi-source pack; results and
    iteration counts match :func:`repro.sparse.fixpoint.
    sparse_seminaive_fixpoint` exactly, row for row.

    ``exchange`` selects the per-iteration frontier exchange:
    ``"auto"`` (default) runs the Δ-sparse ladder with its dense
    fallback; ``"dense"`` forces the reference all-gather every round.
    Both produce bit-identical answers — "dense" is the oracle the
    property tests hold "auto" to.  ``exchange_caps`` overrides the
    ladder (a tuple of ``(frontier_cap, expansion_cap)`` tiers) — the
    fallback boundary's test hook and the benchmark's tuning knob.
    """
    y, iters, _ = _dispatch(edges, mesh, init=init, max_iters=max_iters,
                            exchange=exchange, exchange_caps=exchange_caps)
    return y, iters


def sharded_seminaive_fixpoint_stats(edges, init, *, mesh: Mesh,
                                     max_iters: int = 10_000,
                                     exchange: str = "auto",
                                     exchange_caps=None):
    """:func:`sharded_seminaive_fixpoint` plus the exchange round
    counters: ``(y, iters, rounds)`` where ``rounds[i]`` counts derive
    rounds taken by ladder tier ``i`` and ``rounds[-1]`` the dense
    fallbacks — the benchmark's exchanged-byte accounting input
    (:func:`exchange_byte_report`)."""
    return _dispatch(edges, mesh, init=init, max_iters=max_iters,
                     exchange=exchange, exchange_caps=exchange_caps)


def sharded_resume_fixpoint(edges, y0, d0, *, mesh: Mesh,
                            max_iters: int = 10_000,
                            exchange: str = "auto",
                            exchange_caps=None):
    """Warm-start re-convergence from a ``(y0, d0)`` pre-fixpoint pair —
    the sharded twin of :func:`repro.sparse.fixpoint.resume_fixpoint`,
    sharing this module's loop body (and its Δ-sparse exchange).  Used
    by the serve loop to repair warm answers after a monotone update
    (DESIGN.md §5/§6)."""
    y, iters, _ = _dispatch(edges, mesh, warm=(y0, d0),
                            max_iters=max_iters, exchange=exchange,
                            exchange_caps=exchange_caps)
    return y, iters


def sharded_resume_chunk(edges, y0, d0, it0, *, mesh: Mesh,
                         max_iters: int, exchange: str = "auto",
                         exchange_caps=None):
    """One bounded slice of the sharded batched GSN loop — the graph-axis
    twin of :func:`repro.sparse.fixpoint.resume_fixpoint_chunk` and the
    ``sparse_sharded`` runner's ``run_chunk`` body (DESIGN.md §10).

    Advances the ``(B, n)`` carry ``(y0, d0)`` by at most ``max_iters``
    rounds (Δ-sparse exchange and all) and returns the full carry
    ``(y, d, it_rows)`` in global vertex coordinates, so the adaptive
    executor can hand it to any single-device runner — the round body is
    shared, so the hand-off is bit-exact.  ``it0`` is the ``(B,)``
    per-row iteration counter carried across chunks.
    """
    if np.ndim(y0) != 2:
        raise ValueError("sharded_resume_chunk needs a batched (B, n) "
                         "carry — add a leading batch axis")
    return _dispatch(edges, mesh, warm=(y0, d0), it0=it0, chunk=True,
                     max_iters=max_iters, exchange=exchange,
                     exchange_caps=exchange_caps)


def exchange_byte_report(es: ShardedRelation, rounds, *, batch: int = 1,
                         exchange_caps=None) -> dict:
    """Exchanged-byte accounting for one fixpoint run: ``rounds`` is the
    counter vector from :func:`sharded_seminaive_fixpoint_stats`.  The
    baseline is what the PR-5 *reference* exchange would have moved —
    one ``n_pad``-row all-gather of the raw (unpacked) payload per
    round; "actual" prices each round at the buffer its tier really
    gathered (ids + bit-packed payload; the dense fallback also packs,
    so even forced-dense rounds undercut the reference on 𝔹 rows)."""
    rounds = np.asarray(rounds, np.int64)
    caps = tuple(exchange_caps or default_exchange_caps(es.row_block,
                                                        es.capacity))
    assert len(rounds) == len(caps) + 1, (rounds, caps)
    prow = payload_row_bytes(es.semiring, batch)
    raw = max(1, batch) * np.dtype(sr_mod.get(es.semiring).dtype).itemsize
    dense_ref = es.n_pad * raw
    per_round = [es.d * cs * (4 + prow) for cs, _ in caps] \
        + [es.n_pad * prow]
    total = int(np.dot(rounds, per_round))
    nrounds = max(1, int(rounds.sum()))
    return {
        "rounds": rounds.tolist(),
        "bytes_per_iter": total / nrounds,
        "dense_bytes_per_iter": float(dense_ref),
        "bytes_total": total,
        "dense_bytes_total": float(dense_ref * nrounds),
        "byte_reduction": (dense_ref * nrounds) / max(1, total),
    }


def sharded_contract(edges, x, *, mesh: Mesh):
    """One sharded ``x ⊗ E`` application: all-gather the operand, derive
    locally, return the row-sharded product reassembled to ``(n,)`` /
    ``(B, n)``.  Defined for *every* semiring (no ⊖ needed) — the
    exact-agreement probe for non-lattice semirings like ℕ∞.  One-shot
    (no iteration), so it keeps the dense exchange: there is no Δ to
    be sparse in."""
    es = _as_sharded(edges, mesh)
    sr = sr_mod.get(es.semiring)
    batched = np.ndim(x) == 2
    n, nb, n_pad = es.shape[1], es.row_block, es.n_pad
    xv = jnp.asarray(x).T if batched else jnp.asarray(x)
    if es.perm is not None:
        xv = _permute_rows(xv, es.perm, n_pad, sr.zero)
    else:
        xv = _pad_rows(xv, n_pad, sr.zero)
    vspec = P(GRAPH_AXIS, None) if batched else P(GRAPH_AXIS)

    def body(coords, values, x_loc):
        full = jax.lax.all_gather(x_loc, GRAPH_AXIS, axis=0, tiled=True)
        return _local_derive(sr, coords[0], values[0], full, nb)

    out = shard_map(body, mesh=mesh,
                    in_specs=(P(GRAPH_AXIS), P(GRAPH_AXIS), vspec),
                    out_specs=vspec, check_rep=False)(
        es.coords, es.values, xv)
    out = jnp.take(out, es.perm, axis=0) if es.perm is not None \
        else out[:n]
    return out.T if batched else out


def _permute_rows(x, perm, n_pad: int, fill):
    """Scatter an (n,)/(n, B) vertex-major array into the balanced id
    space: row ``perm[v]`` of the (n_pad,)-row result holds old row
    ``v``; unassigned padding rows stay 0̄."""
    out = jnp.full((n_pad,) + x.shape[1:], fill, x.dtype)
    return out.at[perm].set(x)


def _as_sharded(edges, mesh) -> ShardedRelation:
    if isinstance(edges, ShardedRelation):
        if edges.d != mesh_size(mesh):
            raise ValueError(
                f"relation sharded for D={edges.d} cannot run on a "
                f"{mesh_size(mesh)}-device graph mesh — re-shard it")
        return edges.as_jnp()
    if isinstance(edges, SparseRelation):
        return shard_relation(edges, mesh).as_jnp()
    raise TypeError(f"edges must be a SparseRelation or ShardedRelation, "
                    f"got {type(edges).__name__}")


def _dispatch(edges, mesh, *, init=None, warm=None, max_iters=10_000,
              exchange="auto", exchange_caps=None, it0=None, chunk=False):
    if exchange not in ("auto", "dense"):
        raise ValueError(f"exchange must be 'auto' or 'dense', "
                         f"got {exchange!r}")
    es = _as_sharded(edges, mesh)
    if es.shape[0] != es.shape[1]:
        raise ValueError(f"recursive expansion needs a square binary "
                         f"edge relation, got shape {es.shape}")
    sr = sr_mod.get(es.semiring)
    if sr.minus is None:
        raise ValueError(f"semiring {sr.name} lacks ⊖; "
                         "GSN needs an idempotent lattice")
    batched = np.ndim(init if warm is None else warm[0]) == 2
    n, nb, n_pad = es.shape[1], es.row_block, es.n_pad
    use_sparse = exchange == "auto" and es.has_exchange_geometry
    caps = tuple(exchange_caps) if exchange_caps else \
        default_exchange_caps(nb, es.capacity)
    n_tiers = len(caps) if use_sparse else 0
    pack, unpack, _ = _payload_codec(sr, batched)

    def seed(x):
        x = jnp.asarray(x)
        x = x.T if batched else x
        if es.perm is not None:
            return _permute_rows(x, es.perm, n_pad, sr.zero)
        return _pad_rows(x, n_pad, sr.zero)

    # vertex-major layout throughout: (n_pad,) or (n_pad, B), sharded on
    # the vertex axis; the (B,) batch axis stays replicated
    vspec = P(GRAPH_AXIS, None) if batched else P(GRAPH_AXIS)
    if warm is None:
        carry_in = (seed(init),)
        wspecs = (vspec,)
    else:
        carry_in = (seed(warm[0]), seed(warm[1]))
        wspecs = (vspec, vspec)
    if chunk:
        # the (B,) iteration counter rides along replicated; the chunk
        # path is batched-warm only (the resumable-carry contract)
        assert warm is not None and batched
        carry_in = carry_in + (jnp.asarray(it0, jnp.int32),)
        wspecs = wspecs + (P(None),)
    geo_in = (es.ssrc, es.sdst, es.sval, es.usrc, es.ustart) \
        if use_sparse else ()

    def changed_of(d_loc):
        """psum-reduced emptiness of the new Δ — the global convergence
        check every device agrees on (batched: per-source (B,) mask)."""
        if batched:
            local = jnp.any(d_loc != sr.zero, axis=0).astype(jnp.int32)
        else:
            local = jnp.any(d_loc != sr.zero).astype(jnp.int32)
        return jax.lax.psum(local, GRAPH_AXIS) > 0

    def body(coords, values, *rest):
        coords, values = coords[0], values[0]
        geo = tuple(g[0] for g in rest[:len(geo_in)])
        carry = rest[len(geo_in):]

        def dense_derive(d_loc):
            full = jax.lax.all_gather(pack(d_loc), GRAPH_AXIS, axis=0,
                                      tiled=True)
            if batched:
                full = unpack(full, d_loc.shape[1])
            return _local_derive(sr, coords, values, full, nb)

        def derive(d_loc, rc):
            if not use_sparse:
                return dense_derive(d_loc), rc.at[n_tiers].add(1)
            out, tier = _sparse_exchange_derive(
                sr, dense_derive, geo, d_loc, nb=nb, n_pad=n_pad,
                cap=es.capacity, caps=caps, batched=batched,
                batch=d_loc.shape[1] if batched else 1)
            return out, rc.at[tier].add(1)

        rc0 = jnp.zeros((n_tiers + 1,), jnp.int32)
        it_start = None
        if warm is None:
            (i_loc,) = carry
            x0 = jnp.full_like(i_loc, sr.zero)
            d0_raw, rc0 = derive(x0, rc0)
            d_loc = sr.minus(sr.add(i_loc, d0_raw), x0)
            # cold start mirrors the single-device runners exactly: the
            # first round always executes (live0 ≡ true), even when the
            # init is already a fixpoint — iteration counts must match
            # bit for bit.  Warm restarts check the seeded Δ instead.
            if batched:
                live0 = jnp.ones((d_loc.shape[1],), bool)
            else:
                live0 = jnp.asarray(True)
        else:
            if chunk:
                x0, d_loc, it_start = carry
            else:
                x0, d_loc = carry
            live0 = changed_of(d_loc)
        if batched:
            b = d_loc.shape[1]
            if it_start is None:
                it_start = jnp.zeros((b,), jnp.int32)

            def cond(c):
                y, d, live, it_rows, it, rc = c
                return jnp.logical_and(jnp.any(live), it < max_iters)

            def step(c):
                y, d, live, it_rows, it, rc = c
                y_new = sr.add(y, d)
                d_raw, rc = derive(d, rc)
                d_new = sr.minus(d_raw, y_new)
                live_new = changed_of(d_new)
                return y_new, d_new, live_new, it_rows + live, it + 1, rc

            y, d, _, it_rows, _, rc = jax.lax.while_loop(
                cond, step, (x0, d_loc, live0, it_start, jnp.asarray(0),
                             rc0))
            # per-source counts are psum-derived, identical on every
            # device — tile to (1, B) so the out spec stays sharded
            if chunk:
                return y, d, it_rows[None, :]
            return y, it_rows[None, :], rc[None, :]

        def cond(c):
            y, d, ch, it, rc = c
            return jnp.logical_and(ch, it < max_iters)

        def step(c):
            y, d, _, it, rc = c
            y_new = sr.add(y, d)
            d_raw, rc = derive(d, rc)
            d_new = sr.minus(d_raw, y_new)
            return y_new, d_new, changed_of(d_new), it + 1, rc

        y, _, _, iters, rc = jax.lax.while_loop(
            cond, step, (x0, d_loc, live0, jnp.asarray(0), rc0))
        return y, jnp.broadcast_to(iters, (1,)), rc[None, :]

    ispec = P(GRAPH_AXIS, None) if batched else P(GRAPH_AXIS)
    out_specs = (vspec, vspec, ispec) if chunk \
        else (vspec, ispec, P(GRAPH_AXIS, None))
    y, second, third = shard_map(
        body, mesh=mesh,
        in_specs=(P(GRAPH_AXIS), P(GRAPH_AXIS))
        + (P(GRAPH_AXIS),) * len(geo_in) + wspecs,
        out_specs=out_specs,
        check_rep=False)(
        es.coords, es.values, *geo_in, *carry_in)
    y = jnp.take(y, es.perm, axis=0) if es.perm is not None else y[:n]
    if chunk:
        d = jnp.take(second, es.perm, axis=0) if es.perm is not None \
            else second[:n]
        return y.T, d.T, third[0]
    iters, rounds = second, third
    if batched:
        return y.T, iters[0], rounds[0]
    return y, iters[0], rounds[0]
