"""Graph-axis sharded fixpoints: row-partitioned COO SpMM under shard_map.

The serve/incremental layers (DESIGN.md §3–§5) make the recursive matvec

    x[y]  =  init[y] ⊕ ⊕_z x[z] ⊗ E[z, y]

fast on one device, but the graph dimension ``n`` still had to fit that
device.  This module partitions the problem along a ``("graph",)`` mesh
axis instead (DESIGN.md §6): **destination-row blocks**.  Device ``k`` of
``D`` owns rows ``[k·nb, (k+1)·nb)`` of ``x``/``Δ`` (``nb = ⌈n/D⌉``) and
the edge tuples *landing* in that block — exactly the hash-partitioned
rule evaluation of Scaling-Up In-Memory Datalog (Fan et al.) with the
join key being the destination vertex, mapped onto semiring SpMM:

* the carry Δ is sharded by rows; one ``all_gather`` per iteration
  rebuilds the full frontier (the "exchange" of the Datalog engines);
* each device contracts its local COO block against the gathered
  frontier — per-shard O(nnz/D) gather/⊗/segment-reduce work into its
  ``nb`` output rows only;
* convergence is a ``psum``-reduced emptiness check of the new Δ, so
  every device leaves the ``lax.while_loop`` on the same iteration and
  the iteration count is bit-identical to the single-device runner.

The cold, warm-start (:func:`sharded_resume_fixpoint`, the incremental
§5 repair path), and batched ``(B, n)`` multi-source forms all share one
loop body, mirroring :mod:`repro.sparse.fixpoint`.

Sharded storage is a :class:`ShardedRelation`: per-shard padded COO
stacked on a leading device axis, local destination indices, global
source indices.  Padding follows the §2 discipline — source sentinel
``n_pad`` gathers the ⊗-identity fill, destination sentinel ``nb`` is
dropped by the scatter, padded values are 0̄ — so per-shard nnz may be
ragged under one static capacity and ``apply_delta`` can route new
tuples into padding slots without retracing compiled consumers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import semiring as sr_mod
from repro.sparse.coo import SparseRelation

try:  # jax ≥ 0.4.35 exposes shard_map at the top level eventually
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map  # type: ignore[attr-defined]

#: the mesh axis name every sharded fixpoint runs over
GRAPH_AXIS = "graph"


def mesh_size(mesh) -> int:
    """Device count along the graph axis of ``mesh`` (a Mesh with a
    "graph" axis, or a plain int D for planning/host-side partitioning)."""
    if isinstance(mesh, int):
        if mesh < 1:
            raise ValueError(f"device count must be ≥ 1, got {mesh}")
        return mesh
    if isinstance(mesh, Mesh):
        if GRAPH_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh {mesh.axis_names} has no "
                             f"{GRAPH_AXIS!r} axis — build one with "
                             f"launch.mesh.make_graph_mesh")
        return int(mesh.shape[GRAPH_AXIS])
    raise TypeError(f"mesh must be a Mesh or an int device count, "
                    f"got {type(mesh).__name__}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedRelation:
    """A binary S-relation partitioned into D destination-row blocks.

    ``coords[(D, cap, 2)]`` holds per-shard tuples as (global source,
    **local** destination); ``values[(D, cap)]`` their semiring values;
    ``nnz[(D,)]`` the ragged live counts.  ``cap`` is one static
    capacity shared by every shard so the type is a pytree whose leaves
    carry a leading device axis ready for ``P("graph")`` in/out specs.
    """

    coords: jnp.ndarray   # (D, cap, 2) int32 — [:, :, 0] global src,
    #                       [:, :, 1] local dst (block-relative)
    values: jnp.ndarray   # (D, cap) semiring dtype
    nnz: jnp.ndarray      # (D,) int32 live rows per shard
    shape: tuple[int, ...]
    semiring: str

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        return (self.coords, self.values, self.nnz), (self.shape,
                                                      self.semiring)

    @classmethod
    def tree_unflatten(cls, aux, children):
        coords, values, nnz = children
        shape, semiring = aux
        return cls(coords, values, nnz, shape, semiring)

    # -- basics ------------------------------------------------------------
    @property
    def d(self) -> int:
        """Shard count D (the graph-axis mesh size this was built for)."""
        return int(self.coords.shape[0])

    @property
    def capacity(self) -> int:
        """Per-shard static capacity."""
        return int(self.coords.shape[1])

    @property
    def row_block(self) -> int:
        """Destination rows per shard, ``nb = ⌈n/D⌉``."""
        return -(-self.shape[1] // self.d)

    @property
    def n_pad(self) -> int:
        """Padded global row count ``nb · D`` (≥ shape[1])."""
        return self.row_block * self.d

    @property
    def lib(self) -> str:
        return "np" if isinstance(self.values, np.ndarray) else "jnp"

    def total_nnz(self) -> int:
        return int(np.asarray(self.nnz).sum())

    def __repr__(self) -> str:
        return (f"ShardedRelation({self.semiring}{list(self.shape)}, "
                f"D={self.d}×nnz≤{self.capacity}, "
                f"rows/shard={self.row_block})")

    def as_jnp(self) -> "ShardedRelation":
        return ShardedRelation(jnp.asarray(self.coords),
                               jnp.asarray(self.values),
                               jnp.asarray(self.nnz, jnp.int32),
                               self.shape, self.semiring)

    def as_np(self) -> "ShardedRelation":
        return ShardedRelation(np.asarray(self.coords),
                               np.asarray(self.values),
                               np.asarray(self.nnz, np.int32),
                               self.shape, self.semiring)

    # -- streaming updates -------------------------------------------------
    def apply_delta(self, coords, values=None) -> "ShardedRelation":
        """⊕-merge a batch of global-coordinate tuple updates, routing
        each row to its owning destination shard (DESIGN.md §5/§6).

        The incremental overlay discipline of
        :meth:`repro.sparse.coo.SparseRelation.apply_delta` carries over
        shard-wise: rows land in padding slots while every shard fits
        (static capacity — and therefore the compiled fixpoint's trace —
        unchanged), appended duplicates are left for the ⊕-combining
        consumers to merge, and overflow re-pads **all** shards by
        doubling until the worst shard's live count fits (one uniform
        capacity keeps the stacked pytree rectangular; amortized-O(1),
        one retrace per doubling — the §5 discipline, shard-wise).
        """
        sr = sr_mod.get(self.semiring, lib="np")
        coords = np.asarray(coords, np.int64).reshape(-1, 2)
        if values is None:
            values = np.full(len(coords), sr.one, sr.dtype)
        values = np.asarray(values, sr.dtype).reshape(-1)
        assert len(coords) == len(values), (coords.shape, values.shape)
        if np.any(coords < 0) or np.any(coords >= np.asarray(self.shape)):
            raise ValueError("delta coordinates out of range for shape "
                             f"{self.shape}")
        live = values if self.semiring == "bool" else values != sr.zero
        coords, values = coords[live], values[live]
        if len(values) == 0:
            return self
        host = self.as_np()
        nb = self.row_block
        owner = coords[:, 1] // nb
        k = host.nnz.astype(np.int64)
        add = np.bincount(owner, minlength=self.d)
        need = k + add
        cap = self.capacity
        if int(need.max()) > cap:
            cap = max(1, cap)
            while cap < int(need.max()):
                cap <<= 1
        new_coords = np.empty((self.d, cap, 2), np.int32)
        new_coords[:, :, 0] = self.n_pad
        new_coords[:, :, 1] = nb
        new_values = np.full((self.d, cap), sr.zero, sr.dtype)
        new_coords[:, :self.capacity] = host.coords
        new_values[:, :self.capacity] = host.values
        for s in range(self.d):
            sel = owner == s
            if not sel.any():
                continue
            lo = int(k[s])
            hi = lo + int(sel.sum())
            new_coords[s, lo:hi, 0] = coords[sel, 0]
            new_coords[s, lo:hi, 1] = coords[sel, 1] - s * nb
            new_values[s, lo:hi] = values[sel]
        out = ShardedRelation(new_coords, new_values,
                              need.astype(np.int32), self.shape,
                              self.semiring)
        return out if self.lib == "np" else out.as_jnp()


def shard_relation(rel: SparseRelation, mesh) -> ShardedRelation:
    """Partition a binary :class:`SparseRelation` into per-device
    destination-row blocks for ``mesh`` (host-side, one pass).

    Shard ``k`` receives every live tuple ``(i, j, w)`` with
    ``j ∈ [k·nb, (k+1)·nb)``, stored as ``(i, j - k·nb)``.  All shards
    share one capacity (the worst shard's nnz, min 1) so the stacked
    buffers stay rectangular; per-shard nnz is ragged.
    """
    if rel.arity != 2:
        raise ValueError(f"graph sharding needs a binary relation, got "
                         f"arity {rel.arity}")
    d = mesh_size(mesh)
    host = rel.as_np()
    k = int(host.nnz)
    src = host.coords[:k, 0].astype(np.int64)
    dst = host.coords[:k, 1].astype(np.int64)
    w = host.values[:k]
    nb = -(-rel.shape[1] // d)
    n_pad = nb * d
    owner = dst // nb
    counts = np.bincount(owner, minlength=d)
    cap = max(1, int(counts.max()) if k else 1)
    sr = sr_mod.get(rel.semiring, lib="np")
    coords = np.empty((d, cap, 2), np.int32)
    coords[:, :, 0] = n_pad
    coords[:, :, 1] = nb
    values = np.full((d, cap), sr.zero, sr.dtype)
    order = np.argsort(owner, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in range(d):
        sel = order[starts[s]:starts[s + 1]]
        c = len(sel)
        coords[s, :c, 0] = src[sel]
        coords[s, :c, 1] = dst[sel] - s * nb
        values[s, :c] = w[sel]
    out = ShardedRelation(coords, values, counts.astype(np.int32),
                          rel.shape, rel.semiring)
    return out if rel.lib == "np" else out.as_jnp()


def unshard(sh: ShardedRelation, *,
            capacity: int | None = None) -> SparseRelation:
    """Reassemble the global COO relation (host-side, coalescing ⊕ at
    duplicate keys — the round-trip inverse of :func:`shard_relation`)."""
    host = sh.as_np()
    nb = sh.row_block
    coords, values = [], []
    for s in range(sh.d):
        c = int(host.nnz[s])
        blk = host.coords[s, :c].astype(np.int64)
        coords.append(np.stack([blk[:, 0], blk[:, 1] + s * nb], axis=1))
        values.append(host.values[s, :c])
    coords = np.concatenate(coords) if coords else np.zeros((0, 2),
                                                            np.int64)
    values = np.concatenate(values) if values else np.zeros(
        0, sr_mod.get(sh.semiring, lib="np").dtype)
    return SparseRelation.from_coo(coords, values, sh.shape, sh.semiring,
                                   capacity=capacity, lib=sh.lib)


# --------------------------------------------------------------------------
# The sharded GSN loop
# --------------------------------------------------------------------------


def _local_derive(sr, coords, values, d_full, nb: int):
    """One shard's δF: gather the gathered frontier at the global source
    coordinates, ⊗ with the local edge values, ⊕-segment-reduce by local
    destination.  ``d_full`` is (n_pad,) or (n_pad, B); the result is
    (nb,) or (nb, B).  The padding discipline (sentinel src → ⊗-identity
    fill, 0̄ values, OOB dst dropped) makes ragged per-shard nnz exact."""
    from repro.kernels import ops as kops
    gathered = jnp.take(d_full, coords[:, 0], axis=0, mode="fill",
                        fill_value=sr.one)
    if d_full.ndim == 1:
        prod = sr.mul(values, gathered)
    else:
        prod = sr.mul(values[:, None], gathered)
    return kops.semiring_segment_reduce(sr, prod, coords[:, 1], nb)


def _pad_rows(x, n_pad: int, fill):
    """Zero-pad the vertex axis (axis 0) of a (n,)/(n, B) array to
    ``n_pad`` phantom rows (0̄ init, never referenced by any edge)."""
    n = x.shape[0]
    if n == n_pad:
        return x
    pad = jnp.full((n_pad - n,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def sharded_seminaive_fixpoint(edges, init, *, mesh: Mesh,
                               max_iters: int = 10_000):
    """Least fixpoint of ``x = init ⊕ x ⊗ E`` with the graph axis
    partitioned across ``mesh`` (module docstring).

    ``edges`` is a :class:`ShardedRelation` built for the mesh's D (or a
    plain :class:`SparseRelation`, sharded here).  ``init`` may be
    ``(n,)`` or a batched ``(B, n)`` multi-source pack; results and
    iteration counts match :func:`repro.sparse.fixpoint.
    sparse_seminaive_fixpoint` exactly, row for row.
    """
    return _dispatch(edges, mesh, init=init, max_iters=max_iters)


def sharded_resume_fixpoint(edges, y0, d0, *, mesh: Mesh,
                            max_iters: int = 10_000):
    """Warm-start re-convergence from a ``(y0, d0)`` pre-fixpoint pair —
    the sharded twin of :func:`repro.sparse.fixpoint.resume_fixpoint`,
    sharing this module's loop body.  Used by the serve loop to repair
    warm answers after a monotone update (DESIGN.md §5/§6)."""
    return _dispatch(edges, mesh, warm=(y0, d0), max_iters=max_iters)


def sharded_contract(edges, x, *, mesh: Mesh):
    """One sharded ``x ⊗ E`` application: all-gather the operand, derive
    locally, return the row-sharded product reassembled to ``(n,)`` /
    ``(B, n)``.  Defined for *every* semiring (no ⊖ needed) — the
    exact-agreement probe for non-lattice semirings like ℕ∞."""
    es = _as_sharded(edges, mesh)
    sr = sr_mod.get(es.semiring)
    batched = np.ndim(x) == 2
    n, nb, n_pad = es.shape[1], es.row_block, es.n_pad
    xv = jnp.asarray(x).T if batched else jnp.asarray(x)
    xv = _pad_rows(xv, n_pad, sr.zero)
    vspec = P(GRAPH_AXIS, None) if batched else P(GRAPH_AXIS)

    def body(coords, values, x_loc):
        full = jax.lax.all_gather(x_loc, GRAPH_AXIS, axis=0, tiled=True)
        return _local_derive(sr, coords[0], values[0], full, nb)

    out = shard_map(body, mesh=mesh,
                    in_specs=(P(GRAPH_AXIS), P(GRAPH_AXIS), vspec),
                    out_specs=vspec, check_rep=False)(
        es.coords, es.values, xv)
    out = out[:n]
    return out.T if batched else out


def _as_sharded(edges, mesh) -> ShardedRelation:
    if isinstance(edges, ShardedRelation):
        if edges.d != mesh_size(mesh):
            raise ValueError(
                f"relation sharded for D={edges.d} cannot run on a "
                f"{mesh_size(mesh)}-device graph mesh — re-shard it")
        return edges.as_jnp()
    if isinstance(edges, SparseRelation):
        return shard_relation(edges, mesh).as_jnp()
    raise TypeError(f"edges must be a SparseRelation or ShardedRelation, "
                    f"got {type(edges).__name__}")


def _dispatch(edges, mesh, *, init=None, warm=None, max_iters=10_000):
    es = _as_sharded(edges, mesh)
    if es.shape[0] != es.shape[1]:
        raise ValueError(f"recursive expansion needs a square binary "
                         f"edge relation, got shape {es.shape}")
    sr = sr_mod.get(es.semiring)
    if sr.minus is None:
        raise ValueError(f"semiring {sr.name} lacks ⊖; "
                         "GSN needs an idempotent complete lattice")
    batched = np.ndim(init if warm is None else warm[0]) == 2
    n, nb, n_pad = es.shape[1], es.row_block, es.n_pad
    # vertex-major layout throughout: (n_pad,) or (n_pad, B), sharded on
    # the vertex axis; the (B,) batch axis stays replicated
    vspec = P(GRAPH_AXIS, None) if batched else P(GRAPH_AXIS)
    if warm is None:
        iv = jnp.asarray(init)
        iv = _pad_rows(iv.T if batched else iv, n_pad, sr.zero)
        carry_in = (iv,)
        wspecs = (vspec,)
    else:
        y0, d0 = (jnp.asarray(warm[0]), jnp.asarray(warm[1]))
        y0 = _pad_rows(y0.T if batched else y0, n_pad, sr.zero)
        d0 = _pad_rows(d0.T if batched else d0, n_pad, sr.zero)
        carry_in = (y0, d0)
        wspecs = (vspec, vspec)

    def changed_of(d_loc):
        """psum-reduced emptiness of the new Δ — the global convergence
        check every device agrees on (batched: per-source (B,) mask)."""
        if batched:
            local = jnp.any(d_loc != sr.zero, axis=0).astype(jnp.int32)
        else:
            local = jnp.any(d_loc != sr.zero).astype(jnp.int32)
        return jax.lax.psum(local, GRAPH_AXIS) > 0

    def body(coords, values, *carry):
        coords, values = coords[0], values[0]

        def derive(d_loc):
            full = jax.lax.all_gather(d_loc, GRAPH_AXIS, axis=0,
                                      tiled=True)
            return _local_derive(sr, coords, values, full, nb)

        if warm is None:
            (i_loc,) = carry
            x0 = jnp.full_like(i_loc, sr.zero)
            d_loc = sr.minus(sr.add(i_loc, derive(x0)), x0)
            # cold start mirrors the single-device runners exactly: the
            # first round always executes (live0 ≡ true), even when the
            # init is already a fixpoint — iteration counts must match
            # bit for bit.  Warm restarts check the seeded Δ instead.
            if batched:
                live0 = jnp.ones((d_loc.shape[1],), bool)
            else:
                live0 = jnp.asarray(True)
        else:
            x0, d_loc = carry
            live0 = changed_of(d_loc)
        if batched:
            b = d_loc.shape[1]
            it0 = jnp.zeros((b,), jnp.int32)

            def cond(c):
                y, d, live, it_rows, it = c
                return jnp.logical_and(jnp.any(live), it < max_iters)

            def step(c):
                y, d, live, it_rows, it = c
                y_new = sr.add(y, d)
                d_new = sr.minus(derive(d), y_new)
                live_new = changed_of(d_new)
                return y_new, d_new, live_new, it_rows + live, it + 1

            y, _, _, it_rows, _ = jax.lax.while_loop(
                cond, step, (x0, d_loc, live0, it0, jnp.asarray(0)))
            # per-source counts are psum-derived, identical on every
            # device — tile to (1, B) so the out spec stays sharded
            return y, it_rows[None, :]

        def cond(c):
            y, d, ch, it = c
            return jnp.logical_and(ch, it < max_iters)

        def step(c):
            y, d, _, it = c
            y_new = sr.add(y, d)
            d_new = sr.minus(derive(d), y_new)
            return y_new, d_new, changed_of(d_new), it + 1

        y, _, _, iters = jax.lax.while_loop(
            cond, step, (x0, d_loc, live0, jnp.asarray(0)))
        return y, jnp.broadcast_to(iters, (1,))

    ispec = P(GRAPH_AXIS, None) if batched else P(GRAPH_AXIS)
    y, iters = shard_map(
        body, mesh=mesh,
        in_specs=(P(GRAPH_AXIS), P(GRAPH_AXIS)) + wspecs,
        out_specs=(vspec, ispec), check_rep=False)(
        es.coords, es.values, *carry_in)
    y = y[:n]
    if batched:
        return y.T, iters[0]
    return y, iters[0]
