"""Distributed runtime: sharding rules, collectives, fault tolerance,
and graph-axis sharded Datalog fixpoints (DESIGN.md §6)."""

from repro.distributed.datalog import (  # noqa: F401
    GRAPH_AXIS,
    ShardedRelation,
    shard_relation,
    sharded_contract,
    sharded_resume_fixpoint,
    sharded_seminaive_fixpoint,
    unshard,
)
