"""Distributed runtime: sharding rules, collectives, fault tolerance."""
