"""Fleet orchestration: heartbeats, straggler detection, restart policy.

The coordinator supervises one worker process per host.  Mechanisms (all
testable locally with mock workers — tests/test_fault_tolerance.py):

* **Heartbeats** — workers touch a per-host heartbeat file every step; the
  coordinator marks a host dead after ``dead_after`` seconds of silence
  and triggers a restart-from-latest-checkpoint of the fleet (the data
  pipeline's deterministic addressing makes this exactly-once).
* **Straggler mitigation** — per-step durations are reported in the
  heartbeat payload; a host whose p50 over the last window exceeds
  ``straggler_factor`` × fleet-median is flagged and (policy) restarted or
  excluded — with reshard-on-restore the fleet can come back at a smaller
  mesh (elastic scale-down) instead of waiting.
* **Elasticity** — `plan_remesh` picks the largest (data, model) mesh that
  the surviving host set supports; checkpoint restore re-shards onto it.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time


@dataclasses.dataclass
class FTConfig:
    heartbeat_dir: str
    dead_after: float = 60.0
    straggler_factor: float = 1.5
    window: int = 20


class HeartbeatWriter:
    """Worker side: called once per step."""

    def __init__(self, cfg: FTConfig, host: int):
        self.path = os.path.join(cfg.heartbeat_dir, f"host_{host}.json")
        os.makedirs(cfg.heartbeat_dir, exist_ok=True)
        self._durations: list[float] = []
        self._last = time.time()
        self.window = cfg.window

    def beat(self, step: int):
        now = time.time()
        self._durations.append(now - self._last)
        self._last = now
        self._durations = self._durations[-self.window:]
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now,
                       "durations": self._durations}, f)
        os.replace(tmp, self.path)


@dataclasses.dataclass
class HostStatus:
    host: int
    alive: bool
    step: int
    p50_step_s: float
    straggler: bool


class Coordinator:
    """Coordinator side: poll heartbeats, decide restarts/remesh."""

    def __init__(self, cfg: FTConfig, n_hosts: int):
        self.cfg = cfg
        self.n_hosts = n_hosts

    def poll(self, now: float | None = None) -> list[HostStatus]:
        now = now or time.time()
        stats = []
        for h in range(self.n_hosts):
            path = os.path.join(self.cfg.heartbeat_dir, f"host_{h}.json")
            try:
                with open(path) as f:
                    hb = json.load(f)
                alive = (now - hb["time"]) < self.cfg.dead_after
                dur = sorted(hb.get("durations", [0.0]))
                p50 = dur[len(dur) // 2]
                stats.append(HostStatus(h, alive, hb.get("step", -1), p50,
                                        False))
            except (FileNotFoundError, json.JSONDecodeError):
                stats.append(HostStatus(h, False, -1, float("inf"), False))
        med = sorted(s.p50_step_s for s in stats if s.alive)
        fleet_p50 = med[len(med) // 2] if med else 0.0
        for s in stats:
            if s.alive and fleet_p50 > 0 and \
                    s.p50_step_s > self.cfg.straggler_factor * fleet_p50:
                s.straggler = True
        return stats

    def decide(self, stats: list[HostStatus]) -> dict:
        dead = [s.host for s in stats if not s.alive]
        stragglers = [s.host for s in stats if s.straggler]
        if dead:
            return {"action": "restart_from_checkpoint", "lost": dead,
                    "remesh": plan_remesh(self.n_hosts - len(dead))}
        if stragglers:
            return {"action": "restart_hosts", "hosts": stragglers}
        return {"action": "none"}


def plan_remesh(usable_hosts: int, chips_per_host: int = 4,
                model_parallel: int = 16) -> dict:
    """Largest (data, model) mesh on the surviving chips (elastic)."""
    chips = usable_hosts * chips_per_host
    model = min(model_parallel, chips)
    data = max(1, chips // model)
    # keep powers of two on the data axis for even batch sharding
    p = 1
    while p * 2 <= data:
        p *= 2
    return {"data": p, "model": model, "chips_used": p * model}
