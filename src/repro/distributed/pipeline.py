"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

For very deep models (llama3's 126 layers) an alternative to pure scan:
split the layer stack into S stages mapped onto a "stage" mesh axis and
stream M microbatches through with `jax.lax.ppermute` handoffs inside a
`shard_map`.  The schedule is the classic fill/steady/drain loop
(S + M - 1 ticks); bubble fraction = (S-1)/(S+M-1).

This module is self-contained (works on any callable stage function) and
is exercised by tests/test_pipeline.py on local devices; the production
launcher can map "stage" onto the pod axis for cross-pod pipelining,
which converts the per-layer FSDP all-gathers into point-to-point
activation handoffs — the standard trade when DCN bandwidth is the
constraint (DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipelined_forward(stage_fn, n_stages: int, n_micro: int):
    """Build fn(stage_params, x_micro) -> y running inside shard_map.

    stage_params: leaves with a leading stage axis (sharded on "stage");
    x_micro: (n_micro, micro_batch, ...) microbatched input, replicated.
    Each device executes its stage; activations hop stage→stage+1 via
    ppermute; outputs collect from the last stage.
    """

    def body(params, xs):
        idx = jax.lax.axis_index("stage")
        ticks = n_stages + n_micro - 1
        micro_shape = xs.shape[1:]
        buf = jnp.zeros(micro_shape, xs.dtype)      # current activation
        outs = jnp.zeros((n_micro,) + micro_shape, xs.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when available)
            feed = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jnp.where(idx == 0, xs[feed], buf)
            y = stage_fn(params, x_in)
            # drop garbage during fill for stage>t
            y = jnp.where(idx <= t, y, jnp.zeros_like(y))
            # last stage emits microbatch t-(S-1)
            out_slot = t - (n_stages - 1)
            slot = jnp.clip(out_slot, 0, n_micro - 1)
            emit = (idx == n_stages - 1) & (out_slot >= 0) & \
                (out_slot < n_micro)
            outs = jax.lax.cond(
                emit, lambda o: o.at[slot].set(y), lambda o: o, outs)
            # hand activations to the next stage
            buf = jax.lax.ppermute(
                y, "stage",
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)),
            "stage")
        return outs

    return body


def run_pipeline(mesh: Mesh, stage_fn, stage_params, x_micro, *,
                 n_stages: int, n_micro: int):
    """Execute the pipeline on ``mesh`` (must have a "stage" axis)."""
    body = pipelined_forward(stage_fn, n_stages, n_micro)
    param_spec = jax.tree.map(lambda _: P("stage"), stage_params)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(param_spec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)
