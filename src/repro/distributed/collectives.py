"""Distributed-optimization tricks: compressed gradient reduction.

Under pjit, data-parallel gradient reduction is implicit (XLA inserts the
all-reduce).  These helpers implement the *compressed* variants as
shard_map collectives for bandwidth-bound interconnects (DCN between
pods):

* ``bf16_all_reduce`` — cast f32 grads to bf16 for the wire, accumulate
  back in f32 (2× DCN volume reduction, standard at pod boundaries);
* ``int8_all_reduce`` — per-tensor scale + int8 quantization with error
  feedback residual carried by the caller (4×);
* both are exposed through ``compressed_grad_reduce`` which reduces over
  an explicit mesh axis inside shard_map — the training driver uses it
  for the "pod" axis while leaving the intra-pod reduction to XLA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def bf16_all_reduce(x, axis_name: str):
    return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(x.dtype)


def int8_all_reduce(x, axis_name: str):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    # sum int8 payloads in int32, then rescale; scales are psum-averaged
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s = jax.lax.psum(scale, axis_name) / jax.lax.psum(1, axis_name)
    return (total.astype(jnp.float32) * s).astype(x.dtype)


def compressed_grad_reduce(grads, mesh, axis_name: str = "pod",
                           mode: str = "bf16"):
    """Reduce a grad pytree over ``axis_name`` with wire compression."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    red = bf16_all_reduce if mode == "bf16" else int8_all_reduce

    def body(g):
        return jax.tree.map(lambda t: red(t, axis_name) /
                            jax.lax.psum(1, axis_name), g)

    spec = jax.tree.map(lambda _: P(), grads)
    return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_rep=False)(grads)
