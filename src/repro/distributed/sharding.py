"""Logical-axis sharding: the bridge between models and meshes.

Models annotate parameters/activations with *logical* axis names
("embed", "heads", "batch", …).  The launcher installs a rule set mapping
logical → mesh axes for the current mesh + workload shape; `constrain`
then applies `with_sharding_constraint` only when a mesh is active, so the
same model code runs unsharded on CPU tests and fully sharded under pjit.

Rule sets are divisibility-aware: a logical axis maps to the first mesh
axis (or axis tuple) whose size divides the dimension; otherwise it stays
unsharded.  This is what lets e.g. an 8-kv-head cache fall back from a
16-way "model" axis to sequence sharding (DESIGN.md §6).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> dict | None:
    return getattr(_state, "rules", None)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict):
    """Install logical→mesh axis rules for the duration of a lowering."""
    prev = (current_mesh(), current_rules())
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_for(logical: tuple, shape: tuple | None = None,
             mesh: Mesh | None = None, rules: dict | None = None) -> P:
    """Map logical axes to a PartitionSpec, skipping non-divisible dims."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules() or {}
    parts = []
    used: set = set()
    for i, name in enumerate(logical):
        options = rules.get(name, None)
        if options is None:
            parts.append(None)
            continue
        if not isinstance(options, list):
            options = [options]
        chosen = None
        for axis in options:
            axes = axis if isinstance(axis, tuple) else (axis,)
            if any(a in used for a in axes):
                continue
            if shape is not None and mesh is not None:
                if shape[i] % _axis_size(mesh, axis) != 0:
                    continue
            chosen = axis
            break
        if chosen is not None:
            used.update(chosen if isinstance(chosen, tuple) else (chosen,))
        parts.append(chosen)
    return P(*parts)


def constrain(x, logical: tuple):
    """with_sharding_constraint when a mesh is active; no-op otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def put(x, logical: tuple):
    """``device_put`` with the resolved NamedSharding when a mesh is
    active; identity otherwise.  Host-side twin of :func:`constrain` —
    the serve loop uses it to lay out a packed query batch across the
    data axis before dispatching the compiled fixpoint."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape, mesh)
    return jax.device_put(x, NamedSharding(mesh, spec))


def tree_shardings(specs, shapes, mesh: Mesh, rules: dict):
    """NamedShardings for a whole param tree given logical-spec tree."""
    def one(spec, shape_struct):
        return NamedSharding(mesh, spec_for(tuple(spec), shape_struct.shape,
                                            mesh, rules))
    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda s: isinstance(s, tuple))
