"""The paper's benchmark programs (Fig. 10, Appendix B, Figs. 14–20).

Each benchmark bundles the *original* program Π₁, the *known optimized*
program Π₂ (the paper's published FGH rewrite — used as ground truth for
the synthesizer tests and as the executable optimized form), and a database
builder.  The FGH optimizer (repro.core.fgh) re-derives Π₂'s recursive rule
H from Π₁; benchmarks then measure original-vs-optimized runtime like the
paper's Figs. 11–12.

Dense-domain note: programs that key on numeric values (SSSP's D(x,d),
R's TC(x,y,w), WS's W(t,j,w)) materialize the value domain densely — this
faithfully reproduces the asymptotic waste the FGH rewrite removes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import engine, ir
from repro.core.ir import C, ConstAtom, PredAtom, RelAtom, Term, ValAtom
from repro.core.program import Program, Rule, Stratum
from repro.datalog import datasets


@dataclasses.dataclass
class Bench:
    name: str
    original: Program
    optimized: Program
    make_db: Callable[..., engine.Database]
    constraint: str | None = None      # 'tree' → Γ-constrained verification
    needs_invariant: bool = False      # paper Fig. 10 column
    synthesis: str = "rule"            # 'rule' | 'cegis' (paper Fig. 10)
    optimized_fn: Callable | None = None  # host-JAX optimized form (BC)


def _ssp(head, terms, sr):
    return ir.normalize(ir.SSP(tuple(head), tuple(terms), sr))


def _t(atoms, bound=()):
    return Term(tuple(atoms), tuple(bound))


# --------------------------------------------------------------------------
# BM — Beyond Magic (Example 3.8 / Fig. 14): right-recursive reachability
# --------------------------------------------------------------------------


def bm(a: int = 0) -> Bench:
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    schema.declare("V", ("id",), "bool")
    schema.declare("TC", ("id", "id"), "bool")
    schema.declare("Q", ("id",), "bool")

    f_tc = Rule("TC", _ssp(("x", "y"), [
        _t([RelAtom("V", ("x",)), PredAtom("eq", ("x", "y"))]),
        _t([RelAtom("E", ("x", "z")), RelAtom("TC", ("z", "y"))], ["z"]),
    ], "bool"))
    g = Rule("Q", _ssp(("y",), [_t([RelAtom("TC", (C(a), "y"))])], "bool"))
    original = Program("BM", schema, [Stratum({"TC": f_tc})], [g])

    h = Rule("Q", _ssp(("y",), [
        _t([PredAtom("eq", ("y", C(a))), RelAtom("V", (C(a),))]),
        _t([RelAtom("Q", ("z",)), RelAtom("E", ("z", "y"))], ["z"]),
    ], "bool"))
    out = Rule("Qans", _ssp(("y",), [_t([RelAtom("Q", ("y",))])], "bool"))
    optimized = Program("BM_opt", schema, [Stratum({"Q": h})], [out])

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n}, {
            "E": g_.adjacency(), "V": g_.vertex_set()})

    return Bench("BM", original, optimized, make_db,
                 needs_invariant=True, synthesis="rule")


# --------------------------------------------------------------------------
# CC — Connected Components (Fig. 1 / Fig. 15)
# --------------------------------------------------------------------------


def cc() -> Bench:
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    schema.declare("V", ("id",), "bool")
    schema.declare("TC", ("id", "id"), "bool")
    schema.declare("CC", ("id",), "trop")

    f_tc = Rule("TC", _ssp(("x", "y"), [
        _t([RelAtom("V", ("x",)), PredAtom("eq", ("x", "y"))]),
        _t([RelAtom("E", ("x", "z")), RelAtom("TC", ("z", "y"))], ["z"]),
    ], "bool"))
    # SCC[x] = min_v { v | TC(x, v) }   (vertex id is its own label)
    g = Rule("CC", _ssp(("x",), [
        _t([ValAtom("v"), RelAtom("TC", ("x", "v"), cast=True)], ["v"]),
    ], "trop"))
    original = Program("CC", schema, [Stratum({"TC": f_tc})], [g])

    h = Rule("CC", _ssp(("x",), [
        _t([ValAtom("x"), RelAtom("V", ("x",), cast=True)]),
        _t([RelAtom("CC", ("y",)), RelAtom("E", ("x", "y"), cast=True)], ["y"]),
    ], "trop"))
    out = Rule("CCans", _ssp(("x",), [_t([RelAtom("CC", ("x",))])], "trop"))
    optimized = Program("CC_opt", schema, [Stratum({"CC": h})], [out])

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n}, {
            "E": g_.adjacency(symmetric=True), "V": g_.vertex_set()})

    return Bench("CC", original, optimized, make_db, synthesis="rule")


# --------------------------------------------------------------------------
# SSSP — Single-Source Shortest Paths (Fig. 16)
# --------------------------------------------------------------------------


def sssp(a: int = 0, wmax: int = 8, dmax: int = 64) -> Bench:
    schema = ir.Schema()
    schema.declare("E3", ("id", "id", "w"), "bool")   # E(y, x, d2)
    schema.declare("D", ("id", "d"), "bool")
    schema.declare("SP", ("id",), "trop")

    f_d = Rule("D", _ssp(("x", "d"), [
        _t([PredAtom("eq", ("x", C(a))), PredAtom("eq", ("d", C(0)))]),
        _t([RelAtom("D", ("y", "d1")), RelAtom("E3", ("y", "x", "d2")),
            PredAtom("sum3", ("d", "d1", "d2"))], ["y", "d1", "d2"]),
    ], "bool"))
    g = Rule("SP", _ssp(("x",), [
        _t([ValAtom("d"), RelAtom("D", ("x", "d"), cast=True)], ["d"]),
    ], "trop"))
    original = Program("SSSP", schema, [Stratum({"D": f_d})], [g])

    h = Rule("SP", _ssp(("x",), [
        _t([PredAtom("eq", ("x", C(a)))]),
        _t([RelAtom("SP", ("y",)), RelAtom("E3", ("y", "x", "d2"), cast=True),
            ValAtom("d2")], ["y", "d2"]),
    ], "trop"))
    out = Rule("SPans", _ssp(("x",), [_t([RelAtom("SP", ("x",))])], "trop"))
    optimized = Program("SSSP_opt", schema, [Stratum({"SP": h})], [out])

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n, "w": wmax, "d": dmax}, {
            "E3": g_.weighted_adjacency(wmax)})

    return Bench("SSSP", original, optimized, make_db, synthesis="rule")


# --------------------------------------------------------------------------
# WS — Window Sum (Fig. 17)
# --------------------------------------------------------------------------


def ws(window: int = 10, vmax: int = 8) -> Bench:
    schema = ir.Schema()
    schema.declare("A2", ("pos", "w"), "bool")      # A(j, w)
    schema.declare("W", ("pos", "pos", "w"), "bool")
    schema.declare("P", ("pos",), "nat")

    f_w = Rule("W", _ssp(("t", "j", "w"), [
        _t([RelAtom("A2", ("j", "w")), PredAtom("eq", ("t", "j"))]),
        _t([PredAtom("succ", ("t", "s")), RelAtom("W", ("s", "j", "w")),
            PredAtom("lt", ("j", "t"))], ["s"]),
    ], "bool"))
    g = Rule("P", _ssp(("t",), [
        _t([ValAtom("w"), RelAtom("W", ("t", "j", "w"), cast=True)],
           ["j", "w"]),
    ], "nat"))

    def post(p, db):  # S[t] = P[t] - P[t-window]
        shifted = jnp.concatenate([jnp.zeros(window, p.dtype), p[:-window]])
        return p - shifted

    original = Program("WS", schema, [Stratum({"W": f_w})], [g], post=post)

    h = Rule("P", _ssp(("t",), [
        _t([ValAtom("w"), RelAtom("A2", ("t", "w"), cast=True)], ["w"]),
        _t([PredAtom("succ", ("t", "s")), RelAtom("P", ("s",))], ["s"]),
    ], "nat"))
    out = Rule("Pans", _ssp(("t",), [_t([RelAtom("P", ("t",))])], "nat"))
    optimized = Program("WS_opt", schema, [Stratum({"P": h})], [out],
                        post=post)

    def make_db(values: np.ndarray) -> engine.Database:
        n = len(values)
        a2 = np.zeros((n, vmax), bool)
        a2[np.arange(n), np.minimum(values, vmax - 1)] = True
        return engine.Database(schema, {"pos": n, "w": vmax},
                               {"A2": jnp.asarray(a2)})

    return Bench("WS", original, optimized, make_db,
                 needs_invariant=True, synthesis="cegis")


# --------------------------------------------------------------------------
# R — Graph Radius (Fig. 19); semantic optimization on trees
# --------------------------------------------------------------------------


def radius(dmax: int = 64) -> Bench:
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    schema.declare("V", ("id",), "bool")
    schema.declare("TC3", ("id", "id", "d"), "bool")
    schema.declare("SP2", ("id", "id"), "trop")
    schema.declare("R", ("id",), "maxplus")

    f_tc = Rule("TC3", _ssp(("x", "y", "w"), [
        _t([RelAtom("V", ("x",)), PredAtom("eq", ("x", "y")),
            PredAtom("eq", ("w", C(0)))]),
        _t([RelAtom("TC3", ("x", "z", "w1")), RelAtom("E", ("z", "y")),
            PredAtom("succ", ("w", "w1"))], ["z", "w1"]),
    ], "bool"))
    g_sp = Rule("SP2", _ssp(("x", "y"), [
        _t([ValAtom("w"), RelAtom("TC3", ("x", "y", "w"), cast=True)], ["w"]),
    ], "trop"))
    g_r = Rule("R", _ssp(("x",), [
        _t([RelAtom("SP2", ("x", "y"), cast=True)], ["y"]),
    ], "maxplus"))
    original = Program("R", schema, [Stratum({"TC3": f_tc})], [g_sp, g_r])

    h = Rule("R", _ssp(("x",), [
        _t([RelAtom("V", ("x",), cast=True)]),
        _t([RelAtom("R", ("y",)), RelAtom("E", ("x", "y"), cast=True),
            ConstAtom(1.0)], ["y"]),
    ], "maxplus"))
    out = Rule("Rans", _ssp(("x",), [_t([RelAtom("R", ("x",))])], "maxplus"))
    optimized = Program("R_opt", schema, [Stratum({"R": h})], [out])

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n, "d": dmax}, {
            "E": g_.adjacency(), "V": g_.vertex_set()})

    return Bench("R", original, optimized, make_db,
                 constraint="tree", needs_invariant=True, synthesis="cegis")


# --------------------------------------------------------------------------
# MLM — Multi-Level Marketing (Example 3.9 / Fig. 20); trees
# --------------------------------------------------------------------------


def mlm() -> Bench:
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    schema.declare("V", ("id",), "bool")
    schema.declare("TC", ("id", "id"), "bool")
    schema.declare("M", ("id",), "nat")

    f_tc = Rule("TC", _ssp(("x", "y"), [
        _t([RelAtom("V", ("x",)), PredAtom("eq", ("x", "y"))]),
        _t([RelAtom("TC", ("x", "z")), RelAtom("E", ("z", "y"))], ["z"]),
    ], "bool"))
    g = Rule("M", _ssp(("x",), [
        _t([ValAtom("v"), RelAtom("TC", ("x", "v"), cast=True)], ["v"]),
    ], "nat"))
    original = Program("MLM", schema, [Stratum({"TC": f_tc})], [g])

    h = Rule("M", _ssp(("x",), [
        _t([ValAtom("x"), RelAtom("V", ("x",), cast=True)]),
        _t([RelAtom("M", ("z",)), RelAtom("E", ("x", "z"), cast=True)], ["z"]),
    ], "nat"))
    out = Rule("Mans", _ssp(("x",), [_t([RelAtom("M", ("x",))])], "nat"))
    optimized = Program("MLM_opt", schema, [Stratum({"M": h})], [out])

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n}, {
            "E": g_.adjacency(), "V": g_.vertex_set()})

    return Bench("MLM", original, optimized, make_db,
                 constraint="tree", needs_invariant=True, synthesis="cegis")


# --------------------------------------------------------------------------
# APSP100 — Example 5.1 (verifier showcase: factorized constant)
# --------------------------------------------------------------------------


def apsp100(cap: float = 100.0) -> Bench:
    schema = ir.Schema()
    schema.declare("Ew", ("id", "id"), "trop")
    schema.declare("Dap", ("id", "id"), "trop")
    schema.declare("Qap", ("id", "id"), "trop")

    f_d = Rule("Dap", _ssp(("x", "y"), [
        _t([PredAtom("eq", ("x", "y"))]),
        _t([RelAtom("Dap", ("x", "z")), RelAtom("Ew", ("z", "y")),
            PredAtom("neq", ("x", "y"))], ["z"]),
    ], "trop"))
    g = Rule("Qap", _ssp(("x", "y"), [
        _t([RelAtom("Dap", ("x", "y"))]),
        _t([ConstAtom(cap)]),
    ], "trop"))
    original = Program("APSP100", schema, [Stratum({"Dap": f_d})], [g])

    h = Rule("Qap", _ssp(("x", "y"), [
        _t([PredAtom("eq", ("x", "y"))]),
        _t([RelAtom("Qap", ("x", "z")), RelAtom("Ew", ("z", "y")),
            PredAtom("neq", ("x", "y"))], ["z"]),
        _t([ConstAtom(cap)]),
    ], "trop"))
    out = Rule("Qans", _ssp(("x", "y"),
                            [_t([RelAtom("Qap", ("x", "y"))])], "trop"))
    optimized = Program("APSP100_opt", schema, [Stratum({"Qap": h})], [out])

    def make_db(g_: datasets.Graph, wmax: int = 8) -> engine.Database:
        rng = np.random.default_rng(7)
        w = np.full((g_.n, g_.n), np.inf, np.float32)
        costs = (g_.weights if g_.weights is not None
                 else rng.integers(1, wmax, len(g_.edges)))
        w[g_.edges[:, 0], g_.edges[:, 1]] = costs
        return engine.Database(schema, {"id": g_.n}, {"Ew": jnp.asarray(w)})

    return Bench("APSP100", original, optimized, make_db, synthesis="cegis")


ALL = {b.__name__: b for b in (bm, cc, sssp, ws, radius, mlm, apsp100)}


# --------------------------------------------------------------------------
# BC — Betweenness Centrality (Fig. 18); FGH-optimizes to Brandes [7]
# --------------------------------------------------------------------------


def bc(dmax: int = 32) -> Bench:
    """Original: materialize levels R3/Lv (bounded-depth reachability with
    stratified negation), shortest-path counts σ over ℕ, then the triple
    join B[v] = Σ σ_sv·σ_vt/σ_st.  The value-ratio epilogue is
    host-composed (our IR's interpreted value functions act on keys, the
    paper's act on helper-relation values — Appendix A).  Optimized:
    Brandes' backward accumulation as a level-synchronous dense JAX
    program (`bc_brandes`)."""
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    schema.declare("V", ("id",), "bool")
    schema.declare("R3", ("id", "id", "d"), "bool")
    schema.declare("Lv", ("id", "id", "d"), "bool")
    schema.declare("sig", ("id", "id"), "nat")

    f_r3 = Rule("R3", _ssp(("s", "t", "k"), [
        _t([RelAtom("V", ("s",)), PredAtom("eq", ("s", "t"))]),
        _t([RelAtom("R3", ("s", "v", "l")), RelAtom("E", ("v", "t")),
            PredAtom("succ", ("k", "l"))], ["v", "l"]),
        _t([RelAtom("R3", ("s", "t", "l")), PredAtom("succ", ("k", "l"))],
           ["l"]),
    ], "bool"))
    f_lv = Rule("Lv", _ssp(("s", "t", "k"), [
        _t([RelAtom("R3", ("s", "t", "k")), PredAtom("eq", ("k", C(0)))]),
        _t([RelAtom("R3", ("s", "t", "k")),
            RelAtom("R3", ("s", "t", "l"), neg=True),
            PredAtom("succ", ("k", "l"))], ["l"]),
    ], "bool"))
    f_sig = Rule("sig", _ssp(("s", "t"), [
        _t([PredAtom("eq", ("s", "t"))]),
        _t([RelAtom("sig", ("s", "v")), RelAtom("E", ("v", "t"), cast=True),
            RelAtom("Lv", ("s", "t", "k"), cast=True),
            RelAtom("Lv", ("s", "v", "l"), cast=True),
            PredAtom("succ", ("k", "l"))], ["v", "k", "l"]),
    ], "nat"))

    def _dist_from_lv(lv):
        kk = jnp.arange(lv.shape[-1], dtype=jnp.float32)
        return jnp.where(lv.any(-1), (lv * kk).sum(-1), jnp.inf)

    def post(_, db):
        import jax
        sig = db.relations["sig"]
        dist = _dist_from_lv(db.relations["Lv"])
        n = sig.shape[0]
        eye = jnp.eye(n, dtype=bool)

        def one_v(v):
            on_path = dist == dist[:, v][:, None] + dist[v][None, :]
            ok = on_path & ~eye & (dist != jnp.inf)
            ok &= (jnp.arange(n) != v)[None, :] & (jnp.arange(n) != v)[:, None]
            contrib = jnp.where(ok, sig[:, v][:, None] * sig[v][None, :]
                                / jnp.maximum(sig, 1.0), 0.0)
            return contrib.sum()

        return jax.lax.map(one_v, jnp.arange(n))

    original = Program("BC", schema,
                       [Stratum({"R3": f_r3}), Stratum({"Lv": f_lv}),
                        Stratum({"sig": f_sig})],
                       [], post=post)

    def bc_brandes(db: engine.Database) -> jnp.ndarray:
        """Brandes' algorithm, level-synchronous and dense (all sources at
        once): the FGH-optimized GH-form — B accumulates backwards via
        δ(s,v) = Σ_w σ_sv/σ_sw (1+δ(s,w)) over the shortest-path DAG."""
        import jax
        e = db.relations["E"].astype(jnp.float32)
        n = e.shape[0]
        inf = jnp.inf
        dist0 = jnp.where(jnp.eye(n, dtype=bool), 0.0, inf)
        sig0 = jnp.eye(n, dtype=jnp.float32)

        def fwd(carry):
            dist, sig, lvl = carry
            # frontier: nodes at distance lvl
            fr = dist == lvl
            reach = (fr.astype(jnp.float32) @ e) > 0          # (s, t)
            newly = reach & (dist == inf)
            cnt = (jnp.where(fr, sig, 0.0) @ e)               # path counts
            dist = jnp.where(newly, lvl + 1.0, dist)
            sig = jnp.where(newly, cnt, sig)
            return dist, sig, lvl + 1.0

        def fwd_cond(carry):
            dist, _, lvl = carry
            return jnp.any(dist == lvl)

        dist, sig, lmax = jax.lax.while_loop(fwd_cond, fwd,
                                             (dist0, sig0, 0.0))

        def bwd(lvl_rev, delta):
            lvl = lmax - lvl_rev  # from deepest level down to 1
            m_w = dist == lvl                                  # (s, w)
            t = jnp.where(m_w, (1.0 + delta) / jnp.maximum(sig, 1.0), 0.0)
            upd = sig * (t @ e.T) * (dist == lvl - 1.0)
            return delta + upd

        delta = jax.lax.fori_loop(0, n, lambda i, d: jax.lax.cond(
            lmax - i >= 1.0, lambda dd: bwd(jnp.float32(i), dd),
            lambda dd: dd, d), jnp.zeros((n, n), jnp.float32))
        return jnp.sum(delta * ~jnp.eye(n, dtype=bool), axis=0)

    optimized = Program("BC_opt", schema, [], [],
                        post=lambda _, db: bc_brandes(db))

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n, "d": dmax}, {
            "E": g_.adjacency(), "V": g_.vertex_set()})

    return Bench("BC", original, optimized, make_db, synthesis="cegis",
                 optimized_fn=bc_brandes)


ALL["bc"] = bc


# --------------------------------------------------------------------------
# SM — Simple Magic (Example 3.5): left-recursive TC → reachability
# --------------------------------------------------------------------------


def simple_magic(a: int = 0) -> Bench:
    """Example 3.5: TC(x,y) := [x=y] ∨ ∃z(TC(x,z) ∧ E(z,y)); Q(y)=TC(a,y)
    → Q(y) := [y=a] ∨ ∃z(Q(z) ∧ E(z,y)).  Unlike BM (Example 3.8), here
    G(F(TC)) = H(G(TC)) holds for *every* TC — no loop invariant needed:
    the magic-set rewrite falls out of plain rule-based denormalization."""
    schema = ir.Schema()
    schema.declare("E", ("id", "id"), "bool")
    schema.declare("V", ("id",), "bool")
    schema.declare("TC", ("id", "id"), "bool")
    schema.declare("Q", ("id",), "bool")

    f_tc = Rule("TC", _ssp(("x", "y"), [
        _t([RelAtom("V", ("x",)), PredAtom("eq", ("x", "y"))]),
        _t([RelAtom("TC", ("x", "z")), RelAtom("E", ("z", "y"))], ["z"]),
    ], "bool"))
    g = Rule("Q", _ssp(("y",), [_t([RelAtom("TC", (C(a), "y"))])], "bool"))
    original = Program("SM", schema, [Stratum({"TC": f_tc})], [g])

    h = Rule("Q", _ssp(("y",), [
        _t([PredAtom("eq", ("y", C(a))), RelAtom("V", (C(a),))]),
        _t([RelAtom("Q", ("z",)), RelAtom("E", ("z", "y"))], ["z"]),
    ], "bool"))
    out = Rule("Qans", _ssp(("y",), [_t([RelAtom("Q", ("y",))])], "bool"))
    optimized = Program("SM_opt", schema, [Stratum({"Q": h})], [out])

    def make_db(g_: datasets.Graph) -> engine.Database:
        return engine.Database(schema, {"id": g_.n}, {
            "E": g_.adjacency(), "V": g_.vertex_set()})

    return Bench("SM", original, optimized, make_db, synthesis="rule")


ALL["simple_magic"] = simple_magic
