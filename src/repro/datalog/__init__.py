"""Datalog° applications: the paper's benchmark programs and datasets."""
