"""Dataset generators for the paper's benchmarks (Sec. 8.1).

The SNAP datasets (twitter/epinions/wiki) are not redistributable offline;
we generate power-law stand-ins with matched degree structure
(Barabási–Albert / Erdős–Rényi via networkx), plus the paper's synthetic
families: Erdős–Rényi graphs (BC), random recursive trees with O(log n)
expected depth and exponential-decay trees with O(n) expected depth (R,
MLM, Fig. 12), and plain vectors (WS).

All graphs are returned as dense boolean adjacency tensors (S-relations
over 𝔹) together with the sort domain sizes used by the engine.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Graph:
    n: int
    edges: np.ndarray  # (m, 2) int array
    weights: np.ndarray | None = None  # (m,) ints ≥ 1

    def adjacency(self, symmetric: bool = False) -> jnp.ndarray:
        a = np.zeros((self.n, self.n), bool)
        a[self.edges[:, 0], self.edges[:, 1]] = True
        if symmetric:
            a |= a.T
        return jnp.asarray(a)

    def weighted_adjacency(self, wmax: int) -> jnp.ndarray:
        """E(x, y, w) as a dense boolean (n, n, wmax) tensor."""
        w = self.weights if self.weights is not None else \
            np.ones(len(self.edges), np.int64)
        t = np.zeros((self.n, self.n, wmax), bool)
        t[self.edges[:, 0], self.edges[:, 1], np.minimum(w, wmax - 1)] = True
        return jnp.asarray(t)

    def vertex_set(self) -> jnp.ndarray:
        return jnp.ones((self.n,), bool)


def erdos_renyi(n: int, avg_deg: float, seed: int = 0,
                weighted: bool = False, wmax: int = 8) -> Graph:
    rng = np.random.default_rng(seed)
    p = min(1.0, avg_deg / max(1, n - 1))
    mask = rng.random((n, n)) < p
    np.fill_diagonal(mask, False)
    edges = np.argwhere(mask)
    weights = rng.integers(1, wmax, len(edges)) if weighted else None
    return Graph(n, edges, weights)


def powerlaw(n: int, m_attach: int = 4, seed: int = 0) -> Graph:
    """Barabási–Albert stand-in for the SNAP social graphs."""
    import networkx as nx
    g = nx.barabasi_albert_graph(n, m_attach, seed=seed)
    edges = np.array(g.edges(), np.int64)
    edges = np.concatenate([edges, edges[:, ::-1]])  # directed both ways
    return Graph(n, edges)


def random_recursive_tree(n: int, seed: int = 0) -> Graph:
    """Node i attaches uniformly to j<i: expected depth O(log n)."""
    rng = np.random.default_rng(seed)
    parents = np.array([rng.integers(0, i) for i in range(1, n)])
    edges = np.stack([parents, np.arange(1, n)], axis=1)  # parent -> child
    return Graph(n, edges)


def decay_tree(n: int, tau: float = 1.5, seed: int = 0) -> Graph:
    """Exponential-decay attachment (paper Sec. 8.1, multi-level-marketing
    association decay): node i attaches to j<i with P ∝ exp(-(i-j)/τ);
    small τ yields expected depth O(n)."""
    rng = np.random.default_rng(seed)
    parents = []
    for i in range(1, n):
        w = np.exp(-np.arange(i, 0, -1) / tau)
        parents.append(rng.choice(i, p=w / w.sum()))
    edges = np.stack([np.array(parents), np.arange(1, n)], axis=1)
    return Graph(n, edges)


def path_graph(n: int) -> Graph:
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Graph(n, edges)


def vector_data(n: int, seed: int = 0, vmax: int = 8) -> np.ndarray:
    """A(j, w) for WS: the paper inputs [1..n]; values don't affect runtime.
    We use small random ints so the dense value domain stays bounded."""
    rng = np.random.default_rng(seed)
    return rng.integers(1, vmax, n)


def tree_depth(g: Graph) -> int:
    depth = np.zeros(g.n, np.int64)
    for p, c in g.edges:  # edges are emitted parent->child in index order
        depth[c] = depth[p] + 1
    return int(depth.max())
