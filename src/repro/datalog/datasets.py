"""Dataset generators for the paper's benchmarks (Sec. 8.1).

The SNAP datasets (twitter/epinions/wiki) are not redistributable offline;
we generate power-law stand-ins with matched degree structure
(Barabási–Albert / Erdős–Rényi via networkx), plus the paper's synthetic
families: Erdős–Rényi graphs (BC), random recursive trees with O(log n)
expected depth and exponential-decay trees with O(n) expected depth (R,
MLM, Fig. 12), and plain vectors (WS).

All graphs are returned as dense boolean adjacency tensors (S-relations
over 𝔹) together with the sort domain sizes used by the engine.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Graph:
    n: int
    edges: np.ndarray  # (m, 2) int array
    weights: np.ndarray | None = None  # (m,) ints ≥ 1

    def adjacency(self, symmetric: bool = False) -> jnp.ndarray:
        a = np.zeros((self.n, self.n), bool)
        a[self.edges[:, 0], self.edges[:, 1]] = True
        if symmetric:
            a |= a.T
        return jnp.asarray(a)

    def sparse_adjacency(self, symmetric: bool = False, *,
                         semiring: str = "bool",
                         capacity: int | None = None):
        """E as a COO SparseRelation — never materializes n × n, so
        SNAP-scale graphs (50k–500k vertices) stay allocatable.

        ``semiring="bool"`` stores 1̄ per edge; ``"trop"``/``"maxplus"``
        store the edge weight (1 when unweighted) as the value.
        """
        from repro.sparse.coo import SparseRelation
        edges = self.edges
        if symmetric:
            edges = np.concatenate([edges, edges[:, ::-1]])
        if semiring == "bool":
            vals = np.ones(len(edges), bool)
        else:
            w = (self.weights if self.weights is not None
                 else np.ones(len(self.edges), np.int64))
            vals = np.asarray(np.concatenate([w, w]) if symmetric else w,
                              np.float32)
        return SparseRelation.from_coo(edges, vals, (self.n, self.n),
                                       semiring, capacity=capacity)

    def weighted_adjacency(self, wmax: int) -> jnp.ndarray:
        """E(x, y, w) as a dense boolean (n, n, wmax) tensor."""
        w = self.weights if self.weights is not None else \
            np.ones(len(self.edges), np.int64)
        t = np.zeros((self.n, self.n, wmax), bool)
        t[self.edges[:, 0], self.edges[:, 1], np.minimum(w, wmax - 1)] = True
        return jnp.asarray(t)

    def vertex_set(self) -> jnp.ndarray:
        return jnp.ones((self.n,), bool)


def erdos_renyi(n: int, avg_deg: float, seed: int = 0,
                weighted: bool = False, wmax: int = 8) -> Graph:
    rng = np.random.default_rng(seed)
    p = min(1.0, avg_deg / max(1, n - 1))
    mask = rng.random((n, n)) < p
    np.fill_diagonal(mask, False)
    edges = np.argwhere(mask)
    weights = rng.integers(1, wmax, len(edges)) if weighted else None
    return Graph(n, edges, weights)


def powerlaw(n: int, m_attach: int = 4, seed: int = 0) -> Graph:
    """Barabási–Albert stand-in for the SNAP social graphs.

    Uses networkx when available; otherwise a native preferential-
    attachment generator (same repeated-nodes algorithm), so 50k–500k
    vertex graphs are buildable in this container.
    """
    try:
        import networkx as nx
    except ImportError:
        edges = _ba_edges(n, m_attach, np.random.default_rng(seed))
    else:
        g = nx.barabasi_albert_graph(n, m_attach, seed=seed)
        edges = np.array(g.edges(), np.int64)
    edges = np.concatenate([edges, edges[:, ::-1]])  # directed both ways
    return Graph(n, edges)


def _ba_edges(n: int, m: int, rng: np.random.Generator) -> np.ndarray:
    """Preferential attachment via the repeated-nodes trick: each new
    vertex draws ``m`` distinct targets ∝ degree from the flat endpoint
    list.  O(n·m); no networkx dependency."""
    assert 1 <= m < n, (n, m)
    src, dst = [], []
    repeated: list[int] = []
    targets = list(range(m))
    for v in range(m, n):
        src.extend([v] * len(targets))
        dst.extend(targets)
        repeated.extend(targets)
        repeated.extend([v] * m)
        picks: set[int] = set()
        while len(picks) < m:
            take = rng.integers(0, len(repeated),
                                size=2 * (m - len(picks)))
            picks.update(repeated[t] for t in take)
            while len(picks) > m:
                picks.pop()
        targets = list(picks)
    return np.stack([np.asarray(src, np.int64),
                     np.asarray(dst, np.int64)], axis=1)


def erdos_renyi_sparse(n: int, avg_deg: float, seed: int = 0,
                       weighted: bool = False, wmax: int = 8) -> Graph:
    """G(n, p) by direct edge sampling — O(m) memory instead of the n×n
    Bernoulli mask of :func:`erdos_renyi`, so 50k–500k vertices fit.

    Draws ``M ~ Binomial(n(n−1), p)`` then samples M ordered pairs,
    rejecting self-loops and duplicates (indistinguishable from G(n, p)
    at the sparse densities this is meant for).
    """
    rng = np.random.default_rng(seed)
    p = min(1.0, avg_deg / max(1, n - 1))
    m = int(rng.binomial(n * (n - 1), p))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    edges = np.unique(np.stack([src[keep], dst[keep]], axis=1), axis=0)
    weights = rng.integers(1, wmax, len(edges)) if weighted else None
    return Graph(n, edges, weights)


def random_recursive_tree(n: int, seed: int = 0) -> Graph:
    """Node i attaches uniformly to j<i: expected depth O(log n)."""
    rng = np.random.default_rng(seed)
    parents = np.array([rng.integers(0, i) for i in range(1, n)])
    edges = np.stack([parents, np.arange(1, n)], axis=1)  # parent -> child
    return Graph(n, edges)


def decay_tree(n: int, tau: float = 1.5, seed: int = 0) -> Graph:
    """Exponential-decay attachment (paper Sec. 8.1, multi-level-marketing
    association decay): node i attaches to j<i with P ∝ exp(-(i-j)/τ);
    small τ yields expected depth O(n)."""
    rng = np.random.default_rng(seed)
    parents = []
    for i in range(1, n):
        w = np.exp(-np.arange(i, 0, -1) / tau)
        parents.append(rng.choice(i, p=w / w.sum()))
    edges = np.stack([np.array(parents), np.arange(1, n)], axis=1)
    return Graph(n, edges)


def path_graph(n: int) -> Graph:
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Graph(n, edges)


def vector_data(n: int, seed: int = 0, vmax: int = 8) -> np.ndarray:
    """A(j, w) for WS: the paper inputs [1..n]; values don't affect runtime.
    We use small random ints so the dense value domain stays bounded."""
    rng = np.random.default_rng(seed)
    return rng.integers(1, vmax, n)


def tree_depth(g: Graph) -> int:
    depth = np.zeros(g.n, np.int64)
    for p, c in g.edges:  # edges are emitted parent->child in index order
        depth[c] = depth[p] + 1
    return int(depth.max())
