"""Program-family machinery shared by both serve loops (DESIGN.md §3, §7).

A *family* is one source-parameterized Π₂ program registered with a
server: its cost-based plan, materialized linear operator ``E``, host
twin of the database for eager per-request ``init`` evaluation, memoized
init vectors, and the capacity-bounded warm-answer LRU.  Everything here
used to live inside ``launch.datalog_serve.DatalogServer``; it was
extracted so the continuous-batching scheduler
(:class:`repro.serve.scheduler.ContinuousServer`) and the packed-FIFO
compatibility shim share one registration, init-evaluation, and
streaming-update implementation — the update semantics (monotone
⊕-merge appends with batched delta-restart warm repair; non-monotone
deletes applied in place at unchanged capacity, with warm answers
repaired through the synthesized ⊖/recount maintenance rule of
DESIGN.md §11 when one is verified for the family's signature, dropped
otherwise) are identical under both schedulers by construction.

Also here: the **single-request latency path**.  A (1, n) batched
fixpoint pays full SpMM scatters per iteration for one live row — the
B=1 regression in BENCH_serve.json.  :func:`latency_serve` routes a lone
request the way a fresh ``objective="latency"`` plan would run it (the
planner's per-source path: the host frontier worklist on CPU sparse
families), falling back to the batched runner when the latency plan
picks something with no cheaper single-source form.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, planner, vectorize
from repro.core import semiring as sr_mod
from repro.core.program import Program
from repro.serve.cache import LRUCache
from repro.sparse.coo import SparseRelation


@dataclasses.dataclass
class QueryRequest:
    """One (program family, source vertex) query; filled in by the server.

    A request that cannot be served (e.g. its source changed the
    family's linear operator) comes back with ``result=None`` and the
    failure message in ``error`` — it never takes its batch down.
    """

    family: str
    source: int
    result: np.ndarray | None = None
    iters: int | None = None
    error: str | None = None
    submitted_s: float = 0.0
    done_s: float = 0.0
    #: continuous scheduler stamps: admitted into a slot / mask fired
    admitted_s: float = 0.0
    converged_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submitted_s


@dataclasses.dataclass
class UpdateRequest:
    """One batch of edge mutations against a family's linear operator.

    ``op="merge"`` is the monotone ⊕-merge (edge insertion; tropical
    weight decrease); ``op="delete"`` removes keys and ``op="increase"``
    replaces stored values with larger ones — both non-monotone,
    repaired through the synthesized maintenance rule when one verifies
    (DESIGN.md §11).
    Coordinates live in the space the family's operator was built from:
    the stored edge relation ``E(i, j)`` when one exists (the server
    re-orients them for the operator), else the ``edges=`` override
    given at registration.  Once ``applied`` is set the server
    guarantees no later-served answer predates the update.
    """

    family: str
    coords: np.ndarray
    values: np.ndarray | None = None
    op: str = "merge"
    applied: bool = False
    repaired: int = 0           # warm answers repaired in place
    error: str | None = None
    submitted_s: float = 0.0
    done_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submitted_s


#: per-family cap on memoized init vectors (n floats each)
INIT_CACHE_MAX = 4096


@dataclasses.dataclass
class Family:
    name: str
    make_program: Callable[[int], Program]
    db: engine.Database
    host_db: engine.Database    # numpy twin for eager per-request init eval
    plan: planner.ExecutionPlan
    edges: object               # SparseRelation (jnp) or dense (n, n) array
    hints: dict
    n: int
    max_iters: int
    #: graph-sharded twin of ``edges`` (ShardedRelation) when the plan
    #: picked the row-partitioned runner; the compiled fixpoint's operand
    sharded: object | None = None
    edge_rel: str | None = None  # stored relation behind E (None: override)
    init_reads_edges: bool = False  # init term references edge_rel too
    init_cache: dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict)
    #: warm x* per source, repaired on update (capacity-bounded LRU)
    answers: LRUCache = dataclasses.field(
        default_factory=lambda: LRUCache(256))
    #: host-kernel geometry (destination-sorted edge views) reused
    #: across pool rebuilds; invalidated whenever ``edges`` mutates
    kernel_cache: dict = dataclasses.field(default_factory=dict)
    #: one-hot init fast path: ``(template_prog, template_source,
    #: background, source_value, dtype)`` when registration probed the
    #: init as "uniform background + one value at the source" — then a
    #: request's init is two writes instead of a host program eval
    #: (the request's program is still structurally verified against
    #: the template first, so an operator-changing source fails as
    #: before).  None = probe failed / not applicable.
    fast_init: tuple | None = None
    #: lazily planned objective="latency" route for B=1 requests;
    #: False = probed and unavailable (no cheap per-source form)
    latency_plan: object = None

    @property
    def backend(self) -> str:
        # derived from the plan so it can never disagree with the routing
        return "sparse" if self.plan.strata[0].runner in (
            "sparse_jit", "sparse_sharded",
            "sparse_frontier_pallas") else "dense"

    @property
    def semiring(self) -> str:
        return self.plan.strata[0].vf.semiring


def bucket(b: int, max_batch: int) -> int:
    """Smallest power of two ≥ b, capped at max_batch."""
    out = 1
    while out < b:
        out <<= 1
    return min(out, max_batch)


def build_family(name: str, make_program: Callable[[int], Program],
                 db: engine.Database, *, edges=None,
                 template_source: int = 0, graph_mesh=None,
                 max_iters: int = 10_000,
                 warm_answers: int = 256) -> Family:
    """Plan and materialize one family (DESIGN.md §3).

    ``make_program(source)`` must return the optimized program for
    that source; all sources must share the linear operator (checked
    per request by ``planner.source_init`` via the vector-form
    signature).  ``edges`` overrides the extracted E — e.g. a weighted
    COO adjacency for SSSP-style families whose schema-level edge
    relation is a dense 3-ary tensor that would not scale.
    """
    template = make_program(template_source)
    hints = dict(template.sort_hints)
    plan = planner.plan_program(
        template, db, planner.PlanHints(sorts=hints),
        objective="throughput", edges=edges,
        adapt_storage=False, require_vector=True, mesh=graph_mesh)
    edges = planner.materialize_edges(plan, db, hints)
    n = db.dom(plan.strata[0].vf.out_sort)
    # numpy twin of the relations: per-request init evaluation runs
    # eagerly on the host (the jnp dispatch overhead of an O(n) eval
    # would dominate a packed batch otherwise).  Sparse relations go
    # to their np lib too — an init term may read the edge relation
    # itself (e.g. Q(y) := E(a, y) ⊕ …), which the evaluator then
    # densifies host-side.
    host_rels = {k: (v.as_np() if isinstance(v, SparseRelation)
                     else np.asarray(v))
                 for k, v in db.relations.items()}
    host_db = engine.Database(db.schema, db.domains, host_rels)
    fam = Family(name, make_program, db, host_db, plan, edges, hints,
                 n, max_iters, answers=LRUCache(warm_answers))
    if plan.strata[0].runner == "sparse_sharded":
        from repro.distributed import datalog as dd
        fam.sharded = dd.shard_relation(edges, graph_mesh)
    if plan.strata[0].edges_override is None:
        a = vectorize.edge_atom(plan.strata[0].vf)
        if a is not None and isinstance(db.relations.get(a.name),
                                        SparseRelation):
            fam.edge_rel = a.name
            fam.init_reads_edges = vectorize.init_reads(
                plan.strata[0].vf, a.name)
    _probe_fast_init(fam, template, template_source)
    return fam


def _probe_fast_init(fam: Family, template: Program,
                     s0: int) -> None:
    """Enable the one-hot init fast path when two probe sources show
    the init is "uniform background + one value at the source" and the
    two programs differ only in that source constant.  Disabled for
    edge-reading inits (their vectors change under updates) — those
    keep the evaluating slow path."""
    if fam.init_reads_edges or fam.n < 2:
        return
    s1 = s0 + 1 if s0 + 1 < fam.n else s0 - 1
    try:
        p1 = fam.make_program(s1)
        if not _source_equiv(template, p1, s0, s1):
            return
        h = dict(template.sort_hints)
        i0 = planner.source_init(fam.plan, template, fam.host_db,
                                 hints=h, backend="np")
        i1 = planner.source_init(fam.plan, p1, fam.host_db,
                                 hints=dict(p1.sort_hints), backend="np")
    except Exception:
        return
    i0, i1 = np.asarray(i0), np.asarray(i1)
    bg, src_val = i0[s1], i0[s0]
    rest = np.delete(i0, s0)
    if (src_val != bg and i1[s1] == src_val and i1[s0] == bg
            and np.all(rest == bg)
            and np.array_equal(np.delete(i1, s1), rest)):
        fam.fast_init = (template, s0, bg, src_val, i0.dtype)
        fam.init_cache[s0] = i0
        fam.init_cache[s1] = i1


def _source_equiv(p0: Program, p1: Program, s0: int, s1: int) -> bool:
    """True iff ``p1`` is exactly ``p0`` with the source constant
    ``s0`` replaced by ``s1`` (variable names ignored) — the
    verification half of the shim's two-placeholder substitution.  When
    it holds, the request's program kept the family's linear operator
    by construction."""
    from repro.core import ir

    def args_ok(a0, a1):
        if len(a0.args) != len(a1.args):
            return False
        for x0, x1 in zip(a0.args, a1.args):
            c0, c1 = isinstance(x0, ir.C), isinstance(x1, ir.C)
            if c0 != c1:
                return False
            if c0 and x0.value != x1.value \
                    and (x0.value, x1.value) != (s0, s1):
                return False
        return True

    def atom_ok(a0, a1):
        if type(a0) is not type(a1):
            return False
        if isinstance(a0, ir.RelAtom):
            return ((a0.name, a0.cast, a0.neg)
                    == (a1.name, a1.cast, a1.neg) and args_ok(a0, a1))
        if isinstance(a0, ir.PredAtom):
            return a0.pred == a1.pred and args_ok(a0, a1)
        if isinstance(a0, ir.ValFnAtom):
            return a0.fn == a1.fn and args_ok(a0, a1)
        if isinstance(a0, ir.ConstAtom):
            return a0.value == a1.value
        return True  # ValAtom: var names may drift

    def ssp_ok(e0, e1):
        if (len(e0.terms) != len(e1.terms)
                or len(e0.head) != len(e1.head)
                or e0.semiring != e1.semiring):
            return False
        return all(
            len(t0.atoms) == len(t1.atoms)
            and len(t0.bound) == len(t1.bound)
            and all(atom_ok(a0, a1)
                    for a0, a1 in zip(t0.atoms, t1.atoms))
            for t0, t1 in zip(e0.terms, e1.terms))

    if (len(p0.strata) != len(p1.strata)
            or len(p0.outputs) != len(p1.outputs)):
        return False
    for st0, st1 in zip(p0.strata, p1.strata):
        if tuple(st0.rules) != tuple(st1.rules):
            return False
        if not all(ssp_ok(st0.rules[nm].body, st1.rules[nm].body)
                   for nm in st0.rules):
            return False
        if (st0.init is None) != (st1.init is None):
            return False
        if st0.init is not None:
            if set(st0.init) != set(st1.init):
                return False
            if not all(ssp_ok(st0.init[nm], st1.init[nm])
                       for nm in st0.init):
                return False
    return all(r0.head == r1.head and ssp_ok(r0.body, r1.body)
               for r0, r1 in zip(p0.outputs, p1.outputs))


def family_init(fam: Family, source: int) -> np.ndarray:
    """The per-request O(n) host work, memoized per source: rebuild
    the source's program, check it kept the family's linear operator,
    produce its init vector.  One-hot families take the probed fast
    path (structural check + two writes); everything else evaluates
    through ``planner.source_init`` (vector-form signature equality +
    host init eval)."""
    if source in fam.init_cache:
        return fam.init_cache[source]
    prog = fam.make_program(source)
    init = None
    if fam.fast_init is not None and 0 <= source < fam.n:
        template, t0, bg, src_val, dtype = fam.fast_init
        if _source_equiv(template, prog, t0, source):
            init = np.full(fam.n, bg, dtype)
            init[source] = src_val
    if init is None:
        init = planner.source_init(fam.plan, prog, fam.host_db,
                                   hints=dict(prog.sort_hints),
                                   backend="np")
    if len(fam.init_cache) >= INIT_CACHE_MAX:
        fam.init_cache.pop(next(iter(fam.init_cache)))  # FIFO evict
    fam.init_cache[source] = init
    return init


# --------------------------------------------------------------------------
# B=1 latency routing
# --------------------------------------------------------------------------


def _latency_plan(fam: Family):
    """The family's ``objective="latency"`` plan, probed lazily once.

    Reuses the registration-time template and edges override, so the
    linear operator (and every signature-keyed cache) is unchanged —
    only stratum 0's runner pick differs.  ``False`` caches a probe that
    found no usable per-source route.
    """
    if fam.latency_plan is None:
        try:
            template = fam.make_program(0)
            plan = planner.plan_program(
                template, fam.db,
                planner.PlanHints(sorts=dict(template.sort_hints)),
                objective="latency",
                edges=fam.plan.strata[0].edges_override,
                adapt_storage=False, require_vector=True)
            fam.latency_plan = (
                plan if plan.strata[0].runner == "sparse_frontier"
                else False)
        except Exception:
            fam.latency_plan = False
    return fam.latency_plan


def latency_serve(fam: Family, init: np.ndarray):
    """Serve ONE request down the planner's per-source latency path.

    Returns ``(x*, iters)`` or ``None`` when the family has no cheaper
    single-source form (dense operator, sharded operand, or a latency
    plan that picked the same batched runner) — the caller then falls
    back to a (1, n) batched serve.  Only worth taking for a lone
    request: the frontier worklist's per-round work is proportional to
    the frontier, so it beats a one-live-row SpMM whose scatters still
    touch every edge (the BENCH_serve.json B=1 row)."""
    if fam.sharded is not None or not isinstance(fam.edges,
                                                 SparseRelation):
        return None
    if jax.default_backend() != "cpu" or _latency_plan(fam) is False:
        return None
    from repro.sparse.fixpoint import fixpoint
    y, iters = fixpoint(fam.edges, np.asarray(init), mode="frontier",
                        max_iters=fam.max_iters)
    return np.asarray(y), int(iters)


# --------------------------------------------------------------------------
# Streaming updates (DESIGN.md §5): shared by both serve loops
# --------------------------------------------------------------------------


def apply_updates(fam: Family, ups: list, stats: dict,
                  graph_mesh=None) -> None:
    """Apply a run of same-op updates in one pass: mutate the stored
    relation + operator, then repair (monotone) or drop (delete) the
    warm answer cache.  The family's plan, signature, and compiled
    runners are untouched — within operator capacity not even the
    staged fixpoint's trace changes."""
    now = time.perf_counter()
    try:
        coords = np.concatenate([u.coords for u in ups])
        values = None
        if any(u.values is not None for u in ups):
            one = np.asarray(
                sr_mod.get(rel_semiring(fam), lib="np").one)
            values = np.concatenate(
                [u.values if u.values is not None
                 else np.full(len(u.coords), one) for u in ups])
        if ups[0].op == "merge":
            _merge_edges(fam, coords, values, stats, graph_mesh)
        else:
            _nonmono_edges(fam, coords, values, ups[0].op, stats,
                           graph_mesh)
    except Exception as e:  # a bad update must not kill the queue
        for u in ups:
            u.error = f"{type(e).__name__}: {e}"
            u.done_s = now
        stats["failed"] += len(ups)
        return
    for u in ups:
        u.applied = True
        u.done_s = time.perf_counter()
    stats["updates"] += len(ups)


def rel_semiring(fam: Family) -> str:
    if fam.edge_rel is not None:
        return fam.db.schema[fam.edge_rel].semiring
    vf = fam.plan.strata[0].vf
    return (fam.edges.semiring
            if isinstance(fam.edges, SparseRelation) else vf.semiring)


def operator_delta(fam: Family, coords, values) -> SparseRelation:
    """The update batch as a sparse Δ in the operator's own space:
    re-oriented from stored-relation order when needed, values cast
    into the vector equation's semiring."""
    vf = fam.plan.strata[0].vf
    rel_sr = rel_semiring(fam)
    delta = SparseRelation.from_coo(
        coords,
        np.ones(len(coords), sr_mod.get(rel_sr, lib="np").dtype)
        * sr_mod.get(rel_sr, lib="np").one
        if values is None else values,
        (fam.n, fam.n), rel_sr)
    if fam.edge_rel is not None:
        a = vectorize.edge_atom(vf)
        if tuple(a.args) != vf.edge.head:
            delta = delta.transpose()
    return vectorize._sparse_into_semiring(delta, vf.semiring)


def _drop_answers(fam: Family, stats: dict) -> None:
    stats["answers_dropped"] += fam.answers.clear()


def _merge_edges(fam: Family, coords, values, stats: dict,
                 graph_mesh) -> None:
    from repro.incremental import DeltaEntry, delta_restart_fixpoint
    fam.kernel_cache.clear()
    delta_op = operator_delta(fam, coords, values)
    dh = delta_op.as_np()
    k = int(dh.nnz)
    if fam.edge_rel is not None:
        ent = [DeltaEntry(fam.edge_rel, coords, values, "merge")]
        fam.db = fam.db.apply_delta(ent)
        fam.host_db = fam.host_db.apply_delta(ent)
    if isinstance(fam.edges, SparseRelation):
        fam.edges = fam.edges.apply_delta(dh.coords[:k], dh.values[:k])
        if fam.sharded is not None:
            # route the same rows to their owning destination shards
            # — per-shard capacity usually holds, so the compiled
            # sharded fixpoint's trace (and cache entry) survives
            fam.sharded = fam.sharded.apply_delta(dh.coords[:k],
                                                  dh.values[:k])
    else:  # dense operator: ⊕-scatter in place
        idx = tuple(np.asarray(dh.coords[:k]).T)
        fam.edges = sr_mod.scatter_op(
            delta_op.semiring,
            jnp.asarray(fam.edges).at[idx])(jnp.asarray(dh.values[:k]),
                                            mode="drop")
    if fam.init_reads_edges:
        # the merge also changed the init term: memoized init vectors
        # are stale and a Δ-seeded repair would miss the init
        # contribution — recompute cold (correctness over warmth)
        fam.init_cache.clear()
        _drop_answers(fam, stats)
        return
    if not len(fam.answers):
        return
    if not isinstance(fam.edges, SparseRelation):
        # no sparse Δ-seed path for a dense operator — recompute cold
        _drop_answers(fam, stats)
        return
    # one batched delta-restart pass repairs every warm answer:
    # bucketed to a power of two with inert 0̄ rows, one SpMM per
    # round (DESIGN.md §5)
    sources = list(fam.answers.keys())
    sr = sr_mod.get(fam.plan.strata[0].vf.semiring, lib="np")
    bb = bucket(len(sources), 1 << 30)
    prev = np.full((bb, fam.n), sr.zero, sr.dtype)
    for i, s in enumerate(sources):
        prev[i] = fam.answers.peek(s)
    if fam.sharded is not None:
        # sharded warm repair: the O(nnz(Δ)) seed is derived on the
        # host, then the graph-axis resume loop re-converges every
        # row — same loop body as cold sharded serving
        from repro.distributed import datalog as dd
        from repro.incremental import delta_seed
        d0 = delta_seed(delta_op, prev, backend="np")
        y, _ = dd.sharded_resume_fixpoint(
            fam.sharded, prev, d0, mesh=graph_mesh,
            max_iters=fam.max_iters)
    else:
        y, _ = delta_restart_fixpoint(fam.edges, delta_op, prev,
                                      max_iters=fam.max_iters,
                                      mode="jit")
    y = np.asarray(y)
    for i, s in enumerate(sources):
        fam.answers.replace(s, y[i])
    stats["answers_repaired"] += len(sources)


def _nonmono_edges(fam: Family, coords, values, op: str, stats: dict,
                   graph_mesh) -> None:
    """The non-monotone update path: ``op="delete"`` removes keys,
    ``op="increase"`` replaces stored values with larger ones (delete
    the old ⊕ merge the new)."""
    from repro.incremental import (DeltaEntry, ensure_rule,
                                   maintain_nonmonotone)
    from repro.incremental import maintenance
    fam.kernel_cache.clear()
    vf = fam.plan.strata[0].vf
    # gather the touched keys' *old* stored values (in operator space)
    # before mutating — they decide which removals were support-carrying
    # when the maintenance rule repairs warm answers below
    dcoords = dvals = new_delta = None
    if isinstance(fam.edges, SparseRelation):
        dh = operator_delta(fam, coords, None).as_np()
        dcoords = np.asarray(dh.coords[:int(dh.nnz)])
        dvals = maintenance._gather_values(fam.edges.as_np(), dcoords)
        if op == "increase":
            new_delta = operator_delta(fam, coords, values)
    if fam.edge_rel is not None:
        ent = [DeltaEntry(fam.edge_rel, coords,
                          values if op == "increase" else None, op)]
        fam.db = fam.db.apply_delta(ent)
        fam.host_db = fam.host_db.apply_delta(ent)
    if dcoords is not None:
        # mutate in place at the same capacity: shapes, plan, and every
        # compiled runner keyed on them survive untouched
        fam.edges = fam.edges.delete_keys(dcoords)
        if new_delta is not None:
            nh = new_delta.as_np()
            fam.edges = fam.edges.apply_delta(
                nh.coords[:int(nh.nnz)], nh.values[:int(nh.nnz)])
    elif fam.edge_rel is not None:
        fam.edges = planner.materialize_edges(fam.plan, fam.db,
                                              fam.hints)
    else:
        sr = sr_mod.get(vf.semiring)
        idx = tuple(np.asarray(np.atleast_2d(coords)).T)
        new = (sr.zero if op == "delete"
               else jnp.asarray(np.asarray(values, sr.dtype)))
        fam.edges = jnp.asarray(fam.edges).at[idx].set(new)
    if fam.sharded is not None:
        # re-partition the mutated operator (the compiled sharded
        # runners survive unless per-shard capacity moved)
        from repro.distributed import datalog as dd
        fam.sharded = dd.shard_relation(fam.edges, graph_mesh)
    if fam.init_reads_edges:
        # the update also changed the init term — memoized inits and
        # warm answers are both stale beyond what the rule repairs
        fam.init_cache.clear()
        _drop_answers(fam, stats)
        return
    if not len(fam.answers):
        return
    # deletes/increases are non-monotone: warm answers may over-derive.
    # A CEGIS-verified ⊖/recount rule (DESIGN.md §11) repairs them in
    # place; without one (no ⊖ on the semiring, synthesis timed out,
    # sharded operand) they are dropped as before.
    if dcoords is None or fam.sharded is not None:
        _drop_answers(fam, stats)
        return
    rule = ensure_rule(vf.signature, vf.semiring, op)
    if not rule.verified:
        _drop_answers(fam, stats)
        return
    sources = list(fam.answers.keys())
    sr = sr_mod.get(vf.semiring, lib="np")
    prev = np.stack([np.asarray(fam.answers.peek(s), sr.dtype)
                     for s in sources])
    init = np.stack([np.asarray(family_init(fam, s), sr.dtype)
                     for s in sources])
    y, _ = maintain_nonmonotone(fam.edges, dcoords, dvals, prev, init,
                                rule, merge_delta=new_delta,
                                max_iters=fam.max_iters)
    y = np.asarray(y)
    for i, s in enumerate(sources):
        fam.answers.replace(s, y[i])
    stats["answers_repaired"] += len(sources)
