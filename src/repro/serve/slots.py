"""Slot pools: persistent batched fixpoints with per-row admit/evict.

The continuous-batching core (DESIGN.md §7).  A :class:`SlotPool` owns
one live ``(B, n)`` GSN carry for a (family, B-bucket) pair.  Instead of
packing a batch, running it to *global* convergence, and answering —
the packed-FIFO shape, whose makespan is the slowest row's — the pool:

* **admits** a queued source into a free slot by splicing its ``init``
  column into the live carry (``y_row ← 0̄``, ``Δ_row ← init ⊖ 0̄`` — the
  cold GSN seed; rows are independent under the per-row masks, so a
  spliced row's trajectory is bit-identical to its single-source run);
* **steps** the whole carry a bounded number of iterations (one chunk);
* **harvests** rows whose per-row convergence mask fired — their answers
  leave immediately and their slots free up for the next admission.

Three interchangeable chunk steppers implement the same GSN body:

* :class:`JaxChunkStepper` — the general path: a jitted
  ``resume_fixpoint_chunk`` (one SpMM per round, chunked
  ``lax.while_loop``), compiled once per ``(plan.signature, B-bucket,
  D)`` exactly like the packed server's runners.
* :class:`BitsetBoolStepper` — boolean semiring on CPU: the B query
  lanes live as bits of ``⌈B/64⌉`` uint64 words per vertex, and a round
  is the fused kernel's packed-𝔹 advance
  (:func:`repro.kernels.coo_spmm.bool_round_packed` — one
  ``bitwise_or.reduceat`` over dst-sorted edges) — 64 frontier advances
  per word-op, no XLA scatter.  ~25× the (B, n) SpMM's round
  throughput at B=64 on the 50k power-law serving graph.
* :class:`LevelSyncTropStepper` — tropical semiring with small positive
  *integer* weights on CPU: min-plus distances are computed as
  level-synchronous BFS over the weight-expanded graph (an edge of
  weight w advances a frontier by w levels), again as lane-bitsets with
  one reduceat per weight class per level.  Exact: every reachable
  distance is an integer ≤ levels walked, recovered as
  ``settle_level - admit_level`` and cast to the operator's dtype.

Stepper *selection* is a pool-construction concern
(:func:`build_stepper`); per-request applicability is an admission
concern (``admit`` may refuse an init shape the kernel cannot encode —
e.g. a tropical init with finite non-zero entries — and the scheduler
serves that request through the fallback path instead).

Iteration counts: the jax and bitset steppers count exact GSN rounds
(identical to the single-source runner); the level-sync stepper counts
BFS levels, which is its natural round unit — ``QueryRequest.iters`` is
informational either way.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import semiring as sr_mod
from repro.serve.family import Family, QueryRequest
from repro.sparse.coo import SparseRelation

#: level-sync admissibility: weights must be positive integers ≤ this
#: (the ring buffer holds wmax+1 frontier levels; huge weights would
#: also walk absurd level counts — the jax stepper handles those)
TROP_WMAX_CAP = 64

_INF32 = np.uint32(0xFFFFFFFF)


def _dst_sorted(edges: SparseRelation, select=None):
    """Destination-sorted COO view + unique-dst segment starts, the
    ``reduceat`` geometry shared by both host kernels."""
    eh = edges.as_np()
    k = int(eh.nnz)
    src = eh.coords[:k, 0].astype(np.int64)
    dst = eh.coords[:k, 1].astype(np.int64)
    w = eh.values[:k]
    if select is not None:
        src, dst, w = src[select], dst[select], w[select]
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    udst, seg = np.unique(dst, return_index=True)
    return src, udst, seg, w[order]


def _lane_bits(words: np.ndarray, b: int) -> np.ndarray:
    """(…, W) uint64 words → (…, b) bool lanes."""
    return np.unpackbits(words.view(np.uint8), axis=-1,
                         bitorder="little")[..., :b].astype(bool)


class BitsetBoolStepper:
    """Boolean GSN rounds over lane-bitset state (CPU host kernel).

    Geometry and the per-round advance both delegate to
    :mod:`repro.kernels.coo_spmm`: the pool's rounds are exactly the
    fused kernel's packed-𝔹 path (``bool_round_packed`` over the shared
    dst-sorted :class:`~repro.kernels.coo_spmm.SpmmPlan`), so the serve
    hot loop and the planner-priced backend cannot drift apart.
    """

    def __init__(self, edges: SparseRelation, n: int, b: int,
                 geom_cache: dict | None = None):
        if edges.semiring != "bool":
            raise ValueError("bitset stepper is boolean-only")
        from repro.kernels import coo_spmm
        self.n, self.b = n, b
        self.w = (b + 63) // 64
        cache = geom_cache if geom_cache is not None else {}
        key = ("spmm_plan", "fused")
        plan = cache.get(key)
        if plan is None:
            plan = cache[key] = coo_spmm.plan_geometry(edges,
                                                       transpose=True)
        self._plan = plan
        self._round = coo_spmm.bool_round_packed
        self.y = np.zeros((n, self.w), np.uint64)
        self.d = np.zeros((n, self.w), np.uint64)
        self.it = np.zeros(b, np.int64)

    def admit(self, j: int, init: np.ndarray) -> bool:
        wj, bit = divmod(j, 64)
        col = np.asarray(init, bool).astype(np.uint64) << np.uint64(bit)
        self.y[:, wj] &= ~np.uint64(1 << bit)
        self.d[:, wj] = (self.d[:, wj] & ~np.uint64(1 << bit)) | col
        self.it[j] = 0
        return True

    def live_lanes(self) -> np.ndarray:
        return _lane_bits(np.bitwise_or.reduce(self.d, axis=0), self.b)

    def frontier_nnz(self) -> int:
        return int(np.unpackbits(self.d.view(np.uint8)).sum())

    def step(self, k: int) -> None:
        for _ in range(k):
            live = self.live_lanes()
            if not live.any():
                return
            self.it += live
            self.y |= self.d
            self.d = self._round(self._plan, self.d) & ~self.y

    def extract(self, j: int) -> tuple[np.ndarray, int]:
        wj, bit = divmod(j, 64)
        one = np.uint64(1 << bit)
        return (self.y[:, wj] & one).astype(bool), int(self.it[j])

    def release(self, j: int) -> None:
        wj, bit = divmod(j, 64)
        mask = ~np.uint64(1 << bit)
        self.y[:, wj] &= mask
        self.d[:, wj] &= mask


class LevelSyncTropStepper:
    """Min-plus distances as level-synchronous bitset BFS (CPU kernel).

    Raises ``ValueError`` at construction when the operator's weights
    are not positive integers ≤ :data:`TROP_WMAX_CAP` — selection then
    falls back to the jax stepper.
    """

    def __init__(self, edges: SparseRelation, n: int, b: int,
                 geom_cache: dict | None = None):
        if edges.semiring != "trop":
            raise ValueError("level-sync stepper is tropical-only")
        self.n, self.b = n, b
        self.w = (b + 63) // 64
        cache = geom_cache if geom_cache is not None else {}
        geom = cache.get("trop_geom")
        if geom is None:
            eh = edges.as_np()
            vals = eh.values[:int(eh.nnz)]
            if len(vals) and (not np.all(vals == np.round(vals))
                              or vals.min() < 1
                              or vals.max() > TROP_WMAX_CAP):
                raise ValueError("level-sync needs positive integer "
                                 f"weights ≤ {TROP_WMAX_CAP}")
            wmax = int(vals.max()) if len(vals) else 1
            iw = vals.astype(np.int64)
            classes = []
            for wc in range(1, wmax + 1):
                sel = np.flatnonzero(iw == wc)
                classes.append(_dst_sorted(edges, sel)[:3]
                               if len(sel) else None)
            geom = cache["trop_geom"] = (vals.dtype, wmax, classes)
        self.dtype, self.wmax, self._classes = geom
        self.ring = np.zeros((self.wmax + 1, n, self.w), np.uint64)
        self.settled = np.zeros((n, self.w), np.uint64)
        # (b, n): lane-major so extract/release touch one contiguous row
        self.dist = np.full((b, n), _INF32, np.uint32)
        self.admit_level = np.zeros(b, np.int64)
        self.level = 0
        self.it = np.zeros(b, np.int64)

    def admit(self, j: int, init: np.ndarray) -> bool:
        init = np.asarray(init)
        finite = np.isfinite(init)
        if finite.any() and init[finite].any():
            return False  # only 0/∞ inits encode as a level-0 frontier
        wj, bit = divmod(j, 64)
        one = np.uint64(1 << bit)
        col = finite.astype(np.uint64) << np.uint64(bit)
        self.ring[self.level % (self.wmax + 1), :, wj] |= col
        self.settled[:, wj] |= col
        self.dist[j, finite] = np.uint32(self.level)
        self.admit_level[j] = self.level
        self.it[j] = 0
        return True

    def live_lanes(self) -> np.ndarray:
        any_front = np.bitwise_or.reduce(
            np.bitwise_or.reduce(self.ring, axis=0), axis=0)
        return _lane_bits(any_front, self.b)

    def frontier_nnz(self) -> int:
        front = np.bitwise_or.reduce(self.ring, axis=0)
        return int(np.unpackbits(front.view(np.uint8)).sum())

    def step(self, k: int) -> None:
        r = self.wmax + 1
        for _ in range(k):
            live = self.live_lanes()
            if not live.any():
                return
            self.it += live
            self.level += 1
            t = self.level
            new = np.zeros((self.n, self.w), np.uint64)
            for wc in range(1, self.wmax + 1):
                cls = self._classes[wc - 1]
                if cls is None or t - wc < 0:
                    continue
                src, udst, seg = cls
                new[udst] |= np.bitwise_or.reduceat(
                    self.ring[(t - wc) % r][src], seg, axis=0)
            new &= ~self.settled
            self.ring[t % r] = new
            rows = np.flatnonzero(new.any(axis=1))
            if len(rows):
                self.settled |= new
                # scatter only the (vertex, lane) pairs that settled
                # this level — a dense where() over dist[rows] gathers
                # and rewrites 64 lanes per row, ~10× the traffic
                r_idx, l_idx = np.nonzero(_lane_bits(new[rows], self.b))
                self.dist[l_idx, rows[r_idx]] = np.uint32(t)

    def extract(self, j: int) -> tuple[np.ndarray, int]:
        col = self.dist[j]
        out = col.astype(np.float64) - self.admit_level[j]
        out[col == _INF32] = np.inf
        return out.astype(self.dtype), int(self.it[j])

    def release(self, j: int) -> None:
        wj, bit = divmod(j, 64)
        mask = ~np.uint64(1 << bit)
        # no ring sweep: a releasable lane converged, i.e. has no
        # frontier bits anywhere in the ring by definition
        self.settled[:, wj] &= mask
        self.dist[j] = _INF32


class JaxChunkStepper:
    """The general chunk stepper: host-resident (B, n) carry advanced by
    a jitted bounded slice of the batched GSN loop."""

    def __init__(self, edges: SparseRelation, n: int, b: int,
                 chunk_fn):
        self.edges = edges
        self.n, self.b = n, b
        self._chunk = chunk_fn          # (edges, y, d, it) -> (y, d, it)
        sr = sr_mod.get(edges.semiring, lib="np")
        self._sr = sr
        self.y = np.full((b, n), sr.zero, sr.dtype)
        self.d = np.full((b, n), sr.zero, sr.dtype)
        self.it = np.zeros(b, np.int32)

    def admit(self, j: int, init: np.ndarray) -> bool:
        zero_row = np.full(self.n, self._sr.zero, self._sr.dtype)
        self.y[j] = zero_row
        # the cold GSN seed: d0 = (init ⊕ 0̄⊗E) ⊖ 0̄ = init ⊖ 0̄
        self.d[j] = self._sr.minus(np.asarray(init, self._sr.dtype),
                                   zero_row)
        self.it[j] = 0
        return True

    def live_lanes(self) -> np.ndarray:
        return np.asarray(
            (self.d != np.asarray(self._sr.zero,
                                  self._sr.dtype)).any(axis=1))

    def frontier_nnz(self) -> int:
        return int((self.d != np.asarray(self._sr.zero,
                                         self._sr.dtype)).sum())

    def step(self, k: int) -> None:
        if not self.live_lanes().any():
            return
        y, d, it = self._chunk(self.edges.as_jnp(), self.y, self.d,
                               self.it)
        # np.array, not asarray: jax hands back read-only zero-copy
        # views on CPU, and admit/release scribble rows in place
        self.y = np.array(y)
        self.d = np.array(d)
        self.it = np.array(it, np.int32)

    def extract(self, j: int) -> tuple[np.ndarray, int]:
        return self.y[j].copy(), int(self.it[j])

    def release(self, j: int) -> None:
        zero_row = np.full(self.n, self._sr.zero, self._sr.dtype)
        self.y[j] = zero_row
        self.d[j] = zero_row


def build_stepper(fam: Family, b: int, *, host_kernels: bool,
                  chunk_fn_factory):
    """Pick the cheapest applicable stepper for this family's operator.

    ``chunk_fn_factory()`` lazily supplies the compiled jax chunk
    function (so host-kernel pools never touch the compile cache).
    """
    import jax

    edges = fam.edges
    if not isinstance(edges, SparseRelation):
        raise ValueError("slot pools need a sparse linear operator")
    if host_kernels and jax.default_backend() == "cpu":
        if edges.semiring == "bool":
            return BitsetBoolStepper(edges, fam.n, b,
                                     geom_cache=fam.kernel_cache)
        if edges.semiring == "trop":
            try:
                return LevelSyncTropStepper(edges, fam.n, b,
                                            geom_cache=fam.kernel_cache)
            except ValueError:
                pass
    return JaxChunkStepper(edges, fam.n, b, chunk_fn_factory())


@dataclasses.dataclass
class _Slot:
    req: QueryRequest | None = None


class SlotPool:
    """Occupancy bookkeeping around one chunk stepper."""

    def __init__(self, fam: Family, b: int, *, host_kernels: bool,
                 chunk_fn_factory):
        self.fam = fam
        self.b = b
        self.stepper = build_stepper(fam, b, host_kernels=host_kernels,
                                     chunk_fn_factory=chunk_fn_factory)
        self.slots: list[QueryRequest | None] = [None] * b
        self._free: list[int] = list(range(b))[::-1]

    @property
    def occupied(self) -> int:
        return self.b - len(self._free)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def admit(self, req: QueryRequest, init: np.ndarray) -> bool:
        """Splice ``init`` into a free slot; False when the stepper
        cannot encode this init (caller serves it another way) or the
        pool is full."""
        if not self._free:
            return False
        j = self._free[-1]
        if not self.stepper.admit(j, init):
            return False
        self._free.pop()
        self.slots[j] = req
        return True

    def step(self, k: int) -> None:
        self.stepper.step(k)

    def frontier_nnz(self) -> int:
        """Live Δ entries across all lanes — the chunk-boundary frontier
        observation the scheduler streams into its per-family
        :class:`~repro.serve.metrics.FrontierMetrics`."""
        return self.stepper.frontier_nnz()

    def frontier_density(self) -> float:
        return self.frontier_nnz() / float(self.b * self.fam.n or 1)

    def harvest(self) -> list[tuple[QueryRequest, np.ndarray, int]]:
        """Evict every occupied slot whose convergence mask fired:
        extract its answer, free the slot."""
        live = self.stepper.live_lanes()
        out = []
        for j, req in enumerate(self.slots):
            if req is None or live[j]:
                continue
            y, iters = self.stepper.extract(j)
            self.stepper.release(j)
            self.slots[j] = None
            self._free.append(j)
            out.append((req, y, iters))
        return out
