"""Capacity-bounded LRU caches for the serving subsystem.

Two cache populations share this one implementation (DESIGN.md §7):

* **warm answers** — per-family ``source → x*`` solutions, repaired in
  place by monotone updates (:func:`repro.serve.family.apply_updates`)
  and invalidated by deletes; replaces the unbounded ``warm_answers``
  dict the packed-FIFO server used to grow forever.
* **compiled runners** — ``(plan.signature, B-bucket, D) → jitted fn``;
  a server that sees many (family, bucket) shapes over its lifetime now
  sheds the cold ones instead of leaking every trace ever lowered.

Eviction is strict LRU on *access* (a hit refreshes recency); ``hits`` /
``misses`` / ``evictions`` counters feed ``server.stats()``.  Capacity 0
disables the cache entirely (every get misses, puts are dropped) —
benchmarks use that to force cold compute.
"""

from __future__ import annotations

import collections
from typing import Any, Hashable, Iterator


class LRUCache:
    """An ordered-dict LRU with hit/miss/eviction counters."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._data: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def __iter__(self) -> Iterator:
        return iter(self._data)

    def get(self, key: Hashable, default: Any = None) -> Any:
        """Counted, recency-refreshing lookup."""
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return default

    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Uncounted lookup that leaves recency untouched (for
        invariants/tests, never the serving hot path)."""
        return self._data.get(key, default)

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def pop(self, key: Hashable, default: Any = None) -> Any:
        return self._data.pop(key, default)

    def clear(self) -> int:
        """Drop everything (delete-update invalidation); returns how many
        entries were dropped."""
        n = len(self._data)
        self._data.clear()
        return n

    def items(self):
        return self._data.items()

    def keys(self):
        return self._data.keys()

    def replace(self, key: Hashable, value: Any) -> None:
        """In-place value repair that does NOT touch recency or counters
        (warm-answer repair must not look like serving traffic)."""
        if key in self._data:
            self._data[key] = value
