"""Continuous-batching serve subsystem (DESIGN.md §7).

The production serving surface: :class:`ContinuousServer` runs one
persistent batched fixpoint per program family as a slot pool —
admitting queued sources into freed rows, evicting rows the moment
their convergence mask fires, fencing updates FIFO-per-family, and
streaming tail-latency histograms.  ``launch.datalog_serve`` remains as
a packed-FIFO compatibility shim built on the same family machinery.
"""

from repro.serve.cache import LRUCache
from repro.serve.family import (Family, QueryRequest, UpdateRequest,
                                build_family, bucket)
from repro.serve.metrics import LatencyHistogram, RequestMetrics
from repro.serve.scheduler import BackpressureError, ContinuousServer
from repro.serve.slots import (BitsetBoolStepper, JaxChunkStepper,
                               LevelSyncTropStepper, SlotPool)

__all__ = [
    "BackpressureError", "BitsetBoolStepper", "ContinuousServer",
    "Family", "JaxChunkStepper", "LRUCache", "LatencyHistogram",
    "LevelSyncTropStepper", "QueryRequest", "RequestMetrics",
    "SlotPool", "UpdateRequest", "build_family", "bucket",
]
