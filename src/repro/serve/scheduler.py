"""Continuous-batching serve scheduler (DESIGN.md §7).

:class:`ContinuousServer` replaces the packed-FIFO serving shape (pack a
batch, run it to *global* convergence, answer, repeat) with the
vLLM-style loop the per-row convergence masks were built for:

* one persistent :class:`~repro.serve.slots.SlotPool` per registered
  family holds a live ``(B, n)`` fixpoint; each scheduling round steps
  it a bounded chunk of iterations, **evicts** rows whose mask fired,
  and **admits** queued sources into the freed slots by splicing their
  init columns — the batch never waits for its slowest row, and the
  compiled chunk runner is reused across the entire request stream
  (cache key ``(plan.signature, B-bucket, D)``, as for the packed
  server's runners).
* **admission control**: each family's queue is bounded; ``submit``
  raises :class:`BackpressureError` (and counts a shed) past the limit,
  so overload degrades by rejecting at the edge instead of growing an
  unbounded in-process queue.
* **fairness**: weighted round-robin over families — every scheduling
  round gives each family with work ``weight`` step-quanta, so a hot
  family with a deep queue cannot starve a light one (its pool still
  advances every round).
* **update fencing**: queries and updates share one FIFO per family; a
  queued update blocks later same-family admissions, applies once the
  pool drains, then reopens admission — an answer never predates an
  update acknowledged before its query was submitted.
* **FIFO-per-family delivery**: rows may *converge* out of order (that
  is the point), but answers are published in submission order through
  a per-family reorder buffer, so clients observe the same ordering
  contract as the packed server.
* **single-request latency routing**: a lone query with an idle pool
  skips the batched machinery entirely and runs the planner's
  per-source path (:func:`repro.serve.family.latency_serve`) — the B=1
  fix for BENCH_serve.json.
* **metrics**: queue/compute/total latency of every request stream into
  the streaming histograms of :mod:`repro.serve.metrics`; ``stats()``
  exposes p50/p95/p99 plus counter totals and per-family gauges.

Families whose operator is dense or graph-sharded have no columnwise
splice (dense batched runners carry no per-row state the host can cheaply
edit; the sharded operand lives device-partitioned) — those fall back to
packed whole-run serving inside this scheduler, and multi-host sharded
serving stays on the ``launch.datalog_serve`` shim.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro.core import engine, planner
from repro.core import semiring as sr_mod
from repro.serve import family as fam_mod
from repro.serve.cache import LRUCache
from repro.serve.family import (Family, QueryRequest, UpdateRequest,
                                bucket)
from repro.serve.metrics import FrontierMetrics, RequestMetrics
from repro.serve.slots import SlotPool
from repro.sparse.coo import SparseRelation


class BackpressureError(RuntimeError):
    """Raised by ``submit`` when a family's queue is at its bound."""

    def __init__(self, family: str, depth: int, limit: int):
        super().__init__(
            f"family {family!r} queue at {depth}/{limit}: request shed "
            f"(retry with backoff or raise queue_limit)")
        self.family = family
        self.depth = depth
        self.limit = limit


@dataclasses.dataclass
class _FamilyState:
    fam: Family
    weight: int
    queue: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    pool: SlotPool | None = None
    seq: int = 0                 # next submission sequence number
    next_deliver: int = 0        # FIFO delivery cursor
    done: dict = dataclasses.field(default_factory=dict)
    served: int = 0
    frontier: FrontierMetrics = dataclasses.field(
        default_factory=FrontierMetrics)


class ContinuousServer:
    """Slot-based continuous batching over registered program families."""

    def __init__(self, *, max_batch: int = 64, chunk_iters: int = 4,
                 queue_limit: int = 1024, warm_answers: int = 256,
                 compiled_cache: int = 32, max_iters: int = 10_000,
                 host_kernels: bool = True):
        if max_batch < 1 or chunk_iters < 1 or queue_limit < 1:
            raise ValueError("max_batch, chunk_iters and queue_limit "
                             "must be >= 1")
        self.max_batch = max_batch
        self.chunk_iters = chunk_iters
        self.queue_limit = queue_limit
        self.warm_answers = warm_answers
        self.max_iters = max_iters
        self.host_kernels = host_kernels
        self._families: dict[str, _FamilyState] = {}
        self._compiled = LRUCache(compiled_cache)
        self.metrics = RequestMetrics()
        self._counters = {
            "served": 0, "failed": 0, "shed": 0, "updates": 0,
            "warm_hits": 0, "answers_repaired": 0, "answers_dropped": 0,
            "admitted": 0, "evicted": 0, "chunks": 0, "migrated": 0,
            "latency_routed": 0, "packed_fallback": 0,
        }

    # -- registration -------------------------------------------------------

    def register(self, name: str, make_program, db: engine.Database, *,
                 edges=None, template_source: int = 0,
                 weight: int = 1) -> Family:
        """Register a family (see :func:`repro.serve.family.build_family`)
        with a fairness ``weight``: step-quanta per scheduling round."""
        if weight < 1:
            raise ValueError(f"weight must be >= 1, got {weight}")
        fam = fam_mod.build_family(
            name, make_program, db, edges=edges,
            template_source=template_source, max_iters=self.max_iters,
            warm_answers=self.warm_answers)
        self._families[name] = _FamilyState(fam, weight)
        return fam

    # -- submission ---------------------------------------------------------

    def _state(self, family: str) -> _FamilyState:
        if family not in self._families:
            raise KeyError(f"unknown family {family!r}; "
                           f"registered: {sorted(self._families)}")
        return self._families[family]

    def submit(self, family: str, source: int) -> QueryRequest:
        fs = self._state(family)
        if len(fs.queue) >= self.queue_limit:
            self._counters["shed"] += 1
            raise BackpressureError(family, len(fs.queue),
                                    self.queue_limit)
        req = QueryRequest(family, int(source),
                           submitted_s=time.perf_counter())
        req._seq = fs.seq
        fs.seq += 1
        fs.queue.append(req)
        return req

    def submit_update(self, family: str, coords, values=None, *,
                      op: str = "merge") -> UpdateRequest:
        """Updates share the family FIFO with queries (fencing) and are
        never shed — dropping an acknowledged mutation would silently
        fork the graph state."""
        fs = self._state(family)
        if op not in ("merge", "delete", "increase"):
            raise ValueError(f"unknown update op {op!r}")
        if op == "increase" and values is None:
            raise ValueError("op='increase' needs the new (larger) values")
        req = UpdateRequest(family,
                            np.atleast_2d(np.asarray(coords, np.int64)),
                            None if values is None
                            else np.asarray(values).reshape(-1), op,
                            submitted_s=time.perf_counter())
        req._seq = fs.seq
        fs.seq += 1
        fs.queue.append(req)
        return req

    def pending(self) -> int:
        return sum(len(fs.queue) + (fs.pool.occupied if fs.pool else 0)
                   for fs in self._families.values())

    # -- the scheduling loop ------------------------------------------------

    def step(self) -> list:
        """One scheduling round: per family (weighted), apply due
        updates, admit into free slots, step one chunk, harvest fired
        rows.  Returns the requests *delivered* this round (FIFO per
        family)."""
        delivered: list = []
        for fs in self._families.values():
            for _ in range(fs.weight):
                self._apply_due_updates(fs, delivered)
                self._admit(fs, delivered)
                if fs.pool is None or fs.pool.occupied == 0:
                    break
                fs.pool.step(self.chunk_iters)
                self._counters["chunks"] += 1
                fs.frontier.record(fs.pool.frontier_nnz(),
                                   fs.pool.frontier_density())
                self._harvest(fs, delivered)
        return delivered

    def run_until_idle(self) -> int:
        """Drive ``step`` until every queue and pool is empty; returns
        the number of requests delivered."""
        done = 0
        while self.pending():
            before = (self._counters["chunks"], self._counters["admitted"],
                      self._counters["updates"])
            n = len(self.step())
            done += n
            after = (self._counters["chunks"], self._counters["admitted"],
                     self._counters["updates"])
            assert n or after != before or not self.pending(), \
                "scheduler made no progress"
        return done

    drain = run_until_idle

    # -- internals ----------------------------------------------------------

    def _apply_due_updates(self, fs: _FamilyState, delivered: list):
        """The update fence: a queued update waits for the pool to drain
        (every earlier query was admitted before it), applies, then
        reopens admission for the queries behind it."""
        while (fs.queue and isinstance(fs.queue[0], UpdateRequest)
               and (fs.pool is None or fs.pool.occupied == 0)):
            lead = fs.queue.popleft()
            ups = [lead]
            while (fs.queue and isinstance(fs.queue[0], UpdateRequest)
                   and fs.queue[0].op == lead.op):
                ups.append(fs.queue.popleft())
            fam_mod.apply_updates(fs.fam, ups, self._counters)
            # the operator changed: steppers index stale edge buffers,
            # so the pool is rebuilt lazily on next admission
            fs.pool = None
            for u in ups:
                self._publish(fs, u, delivered)

    def _head_run(self, fs: _FamilyState) -> int:
        """How many queries are admissible before the next fence."""
        n = 0
        for item in fs.queue:
            if not isinstance(item, QueryRequest):
                break
            n += 1
        return n

    def _admit(self, fs: _FamilyState, delivered: list) -> None:
        fam = fs.fam
        while fs.queue and isinstance(fs.queue[0], QueryRequest):
            req = fs.queue[0]
            now = time.perf_counter()
            warm = fam.answers.get(req.source)
            if warm is not None:
                fs.queue.popleft()
                req.admitted_s = req.converged_s = now
                req.result = warm
                req.iters = 0
                self._counters["warm_hits"] += 1
                self._finish(fs, req, delivered)
                continue
            try:
                init = fam_mod.family_init(fam, req.source)
            except Exception as e:  # bad source must not strand the rest
                fs.queue.popleft()
                req.error = f"{type(e).__name__}: {e}"
                req.admitted_s = req.converged_s = now
                self._counters["failed"] += 1
                self._finish(fs, req, delivered)
                continue
            poolable = (isinstance(fam.edges, SparseRelation)
                        and fam.sharded is None)
            run_len = self._head_run(fs)
            idle = fs.pool is None or fs.pool.occupied == 0
            if run_len == 1 and idle:
                y = fam_mod.latency_serve(fam, init)
                if y is not None:
                    fs.queue.popleft()
                    req.admitted_s = now
                    req.result, req.iters = y
                    req.converged_s = time.perf_counter()
                    self._counters["latency_routed"] += 1
                    self._remember(fam, req.source, req.result)
                    self._finish(fs, req, delivered)
                    continue
            if not poolable:
                self._serve_packed(fs, delivered)
                continue
            occ = fs.pool.occupied if fs.pool is not None else 0
            want = bucket(max(run_len + occ, 2), self.max_batch)
            if fs.pool is not None and occ and fs.pool.b < want:
                # demand outgrew an undersized pool (built during the
                # first trickle of a burst): rebuild at the larger
                # bucket and re-splice the in-flight rows from their
                # inits.  A restarted row's trajectory is identical
                # (the splice is the cold GSN seed), and the few
                # restarts at ramp-up are far cheaper than letting the
                # pool drain serially — a continuously-refilled pool
                # never hits occupied == 0.
                live = [r for r in fs.pool.slots if r is not None]
                fs.pool = None
                self._ensure_pool(fs, want)
                self._counters["migrated"] += len(live)
                for lr in live:
                    linit = fam_mod.family_init(fam, lr.source)
                    if not fs.pool.admit(lr, linit):
                        self._serve_solo(fs, lr, linit, delivered)
            else:
                self._ensure_pool(fs, want)
            if fs.pool.free_slots == 0:
                break
            req.admitted_s = now
            if not fs.pool.admit(req, init):
                # the stepper cannot encode this init — solo fallback
                fs.queue.popleft()
                self._serve_solo(fs, req, init, delivered)
                continue
            fs.queue.popleft()
            self._counters["admitted"] += 1

    def _ensure_pool(self, fs: _FamilyState, want: int) -> None:
        # grow-only: a pool bigger than current demand is kept (free
        # lanes are near-free; rebuilding costs an edge re-sort), so a
        # stream's tail doesn't thrash 64 → 32 → … → 2 rebuilds
        if fs.pool is not None and (fs.pool.occupied > 0
                                    or fs.pool.b >= want):
            return
        fam = fs.fam

        def chunk_fn_factory(b=want):
            # the chunk is the plan runner's serve_chunk_fn (Runner
            # protocol, DESIGN.md §10) — a jitted traceable chunk for
            # jnp runners, the fused kernel's un-jitted chunk (which
            # plans host geometry and memoizes its own per-operator
            # compile) for a pallas-runner plan; keyed on the resolved
            # SpMM backend so backend overrides recompile
            runner = fam.plan.strata[0].runner
            be = planner.spmm_exec_backend(runner)
            key = (fam.plan.signature, be, b, 1)
            fn = self._compiled.get(key)
            if fn is None:
                from repro.core import runners as runners_mod
                fn = runners_mod.get(runner).serve_chunk_fn(
                    self.chunk_iters)
                self._compiled.put(key, fn)
            return fn

        fs.pool = SlotPool(fam, want, host_kernels=self.host_kernels,
                           chunk_fn_factory=chunk_fn_factory)

    def _harvest(self, fs: _FamilyState, delivered: list) -> None:
        for req, y, iters in fs.pool.harvest():
            req.converged_s = time.perf_counter()
            req.result = y
            req.iters = iters
            self._counters["evicted"] += 1
            self._remember(fs.fam, req.source, y)
            self._finish(fs, req, delivered)

    def _serve_solo(self, fs: _FamilyState, req: QueryRequest, init,
                    delivered: list) -> None:
        """A request no stepper can host: the per-source latency path,
        else a one-row packed run."""
        req.admitted_s = time.perf_counter()
        y = fam_mod.latency_serve(fs.fam, init)
        if y is not None:
            req.result, req.iters = y
            self._counters["latency_routed"] += 1
        else:
            y, iters = self._packed_run(fs.fam, np.asarray(init)[None, :])
            req.result, req.iters = y[0], int(iters[0])
        req.converged_s = time.perf_counter()
        self._remember(fs.fam, req.source, req.result)
        self._finish(fs, req, delivered)

    def _serve_packed(self, fs: _FamilyState, delivered: list) -> None:
        """Whole-run fallback for dense/sharded operators (no columnwise
        splice): behaves like one packed-FIFO batch."""
        self._counters["packed_fallback"] += 1
        fam = fs.fam
        batch, inits = [], []
        while (fs.queue and isinstance(fs.queue[0], QueryRequest)
               and len(batch) < self.max_batch):
            req = fs.queue.popleft()
            req.admitted_s = time.perf_counter()
            warm = fam.answers.get(req.source)
            if warm is not None:
                req.result, req.iters = warm, 0
                req.converged_s = req.admitted_s
                self._counters["warm_hits"] += 1
                self._finish(fs, req, delivered)
                continue
            try:
                inits.append(fam_mod.family_init(fam, req.source))
                batch.append(req)
            except Exception as e:
                req.error = f"{type(e).__name__}: {e}"
                req.converged_s = req.admitted_s
                self._counters["failed"] += 1
                self._finish(fs, req, delivered)
        if not batch:
            return
        sr = sr_mod.get(fam.semiring, lib="np")
        bb = bucket(len(batch), self.max_batch)
        packed = np.full((bb, fam.n), sr.zero, sr.dtype)
        for i, v in enumerate(inits):
            packed[i] = np.asarray(v)
        y, iters = self._packed_run(fam, packed)
        now = time.perf_counter()
        for i, req in enumerate(batch):
            req.result = y[i]
            req.iters = int(iters[i])
            req.converged_s = now
            self._remember(fam, req.source, y[i])
            self._finish(fs, req, delivered)

    def _packed_run(self, fam: Family, packed: np.ndarray):
        be = planner.spmm_exec_backend(fam.plan.strata[0].runner)
        key = ("packed", fam.plan.signature, be, packed.shape[0], 1)
        run = self._compiled.get(key)
        if run is None:
            run = planner.compile_batched(fam.plan,
                                          max_iters=fam.max_iters)
            self._compiled.put(key, run)
        operand = fam.sharded if fam.sharded is not None else fam.edges
        y, iters = run(operand, packed)
        return np.asarray(y), np.asarray(iters)

    def _remember(self, fam: Family, source: int, y: np.ndarray) -> None:
        fam.answers.put(source, y)

    # -- delivery & metrics -------------------------------------------------

    def _finish(self, fs: _FamilyState, req: QueryRequest,
                delivered: list) -> None:
        """A query's answer is ready; publish it and everything behind
        it that was already waiting (FIFO per family)."""
        if req.error is None:
            fs.served += 1
            self._counters["served"] += 1
        self._publish(fs, req, delivered)

    def _publish(self, fs: _FamilyState, item, delivered: list) -> None:
        fs.done[item._seq] = item
        while fs.next_deliver in fs.done:
            out = fs.done.pop(fs.next_deliver)
            fs.next_deliver += 1
            out.done_s = time.perf_counter()
            if isinstance(out, QueryRequest):
                self.metrics.total.record(out.latency_s)
                if out.admitted_s:
                    self.metrics.queue.record(
                        out.admitted_s - out.submitted_s)
                if out.converged_s and out.admitted_s:
                    self.metrics.compute.record(
                        out.converged_s - out.admitted_s)
            delivered.append(out)

    def stats(self) -> dict:
        """Counters, cache stats, latency percentiles, family gauges."""
        out = dict(self._counters)
        out["compile_cache"] = {"size": len(self._compiled),
                                "hits": self._compiled.hits,
                                "misses": self._compiled.misses,
                                "evictions": self._compiled.evictions}
        out["latency"] = self.metrics.summary()
        out["families"] = {
            name: {"queue_depth": len(fs.queue),
                   "in_flight": fs.pool.occupied if fs.pool else 0,
                   "pool_b": fs.pool.b if fs.pool else 0,
                   "served": fs.served,
                   "weight": fs.weight,
                   "warm_answers": len(fs.fam.answers),
                   "warm_evictions": fs.fam.answers.evictions,
                   "frontier": fs.frontier.summary()}
            for name, fs in self._families.items()}
        return out
