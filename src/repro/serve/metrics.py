"""Streaming latency metrics for the serving subsystem (DESIGN.md §7).

Tail latency is the serve loop's SLO currency, but keeping every sample
to sort at quantile time is an unbounded-memory bug in a server.  A
:class:`LatencyHistogram` records each sample into log-spaced buckets —
fixed memory, O(1) record, ~4 % relative quantile error across nine
decades (100 ns … 1000 s) — and reports p50/p95/p99 by walking the
cumulative counts (quantiles interpolate inside the winning bucket's
log-width).

:class:`RequestMetrics` groups the three per-request phases the
scheduler stamps (DESIGN.md §7):

* ``queue``   — submit → admitted into a slot (or warm/latency serve);
* ``compute`` — admitted → convergence mask fired;
* ``total``   — submit → answer delivered (includes the FIFO-per-family
  reorder wait, so it is what a client actually observes).
"""

from __future__ import annotations

import math


#: bucket geometry: 9 decades from 100ns, 16 buckets per decade → 4.4%
#: max relative error, 144 int counters per histogram
_LO = 1e-7
_PER_DECADE = 16
_DECADES = 9
_NBUCKETS = _PER_DECADE * _DECADES


class LatencyHistogram:
    """Fixed-size log-bucketed histogram of seconds-valued samples."""

    def __init__(self):
        self.counts = [0] * _NBUCKETS
        self.n = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        s = max(float(seconds), 0.0)
        self.n += 1
        self.sum_s += s
        if s > self.max_s:
            self.max_s = s
        if s <= _LO:
            self.counts[0] += 1
            return
        b = int(math.log10(s / _LO) * _PER_DECADE)
        self.counts[min(b, _NBUCKETS - 1)] += 1

    def quantile(self, q: float) -> float:
        """The q-quantile in seconds (0 when no samples yet)."""
        if self.n == 0:
            return 0.0
        rank = q * self.n
        seen = 0.0
        for b, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = _LO * 10.0 ** (b / _PER_DECADE)
                hi = _LO * 10.0 ** ((b + 1) / _PER_DECADE)
                frac = (rank - seen) / c
                return min(lo + (hi - lo) * frac, self.max_s)
            seen += c
        return self.max_s

    def summary(self) -> dict:
        """The stats() leaf: count, mean and the SLO percentiles (ms)."""
        return {
            "count": self.n,
            "mean_ms": (self.sum_s / self.n * 1e3) if self.n else 0.0,
            "p50_ms": self.quantile(0.50) * 1e3,
            "p95_ms": self.quantile(0.95) * 1e3,
            "p99_ms": self.quantile(0.99) * 1e3,
            "max_ms": self.max_s * 1e3,
        }


class RequestMetrics:
    """queue/compute/total histograms plus a few scalar counters."""

    def __init__(self):
        self.queue = LatencyHistogram()
        self.compute = LatencyHistogram()
        self.total = LatencyHistogram()

    def summary(self) -> dict:
        return {"queue": self.queue.summary(),
                "compute": self.compute.summary(),
                "total": self.total.summary()}


class FrontierMetrics:
    """Per-family chunk-boundary frontier observations (DESIGN.md §10).

    The continuous scheduler records the slot pool's live-Δ count after
    every chunk it steps — the same ``FrontierStats`` signal the
    adaptive executor re-prices runners from — so operators can see a
    family's frontier drift (collapse → hub re-explosion) from
    ``stats()`` without instrumenting the pool.  Fixed memory: scalars
    plus one running sum, no per-chunk history.
    """

    def __init__(self):
        self.chunks = 0
        self.last_nnz = 0
        self.last_density = 0.0
        self.peak_nnz = 0
        self._nnz_sum = 0

    def record(self, nnz: int, density: float) -> None:
        self.chunks += 1
        self.last_nnz = int(nnz)
        self.last_density = float(density)
        self._nnz_sum += int(nnz)
        if nnz > self.peak_nnz:
            self.peak_nnz = int(nnz)

    def summary(self) -> dict:
        return {
            "chunks": self.chunks,
            "last_nnz": self.last_nnz,
            "last_density": self.last_density,
            "peak_nnz": self.peak_nnz,
            "mean_nnz": (self._nnz_sum / self.chunks) if self.chunks
            else 0.0,
        }
