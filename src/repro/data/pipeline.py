"""Deterministic, shardable data pipeline.

Design points for 1000+-node runs:

* **Deterministic addressing** — batch i of host h is a pure function of
  (seed, step, host), so restarts and elastic re-sharding never replay or
  skip data (the checkpoint stores only ``step``).
* **Host-sharded loading** — each host materializes only its slice of the
  global batch; `jax.make_array_from_process_local_data` assembles the
  global array (single-process here, but the code path is the multi-host
  one).
* **Background prefetch** — a thread fills a small queue so host data prep
  overlaps device compute.
* Sources: synthetic LM stream (zipfian tokens w/ structure), memory-mapped
  token files (`file_stream`), plus frontend-stub embedding streams for the
  VLM/audio architectures.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import numpy as np

Batch = dict


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    kind: str = "synthetic"     # synthetic | file
    path: str | None = None
    embeds_dim: int = 0         # >0: attach stub frontend embeddings
    n_embeds: int = 0
    enc_len: int = 0            # >0: encoder-decoder (enc_embeds)


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def _synth_tokens(rng, n, seq, vocab):
    # zipfian marginals + local repetition structure (so loss can move)
    base = rng.zipf(1.3, size=(n, seq)).astype(np.int64) % vocab
    rep = rng.integers(0, 2, (n, seq)) == 0
    shifted = np.roll(base, 1, axis=1)
    return np.where(rep, shifted, base).astype(np.int32)


def synthetic_stream(cfg: DataConfig, host: int = 0,
                     n_hosts: int = 1, start_step: int = 0) -> Iterator[Batch]:
    per_host = cfg.global_batch // n_hosts
    step = start_step
    while True:
        rng = _rng_for(cfg, step, host)
        toks = _synth_tokens(rng, per_host, cfg.seq_len + 1, cfg.vocab)
        batch: Batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.embeds_dim:
            batch["embeds"] = rng.standard_normal(
                (per_host, cfg.n_embeds, cfg.embeds_dim)).astype(np.float32)
        if cfg.enc_len:
            batch["enc_embeds"] = rng.standard_normal(
                (per_host, cfg.enc_len, cfg.embeds_dim or 64)
            ).astype(np.float32)
        yield batch
        step += 1


def file_stream(cfg: DataConfig, host: int = 0, n_hosts: int = 1,
                start_step: int = 0) -> Iterator[Batch]:
    """Memory-mapped int32 token file; deterministic strided addressing."""
    data = np.memmap(cfg.path, dtype=np.int32, mode="r")
    n_seq = (len(data) - 1) // cfg.seq_len
    per_host = cfg.global_batch // n_hosts
    step = start_step
    while True:
        rng = _rng_for(cfg, step, host)
        idx = rng.integers(0, n_seq, per_host)
        toks = np.stack([
            data[i * cfg.seq_len:(i + 1) * cfg.seq_len + 1] for i in idx])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        step += 1


class _Prefetcher:
    def __init__(self, it: Iterator[Batch], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = False

        def fill():
            for item in it:
                if self._stop:
                    return
                self.q.put(item)

        self.t = threading.Thread(target=fill, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop = True


def make_train_iterator(cfg: DataConfig, *, sharding=None, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[Batch]:
    """Host batches → (optionally) globally-sharded jax.Arrays."""
    src = (file_stream if cfg.kind == "file" else synthetic_stream)(
        cfg, host=jax.process_index(), n_hosts=jax.process_count(),
        start_step=start_step)
    it = _Prefetcher(src, prefetch)

    def to_device(batch: Batch) -> Batch:
        if sharding is None:
            return batch
        out = {}
        for k, v in batch.items():
            out[k] = jax.make_array_from_process_local_data(
                sharding[k] if isinstance(sharding, dict) else sharding, v)
        return out

    return (to_device(b) for b in it)
