"""Data pipeline: sharded synthetic + file-backed token streams."""

from repro.data.pipeline import (DataConfig, synthetic_stream, file_stream,
                                 make_train_iterator, Batch)

__all__ = ["DataConfig", "synthetic_stream", "file_stream",
           "make_train_iterator", "Batch"]
