import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Datalog° on the production mesh: the paper's technique as a
first-class distributed workload (beyond-assignment cells).

Lowers the connected-components fixpoint (paper Fig. 1) — original
(boolean TC matrix iteration, O(n²) state) vs FGH-optimized (tropical
label-propagation vector, O(n) state) — under pjit on the 16×16 /
2×16×16 meshes, and reports the same roofline terms as the LM dry-run.
The FGH rewrite's effect shows up directly in the distributed cost
model: per-iteration HBM bytes and collective volume drop by ~n.

  PYTHONPATH=src python -m repro.launch.datalog_dryrun --n 65536 \
      --variant optimized --mesh single
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch import hlo_cost                   # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def cc_original_step(n: int):
    """One semi-naive-free ICO application: TC ← (E ∘ TC) ∨ I, then the
    min-label aggregate — the Fig. 1(a) loop body on dense 𝔹 relations."""

    def step(e, tc):
        prod = jnp.dot(e.astype(jnp.float32), tc.astype(jnp.float32),
                       preferred_element_type=jnp.float32) > 0.5
        tc2 = prod | jnp.eye(n, dtype=bool)
        labels = jnp.min(jnp.where(tc2, jnp.arange(n, dtype=jnp.float32)[None, :],
                                   jnp.inf), axis=1)
        return tc2, labels

    return step


def cc_optimized_step(n: int):
    """Fig. 1(b): CC[x] ← min(x, min_y CC[y] | E(x,y]) — O(n) state."""

    def step(e, cc):
        neigh = jnp.min(jnp.where(e, cc[None, :], jnp.inf), axis=1)
        return jnp.minimum(jnp.arange(n, dtype=jnp.float32), neigh)

    return step


def run(n: int, variant: str, multi_pod: bool, iters: int = 8) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    e_sharding = NamedSharding(mesh, P(data_axes, "model"))
    t0 = time.time()
    if variant == "original":
        step = cc_original_step(n)

        def loop(e, tc):
            def body(c):
                tc, _, i = c
                tc2, labels = step(e, tc)
                return tc2, labels, i + 1

            def cond(c):
                return c[2] < iters

            tc, labels, _ = jax.lax.while_loop(
                cond, body, (tc, jnp.zeros((n,), jnp.float32),
                             jnp.zeros((), jnp.int32)))
            return labels

        args = (jax.ShapeDtypeStruct((n, n), jnp.bool_),
                jax.ShapeDtypeStruct((n, n), jnp.bool_))
        in_sh = (e_sharding, e_sharding)
    else:
        step = cc_optimized_step(n)

        def loop(e, cc):
            def body(c):
                cc, i = c
                return step(e, cc), i + 1

            def cond(c):
                return c[1] < iters

            cc, _ = jax.lax.while_loop(cond, body,
                                       (cc, jnp.zeros((), jnp.int32)))
            return cc

        args = (jax.ShapeDtypeStruct((n, n), jnp.bool_),
                jax.ShapeDtypeStruct((n,), jnp.float32))
        in_sh = (e_sharding, NamedSharding(mesh, P(data_axes + ("model",))))

    compiled = jax.jit(loop, in_shardings=in_sh).lower(*args).compile()
    walked = hlo_cost.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    row = {
        "workload": f"datalog-cc-{variant}", "n": n,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "iters_lowered": iters,
        "flops": walked.flops, "bytes_accessed": walked.bytes,
        "collective_bytes": walked.collective_bytes,
        "per_collective": walked.per_collective,
        "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
        "compile_s": round(time.time() - t0, 1),
    }
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--variant", default="optimized",
                    choices=["original", "optimized"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--iters", type=int, default=8)
    args = ap.parse_args()
    row = run(args.n, args.variant, args.mesh == "multi", args.iters)
    print(json.dumps(row), flush=True)


if __name__ == "__main__":
    main()
