import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod AOT dry-run (assignment deliverable (e)).

For every (architecture × workload shape × mesh) cell:
  lower jit(step) with production shardings → compile → record
  memory_analysis / cost_analysis / per-collective byte volumes.

The lower→compile→HLO-walk recipe is shared with the cost-based
execution planner (`repro.core.planner`, DESIGN.md §4) via
`launch.hlo_cost.staged_cost`.

The XLA_FLAGS line above must precede EVERY import (jax pins the device
count at first init) — hence this module's unusual layout.  Do not set the
flag globally: smoke tests and benchmarks should see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                              # noqa: E402
from repro.distributed import sharding as sh           # noqa: E402
from repro.launch import hlo_cost                      # noqa: E402
from repro.launch import rules as rules_mod            # noqa: E402
from repro.launch import steps as steps_mod            # noqa: E402
from repro.launch import workloads as wl_mod           # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.models import transformer as T              # noqa: E402
from repro.optimizer import OptConfig                  # noqa: E402

def abstract_params(cfg, dtype=jnp.bfloat16):
    shapes, specs = T.shape_init(cfg, dtype)
    return shapes, specs


def build_cell(arch: str, shape: str, multi_pod: bool, *,
               opt_kind: str = "adamw", remat: str = "full",
               accum_steps: int = 1, attn_impl: str = "chunked",
               scan_impl: str = "assoc", embed_spec: str = "vocab",
               replicate_small: int = 0, moe_buf: str = "expert",
               donate: bool = False):
    """Returns (fn, abstract_args, in_shardings, mesh, rules)."""
    from repro.kernels import ops as kops
    from repro.models import attention as attn_mod
    from repro.models import moe as moe_mod
    attn_mod.set_attention_impl(attn_impl)
    kops.set_scan_impl(scan_impl)
    moe_mod.set_buf_shard(moe_buf)

    cfg = configs.get(arch)
    wl = wl_mod.WORKLOADS[shape]
    reason = wl_mod.skip_reason(cfg, wl)
    if reason:
        return None, reason
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_mod.make_rules(mesh, wl.kind)
    if embed_spec == "embedcol":
        rules["vocab"] = ["data"]     # shard tables on d, gather stays local
    elif embed_spec == "replicated":
        rules["vocab"] = None
    p_shapes, p_specs = abstract_params(cfg)
    if replicate_small:
        # replicate parameters below the threshold: avoids per-step
        # all-gathers whose latency outweighs the memory saved
        p_specs = jax.tree.map(
            lambda spec, shp: ((None,) * len(spec)
                               if _nbytes(shp) < replicate_small else spec),
            p_specs, p_shapes,
            is_leaf=lambda s: isinstance(s, tuple) and
            all(isinstance(x, (str, type(None))) for x in s))

    if wl.kind == "train":
        step, opt_init = steps_mod.make_train_step(
            cfg, OptConfig(kind=opt_kind), remat=remat,
            accum_steps=accum_steps)
        opt_shapes = jax.eval_shape(opt_init, p_shapes)
        opt_specs = _opt_specs(opt_shapes, p_specs)
        batch = wl_mod.batch_specs(cfg, wl)
        batch_specs_tree = {k: rules_mod.batch_logical(k) for k in batch}
        args = (p_shapes, opt_shapes, batch)
        logical = (p_specs, opt_specs, batch_specs_tree)
    elif wl.kind == "prefill":
        step = steps_mod.make_prefill_step(cfg)
        batch = wl_mod.prefill_specs(cfg, wl)
        blog = {k: (rules_mod.cache_spec_tree(batch[k]) if k == "cache"
                    else rules_mod.batch_logical(k)) for k in batch}
        args = (p_shapes, batch)
        logical = (p_specs, blog)
    else:  # decode
        step = steps_mod.make_serve_step(cfg)
        batch = wl_mod.decode_specs(cfg, wl)
        blog = {k: (rules_mod.cache_spec_tree(batch[k]) if k == "cache"
                    else rules_mod.batch_logical(k)) for k in batch}
        args = (p_shapes, batch)
        logical = (p_specs, blog)

    in_shardings = jax.tree.map(
        lambda spec, shape_struct: sh.spec_for(tuple(spec),
                                               shape_struct.shape, mesh,
                                               rules),
        logical, args, is_leaf=lambda s: isinstance(s, tuple) and
        all(isinstance(x, (str, type(None))) for x in s))
    from jax.sharding import NamedSharding
    in_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s)
        if isinstance(s, jax.sharding.PartitionSpec) else s, in_shardings,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    return (step, args, in_shardings, mesh, rules, cfg, wl,
            (1,) if (donate and wl.kind != "train") else ()), None


def _nbytes(shp) -> int:
    n = 1
    for d in shp.shape:
        n *= d
    return n * shp.dtype.itemsize


def _opt_specs(opt_shapes, p_specs):
    """Optimizer state inherits the parameter sharding (ZeRO-style)."""
    def spec_like(sub):
        return jax.tree.map(lambda leaf: None, sub)

    out = {}
    for k, v in opt_shapes.items():
        if k in ("m", "v"):
            out[k] = p_specs
        elif k == "f":  # adafactor: factored dims — replicate (small)
            out[k] = jax.tree.map(lambda leaf: (None,) * leaf.ndim, v)
        else:
            out[k] = (None,) * getattr(v, "ndim", 0) if hasattr(v, "ndim") \
                else v
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, **kw) -> dict:
    t0 = time.time()
    row = {"arch": arch, "shape": shape, "mesh": mesh_kind, **kw}
    built, reason = build_cell(arch, shape, mesh_kind == "multi", **kw)
    if built is None:
        row.update(status="skipped", reason=reason)
        return row
    step, args, in_sh, mesh, rules, cfg, wl, donate_nums = built
    try:
        with sh.use_rules(mesh, rules):
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=donate_nums)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax returns one properties dict, or (older) a per-program list
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo_text = compiled.as_text()
        walked = hlo_cost.analyze(hlo_text)
        row.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=walked.flops,                      # trip-count-aware
            bytes_accessed=walked.bytes,
            xla_flops=cost.get("flops", -1.0),       # body-counted-once ref
            collectives={
                "bytes": walked.per_collective,
                "counts": walked.collective_counts,
                "total_bytes": walked.collective_bytes,
            },
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
                "output_bytes": getattr(mem, "output_size_in_bytes", -1),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", -1),
            },
            params_b=cfg.param_count(),
            active_params_b=cfg.active_param_count(),
            tokens=wl.global_batch * wl.seq_len,
        )
    except Exception as e:  # noqa: BLE001 — report the failure in the row
        row.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    row["wall_s"] = round(time.time() - t0, 1)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--attn", default="chunked",
                    choices=["chunked", "online", "bf16"])
    ap.add_argument("--moe-buf", default="expert",
                    choices=["expert", "expert_data"])
    ap.add_argument("--scan", default="assoc", choices=["assoc", "chunked"])
    ap.add_argument("--embed-spec", default="vocab",
                    choices=["vocab", "embedcol", "replicated"])
    ap.add_argument("--replicate-small", type=int, default=0)
    ap.add_argument("--donate", action="store_true",
                    help="donate the cache buffer (decode/prefill): the "
                         "KV update aliases in place instead of "
                         "double-buffering")
    args = ap.parse_args()

    if args.all:
        # one subprocess per cell: isolates compile-cache/host-memory
        # growth across 80 large AOT compiles
        import subprocess
        import sys
        results = []
        if args.out and os.path.exists(args.out):
            with open(args.out) as f:
                results = json.load(f)
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
        for arch in configs.list_archs():
            for shape in wl_mod.WORKLOADS:
                for mesh in args.meshes.split(","):
                    if (arch, shape, mesh) in done:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh,
                           "--opt", args.opt, "--remat", args.remat,
                           "--accum", str(args.accum)]
                    try:
                        proc = subprocess.run(
                            cmd, capture_output=True, text=True,
                            timeout=2400,
                            env={**os.environ, "PYTHONPATH": "src"})
                        row = None
                        for line in proc.stdout.splitlines():
                            if line.startswith("{"):
                                row = json.loads(line)
                        if row is None:
                            row = {"arch": arch, "shape": shape,
                                   "mesh": mesh, "status": "crashed",
                                   "error": (proc.stderr or "")[-1500:]}
                    except subprocess.TimeoutExpired:
                        row = {"arch": arch, "shape": shape, "mesh": mesh,
                               "status": "timeout"}
                    results.append(row)
                    print(json.dumps(row), flush=True)
                    if args.out:
                        with open(args.out, "w") as f:
                            json.dump(results, f, indent=1)
        return

    row = run_cell(args.arch, args.shape, args.mesh, opt_kind=args.opt,
                   remat=args.remat, accum_steps=args.accum,
                   attn_impl=args.attn, scan_impl=args.scan,
                   embed_spec=args.embed_spec,
                   replicate_small=args.replicate_small,
                   moe_buf=args.moe_buf, donate=args.donate)
    print(json.dumps({k: v for k, v in row.items() if k != "trace"}),
          flush=True)
    if row.get("status") == "error":
        print(row.get("trace", ""), flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump([row], f, indent=1)


if __name__ == "__main__":
    main()
