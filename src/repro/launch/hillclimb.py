"""§Perf hillclimb runner: per-cell variant sweeps with before/after rows.

Each variant is one hypothesis from EXPERIMENTS.md §Perf; the runner
executes the dry-run cell via subprocess (fresh XLA state per compile) and
collects the roofline terms for comparison.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell llama3 --out results/
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

#: the three chosen cells (EXPERIMENTS.md §Perf) and their variant ladders
CELLS = {
    # worst roofline fraction / memory-dominated flagship
    "llama3": {
        "arch": "llama3-405b", "shape": "train_4k", "mesh": "single",
        "variants": [
            ("baseline", []),
            ("online-attn", ["--attn", "online"]),
            ("online+accum8", ["--attn", "online", "--accum", "8"]),
            ("online+accum8+adafactor",
             ["--attn", "online", "--accum", "8", "--opt", "adafactor"]),
        ],
    },
    # most collective-bound
    "deepseek": {
        "arch": "deepseek-moe-16b", "shape": "train_4k", "mesh": "single",
        "variants": [
            ("baseline", []),
            ("online-attn", ["--attn", "online"]),
            ("embedcol", ["--attn", "online", "--embed-spec", "embedcol"]),
            ("replicate-small-8M",
             ["--attn", "online", "--embed-spec", "embedcol",
              "--replicate-small", str(8 << 20)]),
        ],
    },
    # most representative of the paper's technique (FGH-rewritten scan)
    "zamba2": {
        "arch": "zamba2-2.7b", "shape": "train_4k", "mesh": "single",
        "variants": [
            ("baseline", []),
            ("chunked-scan", ["--scan", "chunked"]),
            ("chunked+online",
             ["--scan", "chunked", "--attn", "online"]),
            ("chunked+online+accum4",
             ["--scan", "chunked", "--attn", "online", "--accum", "4"]),
        ],
    },
}


def run_cell(arch, shape, mesh, extra):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh] + list(extra)
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=2400,
                          env={**os.environ, "PYTHONPATH": "src"})
    rows = [json.loads(l) for l in proc.stdout.splitlines()
            if l.startswith("{")]
    return rows[-1] if rows else {"status": "crashed",
                                  "error": proc.stderr[-1000:]}


def terms(row):
    if row.get("status") != "ok":
        return {"status": row.get("status"), "error": row.get("error")}
    return {
        "compute_s": row["flops"] / 197e12,
        "memory_s": row["bytes_accessed"] / 819e9,
        "collective_s": row["collectives"]["total_bytes"] / 50e9,
        "temp_gib": row["memory"]["temp_bytes"] / 2 ** 30,
        "arg_gib": row["memory"]["argument_bytes"] / 2 ** 30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    spec = CELLS[args.cell]
    results = []
    for name, extra in spec["variants"]:
        row = run_cell(spec["arch"], spec["shape"], spec["mesh"], extra)
        entry = {"variant": name, "flags": extra, **terms(row), "raw": row}
        results.append(entry)
        printable = {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in entry.items() if k != "raw"}
        print(json.dumps(printable), flush=True)
        with open(os.path.join(args.out,
                               f"hillclimb_{args.cell}.json"), "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
