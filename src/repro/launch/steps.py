"""train_step / prefill_step / serve_step builders + their shardings.

These are the functions the dry-run lowers and the real launchers run.
Gradient accumulation (microbatching) runs as a lax.scan with f32 grad
accumulators so the reduce stays inside the step (collective overlap).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optimizer import OptConfig, make_optimizer


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *,
                    remat: str = "full", accum_steps: int = 1):
    opt_init, opt_update = make_optimizer(opt_cfg)

    def loss(params, batch):
        return T.loss_fn(params, cfg, batch, remat=remat)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (l, (ce, aux)), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, _ = carry
                (l, (ce, aux)), g = jax.value_and_grad(
                    loss, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return (acc, l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, l), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_params, new_opt, gnorm = opt_update(params, grads, opt_state)
        metrics = {"loss": l, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step, opt_init


def make_prefill_step(cfg: ModelConfig, remat: str = "none"):
    def prefill(params, batch):
        logits, _, cache = T.forward(
            params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
            enc_embeds=batch.get("enc_embeds"), cache=batch["cache"],
            remat=remat)
        return logits[:, -1:], cache
    return prefill


def make_serve_step(cfg: ModelConfig):
    def serve(params, batch):
        logits, cache = T.decode_step(params, cfg, batch["tokens"],
                                      batch["cache"])
        # greedy next token (sampling lives in the serving loop)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve
