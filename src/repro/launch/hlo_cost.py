"""Trip-count-aware cost analysis over optimized HLO text.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` exposes) counts a
``while`` body **once**, so scanned layer stacks under-report FLOPs,
bytes, and in-loop collective volume by a factor of L.  This walker
re-derives the three roofline terms from ``compiled.as_text()``:

* dot FLOPs = 2 · |result| · |contracted dims| (from inline operand shapes)
  — elementwise/transcendental ops add |result| each;
* HBM bytes = operands + results of top-level ops, fusions counted at the
  fusion boundary (one kernel), parameters/constants skipped;
* collective bytes = result bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute ops;
* ``while`` bodies are multiplied by their trip count, recovered from the
  loop condition's comparison constant (scan/fori lowering).

Shapes are parsed from the HLO text itself, so the analysis is exact for
the modules we generate (dots + elementwise + collectives + control flow).

Consumers: the AOT dry-runs (:mod:`repro.launch.dryrun`,
:mod:`repro.launch.datalog_dryrun`) walk their production step functions,
and the cost-based execution planner (:mod:`repro.core.planner`,
DESIGN.md §4) prices candidate fixpoint steps through
:func:`staged_cost`.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)\)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*?\))?\s*->.*{")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "not", "xor", "convert", "floor",
    "ceil", "sign", "cosine", "sine", "remainder", "clamp", "atan2",
    "expm1", "log1p", "logistic", "erf",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = \
                self.collective_counts.get(k, 0) + v * mult


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    args: str
    line: str


def parse_computations(text: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur: list[_Op] | None = None
    for line in text.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = comps.setdefault(m.group(1), [])
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            cur.append(_Op(om.group(1), om.group(2), om.group(3),
                           om.group(4), stripped))
    return comps


def _operand_names(args: str) -> list[str]:
    names = []
    depth = 0
    cur = ""
    # shapes embed commas inside [...] and layouts inside {...}: only a
    # comma at zero bracket depth separates operands
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            names.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        names.append(cur.strip())
    out = []
    for n in names:
        n = n.strip()
        if n.startswith("%"):
            n = n[1:]
        # strip any inline type prefix ("f32[2] %x")
        if " " in n:
            n = n.split()[-1].lstrip("%")
        out.append(n)
    return out


def _dot_flops(op: _Op, table: dict[str, str]) -> float:
    result = _shape_elems(op.result_type)
    ops = _operand_names(op.args)
    if not ops:
        return 0.0
    lhs_type = table.get(ops[0], "")
    lhs_m = _SHAPE_RE.search(lhs_type)
    if not lhs_m:
        return 0.0
    lhs_dims = [int(d) for d in lhs_m.group(2).split(",") if d]
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracted = 1
    if cm:
        for i in cm.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                contracted *= lhs_dims[int(i)]
    return 2.0 * result * contracted


def _trip_count(cond_ops: list[_Op]) -> int:
    # scan/fori lowering: condition compares the induction variable with a
    # constant; take the largest integer constant in the condition body.
    best = 1
    for op in cond_ops:
        for c in _CONST_RE.findall(op.line):
            best = max(best, int(c))
    return best


def staged_cost(fn, *args) -> Cost:
    """Lower + compile ``fn`` on example (or ShapeDtypeStruct) args and
    walk the optimized HLO — the one lower→compile→analyze recipe shared
    by the dry-run drivers and the planner's measured cost model."""
    import jax
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze(compiled.as_text())


def analyze(text: str) -> Cost:
    comps = parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda k: len(comps[k]))

    memo: dict[str, Cost] = {}
    tables: dict[str, dict[str, str]] = {
        name: {op.name: op.result_type for op in ops}
        for name, ops in comps.items()}

    def operand_bytes(op: _Op, table: dict[str, str]) -> int:
        total = 0
        for name in _operand_names(op.args):
            total += _shape_bytes(table.get(name, ""))
        return total

    def comp_cost(name: str, top_level: bool) -> Cost:
        key = f"{name}|{top_level}"
        if key in memo:
            return memo[key]
        total = Cost()
        table = tables.get(name, {})
        for op in comps.get(name, []):
            oc = op.opcode
            if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy", "after-all", "iota"):
                continue
            if oc == "while":
                cond = _COND_RE.search(op.line)
                body = _CALLS_RE.search(op.line)
                known = re.search(
                    r'known_trip_count[":{\s]+n[":\s]+"?(\d+)', op.line)
                if known:
                    trips = int(known.group(1))
                else:
                    trips = _trip_count(comps.get(cond.group(1), [])) \
                        if cond else 1
                if body:
                    total.add(comp_cost(body.group(1), top_level), trips)
                continue
            if oc == "fusion":
                called = _CALLS_RE.search(op.line)
                if called:
                    inner = comp_cost(called.group(1), False)
                    c = Cost(flops=inner.flops,
                             collective_bytes=inner.collective_bytes,
                             per_collective=dict(inner.per_collective),
                             collective_counts=dict(inner.collective_counts))
                    # fusion = one kernel: HBM bytes at the boundary
                    c.bytes = _shape_bytes(op.result_type) + \
                        operand_bytes(op, table)
                    total.add(c)
                continue
            if oc in ("call", "conditional", "map", "reduce", "sort",
                      "scatter", "reduce-window", "select-and-scatter"):
                inner = Cost()
                for called in _CALLS_RE.findall(op.line):
                    inner.add(comp_cost(called, False))
                inner.flops += _shape_elems(op.result_type)
                if top_level:
                    inner.bytes += _shape_bytes(op.result_type) + \
                        operand_bytes(op, table)
                total.add(inner)
                continue
            c = Cost()
            if oc == "dot":
                c.flops = _dot_flops(op, table)
            elif oc == "convolution":
                c.flops = 2.0 * _shape_elems(op.result_type)
            elif oc in _ELEMENTWISE:
                c.flops = float(_shape_elems(op.result_type))
            if oc in _COLLECTIVES:
                b = _shape_bytes(op.result_type)
                c.collective_bytes = b
                c.per_collective = {oc: float(b)}
                c.collective_counts = {oc: 1.0}
            if top_level:
                # fusion-internal ops read VMEM-resident temporaries; HBM
                # traffic is counted once at each fusion boundary
                c.bytes += _shape_bytes(op.result_type) + \
                    operand_bytes(op, table)
            total.add(c)
        memo[key] = total
        return total

    return comp_cost(entry, True)
