"""Packed-FIFO Datalog° serving — compatibility shim over ``repro.serve``.

This module is the original batched serve loop (DESIGN.md §3): a shared
FIFO of queries and updates, a packer that groups up to ``max_batch``
same-family queries, and one compiled batched GSN fixpoint per
(signature, B-bucket) answering each pack to *global* convergence.  The
production serving surface is now the continuous-batching scheduler
(:class:`repro.serve.ContinuousServer`, DESIGN.md §7), which steps
persistent slot pools and evicts rows per-request instead of per-batch;
``DatalogServer`` remains as the stable packed-FIFO API — and as the
baseline the continuous scheduler is benchmarked against
(``benchmarks/serve_batch.py``).

All family machinery is shared with the new subsystem
(:mod:`repro.serve.family`): registration/planning, per-request init
evaluation, and the streaming-update path (monotone ⊕-merge appends
with batched delta-restart warm repair; non-monotone deletes) are one
implementation under both schedulers.  Two behaviors this shim gained
from the subsystem:

* the warm-answer store and the compiled-runner cache are now
  capacity-bounded LRUs (``warm_answers=`` / ``compiled_cache=``), with
  evictions surfaced in ``stats["cache_evictions"]``;
* a batch with exactly one live request routes down the planner's
  per-source latency path (:func:`repro.serve.family.latency_serve`)
  instead of a degenerate (1, n) batched fixpoint — the B=1 row of
  BENCH_serve.json is no longer slower than the naive loop.

FGH families: :func:`fgh_make_program` derives Π₂ from a Π₁ benchmark
*twice* at distinct placeholder sources and diffs the results to locate
the source-constant sites, so one synthesis run serves every source; if
the diff is ambiguous it falls back to re-optimizing per source (cached).
"""

from __future__ import annotations

import argparse
import collections
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, ir, planner, verify
from repro.core import semiring as sr_mod
from repro.core.program import Program
from repro.distributed import sharding as sh
from repro.launch import rules as rules_mod
from repro.serve import family as fam_mod
from repro.serve.cache import LRUCache
from repro.serve.family import (Family as _Family, QueryRequest,
                                UpdateRequest, bucket as _bucket)

__all__ = ["DatalogServer", "QueryRequest", "UpdateRequest",
           "fgh_make_program", "_bucket"]


class DatalogServer:
    """Request-queue serve loop over batched GSN fixpoints."""

    def __init__(self, *, max_batch: int = 64, mesh=None,
                 max_iters: int = 10_000, warm_answers: int = 256,
                 compiled_cache: int = 32):
        self.max_batch = max_batch
        self.max_iters = max_iters
        self.mesh = mesh
        self.warm_answers = warm_answers
        # a ("graph",) mesh partitions the vertex axis (DESIGN.md §6);
        # any other mesh shards the query-batch axis over "data"
        self.graph_mesh = (mesh if mesh is not None
                           and "graph" in mesh.axis_names else None)
        self.graph_d = (1 if self.graph_mesh is None else
                        int(self.graph_mesh.shape["graph"]))
        self.rules = (rules_mod.make_rules(mesh, "datalog")
                      if mesh is not None and self.graph_mesh is None
                      else None)
        self._families: dict[str, _Family] = {}
        self._queue: collections.deque = collections.deque()
        self._compiled = LRUCache(compiled_cache)
        self.stats = {"served": 0, "failed": 0, "batches": 0,
                      "padded_rows": 0, "cache_hits": 0,
                      "cache_misses": 0, "cache_evictions": 0,
                      "updates": 0, "warm_hits": 0,
                      "answers_repaired": 0, "answers_dropped": 0,
                      "latency_routed": 0}

    # -- registration -------------------------------------------------------

    def register(self, name: str, make_program: Callable[[int], Program],
                 db: engine.Database, *, edges=None,
                 template_source: int = 0) -> _Family:
        """Register a family of source-parameterized Π₂ programs
        (:func:`repro.serve.family.build_family`)."""
        fam = fam_mod.build_family(
            name, make_program, db, edges=edges,
            template_source=template_source, graph_mesh=self.graph_mesh,
            max_iters=self.max_iters, warm_answers=self.warm_answers)
        self._families[name] = fam
        return fam

    # -- request queue ------------------------------------------------------

    def submit(self, family: str, source: int) -> QueryRequest:
        if family not in self._families:
            raise KeyError(f"unknown family {family!r}; "
                           f"registered: {sorted(self._families)}")
        req = QueryRequest(family, int(source),
                           submitted_s=time.perf_counter())
        self._queue.append(req)
        return req

    def submit_update(self, family: str, coords, values=None, *,
                      op: str = "merge") -> UpdateRequest:
        """Enqueue a batch of edge mutations behind every already-queued
        request (FIFO: queries submitted after this update are never
        answered from the pre-update graph)."""
        if family not in self._families:
            raise KeyError(f"unknown family {family!r}; "
                           f"registered: {sorted(self._families)}")
        if op not in ("merge", "delete", "increase"):
            raise ValueError(f"unknown update op {op!r}")
        if op == "increase" and values is None:
            raise ValueError("op='increase' needs the new (larger) values")
        req = UpdateRequest(family,
                            np.atleast_2d(np.asarray(coords, np.int64)),
                            None if values is None
                            else np.asarray(values).reshape(-1), op,
                            submitted_s=time.perf_counter())
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> list:
        """Process the queue head: a run of updates is applied (and the
        family's warm answers repaired) in one pass; a query is packed
        with up to ``max_batch - 1`` later same-family queries — but
        never past an intervening same-family update, which would let a
        pre-update answer overtake an acknowledged mutation."""
        if not self._queue:
            return []
        lead = self._queue.popleft()
        if isinstance(lead, UpdateRequest):
            ups = [lead]
            while (self._queue
                   and isinstance(self._queue[0], UpdateRequest)
                   and self._queue[0].family == lead.family
                   and self._queue[0].op == lead.op):
                ups.append(self._queue.popleft())
            fam_mod.apply_updates(self._families[lead.family], ups,
                                  self.stats, graph_mesh=self.graph_mesh)
            return ups
        batch = [lead]
        rest: collections.deque = collections.deque()
        while self._queue and len(batch) < self.max_batch:
            req = self._queue.popleft()
            if isinstance(req, UpdateRequest) and req.family == lead.family:
                # fence: no later same-family query may join this batch,
                # so nothing further can be packed — stop scanning
                rest.append(req)
                break
            if isinstance(req, QueryRequest) and req.family == lead.family:
                batch.append(req)
            else:
                rest.append(req)
        self._queue = rest + self._queue
        return self._serve_batch(self._families[lead.family], batch)

    def _serve_batch(self, fam: _Family, batch: list) -> list:
        live, inits = [], []
        for r in batch:
            warm = fam.answers.get(r.source)
            if warm is not None:
                r.result = warm
                r.iters = 0
                r.done_s = time.perf_counter()
                self.stats["warm_hits"] += 1
                self.stats["served"] += 1
                continue
            try:
                inits.append(fam_mod.family_init(fam, r.source))
                live.append(r)
            except Exception as e:  # bad source must not strand the batch
                r.error = f"{type(e).__name__}: {e}"
                r.done_s = time.perf_counter()
                self.stats["failed"] += 1
        if not live:
            self.stats["batches"] += 1
            return batch
        if len(live) == 1 and self.mesh is None:
            # single-slot requests skip the (1, n) batched fixpoint for
            # the planner's per-source latency path (B=1 regression fix)
            out = fam_mod.latency_serve(fam, inits[0])
            if out is not None:
                req = live[0]
                req.result, req.iters = out
                req.done_s = time.perf_counter()
                self._remember(fam, req.source, req.result)
                self.stats["latency_routed"] += 1
                self.stats["served"] += 1
                self.stats["batches"] += 1
                return batch
        bb = _bucket(len(live), self.max_batch)
        sr = sr_mod.get(fam.plan.strata[0].vf.semiring, lib="np")
        packed = np.full((bb, fam.n), sr.zero, sr.dtype)
        for i, v in enumerate(inits):
            packed[i] = np.asarray(v)
        self.stats["padded_rows"] += bb - len(live)

        run = self._compiled_fixpoint(fam, bb)
        operand = fam.sharded if fam.sharded is not None else fam.edges
        if self.mesh is not None and self.graph_mesh is None:
            with sh.use_rules(self.mesh, self.rules):
                init_dev = sh.put(jnp.asarray(packed),
                                  ("query_batch", "vertex"))
                y, iters = run(operand, init_dev)
                y = np.asarray(jax.device_get(y))
        else:
            # graph-sharded families lay out their own operands: the
            # shard_map in/out specs partition the vertex axis and keep
            # the query batch replicated
            y, iters = run(operand, jnp.asarray(packed))
            y = np.asarray(y)
        iters = np.asarray(iters)
        now = time.perf_counter()
        for i, req in enumerate(live):
            req.result = y[i]
            req.iters = int(iters[i])
            req.done_s = now
            self._remember(fam, req.source, y[i])
        self.stats["served"] += len(live)
        self.stats["batches"] += 1
        return batch

    def run_until_idle(self) -> int:
        done = 0
        while self._queue:
            done += len(self.step())
        return done

    # -- internals ----------------------------------------------------------

    def _remember(self, fam: _Family, source: int, y: np.ndarray) -> None:
        fam.answers.put(source, y)

    def _compiled_fixpoint(self, fam: _Family, bb: int) -> Callable:
        key = (fam.plan.signature, bb, self.graph_d)
        run = self._compiled.get(key)
        if run is not None:
            self.stats["cache_hits"] += 1
            return run
        self.stats["cache_misses"] += 1
        run = planner.compile_batched(fam.plan, max_iters=fam.max_iters)
        self._compiled.put(key, run)
        self.stats["cache_evictions"] = self._compiled.evictions
        return run


# --------------------------------------------------------------------------
# FGH routing: synthesize Π₂ once, serve every source
# --------------------------------------------------------------------------


def fgh_make_program(make_bench, edbs: list[str], *,
                     placeholders: tuple[int, int] = (0, 1),
                     rng=None) -> Callable[[int], Program]:
    """Derive Π₂ from a Π₁ benchmark family with the FGH optimizer and
    return a ``make_program(source)`` suitable for
    :meth:`DatalogServer.register`.

    ``make_bench(source)`` builds the :class:`~repro.datalog.programs.Bench`
    for a source vertex.  The optimizer runs (and fully verifies) at the
    two placeholder sources; diffing the two derived programs pinpoints
    exactly which constants are the query source, so serving source ``s``
    is a constant substitution, not a re-synthesis.  When the diff is
    structurally ambiguous (normalization reordered terms between the
    runs) the returned function falls back to re-optimizing per source,
    memoized.
    """
    from repro.core import fgh

    derived = {}
    for p in placeholders:
        b = make_bench(p)
        task = verify.task_from_program(b.original, edbs,
                                        constraint=b.constraint)
        rep = fgh.optimize(task, rng=rng or np.random.default_rng(0))
        if not rep.ok:
            raise RuntimeError(f"FGH synthesis failed for source {p}: "
                               f"{rep.stats}")
        if b.original.post is not None:
            rep.program.post = b.original.post
        derived[p] = rep.program
    p0, p1 = placeholders
    # serve only p0's derivation directly; p1 (like every other source)
    # goes through substitution so served programs share p0's variable
    # names — derived[p1] exists purely to locate the source constants
    cache: dict[int, Program] = {p0: derived[p0]}

    def make_program(source: int) -> Program:
        if source in cache:
            return cache[source]
        try:
            prog = _subst_sources(derived[p0], derived[p1],
                                  placeholders, source)
        except ValueError:
            b = make_bench(source)
            task = verify.task_from_program(b.original, edbs,
                                            constraint=b.constraint)
            rep = fgh.optimize(task, rng=np.random.default_rng(0))
            if not rep.ok:
                raise RuntimeError(
                    f"FGH synthesis failed for source {source}")
            if b.original.post is not None:
                rep.program.post = b.original.post
            prog = rep.program
        cache[source] = prog
        return prog

    return make_program


def _subst_sources(prog0: Program, prog1: Program,
                   placeholders: tuple[int, int], source: int) -> Program:
    """Rebuild ``prog0`` with every constant site where ``prog0`` and
    ``prog1`` disagree (and agree with the respective placeholders)
    replaced by ``source``.  Variable-name differences (fresh-counter
    drift between the two synthesis runs) are ignored; any structural
    mismatch raises ``ValueError``."""
    from repro.core.program import Rule, Stratum

    def walk_args(a0, a1):
        out = []
        for x0, x1 in zip(a0.args, a1.args):
            c0, c1 = isinstance(x0, ir.C), isinstance(x1, ir.C)
            if c0 != c1:
                raise ValueError("const/var mismatch")
            if c0 and x0.value != x1.value:
                if (x0.value, x1.value) != placeholders:
                    raise ValueError(
                        f"differing constants {x0}/{x1} are not the "
                        f"placeholder pair {placeholders}")
                out.append(ir.C(source))
            else:
                out.append(x0)
        return tuple(out)

    def walk_atom(a0, a1):
        if type(a0) is not type(a1):
            raise ValueError("atom type mismatch")
        if isinstance(a0, ir.RelAtom):
            if (a0.name, a0.cast, a0.neg) != (a1.name, a1.cast, a1.neg):
                raise ValueError("rel atom mismatch")
            return ir.RelAtom(a0.name, walk_args(a0, a1), a0.cast, a0.neg)
        if isinstance(a0, ir.PredAtom):
            if a0.pred != a1.pred:
                raise ValueError("pred mismatch")
            return ir.PredAtom(a0.pred, walk_args(a0, a1))
        if isinstance(a0, ir.ValFnAtom):
            if a0.fn != a1.fn:
                raise ValueError("valfn mismatch")
            return ir.ValFnAtom(a0.fn, walk_args(a0, a1))
        if isinstance(a0, ir.ConstAtom):
            if a0.value != a1.value:
                raise ValueError("semiring constants differ between "
                                 "placeholder derivations")
            return a0
        return a0  # ValAtom: var names may drift, keep prog0's

    def walk_ssp(e0, e1):
        if (len(e0.terms) != len(e1.terms)
                or len(e0.head) != len(e1.head)
                or e0.semiring != e1.semiring):
            raise ValueError("SSP shape mismatch")
        terms = []
        for t0, t1 in zip(e0.terms, e1.terms):
            if len(t0.atoms) != len(t1.atoms) \
                    or len(t0.bound) != len(t1.bound):
                raise ValueError("term shape mismatch")
            terms.append(ir.Term(
                tuple(walk_atom(a0, a1)
                      for a0, a1 in zip(t0.atoms, t1.atoms)), t0.bound))
        return ir.SSP(e0.head, tuple(terms), e0.semiring)

    strata = []
    for s0, s1 in zip(prog0.strata, prog1.strata):
        if tuple(s0.rules) != tuple(s1.rules):
            raise ValueError("stratum IDB mismatch")
        rules = {n: Rule(n, walk_ssp(s0.rules[n].body, s1.rules[n].body))
                 for n in s0.rules}
        init = None
        if s0.init is not None:
            if s1.init is None or set(s0.init) != set(s1.init):
                raise ValueError("stratum init mismatch")
            init = {n: walk_ssp(s0.init[n], s1.init[n]) for n in s0.init}
        strata.append(Stratum(rules, init=init))
    if len(prog0.strata) != len(prog1.strata) \
            or len(prog0.outputs) != len(prog1.outputs):
        raise ValueError("program shape mismatch")
    outputs = [Rule(r0.head, walk_ssp(r0.body, r1.body))
               for r0, r1 in zip(prog0.outputs, prog1.outputs)]
    return Program(prog0.name, prog0.schema, strata, outputs,
                   post=prog0.post, sort_hints=dict(prog0.sort_hints))


# --------------------------------------------------------------------------
# CLI demo
# --------------------------------------------------------------------------


def main():
    from repro.datalog import datasets, programs

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--fgh", action="store_true",
                    help="derive Π₂ with the FGH optimizer instead of "
                         "using the published rewrite")
    args = ap.parse_args()

    g = datasets.powerlaw(args.n, 4, seed=0)
    b0 = programs.bm(a=0)
    db = engine.Database(b0.original.schema, {"id": g.n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((g.n,), bool)})
    server = DatalogServer(max_batch=args.max_batch)
    if args.fgh:
        make_program = fgh_make_program(
            lambda a: programs.bm(a=a), ["E", "V"])
    else:
        make_program = lambda a: programs.bm(a=a).optimized
    server.register("reach", make_program, db)

    rng = np.random.default_rng(0)
    reqs = [server.submit("reach", int(s))
            for s in rng.integers(0, g.n, args.requests)]
    t0 = time.perf_counter()
    server.run_until_idle()
    dt = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in reqs)
    print(f"served {server.stats['served']} queries in {dt:.3f}s "
          f"({server.stats['served'] / dt:.1f} qps, "
          f"{server.stats['batches']} batches, "
          f"compile cache {server.stats['cache_hits']} hits / "
          f"{server.stats['cache_misses']} misses)")
    print(f"latency p50 {lat[len(lat) // 2] * 1e3:.1f} ms  "
          f"p99 {lat[int(len(lat) * 0.99)] * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
