"""Batched multi-source Datalog° query serving (DESIGN.md §3).

The production shape mirrors `launch/serve.py`'s LM batcher: a request
queue, a packer that groups up to ``max_batch`` pending (family, source)
queries of the same program family, and a compiled batched GSN fixpoint
that answers the whole pack in one device program.  The pieces:

* **Plan routing** — registered Π₂ programs (published rewrites or ones
  freshly synthesized by :mod:`repro.core.fgh`) are planned once by the
  cost-based planner (:func:`repro.core.planner.plan_program`,
  ``objective="throughput"``, DESIGN.md §4), which splits them into
  ``x = init ⊕ x ⊗ E`` and picks the batched runner; only the O(n)
  ``init`` is evaluated per request, while the linear operator E and the
  compiled fixpoint are shared by every source.
* **Compile cache** — jitted batched runners are keyed on
  ``(ExecutionPlan.signature, B-bucket)``; the plan signature already
  folds in the linear-operator hash, n, the semiring, and the chosen
  runner.  Batch sizes are bucketed to powers of two (padded with inert
  all-0̄ init rows), so a steady-state server compiles each family a
  handful of times total.
* **Batched runners** — built by :func:`repro.core.planner.
  compile_batched`: sparse families run the SpMM
  ``sparse_seminaive_fixpoint`` (one ``lax.while_loop`` for all B
  sources, per-row convergence); dense families the
  ``fixpoint.batched_seminaive_fixpoint`` semiring-matmul step.
* **Sharding** — with a ``("data",)`` mesh attached, the query-batch
  axis is laid out across the "data" axis (``launch.rules`` kind
  "datalog") and the fixpoint's internal constraints keep it there.
  With a ``("graph",)`` mesh (``launch.mesh.make_graph_mesh``,
  DESIGN.md §6) the *vertex* axis is partitioned instead: registration
  plans with ``mesh=`` so the planner can pick the row-partitioned
  ``sparse_sharded`` runner, the family's operator is kept as a
  :class:`~repro.distributed.datalog.ShardedRelation`, compiled runners
  are keyed ``(plan.signature, B-bucket, D)``, and ``submit_update``
  routes delta rows to their owning destination shards
  (:meth:`~repro.distributed.datalog.ShardedRelation.apply_delta`) so
  capacity — and the compiled trace — survives monotone updates.
* **Streaming updates** (DESIGN.md §5) — :meth:`DatalogServer.
  submit_update` enqueues edge mutations *in the same FIFO queue as
  queries*: a query packed into a batch never jumps ahead of an earlier
  same-family update, and once an update is acknowledged every later
  answer reflects it.  Monotone updates (⊕-merge insertions / tropical
  weight decreases) are applied as a COO append
  (:meth:`~repro.sparse.coo.SparseRelation.apply_delta` — capacity and
  therefore the staged fixpoint's trace usually survive, so the compile
  cache keeps hitting) and the family's warm answer cache is *repaired*,
  not dropped: one batched delta-restart pass
  (:func:`repro.incremental.delta_restart_fixpoint`) re-converges every
  cached solution from an O(nnz(Δ)) SpMM seed.  Non-monotone updates
  (deletions) rebuild the operator and invalidate the warm answers —
  with the plan, signature, and compiled runners all kept.

FGH families: :func:`fgh_make_program` derives Π₂ from a Π₁ benchmark
*twice* at distinct placeholder sources and diffs the results to locate
the source-constant sites, so one synthesis run serves every source; if
the diff is ambiguous it falls back to re-optimizing per source (cached).
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, ir, planner, vectorize, verify
from repro.core import semiring as sr_mod
from repro.core.program import Program
from repro.distributed import sharding as sh
from repro.launch import rules as rules_mod
from repro.sparse.coo import SparseRelation


@dataclasses.dataclass
class QueryRequest:
    """One (program family, source vertex) query; filled in by the server.

    A request that cannot be served (e.g. its source changed the
    family's linear operator) comes back with ``result=None`` and the
    failure message in ``error`` — it never takes its batch down.
    """

    family: str
    source: int
    result: np.ndarray | None = None
    iters: int | None = None
    error: str | None = None
    submitted_s: float = 0.0
    done_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submitted_s


@dataclasses.dataclass
class UpdateRequest:
    """One batch of edge mutations against a family's linear operator.

    ``op="merge"`` is the monotone ⊕-merge (edge insertion; tropical
    weight decrease); ``op="delete"`` removes keys and is non-monotone.
    Coordinates live in the space the family's operator was built from:
    the stored edge relation ``E(i, j)`` when one exists (the server
    re-orients them for the operator), else the ``edges=`` override
    given at registration.  Once ``applied`` is set the server
    guarantees no later-served answer predates the update.
    """

    family: str
    coords: np.ndarray
    values: np.ndarray | None = None
    op: str = "merge"
    applied: bool = False
    repaired: int = 0           # warm answers repaired in place
    error: str | None = None
    submitted_s: float = 0.0
    done_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submitted_s


#: per-family cap on memoized init vectors (n floats each)
_INIT_CACHE_MAX = 4096


@dataclasses.dataclass
class _Family:
    name: str
    make_program: Callable[[int], Program]
    db: engine.Database
    host_db: engine.Database    # numpy twin for eager per-request init eval
    plan: planner.ExecutionPlan
    edges: object               # SparseRelation (jnp) or dense (n, n) array
    hints: dict
    n: int
    max_iters: int
    #: graph-sharded twin of ``edges`` (ShardedRelation) when the plan
    #: picked the row-partitioned runner; the compiled fixpoint's operand
    sharded: object | None = None
    edge_rel: str | None = None  # stored relation behind E (None: override)
    init_reads_edges: bool = False  # init term references edge_rel too
    init_cache: dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict)
    answers: dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict)   # warm x* per source, repaired on update

    @property
    def backend(self) -> str:
        # derived from the plan so it can never disagree with the routing
        return "sparse" if self.plan.strata[0].runner in (
            "sparse_jit", "sparse_sharded") else "dense"


def _bucket(b: int, max_batch: int) -> int:
    """Smallest power of two ≥ b, capped at max_batch."""
    out = 1
    while out < b:
        out <<= 1
    return min(out, max_batch)


class DatalogServer:
    """Request-queue serve loop over batched GSN fixpoints."""

    def __init__(self, *, max_batch: int = 64, mesh=None,
                 max_iters: int = 10_000, warm_answers: int = 256):
        self.max_batch = max_batch
        self.max_iters = max_iters
        self.mesh = mesh
        self.warm_answers = warm_answers
        # a ("graph",) mesh partitions the vertex axis (DESIGN.md §6);
        # any other mesh shards the query-batch axis over "data"
        self.graph_mesh = (mesh if mesh is not None
                           and "graph" in mesh.axis_names else None)
        self.graph_d = (1 if self.graph_mesh is None else
                        int(self.graph_mesh.shape["graph"]))
        self.rules = (rules_mod.make_rules(mesh, "datalog")
                      if mesh is not None and self.graph_mesh is None
                      else None)
        self._families: dict[str, _Family] = {}
        self._queue: collections.deque = collections.deque()
        self._compiled: dict[tuple, Callable] = {}
        self.stats = {"served": 0, "failed": 0, "batches": 0,
                      "padded_rows": 0, "cache_hits": 0,
                      "cache_misses": 0, "updates": 0, "warm_hits": 0,
                      "answers_repaired": 0, "answers_dropped": 0}

    # -- registration -------------------------------------------------------

    def register(self, name: str, make_program: Callable[[int], Program],
                 db: engine.Database, *, edges=None,
                 template_source: int = 0) -> _Family:
        """Register a family of source-parameterized Π₂ programs.

        ``make_program(source)`` must return the optimized program for
        that source; all sources must share the linear operator (checked
        per request by ``planner.source_init`` via the vector-form
        signature).  ``edges`` overrides the
        extracted E — e.g. a weighted COO adjacency for SSSP-style
        families whose schema-level edge relation is a dense 3-ary
        tensor that would not scale.
        """
        template = make_program(template_source)
        hints = dict(template.sort_hints)
        plan = planner.plan_program(
            template, db, hints, objective="throughput", edges=edges,
            adapt_storage=False, require_vector=True,
            mesh=self.graph_mesh)
        edges = planner.materialize_edges(plan, db, hints)
        n = db.dom(plan.strata[0].vf.out_sort)
        # numpy twin of the relations: per-request init evaluation runs
        # eagerly on the host (the jnp dispatch overhead of an O(n) eval
        # would dominate a packed batch otherwise).  Sparse relations go
        # to their np lib too — an init term may read the edge relation
        # itself (e.g. Q(y) := E(a, y) ⊕ …), which the evaluator then
        # densifies host-side.
        host_rels = {k: (v.as_np() if isinstance(v, SparseRelation)
                         else np.asarray(v))
                     for k, v in db.relations.items()}
        host_db = engine.Database(db.schema, db.domains, host_rels)
        fam = _Family(name, make_program, db, host_db, plan, edges, hints,
                      n, self.max_iters)
        if plan.strata[0].runner == "sparse_sharded":
            from repro.distributed import datalog as dd
            fam.sharded = dd.shard_relation(edges, self.graph_mesh)
        if plan.strata[0].edges_override is None:
            a = vectorize.edge_atom(plan.strata[0].vf)
            if a is not None and isinstance(db.relations.get(a.name),
                                            SparseRelation):
                fam.edge_rel = a.name
                fam.init_reads_edges = vectorize.init_reads(
                    plan.strata[0].vf, a.name)
        self._families[name] = fam
        return fam

    # -- request queue ------------------------------------------------------

    def submit(self, family: str, source: int) -> QueryRequest:
        if family not in self._families:
            raise KeyError(f"unknown family {family!r}; "
                           f"registered: {sorted(self._families)}")
        req = QueryRequest(family, int(source),
                           submitted_s=time.perf_counter())
        self._queue.append(req)
        return req

    def submit_update(self, family: str, coords, values=None, *,
                      op: str = "merge") -> UpdateRequest:
        """Enqueue a batch of edge mutations behind every already-queued
        request (FIFO: queries submitted after this update are never
        answered from the pre-update graph)."""
        if family not in self._families:
            raise KeyError(f"unknown family {family!r}; "
                           f"registered: {sorted(self._families)}")
        if op not in ("merge", "delete"):
            raise ValueError(f"unknown update op {op!r}")
        req = UpdateRequest(family,
                            np.atleast_2d(np.asarray(coords, np.int64)),
                            None if values is None
                            else np.asarray(values).reshape(-1), op,
                            submitted_s=time.perf_counter())
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> list:
        """Process the queue head: a run of updates is applied (and the
        family's warm answers repaired) in one pass; a query is packed
        with up to ``max_batch - 1`` later same-family queries — but
        never past an intervening same-family update, which would let a
        pre-update answer overtake an acknowledged mutation."""
        if not self._queue:
            return []
        lead = self._queue.popleft()
        if isinstance(lead, UpdateRequest):
            ups = [lead]
            while (self._queue
                   and isinstance(self._queue[0], UpdateRequest)
                   and self._queue[0].family == lead.family
                   and self._queue[0].op == lead.op):
                ups.append(self._queue.popleft())
            self._apply_updates(self._families[lead.family], ups)
            return ups
        batch = [lead]
        rest: collections.deque = collections.deque()
        while self._queue and len(batch) < self.max_batch:
            req = self._queue.popleft()
            if isinstance(req, UpdateRequest) and req.family == lead.family:
                # fence: no later same-family query may join this batch,
                # so nothing further can be packed — stop scanning
                rest.append(req)
                break
            if isinstance(req, QueryRequest) and req.family == lead.family:
                batch.append(req)
            else:
                rest.append(req)
        self._queue = rest + self._queue
        return self._serve_batch(self._families[lead.family], batch)

    def _serve_batch(self, fam: _Family, batch: list) -> list:
        live, inits = [], []
        for r in batch:
            if r.source in fam.answers:
                r.result = fam.answers[r.source]
                r.iters = 0
                r.done_s = time.perf_counter()
                self.stats["warm_hits"] += 1
                self.stats["served"] += 1
                continue
            try:
                inits.append(self._init_for(fam, r.source))
                live.append(r)
            except Exception as e:  # bad source must not strand the batch
                r.error = f"{type(e).__name__}: {e}"
                r.done_s = time.perf_counter()
                self.stats["failed"] += 1
        if not live:
            self.stats["batches"] += 1
            return batch
        bb = _bucket(len(live), self.max_batch)
        sr = sr_mod.get(fam.plan.strata[0].vf.semiring, lib="np")
        packed = np.full((bb, fam.n), sr.zero, sr.dtype)
        for i, v in enumerate(inits):
            packed[i] = np.asarray(v)
        self.stats["padded_rows"] += bb - len(live)

        run = self._compiled_fixpoint(fam, bb)
        operand = fam.sharded if fam.sharded is not None else fam.edges
        if self.mesh is not None and self.graph_mesh is None:
            with sh.use_rules(self.mesh, self.rules):
                init_dev = sh.put(jnp.asarray(packed),
                                  ("query_batch", "vertex"))
                y, iters = run(operand, init_dev)
                y = np.asarray(jax.device_get(y))
        else:
            # graph-sharded families lay out their own operands: the
            # shard_map in/out specs partition the vertex axis and keep
            # the query batch replicated
            y, iters = run(operand, jnp.asarray(packed))
            y = np.asarray(y)
        iters = np.asarray(iters)
        now = time.perf_counter()
        for i, req in enumerate(live):
            req.result = y[i]
            req.iters = int(iters[i])
            req.done_s = now
            self._remember(fam, req.source, y[i])
        self.stats["served"] += len(live)
        self.stats["batches"] += 1
        return batch

    def run_until_idle(self) -> int:
        done = 0
        while self._queue:
            done += len(self.step())
        return done

    # -- streaming updates ---------------------------------------------------

    def _remember(self, fam: _Family, source: int, y: np.ndarray) -> None:
        if not self.warm_answers:
            return
        if len(fam.answers) >= self.warm_answers:
            fam.answers.pop(next(iter(fam.answers)))  # FIFO evict
        fam.answers[source] = y

    def _apply_updates(self, fam: _Family, ups: list) -> None:
        """Apply a run of same-op updates in one pass: mutate the stored
        relation + operator, then repair (monotone) or drop (delete) the
        warm answer cache.  The family's plan, signature, and compiled
        runners are untouched — within operator capacity not even the
        staged fixpoint's trace changes."""
        now = time.perf_counter()
        try:
            coords = np.concatenate([u.coords for u in ups])
            values = None
            if any(u.values is not None for u in ups):
                one = np.asarray(
                    sr_mod.get(self._rel_semiring(fam), lib="np").one)
                values = np.concatenate(
                    [u.values if u.values is not None
                     else np.full(len(u.coords), one) for u in ups])
            if ups[0].op == "merge":
                self._merge_edges(fam, coords, values)
            else:
                self._delete_edges(fam, coords)
        except Exception as e:  # a bad update must not kill the queue
            for u in ups:
                u.error = f"{type(e).__name__}: {e}"
                u.done_s = now
            self.stats["failed"] += len(ups)
            return
        for u in ups:
            u.applied = True
            u.done_s = time.perf_counter()
        self.stats["updates"] += len(ups)

    def _rel_semiring(self, fam: _Family) -> str:
        if fam.edge_rel is not None:
            return fam.db.schema[fam.edge_rel].semiring
        vf = fam.plan.strata[0].vf
        return (fam.edges.semiring
                if isinstance(fam.edges, SparseRelation) else vf.semiring)

    def _operator_delta(self, fam: _Family, coords, values
                        ) -> SparseRelation:
        """The update batch as a sparse Δ in the operator's own space:
        re-oriented from stored-relation order when needed, values cast
        into the vector equation's semiring."""
        vf = fam.plan.strata[0].vf
        rel_sr = self._rel_semiring(fam)
        delta = SparseRelation.from_coo(
            coords,
            np.ones(len(coords), sr_mod.get(rel_sr, lib="np").dtype)
            * sr_mod.get(rel_sr, lib="np").one
            if values is None else values,
            (fam.n, fam.n), rel_sr)
        if fam.edge_rel is not None:
            a = vectorize.edge_atom(vf)
            if tuple(a.args) != vf.edge.head:
                delta = delta.transpose()
        return vectorize._sparse_into_semiring(delta, vf.semiring)

    def _merge_edges(self, fam: _Family, coords, values) -> None:
        from repro.incremental import DeltaEntry, delta_restart_fixpoint
        delta_op = self._operator_delta(fam, coords, values)
        dh = delta_op.as_np()
        k = int(dh.nnz)
        if fam.edge_rel is not None:
            ent = [DeltaEntry(fam.edge_rel, coords, values, "merge")]
            fam.db = fam.db.apply_delta(ent)
            fam.host_db = fam.host_db.apply_delta(ent)
        if isinstance(fam.edges, SparseRelation):
            fam.edges = fam.edges.apply_delta(dh.coords[:k], dh.values[:k])
            if fam.sharded is not None:
                # route the same rows to their owning destination shards
                # — per-shard capacity usually holds, so the compiled
                # sharded fixpoint's trace (and cache entry) survives
                fam.sharded = fam.sharded.apply_delta(dh.coords[:k],
                                                      dh.values[:k])
        else:  # dense operator: ⊕-scatter in place
            idx = tuple(np.asarray(dh.coords[:k]).T)
            fam.edges = sr_mod.scatter_op(
                delta_op.semiring,
                jnp.asarray(fam.edges).at[idx])(jnp.asarray(dh.values[:k]),
                                                mode="drop")
        if fam.init_reads_edges:
            # the merge also changed the init term: memoized init vectors
            # are stale and a Δ-seeded repair would miss the init
            # contribution — recompute cold (correctness over warmth)
            fam.init_cache.clear()
            self.stats["answers_dropped"] += len(fam.answers)
            fam.answers.clear()
            return
        if not fam.answers:
            return
        if not isinstance(fam.edges, SparseRelation):
            # no sparse Δ-seed path for a dense operator — recompute cold
            self.stats["answers_dropped"] += len(fam.answers)
            fam.answers.clear()
            return
        # one batched delta-restart pass repairs every warm answer:
        # bucketed to a power of two with inert 0̄ rows, one SpMM per
        # round (DESIGN.md §5)
        sources = list(fam.answers)
        sr = sr_mod.get(fam.plan.strata[0].vf.semiring, lib="np")
        bb = _bucket(len(sources), 1 << 30)
        prev = np.full((bb, fam.n), sr.zero, sr.dtype)
        for i, s in enumerate(sources):
            prev[i] = fam.answers[s]
        if fam.sharded is not None:
            # sharded warm repair: the O(nnz(Δ)) seed is derived on the
            # host, then the graph-axis resume loop re-converges every
            # row — same loop body as cold sharded serving
            from repro.distributed import datalog as dd
            from repro.incremental import delta_seed
            d0 = delta_seed(delta_op, prev, backend="np")
            y, _ = dd.sharded_resume_fixpoint(
                fam.sharded, prev, d0, mesh=self.graph_mesh,
                max_iters=fam.max_iters)
        else:
            y, _ = delta_restart_fixpoint(fam.edges, delta_op, prev,
                                          max_iters=fam.max_iters,
                                          mode="jit")
        y = np.asarray(y)
        for i, s in enumerate(sources):
            fam.answers[s] = y[i]
        self.stats["answers_repaired"] += len(sources)

    def _delete_edges(self, fam: _Family, coords) -> None:
        from repro.incremental import DeltaEntry
        if fam.edge_rel is not None:
            ent = [DeltaEntry(fam.edge_rel, coords, None, "delete")]
            fam.db = fam.db.apply_delta(ent)
            fam.host_db = fam.host_db.apply_delta(ent)
            fam.edges = planner.materialize_edges(fam.plan, fam.db,
                                                  fam.hints)
        elif isinstance(fam.edges, SparseRelation):
            delta_op = self._operator_delta(fam, coords, None)
            dh = delta_op.as_np()
            fam.edges = fam.edges.delete_keys(dh.coords[:int(dh.nnz)])
        else:
            vf = fam.plan.strata[0].vf
            sr = sr_mod.get(vf.semiring)
            idx = tuple(np.asarray(np.atleast_2d(coords)).T)
            fam.edges = jnp.asarray(fam.edges).at[idx].set(sr.zero)
        if fam.sharded is not None:
            # a deletion rebuilt the operator — re-partition it (the
            # compiled sharded runners survive unless capacity moved)
            from repro.distributed import datalog as dd
            fam.sharded = dd.shard_relation(fam.edges, self.graph_mesh)
        # deletion is non-monotone: warm answers may over-derive — drop
        # them (the plan and compiled runners survive untouched)
        if fam.init_reads_edges:
            fam.init_cache.clear()
        self.stats["answers_dropped"] += len(fam.answers)
        fam.answers.clear()

    # -- internals ----------------------------------------------------------

    def _init_for(self, fam: _Family, source: int):
        """The per-request O(n) host work, memoized per source: rebuild
        the source's program, check it kept the family's linear operator
        (vector-form signature equality, ``planner.source_init``),
        evaluate its init terms."""
        if source in fam.init_cache:
            return fam.init_cache[source]
        prog = fam.make_program(source)
        init = planner.source_init(fam.plan, prog, fam.host_db,
                                   hints=dict(prog.sort_hints),
                                   backend="np")
        if len(fam.init_cache) >= _INIT_CACHE_MAX:
            fam.init_cache.pop(next(iter(fam.init_cache)))  # FIFO evict
        fam.init_cache[source] = init
        return init

    def _compiled_fixpoint(self, fam: _Family, bb: int) -> Callable:
        key = (fam.plan.signature, bb, self.graph_d)
        if key in self._compiled:
            self.stats["cache_hits"] += 1
            return self._compiled[key]
        self.stats["cache_misses"] += 1
        self._compiled[key] = planner.compile_batched(
            fam.plan, max_iters=fam.max_iters)
        return self._compiled[key]


# --------------------------------------------------------------------------
# FGH routing: synthesize Π₂ once, serve every source
# --------------------------------------------------------------------------


def fgh_make_program(make_bench, edbs: list[str], *,
                     placeholders: tuple[int, int] = (0, 1),
                     rng=None) -> Callable[[int], Program]:
    """Derive Π₂ from a Π₁ benchmark family with the FGH optimizer and
    return a ``make_program(source)`` suitable for
    :meth:`DatalogServer.register`.

    ``make_bench(source)`` builds the :class:`~repro.datalog.programs.Bench`
    for a source vertex.  The optimizer runs (and fully verifies) at the
    two placeholder sources; diffing the two derived programs pinpoints
    exactly which constants are the query source, so serving source ``s``
    is a constant substitution, not a re-synthesis.  When the diff is
    structurally ambiguous (normalization reordered terms between the
    runs) the returned function falls back to re-optimizing per source,
    memoized.
    """
    from repro.core import fgh

    derived = {}
    for p in placeholders:
        b = make_bench(p)
        task = verify.task_from_program(b.original, edbs,
                                        constraint=b.constraint)
        rep = fgh.optimize(task, rng=rng or np.random.default_rng(0))
        if not rep.ok:
            raise RuntimeError(f"FGH synthesis failed for source {p}: "
                               f"{rep.stats}")
        if b.original.post is not None:
            rep.program.post = b.original.post
        derived[p] = rep.program
    p0, p1 = placeholders
    # serve only p0's derivation directly; p1 (like every other source)
    # goes through substitution so served programs share p0's variable
    # names — derived[p1] exists purely to locate the source constants
    cache: dict[int, Program] = {p0: derived[p0]}

    def make_program(source: int) -> Program:
        if source in cache:
            return cache[source]
        try:
            prog = _subst_sources(derived[p0], derived[p1],
                                  placeholders, source)
        except ValueError:
            b = make_bench(source)
            task = verify.task_from_program(b.original, edbs,
                                            constraint=b.constraint)
            rep = fgh.optimize(task, rng=np.random.default_rng(0))
            if not rep.ok:
                raise RuntimeError(
                    f"FGH synthesis failed for source {source}")
            if b.original.post is not None:
                rep.program.post = b.original.post
            prog = rep.program
        cache[source] = prog
        return prog

    return make_program


def _subst_sources(prog0: Program, prog1: Program,
                   placeholders: tuple[int, int], source: int) -> Program:
    """Rebuild ``prog0`` with every constant site where ``prog0`` and
    ``prog1`` disagree (and agree with the respective placeholders)
    replaced by ``source``.  Variable-name differences (fresh-counter
    drift between the two synthesis runs) are ignored; any structural
    mismatch raises ``ValueError``."""
    from repro.core.program import Rule, Stratum

    def walk_args(a0, a1):
        out = []
        for x0, x1 in zip(a0.args, a1.args):
            c0, c1 = isinstance(x0, ir.C), isinstance(x1, ir.C)
            if c0 != c1:
                raise ValueError("const/var mismatch")
            if c0 and x0.value != x1.value:
                if (x0.value, x1.value) != placeholders:
                    raise ValueError(
                        f"differing constants {x0}/{x1} are not the "
                        f"placeholder pair {placeholders}")
                out.append(ir.C(source))
            else:
                out.append(x0)
        return tuple(out)

    def walk_atom(a0, a1):
        if type(a0) is not type(a1):
            raise ValueError("atom type mismatch")
        if isinstance(a0, ir.RelAtom):
            if (a0.name, a0.cast, a0.neg) != (a1.name, a1.cast, a1.neg):
                raise ValueError("rel atom mismatch")
            return ir.RelAtom(a0.name, walk_args(a0, a1), a0.cast, a0.neg)
        if isinstance(a0, ir.PredAtom):
            if a0.pred != a1.pred:
                raise ValueError("pred mismatch")
            return ir.PredAtom(a0.pred, walk_args(a0, a1))
        if isinstance(a0, ir.ValFnAtom):
            if a0.fn != a1.fn:
                raise ValueError("valfn mismatch")
            return ir.ValFnAtom(a0.fn, walk_args(a0, a1))
        if isinstance(a0, ir.ConstAtom):
            if a0.value != a1.value:
                raise ValueError("semiring constants differ between "
                                 "placeholder derivations")
            return a0
        return a0  # ValAtom: var names may drift, keep prog0's

    def walk_ssp(e0, e1):
        if (len(e0.terms) != len(e1.terms)
                or len(e0.head) != len(e1.head)
                or e0.semiring != e1.semiring):
            raise ValueError("SSP shape mismatch")
        terms = []
        for t0, t1 in zip(e0.terms, e1.terms):
            if len(t0.atoms) != len(t1.atoms) \
                    or len(t0.bound) != len(t1.bound):
                raise ValueError("term shape mismatch")
            terms.append(ir.Term(
                tuple(walk_atom(a0, a1)
                      for a0, a1 in zip(t0.atoms, t1.atoms)), t0.bound))
        return ir.SSP(e0.head, tuple(terms), e0.semiring)

    strata = []
    for s0, s1 in zip(prog0.strata, prog1.strata):
        if tuple(s0.rules) != tuple(s1.rules):
            raise ValueError("stratum IDB mismatch")
        rules = {n: Rule(n, walk_ssp(s0.rules[n].body, s1.rules[n].body))
                 for n in s0.rules}
        init = None
        if s0.init is not None:
            if s1.init is None or set(s0.init) != set(s1.init):
                raise ValueError("stratum init mismatch")
            init = {n: walk_ssp(s0.init[n], s1.init[n]) for n in s0.init}
        strata.append(Stratum(rules, init=init))
    if len(prog0.strata) != len(prog1.strata) \
            or len(prog0.outputs) != len(prog1.outputs):
        raise ValueError("program shape mismatch")
    outputs = [Rule(r0.head, walk_ssp(r0.body, r1.body))
               for r0, r1 in zip(prog0.outputs, prog1.outputs)]
    return Program(prog0.name, prog0.schema, strata, outputs,
                   post=prog0.post, sort_hints=dict(prog0.sort_hints))


# --------------------------------------------------------------------------
# CLI demo
# --------------------------------------------------------------------------


def main():
    from repro.datalog import datasets, programs

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--fgh", action="store_true",
                    help="derive Π₂ with the FGH optimizer instead of "
                         "using the published rewrite")
    args = ap.parse_args()

    g = datasets.powerlaw(args.n, 4, seed=0)
    b0 = programs.bm(a=0)
    db = engine.Database(b0.original.schema, {"id": g.n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((g.n,), bool)})
    server = DatalogServer(max_batch=args.max_batch)
    if args.fgh:
        make_program = fgh_make_program(
            lambda a: programs.bm(a=a), ["E", "V"])
    else:
        make_program = lambda a: programs.bm(a=a).optimized
    server.register("reach", make_program, db)

    rng = np.random.default_rng(0)
    reqs = [server.submit("reach", int(s))
            for s in rng.integers(0, g.n, args.requests)]
    t0 = time.perf_counter()
    server.run_until_idle()
    dt = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in reqs)
    print(f"served {server.stats['served']} queries in {dt:.3f}s "
          f"({server.stats['served'] / dt:.1f} qps, "
          f"{server.stats['batches']} batches, "
          f"compile cache {server.stats['cache_hits']} hits / "
          f"{server.stats['cache_misses']} misses)")
    print(f"latency p50 {lat[len(lat) // 2] * 1e3:.1f} ms  "
          f"p99 {lat[int(len(lat) * 0.99)] * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
