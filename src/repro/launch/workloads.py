"""Workload shapes × architectures: abstract inputs for the AOT dry-run.

Shapes (assignment): train_4k (train), prefill_32k (inference prefill),
decode_32k / long_500k (one new token against a seq_len KV cache; these
lower ``serve_step``, not ``train_step``).

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input — shardable, no device allocation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


WORKLOADS = {
    "train_4k": Workload("train_4k", 4096, 256, "train"),
    "prefill_32k": Workload("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Workload("decode_32k", 32768, 128, "decode"),
    "long_500k": Workload("long_500k", 524288, 1, "decode"),
}

def skip_reason(cfg: ModelConfig, wl: Workload) -> str | None:
    if wl.name == "long_500k" and not cfg.subquadratic():
        return ("pure full attention (no window/chunk/recurrence in the "
                "published config) — long_500k needs sub-quadratic "
                "attention; DESIGN.md §Shape skip rules")
    return None


def _vlm_split(cfg: ModelConfig, seq: int) -> tuple[int, int]:
    n_patch = min(1024, seq // 4)
    return n_patch, seq - n_patch


def _dec_len(cfg: ModelConfig, seq: int) -> int:
    # enc-dec training: encoder consumes seq frames, decoder seq//8 tokens
    return max(seq // 8, 64)


def batch_specs(cfg: ModelConfig, wl: Workload) -> dict:
    """Abstract train batch (train kind)."""
    b, s = wl.global_batch, wl.seq_len
    tok = jnp.int32
    if cfg.family == "vlm":
        n_patch, n_text = _vlm_split(cfg, s)
        return {"tokens": S((b, n_text), tok),
                "labels": S((b, n_text), tok),
                "embeds": S((b, n_patch, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "encdec":
        dl = _dec_len(cfg, s)
        return {"tokens": S((b, dl), tok), "labels": S((b, dl), tok),
                "enc_embeds": S((b, s, cfg.d_model), jnp.bfloat16)}
    return {"tokens": S((b, s), tok), "labels": S((b, s), tok)}


def prefill_specs(cfg: ModelConfig, wl: Workload) -> dict:
    b, s = wl.global_batch, wl.seq_len
    if cfg.family == "vlm":
        n_patch, n_text = _vlm_split(cfg, s)
        return {"tokens": S((b, n_text), jnp.int32),
                "embeds": S((b, n_patch, cfg.d_model), jnp.bfloat16),
                "cache": cache_specs(cfg, b, s)}
    if cfg.family == "encdec":
        dl = _dec_len(cfg, s)
        return {"tokens": S((b, dl), jnp.int32),
                "enc_embeds": S((b, s, cfg.d_model), jnp.bfloat16),
                "cache": cache_specs(cfg, b, s)}
    return {"tokens": S((b, s), jnp.int32), "cache": cache_specs(cfg, b, s)}


def decode_specs(cfg: ModelConfig, wl: Workload) -> dict:
    b, s = wl.global_batch, wl.seq_len
    spec = {"tokens": S((b, 1), jnp.int32),
            "cache": cache_specs(cfg, b, s, with_cross=True)}
    return spec


def cache_specs(cfg: ModelConfig, batch: int, t_max: int,
                with_cross: bool = False):
    """ShapeDtypeStruct tree matching models.init_cache."""
    def conv(x):
        return S(x.shape, x.dtype)
    enc_len = t_max if cfg.family == "encdec" else None
    tree = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, t_max, jnp.bfloat16,
                             enc_len=enc_len))
    if cfg.family == "encdec":
        if with_cross:
            n = cfg.n_layers
            te = t_max
            tree = dict(tree)
            tree["cross"] = (
                S((n, batch, te, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
                S((n, batch, te, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
                S((te,), jnp.int32))
    return tree


def windowed_len(cfg: ModelConfig, s: int) -> int:
    """Decode cache length actually needed: sliding-window archs keep a
    rolling window (StarCoder2: 4096) instead of the full context."""
    if cfg.window is not None and cfg.family in ("dense",):
        return min(s, cfg.window)
    return s
