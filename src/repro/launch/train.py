"""End-to-end training driver (deliverable (b): runnable on CPU/TPU).

Wires together: config → mesh+rules → data pipeline → jitted train_step →
checkpoint manager (async, resumable) → heartbeat/fault-tolerance hooks.

Example (CPU, ~100M model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
      --steps 300 --batch 8 --seq 256 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, make_train_iterator
from repro.distributed import sharding as sh
from repro.distributed.fault_tolerance import FTConfig, HeartbeatWriter
from repro.launch import rules as rules_mod
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optimizer import OptConfig, cosine_schedule, wsd_schedule


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 256,
          lr: float = 3e-4, smoke: bool = True, ckpt_dir: str | None = None,
          model_parallel: int = 1, log_every: int = 10, seed: int = 0,
          accum_steps: int = 1, remat: str = "none",
          heartbeat_dir: str | None = None, dtype=jnp.float32):
    cfg = configs.get(arch, smoke=smoke)
    mesh = make_host_mesh(model_parallel)
    rules = rules_mod.make_rules(mesh, "train")

    sched = (wsd_schedule if cfg.schedule == "wsd" else cosine_schedule)(
        lr, warmup=max(steps // 20, 5), total=steps)
    opt_cfg = OptConfig(lr=sched)
    step_fn, opt_init = steps_mod.make_train_step(
        cfg, opt_cfg, remat=remat, accum_steps=accum_steps)

    key = jax.random.PRNGKey(seed)
    with sh.use_rules(mesh, rules):
        params, specs = T.init_params(cfg, key, dtype)
        opt_state = opt_init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    dcfg = DataConfig(
        seq_len=seq, global_batch=batch, vocab=cfg.vocab, seed=seed,
        embeds_dim=cfg.d_model if cfg.family in ("vlm",) else 0,
        n_embeds=32 if cfg.family == "vlm" else 0,
        enc_len=seq if cfg.family == "encdec" else 0)
    if cfg.family == "encdec":
        dcfg = DataConfig(seq_len=max(seq // 4, 16), global_batch=batch,
                          vocab=cfg.vocab, seed=seed,
                          embeds_dim=cfg.d_model, enc_len=seq)
    data = make_train_iterator(dcfg)

    mgr = CheckpointManager(ckpt_dir, every=max(steps // 4, 25)) \
        if ckpt_dir else None
    start = 0
    if mgr:
        restored, start = mgr.restore_latest({"params": params,
                                              "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            print(f"resumed from step {start}")

    hb = HeartbeatWriter(FTConfig(heartbeat_dir), jax.process_index()) \
        if heartbeat_dir else None

    losses = []
    t0 = time.time()
    with sh.use_rules(mesh, rules):
        for step in range(start, steps):
            batch_np = next(data)
            params, opt_state, metrics = jit_step(params, opt_state,
                                                  batch_np)
            losses.append(float(metrics["loss"]))
            if hb:
                hb.beat(step)
            if mgr:
                mgr.maybe_save(step + 1, {"params": params,
                                          "opt": opt_state})
            if step % log_every == 0 or step == steps - 1:
                dt = (time.time() - t0) / max(1, step - start + 1)
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms/step", flush=True)
    if mgr:
        mgr.maybe_save(steps, {"params": params, "opt": opt_state},
                       force=True)
        mgr.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full published config (default: smoke config)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()
    _, losses = train(args.arch, steps=args.steps, batch=args.batch,
                      seq=args.seq, lr=args.lr, smoke=not args.full,
                      ckpt_dir=args.ckpt, model_parallel=args.model_parallel,
                      accum_steps=args.accum, remat=args.remat)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
