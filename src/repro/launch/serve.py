"""Batched serving driver: continuous-batching prefill + greedy decode.

A minimal production shape: a request queue, a batcher that packs up to
``max_batch`` requests, a prefill step filling the shared KV cache, and a
decode loop emitting one token per request per step.  Sampling is greedy
(the serve_step returns argmax; a temperature sampler slot is provided).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed import sharding as sh
from repro.launch import rules as rules_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    prompt: np.ndarray        # (T,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


def serve_batch(arch: str, requests: list[Request], *, smoke: bool = True,
                t_max: int = 512, model_parallel: int = 1, seed: int = 0,
                dtype=jnp.float32):
    cfg = configs.get(arch, smoke=smoke)
    mesh = make_host_mesh(model_parallel)
    rules = rules_mod.make_rules(mesh, "decode")
    key = jax.random.PRNGKey(seed)

    b = len(requests)
    plen = max(len(r.prompt) for r in requests)
    prompts = np.zeros((b, plen), np.int32)
    for i, r in enumerate(requests):
        prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad

    with sh.use_rules(mesh, rules):
        params, _ = T.init_params(cfg, key, dtype)
        cache = T.init_cache(cfg, b, t_max, dtype)

        @jax.jit
        def prefill(params, tokens, cache):
            enc = None
            if cfg.family == "encdec":
                enc = jnp.zeros((b, plen, cfg.d_model), dtype)
            logits, _, cache = T.forward(params, cfg, tokens,
                                         enc_embeds=enc, cache=cache)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        @jax.jit
        def decode(params, tok, cache):
            logits, cache = T.decode_step(params, cfg, tok, cache)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        t0 = time.time()
        tok, cache = prefill(params, jnp.asarray(prompts), cache)
        t_prefill = time.time() - t0
        max_new = max(r.max_new for r in requests)
        t0 = time.time()
        for _ in range(max_new):
            for i, r in enumerate(requests):
                if len(r.out) < r.max_new:
                    r.out.append(int(tok[i]))
            tok, cache = decode(params, tok[:, None], cache)
        t_decode = time.time() - t0
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": b * max_new / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = configs.get(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab, args.prompt_len,
                                 dtype=np.int32), args.max_new)
            for _ in range(args.batch)]
    stats = serve_batch(args.arch, reqs)
    print(f"prefill {stats['prefill_s']*1e3:.0f} ms, "
          f"decode {stats['tok_per_s']:.1f} tok/s")
    print("sample:", reqs[0].out[:10])


if __name__ == "__main__":
    main()
