"""Launchers: production mesh, AOT dry-run, training and serving drivers."""
