"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_graph_mesh(d: int | None = None):
    """1-D ``("graph",)`` mesh for vertex-partitioned Datalog fixpoints
    (DESIGN.md §6).

    Each of the ``d`` devices (default: all local devices) owns an
    ``n/d`` destination-row block of the fixpoint state and the COO
    edge tuples landing there (:mod:`repro.distributed.datalog`).  On a
    CPU host, simulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = len(jax.devices())
    d = n if d is None else d
    if d > n:
        raise ValueError(f"graph mesh needs {d} devices, have {n} "
                         f"(set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={d})")
    return jax.make_mesh((d,), ("graph",), devices=jax.devices()[:d])


def make_datalog_mesh(data: int | None = None):
    """1-D data mesh for batched query serving (DESIGN.md §3).

    The serve loop shards only the query-batch axis, so the mesh is a
    flat "data" axis over the local devices (or the first ``data`` of
    them); the graph stays replicated.
    """
    n = data if data is not None else len(jax.devices())
    return jax.make_mesh((n,), ("data",))
