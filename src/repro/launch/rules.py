"""Logical→mesh axis rule sets per workload kind (DESIGN.md §6).

Each logical axis maps to an ordered list of candidate mesh axes; the
divisibility-aware resolver (distributed.sharding.spec_for) picks the
first that fits, so e.g. an 8-kv-head cache on a 16-way "model" axis
falls back to sequence sharding automatically.
"""

from __future__ import annotations

from jax.sharding import Mesh


def make_rules(mesh: Mesh, kind: str) -> dict:
    multi = "pod" in mesh.axis_names
    data = ("pod", "data") if multi else "data"

    if kind == "datalog":
        # Batched multi-source query serving (DESIGN.md §3): the query
        # batch is embarrassingly parallel — shard it across the data
        # axis; the vertex axis stays replicated (each device advances
        # its slice of sources over the whole graph).  A future
        # vertex-sharded SpMM would map "vertex" to "model".
        return {
            "query_batch": [data, "data"],
            "vertex": [None],
        }

    rules = {
        # --- parameters ---------------------------------------------------
        "vocab": ["model"],
        "embed": ["data"],            # FSDP dim (ZeRO-3 style)
        "heads": ["model"],
        "kv": ["model"],
        "mlp": ["model"],
        "expert": ["model"],
        "layers": None,
        "norm": None,
        # --- activations ----------------------------------------------------
        "batch": [data, "data", None],
        "seq": [None],
        "embed_act": [None],
        "heads_act": ["model"],
        "mlp_act": ["model"],
        "vocab_act": ["model"],
        # --- kv cache ---------------------------------------------------
        "cache_batch": [data, "data"],
        "cache_kv": ["model"],
        "cache_seq": [("data", "model"), "model", "data"],
    }
    if kind == "decode":
        # decode: prefer sharding cache heads; long-context falls through
        # to sequence sharding via divisibility
        pass
    return rules


CACHE_LOGICAL = {
    "k": ("layers", "cache_batch", "cache_seq", "cache_kv", None),
    "v": ("layers", "cache_batch", "cache_seq", "cache_kv", None),
    "pos": (None,),
}


def cache_spec_tree(cache_tree):
    """Logical axes for a cache pytree (matches models.init_cache)."""
    def spec_of(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if "k" in names or "v" in names:
            return CACHE_LOGICAL["k"][:leaf.ndim] if leaf.ndim >= 4 else \
                (None,) * leaf.ndim
        if "state" in names:
            return ("layers", "cache_batch", "mlp")
        if "cross" in names:
            if leaf.ndim >= 4:
                return ("layers", "cache_batch", "cache_seq", "cache_kv",
                        None)[:leaf.ndim]
            return (None,) * leaf.ndim
        return (None,) * leaf.ndim

    import jax
    return jax.tree_util.tree_map_with_path(spec_of, cache_tree)


def batch_logical(name: str) -> tuple:
    if name in ("tokens", "labels"):
        return ("batch", "seq")
    if name in ("embeds", "enc_embeds"):
        return ("batch", "seq", "embed_act")
    raise KeyError(name)
