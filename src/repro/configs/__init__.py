"""Architecture configs: one module per assigned architecture.

``get(name)`` returns the full published config; ``get(name, smoke=True)``
returns the reduced same-family config used by CPU smoke tests.
"""

from repro.configs.base import ARCH_REGISTRY, ModelConfig, get, list_archs

__all__ = ["ARCH_REGISTRY", "ModelConfig", "get", "list_archs"]
