"""LLaVA-NeXT (Mistral-7B backbone) — anyres vision frontend is a STUB
(input_specs provides precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.configs.base import ModelConfig, register


@register("llava-next-mistral-7b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("llava-next-smoke", "vlm", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                           vocab=512, frontend="vision")
    return ModelConfig("llava-next-mistral-7b", "vlm", n_layers=32,
                       d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
                       vocab=32000, frontend="vision")
