"""Llama-4-Maverick 400B-A17B — MoE 128e top-1 + shared expert, chunked
attention (8k) with periodic global layers (iRoPE) [hf:meta-llama/Llama-4]."""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("llama4-maverick-400b-a17b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("llama4-maverick-smoke", "moe", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                           vocab=512, chunk=64, global_every=4,
                           moe=MoEConfig(n_experts=4, top_k=1,
                                         d_ff_expert=256, n_shared=1,
                                         every=2, capacity_factor=8.0))
    return ModelConfig("llama4-maverick-400b-a17b", "moe", n_layers=48,
                       d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
                       vocab=202048, head_dim=128, chunk=8192,
                       global_every=4,
                       moe=MoEConfig(n_experts=128, top_k=1,
                                     d_ff_expert=8192, n_shared=1, every=2))
