"""MiniCPM-2B — dense LM with WSD schedule [arXiv:2404.06395; hf]."""
from repro.configs.base import ModelConfig, register


@register("minicpm-2b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("minicpm-2b-smoke", "dense", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=4, d_ff=320,
                           vocab=512, tie_embeddings=True, schedule="wsd")
    return ModelConfig("minicpm-2b", "dense", n_layers=40, d_model=2304,
                       n_heads=36, n_kv_heads=36, d_ff=5760, vocab=122753,
                       tie_embeddings=True, schedule="wsd")
