"""StarCoder2-7B — GQA + RoPE + sliding-window 4096 [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig, register


@register("starcoder2-7b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("starcoder2-7b-smoke", "dense", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                           vocab=512, window=64, mlp_gated=False)
    return ModelConfig("starcoder2-7b", "dense", n_layers=32, d_model=4608,
                       n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152,
                       window=4096, mlp_gated=False)
