"""Mistral-Large-2407 (123B) — dense GQA [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ModelConfig, register


@register("mistral-large-123b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("mistral-large-123b-smoke", "dense", n_layers=2,
                           d_model=192, n_heads=6, n_kv_heads=2, d_ff=448,
                           vocab=512)
    return ModelConfig("mistral-large-123b", "dense", n_layers=88,
                       d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
                       vocab=32768, head_dim=128)
