"""Whisper-base — encoder-decoder; conv frontend is a STUB (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig, register


@register("whisper-base")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("whisper-base-smoke", "encdec", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                           vocab=512, encoder_layers=2, frontend="audio", mlp_gated=False)
    return ModelConfig("whisper-base", "encdec", n_layers=6, d_model=512,
                       n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
                       encoder_layers=6, frontend="audio", mlp_gated=False)
