"""xLSTM-125M — sLSTM + mLSTM recurrent blocks [arXiv:2405.04517].

Attention-free: the per-layer recurrence h_t = a_t⊙h_{t-1} + b_t runs as
the FGH-rewritten associative scan (kernels/ssm_scan.py); sLSTM positions
use exponential-gating modulation on the same stacked parameterization
(DESIGN.md §Arch-applicability)."""
from repro.configs.base import ModelConfig, register


@register("xlstm-125m")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("xlstm-125m-smoke", "ssm", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=4, d_ff=0,
                           vocab=512, ssm_state=16, slstm_layers=(1,))
    return ModelConfig("xlstm-125m", "ssm", n_layers=12, d_model=768,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
                       ssm_state=64, slstm_layers=(1, 4, 7, 10),
                       tie_embeddings=True)
