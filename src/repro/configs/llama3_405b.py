"""Llama-3.1-405B — dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig, register


@register("llama3-405b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("llama3-405b-smoke", "dense", n_layers=2,
                           d_model=256, n_heads=8, n_kv_heads=2, d_ff=832,
                           vocab=512, rope_theta=5e5)
    return ModelConfig("llama3-405b", "dense", n_layers=126, d_model=16384,
                       n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
                       head_dim=128, rope_theta=5e5)
