"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6, first
layer dense [arXiv:2401.06066]."""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("deepseek-moe-16b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("deepseek-moe-smoke", "moe", n_layers=3,
                           d_model=128, n_heads=4, n_kv_heads=4, d_ff=320,
                           vocab=512,
                           moe=MoEConfig(n_experts=8, top_k=2,
                                         d_ff_expert=64, n_shared=2,
                                         first_dense=1,
                                         capacity_factor=8.0))
    return ModelConfig("deepseek-moe-16b", "moe", n_layers=28, d_model=2048,
                       n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
                       moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                                     n_shared=2, first_dense=1))
