"""Model configuration schema + registry.

Every assigned architecture registers a builder returning the exact
published config and a reduced ``smoke`` config of the same family (small
widths/layers/experts; tiny vocab) for CPU tests.  The FULL configs are
exercised only via the AOT dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared (always-on) experts
    every: int = 1             # MoE layer every N layers (others dense)
    first_dense: int = 0       # leading dense layers (deepseek)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 → d_model // n_heads
    moe: MoEConfig | None = None
    # attention variants
    rope_theta: float = 1e4
    window: int | None = None          # sliding window (StarCoder2)
    chunk: int | None = None           # chunked attention (Llama 4)
    global_every: int = 0              # every Nth layer full-attn (Llama 4)
    # ssm / hybrid
    ssm_state: int = 0
    d_inner_mult: int = 2              # ssm inner expansion
    hybrid_attn_every: int = 0         # shared attn block every N (Zamba2)
    slstm_layers: tuple[int, ...] = () # sLSTM-gated positions (xLSTM)
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stub: 'audio' | 'vision' | None
    frontend: str | None = None
    tie_embeddings: bool = False
    mlp_gated: bool = True             # SwiGLU (3 mats) vs GELU (2 mats)
    norm_eps: float = 1e-5
    # schedule hint (minicpm: WSD)
    schedule: str = "cosine"
    # vocab padded up for even sharding (DESIGN.md): logical vocab used by
    # the embedding/logits; the data pipeline uses ``vocab``.
    vocab_pad_to: int = 256

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.hybrid_attn_every == 0

    def subquadratic(self) -> bool:
        """May run long_500k (DESIGN.md §Shape skip rules)."""
        return (self.family in ("ssm", "hybrid") or self.window is not None
                or self.chunk is not None)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hq, hk, hd = self.n_heads, self.n_kv_heads, self.hd
        nm = 3 if self.mlp_gated else 2
        attn = d * hq * hd + 2 * d * hk * hd + hq * hd * d
        mlp = nm * d * f
        n_emb = v * d * (1 if self.tie_embeddings else 2)
        total = n_emb
        layers = self.n_layers + self.encoder_layers
        for i in range(self.n_layers):
            if self.family in ("ssm", "hybrid") and not self._is_attn_layer(i):
                di = self.d_inner_mult * d
                total += 2 * d * di + di * d + 2 * d * self.n_heads
                if self.family == "ssm":       # mLSTM q,k readout
                    total += 2 * d * di
                continue
            total += attn + 2 * d
            total += self._ffn_params(i)
        for _ in range(self.encoder_layers):
            total += attn + mlp + 2 * d
        if self.hybrid_attn_every:
            total += attn + mlp  # one shared block
        return int(total)

    def _is_attn_layer(self, i: int) -> bool:
        if self.family == "hybrid" and self.hybrid_attn_every:
            return False  # shared attn blocks live outside the scan stack
        return self.family not in ("ssm",)

    def _ffn_params(self, i: int) -> int:
        d = self.d_model
        nm = 3 if self.mlp_gated else 2
        if self.moe is None:
            return nm * d * self.d_ff
        m = self.moe
        if i < m.first_dense or (i % m.every) != (m.every - 1):
            return nm * d * self.d_ff
        routed = m.n_experts * nm * d * m.d_ff_expert
        shared = m.n_shared * nm * d * m.d_ff_expert
        return routed + shared + d * m.n_experts

    def active_param_count(self) -> int:
        """6·N_active for MoE MODEL_FLOPS (roofline)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        for i in range(self.n_layers):
            if i < m.first_dense or (i % m.every) != (m.every - 1):
                continue
            nm = 3 if self.mlp_gated else 2
            routed_all = m.n_experts * nm * d * m.d_ff_expert
            routed_active = m.top_k * nm * d * m.d_ff_expert
            total -= routed_all - routed_active
        return int(total)


ARCH_REGISTRY: dict[str, Callable[[bool], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        ARCH_REGISTRY[name] = fn
        return fn
    return deco


def get(name: str, smoke: bool = False) -> ModelConfig:
    return ARCH_REGISTRY[name](smoke)


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)


# import arch modules so they register (keep at bottom)
from repro.configs import (  # noqa: E402,F401
    deepseek_moe_16b, llama3_405b, llama4_maverick_400b_a17b,
    llava_next_mistral_7b, minicpm_2b, mistral_large_123b, starcoder2_7b,
    whisper_base, xlstm_125m, zamba2_2_7b)
