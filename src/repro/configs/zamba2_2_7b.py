"""Zamba2-2.7B — Mamba2 backbone + shared attention block applied
periodically [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, register


@register("zamba2-2.7b")
def build(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig("zamba2-smoke", "hybrid", n_layers=4,
                           d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                           vocab=512, ssm_state=16, hybrid_attn_every=2)
    return ModelConfig("zamba2-2.7b", "hybrid", n_layers=54, d_model=2560,
                       n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
                       ssm_state=64, hybrid_attn_every=18)
