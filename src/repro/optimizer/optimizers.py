"""AdamW and Adafactor with f32 state over (possibly bf16) params.

* AdamW — f32 m/v moments; the production default.  Moments inherit the
  parameter sharding (ZeRO-style: the 2-D weight sharding shards the
  optimizer state with no extra machinery).
* Adafactor — factored second moment (row/col statistics), no first
  moment: O(n) → O(√n) state for the 100B+ dry-runs where 2×f32 moments
  would not fit 16 GiB/chip (see EXPERIMENTS.md §Perf).
* Gradient clipping by global norm; optional gradient compression hooks
  live in repro/distributed/collectives.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def _lr_at(cfg: OptConfig, step):
    return cfg.lr(step) if callable(cfg.lr) else jnp.asarray(cfg.lr)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# -- AdamW ------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm


# -- Adafactor --------------------------------------------------------------


def adafactor_init(params):
    def one(p):
        if p.ndim >= 2:
            return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(one, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, f):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + 1e-30
        if p.ndim >= 2:
            r = decay * f["r"] + (1 - decay) * g2.mean(-1)
            c = decay * f["c"] + (1 - decay) * g2.mean(-2)
            denom = (r[..., None] * c[..., None, :]
                     / jnp.maximum(r.mean(-1, keepdims=True)[..., None], 1e-30))
            v = denom
            nf = {"r": r, "c": c}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            nf = {"v": v}
        delta = g32 / jnp.sqrt(v + 1e-30)
        # relative update clipping (Adafactor's d=1)
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)))
        delta = delta / jnp.maximum(1.0, rms)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), nf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_f = treedef.flatten_up_to(state["f"])
    outs = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_f = treedef.unflatten([o[1] for o in outs])
    return new_p, {"f": new_f, "step": step}, gnorm


def make_optimizer(cfg: OptConfig):
    if cfg.kind == "adamw":
        return adamw_init, lambda p, g, s: adamw_update(cfg, p, g, s)
    if cfg.kind == "adafactor":
        return adafactor_init, lambda p, g, s: adafactor_update(cfg, p, g, s)
    raise KeyError(cfg.kind)
