"""Optimizers + schedules (pure JAX, no optax)."""

from repro.optimizer.optimizers import (adamw_init, adamw_update,
                                        adafactor_init, adafactor_update,
                                        OptConfig, make_optimizer)
from repro.optimizer.schedules import cosine_schedule, wsd_schedule

__all__ = ["adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "OptConfig", "make_optimizer",
           "cosine_schedule", "wsd_schedule"]
