"""LR schedules: cosine and MiniCPM's Warmup-Stable-Decay (WSD).

WSD (arXiv:2404.06395): linear warmup → long stable plateau → short
(~10%) exponential-ish decay; we use the linear-decay variant."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.1):
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - decay_start) / max(total - decay_start, 1),
                        0.0, 1.0)
        dec = base_lr * (1 - (1 - floor) * frac)
        out = jnp.where(step < warmup, warm, base_lr)
        return jnp.where(step >= decay_start, dec, out)
    return lr
