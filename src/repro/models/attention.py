"""GQA attention: XLA path (differentiable, q-chunked) + KV-cache decode.

The XLA path chunks queries (lax.map) so the (B,H,q,k) logit block stays
bounded — the staged-out analogue of the Pallas flash kernel's VMEM tiling
(the kernel itself is the TPU serving fast path; see kernels/).

Masks support causal, sliding-window (StarCoder2) and chunked+periodic-
global attention (Llama 4 iRoPE) via position arithmetic, so one
implementation serves every assigned dense/MoE/VLM/enc-dec architecture.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.layers import _init, rope

Q_CHUNK = 512
KV_CHUNK = 1024

#: "chunked"  — q-chunked lax.map; materializes (q_chunk × T) f32 logits
#: "bf16"     — as "chunked" with bf16 logit/prob tiles (f32 softmax math
#:              stays fused): halves the O(T²) HBM traffic
#: "online"   — flash-style online softmax over KV chunks inside a lax.scan
#:              (the XLA analogue of the Pallas kernel's tiling; NOTE: the
#:              scan carry routes the accumulator through HBM each step —
#:              see EXPERIMENTS.md §Perf for when this wins/loses)
ATTN_IMPL = "chunked"


def set_attention_impl(impl: str):
    global ATTN_IMPL
    assert impl in ("chunked", "online", "bf16")
    ATTN_IMPL = impl


def attn_init(key, cfg, dtype):
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {"wq": _init(ks[0], (d, hq * hd), s, dtype),
         "wk": _init(ks[1], (d, hk * hd), s, dtype),
         "wv": _init(ks[2], (d, hk * hd), s, dtype),
         "wo": _init(ks[3], (hq * hd, d), 1.0 / np.sqrt(hq * hd), dtype)}
    specs = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
             "wv": ("embed", "kv"), "wo": ("heads", "embed")}
    return p, specs


def _mask(qpos, kpos, *, causal, window, chunk, is_global):
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    if chunk is not None:
        local = (kp // chunk) == (qp // chunk)
        m &= jnp.where(is_global, True, local)
    return m


def _sdpa(q, k, v, qpos, kpos, *, causal, window, chunk, is_global,
          tile_dtype=jnp.float32):
    """q: (B,Tq,Hq,hd); k/v: (B,Tk,Hkv,hd).  f32 softmax math; logit/prob
    tiles stored in ``tile_dtype`` (bf16 halves the T² HBM traffic)."""
    b, tq, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, tq, hkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=tile_dtype)
    logits = logits.astype(jnp.float32) / np.sqrt(hd)
    m = _mask(qpos, kpos, causal=causal, window=window, chunk=chunk,
              is_global=is_global)  # (B?,Tq,Tk) broadcastable
    while m.ndim < logits.ndim:
        m = m[:, None] if m.ndim >= 3 else m[None]
    logits = jnp.where(m, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(
        v.dtype if tile_dtype != jnp.float32 else jnp.float32)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, tq, hq, hd)


def _sdpa_online(q, k, v, qpos, kpos, *, causal, window, chunk, is_global):
    """Online-softmax over KV chunks (flash-style, pure XLA, differentiable).

    Carries (m, l, acc) through a lax.scan over KV chunks so only a
    (Tq × KV_CHUNK) logit tile exists at a time — HBM traffic drops from
    O(Tq·Tk) to O(Tk·d) per q-block (§Perf)."""
    b, tq, hq, hd = q.shape
    tk = k.shape[1]
    hkv = k.shape[2]
    group = hq // hkv
    kc = min(KV_CHUNK, tk)
    if tk % kc != 0:
        return _sdpa(q, k, v, qpos, kpos, causal=causal, window=window,
                     chunk=chunk, is_global=is_global)
    n_chunks = tk // kc
    qg = q.reshape(b, tq, hkv, group, hd)
    ks = k.reshape(b, n_chunks, kc, hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, kc, hkv, hd).transpose(1, 0, 2, 3, 4)
    kps = kpos.reshape(n_chunks, kc)

    def step(carry, xs):
        m_run, l_run, acc = carry
        k_c, v_c, kp_c = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_c,
                       preferred_element_type=jnp.float32) / np.sqrt(hd)
        msk = _mask(qpos, kp_c, causal=causal, window=window, chunk=chunk,
                    is_global=is_global)
        while msk.ndim < s.ndim:
            msk = msk[:, None] if msk.ndim >= 3 else msk[None]
        s = jnp.where(msk, s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + p.sum(-1)
        upd = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_c.dtype), v_c)
        acc = acc * alpha[..., None].astype(acc.dtype) + upd
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, tq, hd), v.dtype)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, tq, hq, hd)


def attn_apply(p, x, cfg, *, positions, cache=None, layer_global=False,
               kv_override=None, causal=True):
    """Full-sequence attention (training/prefill) or cached decode.

    cache: dict(k,v: (B,Tmax,Hkv,hd), pos scalar) — updated functionally.
    kv_override: (k, v, kpos) for cross-attention.
    """
    b, t, d = x.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, t, hq, hd)
    q = constrain(q, ("batch", "seq", "heads_act", None))
    if kv_override is None:
        k = (x @ p["wk"]).reshape(b, t, hk, hd)
        v = (x @ p["wv"]).reshape(b, t, hk, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v, kpos = kv_override

    new_cache = None
    if cache is not None and kv_override is None:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + t}
        k, v = ck, cv
        kpos = jnp.arange(cache["k"].shape[1])
        kvalid = kpos < (pos + t)
    elif kv_override is None:
        kpos = positions
        kvalid = None
    else:
        kvalid = None

    window = cfg.window
    chunk = cfg.chunk if cfg.chunk else None

    if ATTN_IMPL == "online" and t > 1:
        impl = _sdpa_online
    elif ATTN_IMPL == "bf16":
        impl = functools.partial(_sdpa, tile_dtype=jnp.bfloat16)
    else:
        impl = _sdpa

    def run(qc, qpos_c):
        return impl(qc, k, v, qpos_c, kpos, causal=causal, window=window,
                    chunk=chunk, is_global=layer_global)

    # mask out unwritten cache slots by position validity
    if kvalid is not None:
        # fold into kpos trick: invalid slots get kpos = +inf-like sentinel
        kpos = jnp.where(kvalid, kpos, jnp.iinfo(jnp.int32).max // 2)

    if t > Q_CHUNK and t % Q_CHUNK == 0:
        nchunk = t // Q_CHUNK
        qs = q.reshape(b, nchunk, Q_CHUNK, hq, hd).transpose(1, 0, 2, 3, 4)
        ps = positions.reshape(nchunk, Q_CHUNK) if positions.ndim == 1 else \
            positions.reshape(b, nchunk, Q_CHUNK).transpose(1, 0, 2)
        out = jax.lax.map(lambda args: run(*args), (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, t, hq, hd)
    else:
        out = run(q, positions)

    out = constrain(out, ("batch", "seq", "heads_act", None))
    y = out.reshape(b, t, hq * hd) @ p["wo"]
    return y, new_cache
