"""Shared building blocks: norms, MLPs, rotary embeddings, sharding hooks.

Parameters are plain dict pytrees.  Each init function returns
``(params, specs)`` where ``specs`` mirrors the param tree with tuples of
*logical axis names*; the launcher maps logical axes to mesh axes
(`repro.distributed.sharding`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

Dtype = jnp.dtype


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d, dtype):
    return jnp.ones((d,), dtype), ("norm",)


def rmsnorm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def mlp_init(key, d, f, gated, dtype):
    ks = jax.random.split(key, 3)
    scale = 1.0 / np.sqrt(d)
    p = {"wi": _init(ks[0], (d, f), scale, dtype),
         "wo": _init(ks[1], (f, d), 1.0 / np.sqrt(f), dtype)}
    s = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if gated:
        p["wg"] = _init(ks[2], (d, f), scale, dtype)
        s["wg"] = ("embed", "mlp")
    return p, s


def mlp_apply(p, x, gated):
    h = x @ p["wi"]
    if gated:
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", "seq", "mlp_act"))
    return h @ p["wo"]


def embed_init(key, vocab, d, dtype):
    p = _init(key, (vocab, d), 1.0, dtype)
    return p, ("vocab", "embed")


def rope(x, positions, theta):
    """x: (..., T, H, hd); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    ang = ang[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, vocab):
    """Mean CE over valid labels; logits (..., Vp) may be vocab-padded."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0) & (labels < vocab)
    safe = jnp.where(mask, labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
