"""Model assembly: init / forward / loss / cache / decode for all families.

Layer stacks are *scanned* (parameters stacked on a leading L axis) so the
HLO stays one-layer-sized regardless of depth — essential for 126-layer
AOT dry-runs.  Heterogeneous patterns are handled without breaking scan
homogeneity:

* Llama-4: alternate dense/MoE layers → scan over (dense+MoE) pair-blocks;
  chunked-vs-global attention per layer via a scanned boolean flag.
* DeepSeekMoE: leading dense layer unstacked, MoE layers scanned.
* xLSTM: sLSTM positions via a scanned gate-nonlinearity flag.
* Zamba2: Mamba2 segments scanned; the *shared* attention+MLP block (one
  parameter set) applied between segments.
* Whisper: encoder scan (non-causal) + decoder scan with cross-attention.
* LLaVA: vision-stub embeddings prepended to token embeddings.

Caches: per-stack stacked KV tensors threaded through the scan as xs/ys;
recurrent stacks carry O(1) state (long_500k works by construction).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod

Params = dict
PyTree = Any


def _stack_init(key, n, init_fn):
    """Stack n copies of init_fn's params along a leading axis."""
    keys = jax.random.split(key, n)
    ps, spec = init_fn(keys[0])
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[init_fn(k)[0] for k in keys])
    spec = jax.tree.map(lambda s: ("layers",) + tuple(s), spec,
                        is_leaf=lambda s: isinstance(s, tuple))
    return stacked, spec


def _dense_layer_init(cfg, dtype, moe_layer=False):
    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        ap, aspec = attn_mod.attn_init(k1, cfg, dtype)
        if moe_layer:
            fp, fspec = moe_mod.moe_init(k2, cfg, dtype)
        else:
            fp, fspec = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated,
                                   dtype)
        n1, n1s = L.rmsnorm_init(cfg.d_model, dtype)
        n2, n2s = L.rmsnorm_init(cfg.d_model, dtype)
        return ({"attn": ap, "ffn": fp, "norm1": n1, "norm2": n2},
                {"attn": aspec, "ffn": fspec, "norm1": n1s, "norm2": n2s})
    return init


def _cross_layer_init(cfg, dtype):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        base, bspec = _dense_layer_init(cfg, dtype)(k1)
        xp, xspec = attn_mod.attn_init(k2, cfg, dtype)
        n3, n3s = L.rmsnorm_init(cfg.d_model, dtype)
        base["cross"], bspec["cross"] = xp, xspec
        base["norm3"], bspec["norm3"] = n3, n3s
        return base, bspec
    return init


def _recurrent_layer_init(cfg, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        rp, rspec = ssm_mod.recurrent_init(k1, cfg, dtype)
        n1, n1s = L.rmsnorm_init(cfg.d_model, dtype)
        return ({"rec": rp, "norm1": n1}, {"rec": rspec, "norm1": n1s})
    return init


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 8)
    params: Params = {}
    specs: Params = {}

    params["embed"], specs["embed"] = L.embed_init(
        keys[0], cfg.padded_vocab, cfg.d_model, dtype)
    params["out_norm"], specs["out_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = L.embed_init(
            keys[1], cfg.padded_vocab, cfg.d_model, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["stack"], specs["stack"] = _stack_init(
            keys[2], cfg.n_layers, _dense_layer_init(cfg, dtype))
    elif fam == "moe":
        m = cfg.moe
        nd = m.first_dense
        if m.every == 2:
            params["stack"], specs["stack"] = _stack_init(
                keys[2], cfg.n_layers // 2, _pair_init(cfg, dtype))
        else:
            if nd:
                params["head_dense"], specs["head_dense"] = _stack_init(
                    keys[3], nd, _dense_layer_init(cfg, dtype))
            params["stack"], specs["stack"] = _stack_init(
                keys[2], cfg.n_layers - nd,
                _dense_layer_init(cfg, dtype, moe_layer=True))
    elif fam == "ssm":
        params["stack"], specs["stack"] = _stack_init(
            keys[2], cfg.n_layers, _recurrent_layer_init(cfg, dtype))
    elif fam == "hybrid":
        params["stack"], specs["stack"] = _stack_init(
            keys[2], cfg.n_layers, _recurrent_layer_init(cfg, dtype))
        params["shared_attn"], specs["shared_attn"] = \
            _dense_layer_init(cfg, dtype)(keys[4])
    elif fam == "encdec":
        params["encoder"], specs["encoder"] = _stack_init(
            keys[5], cfg.encoder_layers, _dense_layer_init(cfg, dtype))
        params["enc_norm"], specs["enc_norm"] = L.rmsnorm_init(
            cfg.d_model, dtype)
        params["stack"], specs["stack"] = _stack_init(
            keys[2], cfg.n_layers, _cross_layer_init(cfg, dtype))
    else:  # pragma: no cover
        raise ValueError(fam)
    return params, specs


def _pair_init(cfg, dtype):
    def init(key):
        k1, k2 = jax.random.split(key)
        a, aspec = _dense_layer_init(cfg, dtype)(k1)
        b, bspec = _dense_layer_init(cfg, dtype, moe_layer=True)(k2)
        return {"a": a, "b": b}, {"a": aspec, "b": bspec}
    return init


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    _, specs = jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    # specs contain no tracers (pure python), but eval_shape wraps the fn;
    # rebuild directly instead:
    return init_specs_only(cfg, dtype)


def init_specs_only(cfg: ModelConfig, dtype=jnp.bfloat16):
    shapes, specs = shape_init(cfg, dtype)
    return specs


@functools.lru_cache(maxsize=32)
def _shape_init_cached(cfg: ModelConfig, dtype_str: str):
    dtype = jnp.dtype(dtype_str)
    box = {}

    def build(k):
        params, specs = init_params(cfg, k, dtype)
        box["specs"] = specs  # pure-python tree; stash during tracing
        return params

    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def shape_init(cfg: ModelConfig, dtype=jnp.bfloat16):
    """(ShapeDtypeStruct params, logical specs) without any allocation."""
    return _shape_init_cached(cfg, jnp.dtype(dtype).name)


# --------------------------------------------------------------------------
# Stack runners
# --------------------------------------------------------------------------


def _maybe_remat(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _dense_block(p, x, cfg, positions, *, cache=None, is_global=False,
                 moe_layer=False, causal=True, enc_out=None):
    h, new_cache = attn_mod.attn_apply(
        p["attn"], L.rmsnorm(x, p["norm1"], cfg.norm_eps), cfg,
        positions=positions, cache=cache, layer_global=is_global,
        causal=causal)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if enc_out is not None:
        ck, cv, kpos = enc_out
        h, _ = attn_mod.attn_apply(
            p["cross"], L.rmsnorm(x, p["norm3"], cfg.norm_eps), cfg,
            positions=positions, kv_override=(ck, cv, kpos), causal=False)
        x = x + h
    z = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
    if moe_layer:
        f, aux = moe_mod.moe_apply(p["ffn"], z, cfg)
    else:
        f = L.mlp_apply(p["ffn"], z, cfg.mlp_gated)
    return x + f, aux, new_cache


def _run_attn_stack(stack, x, cfg, positions, *, cache=None, flags=None,
                    pair=False, moe_layer=False, causal=True, remat="none",
                    enc_out_proj=None):
    """Scan a stacked attention stack; cache (L, ...) threaded as xs/ys."""
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    if flags is None:
        flags = jnp.zeros((n_layers,), bool)

    def block(carry, xs):
        x, aux = carry
        p_l, flag, cache_l, enc_l = xs

        if pair:
            x, a1, ca = _dense_block(p_l["a"], x, cfg, positions,
                                     cache=None if cache_l is None else cache_l["a"],
                                     is_global=flag, causal=causal)
            x, a2, cb = _dense_block(p_l["b"], x, cfg, positions,
                                     cache=None if cache_l is None else cache_l["b"],
                                     is_global=flag, moe_layer=True,
                                     causal=causal)
            new_c = None if cache_l is None else {"a": ca, "b": cb}
            aux = aux + a1 + a2
        else:
            enc_kv = None
            if enc_l is not None:
                enc_kv = enc_l
            x, a1, new_c = _dense_block(p_l, x, cfg, positions,
                                        cache=cache_l, is_global=flag,
                                        moe_layer=moe_layer, causal=causal,
                                        enc_out=enc_kv)
            aux = aux + a1
        return (x, aux), new_c

    block = _maybe_remat(block, remat)
    xs = (stack, flags, cache, enc_out_proj)
    (x, aux), new_cache = jax.lax.scan(block, (x, jnp.zeros((), jnp.float32)),
                                       xs)
    return x, aux, new_cache


def _run_recurrent_stack(stack, x, cfg, *, state=None, slstm_flags=None,
                         remat="none"):
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    if slstm_flags is None:
        slstm_flags = jnp.zeros((n_layers,), bool)

    def block(carry, xs):
        x = carry
        p_l, flag, st = xs
        y, new_st = ssm_mod.recurrent_apply(
            p_l["rec"], L.rmsnorm(x, p_l["norm1"], cfg.norm_eps), cfg,
            slstm_flag=flag, state=st)
        return x + y, new_st

    block = _maybe_remat(block, remat)
    x, new_state = jax.lax.scan(block, x, (stack, slstm_flags, state))
    return x, new_state


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _global_flags(cfg, n, pair=False):
    if not cfg.global_every:
        return jnp.zeros((n,), bool)
    import numpy as np
    if pair:
        # flag applies to both layers of the pair-block; global layers are
        # every cfg.global_every-th absolute layer
        f = [(2 * i + 1) % cfg.global_every == cfg.global_every - 1
             for i in range(n)]
    else:
        f = [i % cfg.global_every == cfg.global_every - 1 for i in range(n)]
    return jnp.asarray(np.array(f))


def _slstm_flags(cfg, n):
    import numpy as np
    return jnp.asarray(np.array([i in cfg.slstm_layers for i in range(n)]))


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            enc_embeds=None, cache=None, remat: str = "none"):
    """Returns (logits, aux, new_cache).

    tokens: (B, T) int32; embeds: (B, Tp, D) frontend-stub embeddings
    prepended to token embeddings (VLM); enc_embeds: (B, Te, D) encoder
    input (audio stub).  cache=None → full-sequence (train/prefill).
    """
    emb = params["embed"]
    x_parts = []
    if embeds is not None:
        x_parts.append(embeds.astype(emb.dtype))
    if tokens is not None:
        x_parts.append(emb[tokens])
    x = x_parts[0] if len(x_parts) == 1 else jnp.concatenate(x_parts, 1)
    x = constrain(x, ("batch", "seq", "embed_act"))
    b, t, _ = x.shape

    pos0 = cache["pos"] if cache is not None else 0
    positions = pos0 + jnp.arange(t)

    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    fam = cfg.family

    if fam in ("dense", "vlm"):
        flags = _global_flags(cfg, cfg.n_layers)
        x, aux, nc = _run_attn_stack(
            params["stack"], x, cfg, positions,
            cache=None if cache is None else cache["layers"],
            flags=flags, remat=remat)
        if cache is not None:
            new_cache["layers"] = nc
    elif fam == "moe":
        m = cfg.moe
        if m.every == 2:
            flags = _global_flags(cfg, cfg.n_layers // 2, pair=True)
            x, aux, nc = _run_attn_stack(
                params["stack"], x, cfg, positions,
                cache=None if cache is None else cache["layers"],
                flags=flags, pair=True, remat=remat)
        else:
            if m.first_dense:
                x, _, nch = _run_attn_stack(
                    params["head_dense"], x, cfg, positions,
                    cache=None if cache is None else cache["head"],
                    remat=remat)
                if cache is not None:
                    new_cache["head"] = nch
            x, aux, nc = _run_attn_stack(
                params["stack"], x, cfg, positions,
                cache=None if cache is None else cache["layers"],
                moe_layer=True, remat=remat)
        if cache is not None:
            new_cache["layers"] = nc
    elif fam == "ssm":
        flags = _slstm_flags(cfg, cfg.n_layers)
        x, st = _run_recurrent_stack(
            params["stack"], x, cfg,
            state=None if cache is None else cache["state"],
            slstm_flags=flags, remat=remat)
        if cache is not None:
            new_cache["state"] = st
    elif fam == "hybrid":
        k = cfg.hybrid_attn_every
        n_seg = cfg.n_layers // k
        seg_stacks = jax.tree.map(
            lambda a: a.reshape((n_seg, k) + a.shape[1:]), params["stack"])
        for s in range(n_seg):
            seg = jax.tree.map(lambda a: a[s], seg_stacks)
            st = None if cache is None else \
                jax.lax.dynamic_slice_in_dim(cache["state"], s * k, k, 0)
            x, new_st = _run_recurrent_stack(seg, x, cfg, state=st,
                                             remat=remat)
            if cache is not None:
                new_cache["state"] = jax.lax.dynamic_update_slice_in_dim(
                    new_cache["state"], new_st, s * k, 0)
            sc = None if cache is None else \
                jax.tree.map(lambda a: a[s], cache["shared"])
            x, _, nsc = _dense_block(params["shared_attn"], x, cfg,
                                     positions, cache=sc)
            if cache is not None:
                new_cache["shared"] = jax.tree.map(
                    lambda full, upd, s=s: full.at[s].set(upd),
                    new_cache["shared"], nsc)
    elif fam == "encdec":
        if cache is None or cache.get("cross") is None:
            assert enc_embeds is not None
            e = enc_embeds.astype(emb.dtype)
            epos = jnp.arange(e.shape[1])
            e, _, _ = _run_attn_stack(params["encoder"], e, cfg, epos,
                                      causal=False, remat=remat)
            e = L.rmsnorm(e, params["enc_norm"], cfg.norm_eps)
            # per-decoder-layer cross K/V projected from encoder output
            def proj(p_l):
                te = e.shape[1]
                ck = (e @ p_l["cross"]["wk"]).reshape(
                    b, te, cfg.n_kv_heads, cfg.hd)
                cv = (e @ p_l["cross"]["wv"]).reshape(
                    b, te, cfg.n_kv_heads, cfg.hd)
                return ck, cv
            ck, cv = jax.vmap(proj)(params["stack"])
            cross = (ck, cv, jnp.arange(e.shape[1]))
            if cache is not None:
                new_cache["cross"] = cross
        else:
            cross = cache["cross"]
            new_cache["cross"] = cross
        ck, cv, kpos = cross
        x, aux, nc = _run_attn_stack(
            params["stack"], x, cfg, positions,
            cache=None if cache is None else cache["layers"],
            enc_out_proj=(ck, cv,
                          jnp.broadcast_to(kpos, (ck.shape[0],) + kpos.shape)),
            remat=remat)
        if cache is not None:
            new_cache["layers"] = nc
    else:  # pragma: no cover
        raise ValueError(fam)

    x = L.rmsnorm(x, params["out_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.T
    logits = constrain(logits, ("batch", "seq", "vocab_act"))
    if cache is not None:
        new_cache["pos"] = cache["pos"] + t
    return logits, aux, new_cache


def loss_fn(params, cfg, batch, *, remat="none"):
    logits, aux, _ = forward(
        params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
        enc_embeds=batch.get("enc_embeds"), remat=remat)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # VLM: patch positions unlabeled
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], 1)
    ce = L.cross_entropy(logits, labels, cfg.vocab)
    return ce + 0.01 * aux, (ce, aux)


# --------------------------------------------------------------------------
# Caches / decode
# --------------------------------------------------------------------------


def _kv_cache(cfg, n, batch, t_max, dtype):
    shape = (n, batch, t_max, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_cache(cfg: ModelConfig, batch: int, t_max: int, dtype=jnp.bfloat16,
               enc_len: int | None = None):
    fam = cfg.family
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "vlm"):
        cache["layers"] = _kv_cache(cfg, cfg.n_layers, batch, t_max, dtype)
    elif fam == "moe":
        m = cfg.moe
        if m.every == 2:
            half = _kv_cache(cfg, cfg.n_layers // 2, batch, t_max, dtype)
            cache["layers"] = {"a": half,
                               "b": _kv_cache(cfg, cfg.n_layers // 2, batch,
                                              t_max, dtype)}
        else:
            if m.first_dense:
                cache["head"] = _kv_cache(cfg, m.first_dense, batch, t_max,
                                          dtype)
            cache["layers"] = _kv_cache(cfg, cfg.n_layers - m.first_dense,
                                        batch, t_max, dtype)
    elif fam == "ssm":
        cache["state"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.d_inner_mult * cfg.d_model),
            jnp.float32)
    elif fam == "hybrid":
        cache["state"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.d_inner_mult * cfg.d_model),
            jnp.float32)
        n_seg = cfg.n_layers // cfg.hybrid_attn_every
        cache["shared"] = _kv_cache(cfg, n_seg, batch, t_max, dtype)
    elif fam == "encdec":
        cache["layers"] = _kv_cache(cfg, cfg.n_layers, batch, t_max, dtype)
        cache["cross"] = None
    # per-layer caches get a scalar pos each when threaded through scans;
    # we keep one global pos and slice-update at it.
    return _distribute_pos(cache)


def _distribute_pos(cache):
    """KV stacks need a per-layer 'pos' for the scan body; share one."""
    def add_pos(kv):
        n = kv["k"].shape[0]
        kv = dict(kv)
        kv["pos"] = jnp.zeros((n,), jnp.int32)
        return kv
    for key in ("layers", "head", "shared"):
        if key in cache and cache[key] is not None:
            if key == "layers" and "a" in cache[key]:
                cache[key] = {"a": add_pos(cache[key]["a"]),
                              "b": add_pos(cache[key]["b"])}
            else:
                cache[key] = add_pos(cache[key])
    return cache


def decode_step(params, cfg: ModelConfig, tokens, cache, *, remat="none"):
    """One-token decode: tokens (B, 1) → (logits, new_cache)."""
    logits, _, new_cache = forward(params, cfg, tokens, cache=cache,
                                   remat=remat)
    return logits, new_cache
