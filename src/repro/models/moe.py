"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Routing: top-k softmax router → position-in-expert via cumsum → scatter
tokens into an (E, C, D) buffer → batched per-expert FFN (einsum over the
expert axis, sharded over "model"/EP) → weighted combine.  Tokens beyond
expert capacity are dropped (standard TPU MoE; capacity_factor in config).
Shared experts (DeepSeekMoE) run densely on every token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.layers import _init

#: expert-buffer sharding: "expert" (capacity dim replicated across data —
#: the scatter becomes replicate+all-reduce under SPMD) or "expert_data"
#: (capacity dim sharded over "data" — reduce-scatter pattern; §Perf)
BUF_SHARD = "expert"


def set_buf_shard(mode: str):
    global BUF_SHARD
    assert mode in ("expert", "expert_data")
    BUF_SHARD = mode


def moe_init(key, cfg, dtype):
    d = cfg.d_model
    m = cfg.moe
    fe = m.d_ff_expert
    ks = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    p = {
        "router": _init(ks[0], (d, m.n_experts), s, jnp.float32),
        "wi": _init(ks[1], (m.n_experts, d, fe), s, dtype),
        "wg": _init(ks[2], (m.n_experts, d, fe), s, dtype),
        "wo": _init(ks[3], (m.n_experts, fe, d), 1.0 / np.sqrt(fe), dtype),
    }
    specs = {
        "router": ("embed", "expert"),
        "wi": ("expert", "embed", "mlp"),
        "wg": ("expert", "embed", "mlp"),
        "wo": ("expert", "mlp", "embed"),
    }
    if m.n_shared:
        p["shared_wi"] = _init(ks[4], (d, m.n_shared * fe), s, dtype)
        p["shared_wg"] = _init(ks[4], (d, m.n_shared * fe), s, dtype)
        p["shared_wo"] = _init(ks[4], (m.n_shared * fe, d),
                               1.0 / np.sqrt(fe), dtype)
        specs["shared_wi"] = ("embed", "mlp")
        specs["shared_wg"] = ("embed", "mlp")
        specs["shared_wo"] = ("mlp", "embed")
    return p, specs


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)          # (T, E)
    gate, idx = jax.lax.top_k(probs, m.top_k)        # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(t * m.top_k / m.n_experts * m.capacity_factor))
    cap = max(cap, 4)

    # position of each (token, k) routing choice within its expert
    flat_idx = idx.reshape(-1)                       # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, m.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot        # running count
    pos_in_e = (pos.sum(-1) - 1)                     # (T*k,)
    keep = pos_in_e < cap

    token_of = jnp.repeat(jnp.arange(t), m.top_k)
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)

    buf = jnp.zeros((m.n_experts, cap, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[token_of], 0)
    buf = buf.at[flat_idx, safe_pos].add(contrib)
    cap_axis = "cache_batch" if BUF_SHARD == "expert_data" else None
    buf = constrain(buf, ("expert", cap_axis, None))

    # per-expert FFN, batched over the (sharded) expert axis
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = jax.nn.silu(g) * h
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])   # (E, C, D)
    out_e = constrain(out_e, ("expert", cap_axis, None))

    # combine: gather each routing choice's expert output, weight, sum
    picked = out_e[flat_idx, safe_pos]               # (T*k, D)
    picked = jnp.where(keep[:, None], picked, 0)
    weighted = picked * gate.reshape(-1)[:, None].astype(picked.dtype)
    combined = jnp.zeros_like(xf).at[token_of].add(weighted)

    if m.n_shared:
        hs = jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wi"])
        combined = combined + hs @ p["shared_wo"]

    # auxiliary load-balance loss (Switch-style), returned via aux
    me = probs.mean(0)
    ce = (onehot.sum(0) / jnp.maximum(onehot.sum(), 1)).astype(jnp.float32)
    aux = jnp.sum(me * ce) * m.n_experts

    return combined.reshape(b, s, d), aux
