"""Recurrent blocks: xLSTM (mLSTM/sLSTM) and Mamba2-style SSD.

All reduce to the diagonal linear recurrence ``h_t = a_t ⊙ h_{t-1} + b_t``
executed by the FGH-rewritten associative scan (kernels/ssm_scan.py; see
DESIGN.md §Arch-applicability — the sequential F-loop with readout G is
rewritten to the blocked-scan GH-form).

* mLSTM: q/k/v projections, exp/sigmoid input+forget gates, per-channel
  decay a_t = σ(f_t), update b_t = i_t ⊙ (k ⊙ v); readout h ⊙ q.
* sLSTM positions (xLSTM) switch the gate nonlinearity to exponential
  gating via a per-layer flag — elementwise, so the stacked-parameter scan
  stays homogeneous.
* Mamba2/Zamba2: input proj → gated recurrence over d_inner channels with
  per-channel learned decay (SSD's scalar-decay, diagonal-state special
  case; ssm_state sets the head grouping of the decay parameters).
Decode keeps O(1) state: one recurrence step per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.models.layers import _init


def recurrent_init(key, cfg, dtype):
    """Parameter budget follows the published families:

    * Mamba2/Zamba2 (hybrid): in_proj (value+gate) + out_proj + per-HEAD
      decay/input gates (SSD's scalar-per-head decay) ≈ 3·d·d_inner;
    * mLSTM/xLSTM (ssm): adds q,k projections for the matrix-memory
      readout ≈ 5·d·d_inner.
    """
    d = cfg.d_model
    di = cfg.d_inner_mult * d
    nh = max(cfg.n_heads, 1)
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d)
    p = {
        "w_in": _init(ks[0], (d, 2 * di), s, dtype),     # value + gate
        "gate_proj": _init(ks[1], (d, 2 * nh), s, jnp.float32),
        "w_out": _init(ks[3], (di, d), 1.0 / np.sqrt(di), dtype),
        "decay_bias": jnp.ones((nh,), jnp.float32) * 2.0,
    }
    specs = {"w_in": ("embed", "mlp"), "gate_proj": ("embed", None),
             "w_out": ("mlp", "embed"), "decay_bias": ("norm",)}
    if cfg.family == "ssm":  # mLSTM q,k readout projections
        p["w_qk"] = _init(ks[2], (d, 2 * di), s, dtype)
        specs["w_qk"] = ("embed", "mlp")
    return p, specs


def recurrent_apply(p, x, cfg, *, slstm_flag=None, state=None):
    """x: (B,T,D).  state: (B, d_inner) carried across decode steps.

    Returns (y, new_state)."""
    b, t, d = x.shape
    di = cfg.d_inner_mult * d
    nh = max(cfg.n_heads, 1)

    vin = x @ p["w_in"]
    v, og = jnp.split(vin, 2, axis=-1)                 # value, output gate
    if "w_qk" in p:
        qk = x @ p["w_qk"]
        q, k = jnp.split(qk, 2, axis=-1)
    else:  # Mamba2-style: no matrix-memory readout projections
        q = k = jnp.ones_like(v)
    gates = (x @ p["gate_proj"]).astype(jnp.float32)   # per-head (SSD)
    ig, fg = jnp.split(gates, 2, axis=-1)              # (B,T,nh)
    fg = fg + p["decay_bias"]
    # broadcast per-head gates over each head's channels
    rep = di // nh
    ig = jnp.repeat(ig, rep, axis=-1)
    fg = jnp.repeat(fg, rep, axis=-1)

    # mLSTM: sigmoid forget; sLSTM flag switches to exponential gating
    a_sig = jax.nn.sigmoid(fg)
    i_sig = jax.nn.sigmoid(ig)
    if slstm_flag is not None:
        a_exp = jnp.exp(-jnp.exp(-fg))  # exp-gating, stabilized
        i_exp = jnp.exp(jnp.minimum(ig, 0.0))
        a = jnp.where(slstm_flag, a_exp, a_sig)
        i = jnp.where(slstm_flag, i_exp, i_sig)
    else:
        a, i = a_sig, i_sig

    bterm = (i * (k.astype(jnp.float32) * v.astype(jnp.float32)))
    a = a.astype(jnp.float32)

    if t == 1 and state is not None:
        h = a[:, 0] * state + bterm[:, 0]
        new_state = h
        h = h[:, None]
    else:
        h = kops.ssm_scan(a, bterm)
        new_state = h[:, -1]

    y = (h * jax.nn.silu(og.astype(jnp.float32))
         * q.astype(jnp.float32)).astype(x.dtype)
    return y @ p["w_out"], new_state


def init_recurrent_state(cfg, batch, dtype=jnp.float32):
    return jnp.zeros((batch, cfg.d_inner_mult * cfg.d_model), dtype)
