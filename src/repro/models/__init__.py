"""Composable LM stack: dense/MoE/SSM/hybrid/enc-dec/VLM in pure JAX."""

from repro.models.transformer import (init_params, forward, loss_fn,
                                      init_cache, decode_step, param_specs)

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "param_specs"]
