"""Sparse S-relation subsystem (DESIGN.md §2).

COO semiring tensors with fixed-capacity padded buffers (jit/pjit
compatible), sparse semiring contraction (SpMV / SpMM / SpMSpM), an
adaptive density-based densify/sparsify switch, and a frontier-based
semi-naive fixpoint runner whose Δ is a sparse worklist of changed
tuples rather than a dense masked tensor.
"""

from repro.sparse.adaptive import (DENSIFY_ABOVE, SPARSIFY_BELOW,
                                   ReplanPolicy, adapt_value, density)
from repro.sparse.contract import mspm, spmm, spmspm, spmv, vspm
from repro.sparse.coo import SparseRelation
# NOTE: the unified fixpoint() *function* is deliberately not re-exported
# here — binding that name at package level would shadow the
# ``repro.sparse.fixpoint`` submodule.  Import it from the submodule:
# ``from repro.sparse.fixpoint import fixpoint``.
from repro.sparse.fixpoint import (FixpointState, FrontierStats,
                                   resume_fixpoint,
                                   sparse_seminaive_fixpoint)

__all__ = [
    "SparseRelation", "spmv", "vspm", "spmm", "mspm", "spmspm",
    "FixpointState", "FrontierStats", "ReplanPolicy",
    "sparse_seminaive_fixpoint", "resume_fixpoint", "density",
    "adapt_value", "SPARSIFY_BELOW", "DENSIFY_ABOVE",
]
