"""Sparse semiring contraction: SpMV / SpMM / SpMSpM.

The dense engine lowers a binary join-and-aggregate to ``C = A ⊕.⊗ B``
(semiring matmul).  These are the sparse counterparts over a COO
:class:`~repro.sparse.coo.SparseRelation`:

* ``spmv``/``vspm`` — sparse matrix × dense vector (either side): the
  workhorse of frontier fixpoints.  Per edge ``(z, y, w)``: gather the
  vector at the contracted key, ⊗ with the edge value, and ⊕-reduce by the
  output key via :func:`repro.kernels.ops.semiring_segment_reduce`
  (Pallas segment-reduce on TPU, jnp scatter elsewhere).  Cost O(nnz),
  independent of the dense key-space size.
* ``spmm`` — sparse matrix × dense matrix, same scheme with row payloads.
* ``spmspm`` — sparse × sparse → sparse, a host/numpy sort-merge join on
  the contracted key (the eager ``backend="np"`` world of the
  synthesizer); on-device callers densify one side instead, since output
  nnz is data-dependent and cannot be bounded statically.

Padding discipline: gathers use ⊗-identity fill and padded values are 0̄,
so padding rows contribute 0̄ ⊗ 1̄ = 0̄ to every reduction; scatters use
``mode="drop"`` on the out-of-range coordinate sentinel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse.coo import SparseRelation


def _gather(x, idx, fill):
    return jnp.take(x, idx, axis=0, mode="fill", fill_value=fill)


def _fused_spmm(rel: SparseRelation, b, *, transpose: bool, backend: str):
    """Route an SpMM through :mod:`repro.kernels.coo_spmm`.

    ``backend="pallas"`` runs the fused Pallas kernel (interpreted off-TPU
    so CI's CPU job exercises the kernel path); ``backend="fused"`` runs
    the host-numpy fused executor.  Both need a *concrete* operator —
    their edge-tile geometry is host-planned and weakref-cached.
    """
    from repro.kernels import coo_spmm, ops as kops
    plan = coo_spmm.plan_geometry(rel, transpose=transpose)
    if backend == "pallas":
        interpret = kops._FORCE_INTERPRET or jax.default_backend() != "tpu"
        return coo_spmm.spmm_pallas(plan, b, interpret=interpret)
    if backend == "fused":
        return coo_spmm.spmm_host(plan, b)
    raise ValueError(f"unknown SpMM backend {backend!r}")


def spmv(rel: SparseRelation, x, *, transpose: bool = False):
    """``out[i] = ⊕_j rel[i, j] ⊗ x[j]`` (or ``⊕_i rel[i,j] ⊗ x[i]`` with
    ``transpose``).  Returns a dense vector over the non-contracted sort."""
    assert rel.arity == 2, rel
    sr = sr_mod.get(rel.semiring)
    from repro.kernels import ops as kops
    contract_ax, out_ax = (0, 1) if transpose else (1, 0)
    gathered = _gather(jnp.asarray(x), rel.coords[:, contract_ax], sr.one)
    prod = sr.mul(rel.values, gathered)
    return kops.semiring_segment_reduce(
        sr, prod, rel.coords[:, out_ax], rel.shape[out_ax])


def vspm(x, rel: SparseRelation):
    """``out[j] = ⊕_i x[i] ⊗ rel[i, j]`` — vector × sparse matrix."""
    return spmv(rel, x, transpose=True)


def spmm(rel: SparseRelation, b, *, transpose: bool = False,
         backend: str = "jnp"):
    """Sparse (n, k) × dense (k, d) → dense (n, d) over the semiring.

    Per edge the gathered payload is a whole row of ``b`` and the
    ⊕-reduction scatters contiguous rows — so with d = B query lanes the
    per-edge index overhead of SpMV is amortized across the batch (the
    mechanism behind the batched multi-source fixpoint, DESIGN.md §3).

    ``backend`` selects the execution: ``"jnp"`` (default, traceable) is
    the gather/⊗/segment-⊕ composition below; ``"pallas"``/``"fused"``
    route through the fused single-pass kernel (DESIGN.md §9) and need a
    concrete operator.
    """
    assert rel.arity == 2 and b.ndim == 2, (rel, b.shape)
    if backend != "jnp":
        return _fused_spmm(rel, b, transpose=transpose, backend=backend)
    sr = sr_mod.get(rel.semiring)
    from repro.kernels import ops as kops
    contract_ax, out_ax = (0, 1) if transpose else (1, 0)
    rows = _gather(jnp.asarray(b), rel.coords[:, contract_ax],
                   sr.one)                                 # (cap, d)
    prod = sr.mul(rel.values[:, None], rows)
    return kops.semiring_segment_reduce(
        sr, prod, rel.coords[:, out_ax], rel.shape[out_ax])


def mspm(x, rel: SparseRelation, *, backend: str = "jnp"):
    """Dense (B, n) × sparse (n, m) → dense (B, m): batched vspm.

    ``out[b, j] = ⊕_i x[b, i] ⊗ rel[i, j]`` — the multi-source frontier
    advance.  Internally runs in the (n, B) layout (`spmm` on the
    transposed orientation) so gathers and scatters move contiguous
    B-wide rows; the transposes at the boundary are free under jit when
    the caller keeps the (n, B) layout (as the batched fixpoint does).
    ``backend`` as in :func:`spmm`.
    """
    x = jnp.asarray(x) if backend != "fused" else np.asarray(x)
    assert x.ndim == 2, x.shape
    return spmm(rel, x.T, transpose=True, backend=backend).T


def spmspm(a: SparseRelation, b: SparseRelation, *,
           capacity: int | None = None) -> SparseRelation:
    """Sparse × sparse → sparse: ``C[i,j] = ⊕_k A[i,k] ⊗ B[k,j]``.

    Host/numpy only (the output's nnz is data-dependent): a sort-merge
    join on k with ⊕-coalescing of the (i, j) results.
    """
    assert a.arity == 2 and b.arity == 2
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    assert a.semiring == b.semiring
    sr = sr_mod.get(a.semiring, lib="np")
    ah, bh = a.as_np(), b.as_np()
    ka, kb = int(ah.nnz), int(bh.nnz)
    ai, ak, av = (ah.coords[:ka, 0].astype(np.int64),
                  ah.coords[:ka, 1].astype(np.int64), ah.values[:ka])
    bk, bj, bv = (bh.coords[:kb, 0].astype(np.int64),
                  bh.coords[:kb, 1].astype(np.int64), bh.values[:kb])
    # CSR-index B by its contracted key k
    order = np.argsort(bk, kind="stable")
    bk, bj, bv = bk[order], bj[order], bv[order]
    counts = np.bincount(bk, minlength=a.shape[1])
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    # expand: every A entry joins its run of B entries sharing k
    deg = counts[ak]
    rep = np.repeat(np.arange(ka), deg)
    if len(rep):
        run_off = np.arange(len(rep)) - np.repeat(
            np.concatenate([[0], np.cumsum(deg)[:-1]]), deg)
        bsel = starts[ak[rep]] + run_off
    else:
        bsel = np.zeros(0, np.int64)
    coords = np.stack([ai[rep], bj[bsel]], axis=1) if len(rep) else \
        np.zeros((0, 2), np.int64)
    values = sr.mul(av[rep], bv[bsel]) if len(rep) else \
        np.zeros(0, sr.dtype)
    return SparseRelation.from_coo(
        coords, values, (a.shape[0], b.shape[1]), a.semiring,
        capacity=capacity, lib=a.lib)
