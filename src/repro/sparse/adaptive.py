"""Adaptive density-based densify/sparsify switch (DESIGN.md §2).

Real recursive workloads drift: an EDB adjacency is ~10⁻⁴ dense on a
SNAP-scale graph, while a transitive closure on a small dense block
saturates.  The engine therefore tags each relation's storage and flips
representation at hysteresis thresholds:

* below :data:`SPARSIFY_BELOW` live fraction → COO (``O(nnz)`` kernels);
* above :data:`DENSIFY_ABOVE` → dense tensors (MXU-shaped contraction);
* in between → keep the current representation (avoids thrashing when a
  fixpoint frontier hovers around the boundary).

The thresholds are consumed in two places: host-side ``Database.adapt``
(between strata, :func:`adapt_value`) and — since the cost-based planner
(DESIGN.md §4) — :func:`decide`, which folds the same hysteresis into
per-stratum storage decisions of :func:`repro.core.planner.plan_program`.
"""

from __future__ import annotations

import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse.coo import SparseRelation

SPARSIFY_BELOW = 0.05
DENSIFY_ABOVE = 0.25

#: spare capacity factor when sparsifying, so a growing relation does not
#: immediately overflow its padded buffer
CAPACITY_SLACK = 1.5


def density(arr, semiring: str) -> float:
    """Live (non-0̄) fraction of a dense array or SparseRelation (host)."""
    if isinstance(arr, SparseRelation):
        return arr.density()
    sr = sr_mod.get(semiring, lib="np")
    host = np.asarray(arr)
    live = host.sum() if semiring == "bool" else (host != sr.zero).sum()
    return float(live) / (host.size or 1)


def decide(density_value: float, current: str, *,
           sparsify_below: float = SPARSIFY_BELOW,
           densify_above: float = DENSIFY_ABOVE) -> str:
    """Target storage ("sparse" | "dense") for a relation of the given
    live fraction, with hysteresis around the current representation —
    the one threshold table shared by ``Database.adapt`` and the
    planner's storage folding (DESIGN.md §4)."""
    if density_value < sparsify_below:
        return "sparse"
    if density_value > densify_above:
        return "dense"
    return current


def adapt_value(arr, semiring: str, *,
                sparsify_below: float = SPARSIFY_BELOW,
                densify_above: float = DENSIFY_ABOVE):
    """Return ``arr`` in the representation its density warrants.

    Host-side (concrete arrays): used between fixpoint strata and by
    ``Database.adapt``; inside jit the representation is fixed at trace
    time, which is exactly what static shapes require.
    """
    d = density(arr, semiring)
    current = "sparse" if isinstance(arr, SparseRelation) else "dense"
    target = decide(d, current, sparsify_below=sparsify_below,
                    densify_above=densify_above)
    if target == current:
        return arr
    if target == "dense":
        return arr.to_dense()
    if np.asarray(arr).ndim < 1:
        return arr
    cap = max(1, int(d * np.asarray(arr).size * CAPACITY_SLACK) + 1)
    return SparseRelation.from_dense(arr, semiring, capacity=cap)
