"""Adaptive switching policies: storage density (DESIGN.md §2) and
mid-fixpoint runner re-planning (DESIGN.md §10).

Real recursive workloads drift: an EDB adjacency is ~10⁻⁴ dense on a
SNAP-scale graph, while a transitive closure on a small dense block
saturates.  The engine therefore tags each relation's storage and flips
representation at hysteresis thresholds:

* below :data:`SPARSIFY_BELOW` live fraction → COO (``O(nnz)`` kernels);
* above :data:`DENSIFY_ABOVE` → dense tensors (MXU-shaped contraction);
* in between → keep the current representation (avoids thrashing when a
  fixpoint frontier hovers around the boundary).

The thresholds are consumed in two places: host-side ``Database.adapt``
(between strata, :func:`adapt_value`) and — since the cost-based planner
(DESIGN.md §4) — :func:`decide`, which folds the same hysteresis into
per-stratum storage decisions of :func:`repro.core.planner.plan_program`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse.coo import SparseRelation

SPARSIFY_BELOW = 0.05
DENSIFY_ABOVE = 0.25

#: spare capacity factor when sparsifying, so a growing relation does not
#: immediately overflow its padded buffer
CAPACITY_SLACK = 1.5


def density(arr, semiring: str) -> float:
    """Live (non-0̄) fraction of a dense array or SparseRelation (host)."""
    if isinstance(arr, SparseRelation):
        return arr.density()
    sr = sr_mod.get(semiring, lib="np")
    host = np.asarray(arr)
    live = host.sum() if semiring == "bool" else (host != sr.zero).sum()
    return float(live) / (host.size or 1)


def decide(density_value: float, current: str, *,
           sparsify_below: float = SPARSIFY_BELOW,
           densify_above: float = DENSIFY_ABOVE) -> str:
    """Target storage ("sparse" | "dense") for a relation of the given
    live fraction, with hysteresis around the current representation —
    the one threshold table shared by ``Database.adapt`` and the
    planner's storage folding (DESIGN.md §4)."""
    if density_value < sparsify_below:
        return "sparse"
    if density_value > densify_above:
        return "dense"
    return current


def adapt_value(arr, semiring: str, *,
                sparsify_below: float = SPARSIFY_BELOW,
                densify_above: float = DENSIFY_ABOVE):
    """Return ``arr`` in the representation its density warrants.

    Host-side (concrete arrays): used between fixpoint strata and by
    ``Database.adapt``; inside jit the representation is fixed at trace
    time, which is exactly what static shapes require.
    """
    d = density(arr, semiring)
    current = "sparse" if isinstance(arr, SparseRelation) else "dense"
    target = decide(d, current, sparsify_below=sparsify_below,
                    densify_above=densify_above)
    if target == current:
        return arr
    if target == "dense":
        return arr.to_dense()
    if np.asarray(arr).ndim < 1:
        return arr
    cap = max(1, int(d * np.asarray(arr).size * CAPACITY_SLACK) + 1)
    return SparseRelation.from_dense(arr, semiring, capacity=cap)


# --------------------------------------------------------------------------
# Mid-fixpoint re-planning (DESIGN.md §10)
# --------------------------------------------------------------------------
#
# The storage hysteresis above flips a *representation* between strata;
# the pieces below flip the *runner* between chunks of one fixpoint.
# Same design split as the planner's SHARDED_COST/SPMM_COST: a frozen
# policy (when a switch is allowed) and a patchable measured-constant
# model (what each runner's next round costs), so tests and calibration
# sweeps can pin either side.


@dataclasses.dataclass(frozen=True)
class ReplanPolicy:
    """When the adaptive executor may switch runners mid-fixpoint.

    Every guard bounds the regression an adversarial (oscillating-
    density) workload can extract versus the best static plan: a switch
    only fires when the challenger prices at least ``hysteresis``×
    cheaper per round, at most once per ``min_chunks_between`` chunks,
    never before ``warmup_chunks`` chunks have been observed, and never
    more than ``max_switches`` times in one fixpoint — so the total
    hand-off overhead is ≤ ``max_switches`` chunk boundaries and the
    time spent in a mispriced runner is ≤ one chunk per switch.
    """

    #: rounds per chunk — the re-planning granularity (and the serve
    #: loop's chunk_iters twin)
    chunk_iters: int = 8
    #: challenger must price this many × under the incumbent's next-round
    #: estimate before a switch fires
    hysteresis: float = 2.0
    #: chunks that must elapse after a switch before the next one
    min_chunks_between: int = 2
    #: hard cap on switches per fixpoint
    max_switches: int = 4
    #: chunks to observe before the first switch is allowed
    warmup_chunks: int = 1

    def should_switch(self, incumbent_cost: float, challenger_cost: float,
                      *, chunk_index: int, chunks_since_switch: int,
                      switches: int) -> bool:
        if switches >= self.max_switches:
            return False
        if chunk_index + 1 <= self.warmup_chunks:
            return False
        if chunks_since_switch < self.min_chunks_between:
            return False
        return challenger_cost * self.hysteresis <= incumbent_cost


@dataclasses.dataclass
class AdaptiveCostModel:
    """Per-round ns estimates for re-pricing the *remaining* fixpoint at
    a chunk boundary, from the observed :class:`~repro.sparse.fixpoint.
    FrontierStats` (DESIGN.md §10, calibrated against
    ``BENCH_replan.json``).

    Unlike the planner's static models these price one *round*, not a
    whole run — remaining trip counts cancel across candidates sharing
    the same GSN round structure, so the comparison needs only the
    per-round term.  The frontier worklist is the only candidate whose
    round cost tracks the live frontier (O(Σ deg(frontier)) host work);
    the staged runners pay O(nnz(E)·B) regardless of density — that gap
    is exactly the drifting-workload win the adaptive executor captures.
    Module-level instance :data:`ADAPTIVE_COST` is patchable in place.
    """

    #: host worklist: per expanded edge (gather + ⊗ + combine-at)
    host_edge_ns: float = 60.0
    #: host worklist: per vertex per live row per round (the O(n) scans)
    host_vertex_ns: float = 4.0
    #: host worklist: fixed per-round python overhead per live row
    host_round_ns: float = 5_000.0
    #: staged jnp loop: per stored edge per lane per round
    staged_edge_ns: float = 1.5
    #: staged jnp loop: per vertex per lane per round (⊕/⊖/mask sweeps)
    staged_vertex_ns: float = 1.0
    #: staged loop: fixed per-round dispatch/loop overhead
    staged_round_ns: float = 20_000.0
    #: dense matmul runner: per n² cell per lane per round
    dense_cell_ns: float = 0.6
    #: sharded loop: per-round synchronizing-collective toll per device
    sharded_sync_ns: float = 50_000.0

    def round_ns(self, runner: str, *, n: int, e_nnz: int, batch: int,
                 frontier_nnz: int, live_rows: int, semiring: str,
                 fused_speedup: float = 1.0, mesh_d: int = 1) -> float:
        """Estimated cost of the *next* round for ``runner`` given the
        chunk-boundary frontier observation."""
        if runner == "sparse_frontier":
            deg = e_nnz / max(1, n)
            return (frontier_nnz * deg * self.host_edge_ns
                    + live_rows * (n * self.host_vertex_ns
                                   + self.host_round_ns))
        if runner == "sparse_jit":
            return (e_nnz * batch * self.staged_edge_ns
                    + n * batch * self.staged_vertex_ns
                    + self.staged_round_ns)
        if runner == "sparse_frontier_pallas":
            base = self.round_ns("sparse_jit", n=n, e_nnz=e_nnz,
                                 batch=batch, frontier_nnz=frontier_nnz,
                                 live_rows=live_rows, semiring=semiring)
            return base / max(fused_speedup, 1.0)
        if runner == "vector_dense":
            return (n * n * batch * self.dense_cell_ns
                    + n * batch * self.staged_vertex_ns
                    + self.staged_round_ns)
        if runner == "sparse_sharded":
            work = (e_nnz * batch * self.staged_edge_ns
                    + n * batch * self.staged_vertex_ns)
            return (work / max(1, mesh_d)
                    + mesh_d * self.sharded_sync_ns
                    + self.staged_round_ns)
        raise ValueError(f"no adaptive cost model for runner {runner!r}")


#: module-level so tests and calibration sweeps can patch it in place
ADAPTIVE_COST = AdaptiveCostModel()
