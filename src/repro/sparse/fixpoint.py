"""Frontier-based semi-naive fixpoint over sparse S-relations.

Solves the linear vector equation (the paper's GH-form after the FGH
rewrite of BM/CC/SSSP/MLM-style programs, Sec. 3.1):

    x[y]  =  init[y] ⊕ ⊕_z x[z] ⊗ E[z, y]

with ``E`` a binary :class:`~repro.sparse.coo.SparseRelation`.  Two
execution modes share GSN semantics with
:func:`repro.core.fixpoint.seminaive_fixpoint` (identical per-iteration
states, so the runners are interchangeable mid-stream):

* ``mode="jit"`` — a single ``jax.lax.while_loop``; Δ is a length-n
  vector whose re-derivation costs O(nnz(E)) per iteration via
  :func:`repro.sparse.contract.vspm` (vs. the dense engine's O(n²)).
  Staged, pjit-shardable, TPU-ready.
* ``mode="frontier"`` — host worklist evaluation (Fan et al.; FlowLog):
  Δ is a **sparse worklist of changed tuples**.  Each round expands only
  the CSR adjacency rows of frontier vertices, so total work over the
  whole fixpoint is O(Σ_rounds Σ_{z ∈ frontier} deg(z)) ≤ O(nnz · depth),
  and per-round work is proportional to the frontier, not the graph.

``mode="auto"`` picks "frontier" on CPU hosts and "jit" on accelerators;
program-level routing between these and the dense runners is the
cost-based planner's job (:mod:`repro.core.planner`, DESIGN.md §4).

**Batched multi-source serving (DESIGN.md §3):** ``init`` may be a
``(B, n)`` frontier matrix — one row per source.  ``mode="jit"`` then
advances all B sources in a single ``lax.while_loop`` whose per-iteration
step is one SpMM (`repro.sparse.contract.spmm`) instead of B SpMVs, with
a per-row convergence mask so each source's iteration count matches its
single-source run exactly; the carry is kept in the (n, B) layout so
gathers/scatters move contiguous B-wide rows and the batch axis can be
sharded across devices (``query_batch`` logical axis).  ``iters`` comes
back as a ``(B,)`` per-source vector.  Rows whose init is all-0̄ are
inert — the serve loop uses them as batch padding.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse import contract
from repro.sparse.coo import SparseRelation


@dataclasses.dataclass
class FrontierStats:
    """Per-round worklist sizes and expanded-edge counts (frontier mode)."""

    frontier_sizes: list[int]
    edges_expanded: list[int]

    @property
    def total_edges(self) -> int:
        return int(sum(self.edges_expanded))


def sparse_seminaive_fixpoint(edges: SparseRelation, init, *,
                              max_iters: int = 10_000,
                              mode: str = "auto"):
    """Least fixpoint of ``x = init ⊕ vspm(x, edges)``.

    Returns ``(x*, iters)`` like the dense runners; frontier mode
    additionally attaches a :class:`FrontierStats` as ``iters_stats`` on
    the returned stats tuple — use :func:`sparse_seminaive_fixpoint_stats`
    for the instrumented variant.

    A 2-D ``(B, n)`` init runs the batched multi-source path (module
    docstring): the result is ``(B, n)`` and ``iters`` is a ``(B,)``
    per-source iteration-count vector.
    """
    y, iters, _ = _dispatch(edges, init, max_iters=max_iters, mode=mode)
    return y, iters


def sparse_seminaive_fixpoint_stats(edges: SparseRelation, init, *,
                                    max_iters: int = 10_000,
                                    mode: str = "frontier"):
    """Instrumented variant: returns ``(x*, iters, FrontierStats|None)``.

    Batched frontier runs return a list of per-source FrontierStats.
    """
    return _dispatch(edges, init, max_iters=max_iters, mode=mode)


def _dispatch(edges, init, *, max_iters, mode):
    if edges.arity != 2 or edges.shape[0] != edges.shape[1]:
        raise ValueError(f"recursive expansion needs a square binary edge "
                         f"relation, got shape {edges.shape}")
    sr = sr_mod.get(edges.semiring)
    if sr.minus is None:
        raise ValueError(f"semiring {sr.name} lacks ⊖; "
                         "GSN needs an idempotent complete lattice")
    if mode == "auto":
        mode = "frontier" if jax.default_backend() == "cpu" else "jit"
    batched = np.ndim(init) == 2
    if mode == "jit":
        if batched:
            y, iters = _batched_jit_fixpoint(edges.as_jnp(),
                                             jnp.asarray(init), sr,
                                             max_iters)
        else:
            y, iters = _jit_fixpoint(edges.as_jnp(), jnp.asarray(init),
                                     sr, max_iters)
        return y, iters, None
    if mode == "frontier":
        if batched:
            return _batched_frontier_fixpoint(edges, init, max_iters)
        return _frontier_fixpoint(edges, init, max_iters)
    raise ValueError(f"unknown mode {mode!r}")


# --------------------------------------------------------------------------
# Staged path: lax.while_loop, Δ re-derived in O(nnz) by vspm
# --------------------------------------------------------------------------


def _jit_fixpoint(edges: SparseRelation, init, sr, max_iters: int):
    x0 = jnp.full_like(init, sr.zero)
    d0 = sr.minus(sr.add(init, contract.vspm(x0, edges)), x0)

    def cond(carry):
        y, d, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    def body(carry):
        y, d, _, it = carry
        y_new = sr.add(y, d)
        d_new = sr.minus(contract.vspm(d, edges), y_new)
        return y_new, d_new, jnp.any(d_new != sr.zero), it + 1

    y, _, _, iters = jax.lax.while_loop(
        cond, body, (x0, d0, jnp.asarray(True), jnp.asarray(0)))
    return y, iters


def _batched_jit_fixpoint(edges: SparseRelation, init, sr, max_iters: int):
    """All B sources in one ``lax.while_loop``: SpMM frontier advance,
    per-row convergence masks, per-row iteration counts.

    The carry lives in the (n, B) layout so every gather/scatter moves a
    contiguous B-wide row per edge (contract.spmm); the batch axis is
    annotated with the ``query_batch`` logical axis so an active mesh
    shards it across devices (no-op otherwise).
    """
    from repro.distributed import sharding as sh

    b = init.shape[0]
    x0 = jnp.full(init.shape[::-1], sr.zero, sr.dtype)        # (n, B)
    i_nb = sh.constrain(jnp.asarray(init).T, ("vertex", "query_batch"))
    d0 = sr.minus(sr.add(i_nb, contract.spmm(edges, x0, transpose=True)),
                  x0)
    live0 = jnp.ones((b,), bool)

    def cond(carry):
        y, d, live, it_rows, it = carry
        return jnp.logical_and(jnp.any(live), it < max_iters)

    def body(carry):
        y, d, live, it_rows, it = carry
        y_new = sh.constrain(sr.add(y, d), ("vertex", "query_batch"))
        d_new = sr.minus(contract.spmm(edges, d, transpose=True), y_new)
        d_new = sh.constrain(d_new, ("vertex", "query_batch"))
        # a source's row of Δ going all-0̄ is its convergence: from then on
        # the row re-derives 0̄ forever (δF(0̄) ⊖ Y = 0̄), so masking is
        # only needed for the per-row iteration counts, not the values.
        live_new = jnp.any(d_new != sr.zero, axis=0)
        return y_new, d_new, live_new, it_rows + live, it + 1

    y, _, _, it_rows, _ = jax.lax.while_loop(
        cond, body, (x0, d0, live0, jnp.zeros((b,), jnp.int32),
                     jnp.asarray(0)))
    return y.T, it_rows


# --------------------------------------------------------------------------
# Host path: true sparse worklist over a CSR view of the edges
# --------------------------------------------------------------------------


def _batched_frontier_fixpoint(edges, init, max_iters):
    """Host worklist mode for a (B, n) init: one worklist per source.

    The frontier representation is inherently per-source (each row has
    its own changed-tuple set), so batching is a host loop; the batched
    hot path is ``mode="jit"``.  Returns stacked results, a (B,) iters
    vector, and the per-source FrontierStats list.
    """
    ys, iters, stats = [], [], []
    for row in np.asarray(init):
        y, it, st = _frontier_fixpoint(edges, row, max_iters)
        ys.append(y)
        iters.append(it)
        stats.append(st)
    return jnp.stack(ys), np.asarray(iters, np.int32), stats


def _frontier_fixpoint(edges: SparseRelation, init, max_iters: int):
    sr = sr_mod.get(edges.semiring, lib="np")
    eh = edges.as_np()
    k = int(eh.nnz)
    src = eh.coords[:k, 0].astype(np.int64)
    dst = eh.coords[:k, 1].astype(np.int64)
    w = eh.values[:k]
    n_src, n_out = edges.shape
    # CSR by source vertex
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    counts = np.bincount(src, minlength=n_src)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])

    zero = np.asarray(sr.zero, sr.dtype)
    x0 = np.full(n_out, sr.zero, sr.dtype)
    y = x0.copy()
    d = sr.minus(np.asarray(init, sr.dtype), x0)  # δ of the constant term

    stats = FrontierStats([], [])
    iters = 0
    live = d != zero if sr.name != "bool" else d
    while bool(live.any()) and iters < max_iters:
        frontier = np.flatnonzero(live)
        dvals = d[frontier]
        y = sr.add(y, d)                       # Y ← Y ⊕ Δ
        # δF(Δ): expand only the frontier's adjacency rows
        deg = counts[frontier]
        rep = np.repeat(np.arange(len(frontier)), deg)
        if len(rep):
            run_off = np.arange(len(rep)) - np.repeat(
                np.concatenate([[0], np.cumsum(deg)[:-1]]), deg)
            esel = starts[frontier[rep]] + run_off
            cand_dst = dst[esel]
            cand_val = sr.mul(dvals[rep], w[esel])
            derived = np.full(n_out, sr.zero, sr.dtype)
            _combine_at(sr.name, derived, cand_dst, cand_val)
        else:
            derived = np.full(n_out, sr.zero, sr.dtype)
        d = sr.minus(derived, y)               # Δ ← δF(Δ) ⊖ (Y ⊕ Δ)
        stats.frontier_sizes.append(int(len(frontier)))
        stats.edges_expanded.append(int(len(rep)))
        live = d != zero if sr.name != "bool" else d
        iters += 1
    return jnp.asarray(y), iters, stats


def _combine_at(sr_name: str, out: np.ndarray, idx, vals) -> None:
    sr_mod.NP_COMBINE[sr_name].at(out, idx, vals)
