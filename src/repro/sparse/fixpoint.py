"""Frontier-based semi-naive fixpoint over sparse S-relations.

Solves the linear vector equation (the paper's GH-form after the FGH
rewrite of BM/CC/SSSP/MLM-style programs, Sec. 3.1):

    x[y]  =  init[y] ⊕ ⊕_z x[z] ⊗ E[z, y]

with ``E`` a binary :class:`~repro.sparse.coo.SparseRelation`.  Two
execution modes share GSN semantics with
:func:`repro.core.fixpoint.seminaive_fixpoint` (identical per-iteration
states, so the runners are interchangeable mid-stream):

* ``mode="jit"`` — a single ``jax.lax.while_loop``; Δ is a length-n
  vector whose re-derivation costs O(nnz(E)) per iteration via
  :func:`repro.sparse.contract.vspm` (vs. the dense engine's O(n²)).
  Staged, pjit-shardable, TPU-ready.
* ``mode="frontier"`` — host worklist evaluation (Fan et al.; FlowLog):
  Δ is a **sparse worklist of changed tuples**.  Each round expands only
  the CSR adjacency rows of frontier vertices, so total work over the
  whole fixpoint is O(Σ_rounds Σ_{z ∈ frontier} deg(z)) ≤ O(nnz · depth),
  and per-round work is proportional to the frontier, not the graph.

``mode="auto"`` picks "frontier" on CPU hosts and "jit" on accelerators;
program-level routing between these and the dense runners is the
cost-based planner's job (:mod:`repro.core.planner`, DESIGN.md §4).

**Batched multi-source serving (DESIGN.md §3):** ``init`` may be a
``(B, n)`` frontier matrix — one row per source.  ``mode="jit"`` then
advances all B sources in a single ``lax.while_loop`` whose per-iteration
step is one SpMM (`repro.sparse.contract.spmm`) instead of B SpMVs, with
a per-row convergence mask so each source's iteration count matches its
single-source run exactly; the carry is kept in the (n, B) layout so
gathers/scatters move contiguous B-wide rows and the batch axis can be
sharded across devices (``query_batch`` logical axis).  ``iters`` comes
back as a ``(B,)`` per-source vector.  Rows whose init is all-0̄ are
inert — the serve loop uses them as batch padding.
"""

from __future__ import annotations

import dataclasses
import warnings
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse import contract
from repro.sparse.coo import SparseRelation


@dataclasses.dataclass
class FrontierStats:
    """Frontier observations from one fixpoint run or one bounded chunk.

    Frontier mode fills the per-round lists (worklist sizes and expanded
    edge counts).  Chunked execution (:func:`fixpoint` with ``budget=``,
    the adaptive executor, the serve steppers) instead reports the
    *carry* observed at the chunk boundary: ``nnz`` live Δ entries,
    their ``density`` over the ``(B, n)`` carry, at global iteration
    ``iteration`` — the re-planning signal of DESIGN.md §10.
    """

    frontier_sizes: list[int]
    edges_expanded: list[int]
    nnz: int = 0
    density: float = 0.0
    iteration: int = 0

    @property
    def total_edges(self) -> int:
        return int(sum(self.edges_expanded))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FixpointState:
    """The resumable carry of a GSN fixpoint — what every runner consumes
    and produces (DESIGN.md §10).

    Invariant (the warm-restart contract of :func:`resume_fixpoint`):
    ``y`` is a pre-fixpoint (``y ≤ F(y)``) and ``delta = F(y) ⊖ y`` its
    pending frontier, so any runner sharing the GSN round body can pick
    the pair up mid-stream and converge to the identical answer.  The
    arrays live in the canonical batched ``(B, n)`` layout (``B = 1``
    for a single source — ``batched`` remembers whether the caller's
    init had a batch axis); ``iters`` is the per-row ``(B,)`` iteration
    counter carried across chunks.  Registered as a jax pytree so
    compiled chunk bodies can take it apart for free; the observation
    helpers (``frontier_nnz``/``density``/``converged``) pull the Δ to
    host, so call them at chunk boundaries, not inside traced code.
    """

    y: object
    delta: object
    iters: object
    semiring: str = "bool"
    batched: bool = True

    def tree_flatten(self):
        return (self.y, self.delta, self.iters), (self.semiring,
                                                  self.batched)

    @classmethod
    def tree_unflatten(cls, aux, children):
        y, delta, iters = children
        return cls(y, delta, iters, *aux)

    @classmethod
    def cold(cls, edges: SparseRelation, init) -> "FixpointState":
        """Seed a cold start: ``y = 0̄``, ``delta = init ⊖ 0̄`` — exactly
        the first carry of the staged runners (``0̄ ⊗ E = 0̄``, so the
        cold Δ is just the init's live entries)."""
        srn = sr_mod.get(edges.semiring, lib="np")
        i2 = np.asarray(init, srn.dtype)
        batched = i2.ndim == 2
        if not batched:
            i2 = i2[None]
        y0 = np.full(i2.shape, srn.zero, srn.dtype)
        d0 = srn.minus(i2, y0)
        return cls(y0, d0, np.zeros(i2.shape[0], np.int32),
                   edges.semiring, batched)

    @property
    def batch(self) -> int:
        return int(np.shape(self.y)[0])

    @property
    def n(self) -> int:
        return int(np.shape(self.y)[1])

    def frontier_nnz(self) -> int:
        """Live (non-0̄) Δ entries across all rows (host reduction)."""
        zero = sr_mod.get(self.semiring, lib="np").zero
        return int((np.asarray(self.delta) != zero).sum())

    def density(self) -> float:
        return self.frontier_nnz() / max(1, self.batch * self.n)

    def live_rows(self) -> int:
        zero = sr_mod.get(self.semiring, lib="np").zero
        return int((np.asarray(self.delta) != zero).any(axis=1).sum())

    @property
    def converged(self) -> bool:
        return self.frontier_nnz() == 0

    def stats(self) -> FrontierStats:
        """Chunk-boundary observation: the re-planning signal."""
        nnz = self.frontier_nnz()
        return FrontierStats([], [], nnz=nnz,
                             density=nnz / max(1, self.batch * self.n),
                             iteration=int(np.max(np.asarray(self.iters),
                                                  initial=0)))

    def solution(self):
        """``(x*, iters)`` in the caller's original shape — drops the
        synthetic batch axis when the seeding init was 1-D."""
        if self.batched:
            return self.y, np.asarray(self.iters, np.int32)
        return (jnp.asarray(self.y)[0] if not isinstance(self.y, np.ndarray)
                else self.y[0]), int(np.asarray(self.iters)[0])


def fixpoint(edges: SparseRelation, init=None, *, state=None,
             budget=None, max_iters: int = 10_000, mode: str = "auto",
             backend: str = "jnp"):
    """Least fixpoint of ``x = init ⊕ vspm(x, edges)`` — the one sparse
    entrypoint (cold, warm, and chunked; DESIGN.md §10).

    Pass exactly one of ``init`` (cold start) or ``state`` (a
    :class:`FixpointState` carry to resume).  With ``budget=None`` the
    run converges and returns ``(x*, iters)`` — a 2-D ``(B, n)`` init
    runs the batched multi-source path (module docstring) with a
    ``(B,)`` iters vector, and a resumed run's iters *include* the
    rounds already in the carry.  With ``budget=k`` the loop advances
    **at most k rounds** and returns the updated :class:`FixpointState`
    instead — chain calls to interleave work, observe the frontier, or
    hand the carry to a different runner (the adaptive executor's unit,
    :mod:`repro.core.runners`).

    ``mode`` is ``"auto"`` (frontier worklist on CPU hosts, staged jit
    on accelerators; budgeted calls default to the staged chunk body),
    ``"jit"`` or ``"frontier"``.  ``backend`` selects the SpMM execution
    of the staged loop (DESIGN.md §9): ``"jnp"`` is the traceable
    gather/scatter composition, ``"pallas"`` the fused TPU kernel
    (per-operator compiled closures), ``"fused"`` the host-numpy fused
    loop (bit-packed 𝔹 lanes on CPU).  The non-jnp backends need a
    concrete ``edges``.
    """
    if (init is None) == (state is None):
        raise ValueError("fixpoint() takes exactly one of init= or state=")
    if budget is None:
        if state is None:
            y, iters, _ = _dispatch(edges, init, max_iters=max_iters,
                                    mode=mode, backend=backend)
            return y, iters
        y, iters, _ = _dispatch(edges, None, max_iters=max_iters,
                                mode=mode, backend=backend,
                                warm=(state.y, state.delta))
        iters = np.asarray(state.iters, np.int32) \
            + np.asarray(iters, np.int32)
        if not state.batched:
            return jnp.asarray(y)[0], int(iters[0])
        return y, iters
    st = state if state is not None else FixpointState.cold(edges, init)
    budget = int(min(budget, max_iters))
    if mode == "frontier":
        y, d, it = _frontier_chunk(edges, st.y, st.delta, st.iters, budget)
    else:
        # the staged chunk body is the carry-exact unit shared with the
        # serve loop; "auto" means it here — a budgeted frontier pass
        # must be asked for explicitly
        y, d, it = _resume_chunk(edges, st.y, st.delta, st.iters,
                                 max_iters=budget, backend=backend)
    return FixpointState(y, d, it, st.semiring, st.batched)


def sparse_seminaive_fixpoint(edges: SparseRelation, init, *,
                              max_iters: int = 10_000,
                              mode: str = "auto",
                              backend: str = "jnp"):
    """Deprecated alias of :func:`fixpoint` (cold start)."""
    warnings.warn("sparse_seminaive_fixpoint is deprecated; use "
                  "fixpoint(edges, init, ...)", DeprecationWarning,
                  stacklevel=2)
    y, iters, _ = _dispatch(edges, init, max_iters=max_iters, mode=mode,
                            backend=backend)
    return y, iters


def sparse_seminaive_fixpoint_stats(edges: SparseRelation, init, *,
                                    max_iters: int = 10_000,
                                    mode: str = "frontier"):
    """Instrumented variant: returns ``(x*, iters, FrontierStats|None)``.

    Batched frontier runs return a list of per-source FrontierStats.
    """
    return _dispatch(edges, init, max_iters=max_iters, mode=mode)


def resume_fixpoint(edges: SparseRelation, y0, d0, *,
                    max_iters: int = 10_000, mode: str = "auto"):
    """Re-converge ``x = init ⊕ x ⊗ E`` from a warm ``(y0, d0)`` pair.

    The GSN loop body is *identical* to :func:`sparse_seminaive_fixpoint`
    — only the carry's starting point differs: ``y0`` is a known
    pre-fixpoint (``y0 ≤ F(y0)``) and ``d0 = F(y0) ⊖ y0`` its pending
    delta.  Delta-restart maintenance (:mod:`repro.incremental`,
    DESIGN.md §5) seeds ``d0`` from only the touched edges, so the
    re-convergence explores just the affected region instead of the whole
    key space.  ``y0`` may be ``(B, n)`` for a batched repair (one SpMM
    per round, per-row convergence).

    Returns ``(x*, iters)``; ``iters`` counts only the *resumed* rounds.

    Deprecated: build a :class:`FixpointState` and call
    ``fixpoint(edges, state=state)`` (whose iters *include* the carry's).
    """
    warnings.warn("resume_fixpoint is deprecated; use fixpoint(edges, "
                  "state=FixpointState(y0, d0, ...))", DeprecationWarning,
                  stacklevel=2)
    return _dispatch(edges, None, max_iters=max_iters, mode=mode,
                     warm=(y0, d0))[:2]


def resume_fixpoint_chunk(edges: SparseRelation, y0, d0, it0, *,
                          max_iters: int, backend: str = "jnp"):
    """One bounded slice of the batched GSN loop, carry in and carry out.

    Advances the ``(B, n)`` pair ``(y0, d0)`` by **at most** ``max_iters``
    rounds of the exact :func:`_batched_jit_fixpoint` body (one SpMM per
    round, per-row convergence masks) and returns the full carry
    ``(y, d, it_rows)`` instead of just the solution — so a caller can
    chain chunks: splice new init columns into freed rows between calls,
    extract converged rows early, and never pay for a full re-convergence.
    This is the continuous-batching serve loop's compiled unit
    (:mod:`repro.serve.slots`, DESIGN.md §7); jit it with ``max_iters``
    closed over so the chunk length is static.

    ``it0`` is the ``(B,)`` per-row iteration counter carried across
    chunks; rows whose Δ-row is all-0̄ are converged (or inert padding)
    and their counters stop.  Identical chaining invariant to
    :func:`resume_fixpoint`: ``y0`` is a pre-fixpoint and
    ``d0 = F(y0) ⊖ y0`` its pending delta, which the chunk preserves.

    ``backend`` as in :func:`fixpoint`; the non-jnp chunks memoize their
    compiled/host closures on the operator's cached SpMM plan, so
    callers need not (and must not) wrap them in ``jit``.

    Deprecated: use ``fixpoint(edges, state=state, budget=k)``.
    """
    warnings.warn("resume_fixpoint_chunk is deprecated; use "
                  "fixpoint(edges, state=state, budget=max_iters)",
                  DeprecationWarning, stacklevel=2)
    return _resume_chunk(edges, y0, d0, it0, max_iters=max_iters,
                         backend=backend)


def _resume_chunk(edges: SparseRelation, y0, d0, it0, *,
                  max_iters: int, backend: str = "jnp"):
    """The chunk body behind :func:`fixpoint`'s ``budget=`` path and the
    (deprecated) :func:`resume_fixpoint_chunk` shim."""
    if edges.arity != 2 or edges.shape[0] != edges.shape[1]:
        raise ValueError(f"recursive expansion needs a square binary edge "
                         f"relation, got shape {edges.shape}")
    sr = sr_mod.get(edges.semiring)
    if sr.minus is None:
        raise ValueError(f"semiring {sr.name} lacks ⊖; "
                         "GSN needs an idempotent complete lattice")
    if backend != "jnp":
        return _fused_resume_chunk(edges, y0, d0, it0, max_iters, backend)
    return _chunk_loop(edges.as_jnp(), y0, d0, it0, sr, max_iters)


def _dispatch(edges, init, *, max_iters, mode, warm=None, backend="jnp"):
    if edges.arity != 2 or edges.shape[0] != edges.shape[1]:
        raise ValueError(f"recursive expansion needs a square binary edge "
                         f"relation, got shape {edges.shape}")
    sr = sr_mod.get(edges.semiring)
    if sr.minus is None:
        raise ValueError(f"semiring {sr.name} lacks ⊖; "
                         "GSN needs an idempotent complete lattice")
    if backend == "fused":
        return _fused_host_fixpoint(edges, init, max_iters, warm=warm)
    if backend == "pallas":
        return _pallas_fixpoint(edges, init, sr, max_iters, warm=warm)
    if backend != "jnp":
        raise ValueError(f"unknown fixpoint backend {backend!r}")
    if mode == "auto":
        mode = "frontier" if jax.default_backend() == "cpu" else "jit"
    batched = np.ndim(init if warm is None else warm[0]) == 2
    if mode == "jit":
        jw = None if warm is None else (jnp.asarray(warm[0]),
                                        jnp.asarray(warm[1]))
        if batched:
            y, iters = _batched_jit_fixpoint(
                edges.as_jnp(),
                None if init is None else jnp.asarray(init), sr,
                max_iters, warm=jw)
        else:
            y, iters = _jit_fixpoint(
                edges.as_jnp(),
                None if init is None else jnp.asarray(init), sr,
                max_iters, warm=jw)
        return y, iters, None
    if mode == "frontier":
        if batched:
            return _batched_frontier_fixpoint(edges, init, max_iters,
                                              warm=warm)
        y, _, iters, stats = _frontier_fixpoint(edges, init, max_iters,
                                                warm=warm)
        return y, iters, stats
    raise ValueError(f"unknown mode {mode!r}")


# --------------------------------------------------------------------------
# Staged path: lax.while_loop, Δ re-derived in O(nnz) by vspm
# --------------------------------------------------------------------------


def _jit_fixpoint(edges: SparseRelation, init, sr, max_iters: int, *,
                  warm=None, advance=None):
    adv = advance or (lambda d: contract.vspm(d, edges))
    if warm is None:
        x0 = jnp.full_like(init, sr.zero)
        d0 = sr.minus(sr.add(init, adv(x0)), x0)
    else:
        x0, d0 = warm

    live0 = jnp.asarray(True) if warm is None else jnp.any(d0 != sr.zero)

    def cond(carry):
        y, d, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    def body(carry):
        y, d, _, it = carry
        y_new = sr.add(y, d)
        d_new = sr.minus(adv(d), y_new)
        return y_new, d_new, jnp.any(d_new != sr.zero), it + 1

    y, _, _, iters = jax.lax.while_loop(
        cond, body, (x0, d0, live0, jnp.asarray(0)))
    return y, iters


def _batched_jit_fixpoint(edges: SparseRelation, init, sr, max_iters: int,
                          *, warm=None, advance=None):
    """All B sources in one ``lax.while_loop``: SpMM frontier advance,
    per-row convergence masks, per-row iteration counts.

    The carry lives in the (n, B) layout so every gather/scatter moves a
    contiguous B-wide row per edge (contract.spmm); the batch axis is
    annotated with the ``query_batch`` logical axis so an active mesh
    shards it across devices (no-op otherwise).  ``warm`` is an optional
    ``(y0, d0)`` pair of (B, n) arrays for delta-restart repair.
    ``advance`` overrides the (n, B) → (n, B) frontier-advance SpMM —
    the fused-kernel backends inject their closure here.
    """
    from repro.distributed import sharding as sh

    adv = advance or (lambda d: contract.spmm(edges, d, transpose=True))
    if warm is None:
        b = init.shape[0]
        x0 = jnp.full(init.shape[::-1], sr.zero, sr.dtype)    # (n, B)
        i_nb = sh.constrain(jnp.asarray(init).T,
                            ("vertex", "query_batch"))
        d0 = sr.minus(sr.add(i_nb, adv(x0)), x0)
    else:
        b = warm[0].shape[0]
        x0 = sh.constrain(warm[0].T, ("vertex", "query_batch"))
        d0 = sh.constrain(warm[1].T, ("vertex", "query_batch"))
    live0 = (jnp.ones((b,), bool) if warm is None
             else jnp.any(d0 != sr.zero, axis=0))

    def cond(carry):
        y, d, live, it_rows, it = carry
        return jnp.logical_and(jnp.any(live), it < max_iters)

    def body(carry):
        y, d, live, it_rows, it = carry
        y_new = sh.constrain(sr.add(y, d), ("vertex", "query_batch"))
        d_new = sr.minus(adv(d), y_new)
        d_new = sh.constrain(d_new, ("vertex", "query_batch"))
        # a source's row of Δ going all-0̄ is its convergence: from then on
        # the row re-derives 0̄ forever (δF(0̄) ⊖ Y = 0̄), so masking is
        # only needed for the per-row iteration counts, not the values.
        live_new = jnp.any(d_new != sr.zero, axis=0)
        return y_new, d_new, live_new, it_rows + live, it + 1

    y, _, _, it_rows, _ = jax.lax.while_loop(
        cond, body, (x0, d0, live0, jnp.zeros((b,), jnp.int32),
                     jnp.asarray(0)))
    return y.T, it_rows


# --------------------------------------------------------------------------
# Fused-kernel backends: same GSN loop, SpMM via kernels/coo_spmm
# --------------------------------------------------------------------------


def _pallas_fixpoint(edges, init, sr, max_iters, *, warm=None):
    """The jit GSN loop with the fused Pallas SpMM as frontier advance.

    The operator's edge-tile geometry is host-planned, so the whole
    while-loop is compiled *per operator*: a jitted closure over the
    concrete edges, memoized on the cached :class:`SpmmPlan` — repeat
    calls (the serving loop) re-enter compiled code directly.
    """
    from repro.kernels import coo_spmm, ops as kops

    interp = kops._FORCE_INTERPRET or jax.default_backend() != "tpu"
    plan = coo_spmm.plan_geometry(edges, transpose=True)
    batched = np.ndim(init if warm is None else warm[0]) == 2
    key = ("fixpoint", batched, warm is None, max_iters, interp)
    fn = plan.jit_cache.get(key)
    if fn is None:
        ej = edges.as_jnp()

        def adv(d):
            return coo_spmm.spmm_pallas(plan, d, interpret=interp)

        inner = _batched_jit_fixpoint if batched else _jit_fixpoint
        if warm is None:
            fn = jax.jit(lambda i: inner(ej, i, sr, max_iters, advance=adv))
        else:
            fn = jax.jit(lambda y0, d0: inner(ej, None, sr, max_iters,
                                              warm=(y0, d0), advance=adv))
        plan.jit_cache[key] = fn
    if warm is None:
        y, iters = fn(jnp.asarray(init))
    else:
        y, iters = fn(jnp.asarray(warm[0]), jnp.asarray(warm[1]))
    return y, iters, None


def _fused_host_fixpoint(edges, init, max_iters, *, warm=None):
    """Host-numpy fused GSN loop — the CPU serving backend (DESIGN.md §9).

    For 𝔹 the whole carry lives bit-packed: ``y``/``Δ`` are (n, W)
    uint64 words and one round is a single ``bitwise_or.reduceat`` sweep
    (:func:`coo_spmm.bool_round_packed`) plus word-wise ``y |= Δ``,
    ``Δ &= ~y`` — ~64× fewer bytes per iteration than the (n, B) boolean
    gather/scatter.  Other lattices run :func:`coo_spmm.spmm_host`.
    Round structure, convergence masks, and per-row iteration counts
    mirror :func:`_batched_jit_fixpoint` exactly.
    """
    from repro.kernels import coo_spmm

    srn = sr_mod.get(edges.semiring, lib="np")
    plan = coo_spmm.plan_geometry(edges, transpose=True)
    batched = np.ndim(init if warm is None else warm[0]) == 2
    if warm is None:
        i2 = np.asarray(init)
        i2 = i2 if batched else i2[None]
        b = i2.shape[0]
        y0 = np.full((plan.n_in, b), srn.zero, srn.dtype)      # (n, B)
        d0 = srn.minus(srn.add(i2.T.astype(srn.dtype),
                               coo_spmm.spmm_host(plan, y0)), y0)
        live = np.ones(b, bool)
    else:
        y0w, d0w = np.asarray(warm[0]), np.asarray(warm[1])
        if not batched:
            y0w, d0w = y0w[None], d0w[None]
        b = y0w.shape[0]
        y0 = np.ascontiguousarray(y0w.T.astype(srn.dtype))
        d0 = np.ascontiguousarray(d0w.T.astype(srn.dtype))
        live = (d0 != srn.zero).any(axis=0)
    it_rows = np.zeros(b, np.int32)
    it = 0
    if edges.semiring == "bool":
        yw = coo_spmm.pack_lanes(y0.T)
        dw = coo_spmm.pack_lanes(d0.T)
        while live.any() and it < max_iters:
            it_rows += live
            np.bitwise_or(yw, dw, out=yw)
            dw = coo_spmm.bool_round_packed(plan, dw) & ~yw
            live = _packed_live(dw, b)
            it += 1
        y = coo_spmm.unpack_lanes(yw, b)                       # (B, n)
    else:
        y, d = y0, d0
        while live.any() and it < max_iters:
            it_rows += live
            y = srn.add(y, d)
            d = srn.minus(coo_spmm.spmm_host(plan, d), y)
            live = (d != srn.zero).any(axis=0)
            it += 1
        y = y.T
    if batched:
        return jnp.asarray(y), jnp.asarray(it_rows), None
    return jnp.asarray(y[0]), int(it_rows[0]), None


def _packed_live(words: np.ndarray, b: int) -> np.ndarray:
    """Per-lane liveness of a packed (n, W) Δ: lane has any bit set."""
    agg = np.bitwise_or.reduce(words, axis=0)                  # (W,)
    return np.unpackbits(agg.view(np.uint8),
                         bitorder="little")[:b].astype(bool)


def _fused_resume_chunk(edges, y0, d0, it0, max_iters, backend):
    """The non-jnp body of :func:`resume_fixpoint_chunk`.

    ``"pallas"`` memoizes a per-operator jitted chunk on the cached SpMM
    plan; ``"fused"`` runs the bounded host loop (packed 𝔹 rounds).
    """
    from repro.kernels import coo_spmm, ops as kops

    sr = sr_mod.get(edges.semiring)
    plan = coo_spmm.plan_geometry(edges, transpose=True)
    if backend == "pallas":
        interp = kops._FORCE_INTERPRET or jax.default_backend() != "tpu"
        key = ("chunk", max_iters, interp)
        fn = plan.jit_cache.get(key)
        if fn is None:
            ej = edges.as_jnp()
            fn = jax.jit(lambda y, d, it: _chunk_loop(
                ej, y, d, it, sr, max_iters,
                advance=lambda dd: coo_spmm.spmm_pallas(
                    plan, dd, interpret=interp)))
            plan.jit_cache[key] = fn
        return fn(jnp.asarray(y0), jnp.asarray(d0), jnp.asarray(it0))
    if backend != "fused":
        raise ValueError(f"unknown fixpoint backend {backend!r}")
    srn = sr_mod.get(edges.semiring, lib="np")
    b = np.asarray(y0).shape[0]
    it_rows = np.asarray(it0, np.int32).copy()
    it = 0
    if edges.semiring == "bool":
        yw = coo_spmm.pack_lanes(np.asarray(y0))
        dw = coo_spmm.pack_lanes(np.asarray(d0))
        while it < max_iters and dw.any():
            it_rows += _packed_live(dw, b)
            np.bitwise_or(yw, dw, out=yw)
            dw = coo_spmm.bool_round_packed(plan, dw) & ~yw
            it += 1
        y, d = coo_spmm.unpack_lanes(yw, b), coo_spmm.unpack_lanes(dw, b)
    else:
        y = np.ascontiguousarray(np.asarray(y0).T.astype(srn.dtype))
        d = np.ascontiguousarray(np.asarray(d0).T.astype(srn.dtype))
        while it < max_iters and (d != srn.zero).any():
            it_rows += (d != srn.zero).any(axis=0)
            y = srn.add(y, d)
            d = srn.minus(coo_spmm.spmm_host(plan, d), y)
            it += 1
        y, d = y.T, d.T
    return jnp.asarray(y), jnp.asarray(d), jnp.asarray(it_rows)


def _chunk_loop(edges, y0, d0, it0, sr, max_iters, *, advance=None):
    """The traceable chunk body shared by the jnp and pallas chunks."""
    from repro.distributed import sharding as sh

    adv = advance or (lambda d: contract.spmm(edges, d, transpose=True))
    y = sh.constrain(jnp.asarray(y0).T, ("vertex", "query_batch"))
    d = sh.constrain(jnp.asarray(d0).T, ("vertex", "query_batch"))
    it_rows = jnp.asarray(it0, jnp.int32)

    def cond(carry):
        y, d, it_rows, it = carry
        return jnp.logical_and(jnp.any(d != sr.zero), it < max_iters)

    def body(carry):
        y, d, it_rows, it = carry
        live = jnp.any(d != sr.zero, axis=0)
        y_new = sh.constrain(sr.add(y, d), ("vertex", "query_batch"))
        d_new = sr.minus(adv(d), y_new)
        d_new = sh.constrain(d_new, ("vertex", "query_batch"))
        return y_new, d_new, it_rows + live, it + 1

    y, d, it_rows, _ = jax.lax.while_loop(
        cond, body, (y, d, it_rows, jnp.asarray(0)))
    return y.T, d.T, it_rows


# --------------------------------------------------------------------------
# Host path: true sparse worklist over a CSR view of the edges
# --------------------------------------------------------------------------
#
# The CSR adjacency is cached per coords buffer (weakref-evicted, like the
# planner's fingerprint tokens) and — the incremental-maintenance piece,
# DESIGN.md §5 — ``SparseRelation.apply_delta`` *extends* the parent's
# index with an O(nnz(Δ)) unsorted overlay instead of re-sorting, so under
# streaming updates the per-update index work is proportional to the
# delta.  Overlays are compacted into the sorted base once they exceed a
# quarter of it (the child is simply left unregistered, so its next
# frontier solve rebuilds — classic LSM-style amortization).


@dataclasses.dataclass
class _CsrIndex:
    """Sorted CSR base + unsorted appended overlay of one edge relation."""

    counts: np.ndarray   # (n,) out-degrees of the sorted base
    starts: np.ndarray   # (n,) row starts into src/dst/w
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    xsrc: np.ndarray     # overlay rows (appended by apply_delta)
    xdst: np.ndarray
    xw: np.ndarray


_CSR_CACHE: dict[tuple[int, int, bool],
                 tuple[object, object, _CsrIndex]] = {}
_EMPTY = np.zeros(0, np.int64)


def _csr_lookup(rel: SparseRelation, transpose: bool = False
                ) -> _CsrIndex | None:
    # keyed on BOTH buffers: transposes share values and semiring casts
    # share coords — either alone would alias distinct relations
    ent = _CSR_CACHE.get((id(rel.coords), id(rel.values), transpose))
    if ent is not None and ent[0]() is rel.coords \
            and ent[1]() is rel.values:
        return ent[2]
    return None


def _csr_store(rel: SparseRelation, idx: _CsrIndex,
               transpose: bool = False) -> None:
    key = (id(rel.coords), id(rel.values), transpose)

    def _evict(ref, k=key):
        cur = _CSR_CACHE.get(k)
        if cur is not None and ref in (cur[0], cur[1]):
            _CSR_CACHE.pop(k, None)

    try:
        _CSR_CACHE[key] = (weakref.ref(rel.coords, _evict),
                           weakref.ref(rel.values, _evict), idx)
    except TypeError:  # pragma: no cover — all our buffers are weakrefable
        pass


def csr_index(edges: SparseRelation, *,
              transpose: bool = False) -> _CsrIndex:
    """The (cached) host CSR adjacency of a binary sparse relation.

    ``transpose=True`` indexes **in**-edges: row ``a`` of the index lists
    the ``(z, E[z, a])`` pairs, which is what a maintenance recount
    ``d₀[a] = init[a] ⊕ ⊕_z y₀[z] ⊗ E[z, a]`` walks (DESIGN.md §11).
    Both orientations share the cache (separate key slots), so the
    transpose is built once per buffer identity, not per recount.
    """
    idx = _csr_lookup(edges, transpose)
    if idx is None:
        eh = edges.as_np()
        k = int(eh.nnz)
        a, b = (1, 0) if transpose else (0, 1)
        src = eh.coords[:k, a].astype(np.int64)
        dst = eh.coords[:k, b].astype(np.int64)
        w = eh.values[:k]
        order = np.argsort(src, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
        counts = np.bincount(src, minlength=edges.shape[a])
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        idx = _CsrIndex(counts, starts, src, dst, w,
                        _EMPTY, _EMPTY, w[:0])
        _csr_store(edges, idx, transpose)
    return idx


def register_delta(parent: SparseRelation, child: SparseRelation,
                   coords: np.ndarray, values: np.ndarray) -> None:
    """``child = parent ⊕ appended rows``: give the child the parent's
    cached CSR plus an O(nnz(Δ)) overlay (no-op when the parent was
    never indexed, or when the grown overlay warrants a compaction).
    Both orientations propagate when cached."""
    for transpose in (False, True):
        pidx = _csr_lookup(parent, transpose)
        if pidx is None:
            continue
        a, b = (1, 0) if transpose else (0, 1)
        xsrc = np.concatenate([pidx.xsrc, coords[:, a].astype(np.int64)])
        if len(xsrc) > max(1024, len(pidx.src) // 4):
            continue  # compaction point: child rebuilds a sorted base
        xdst = np.concatenate([pidx.xdst, coords[:, b].astype(np.int64)])
        xw = np.concatenate([pidx.xw, values])
        _csr_store(child,
                   _CsrIndex(pidx.counts, pidx.starts, pidx.src,
                             pidx.dst, pidx.w, xsrc, xdst, xw),
                   transpose)


def register_delete(parent: SparseRelation, child: SparseRelation,
                    coords: np.ndarray) -> None:
    """``child = parent ∖ deleted keys``: hand the child a copy of any
    cached CSR whose deleted entries have their weights set to 0̄.

    A 0̄ weight annihilates under ⊗ (``x ⊗ 0̄ = 0̄`` in every semiring
    here) and 0̄ is the ⊕-identity, so a poisoned entry contributes
    nothing to frontier expansion or recount scatters — the row stays in
    place and ``counts``/``starts`` are untouched, which is what makes a
    one-edge delete O(deg) instead of an O(nnz log nnz) re-sort
    (DESIGN.md §11).  Cost: O(nnz(Δ) · deg) probe into the sorted base
    plus an O(overlay) key scan.
    """
    coords = np.asarray(coords, np.int64).reshape(-1, 2)
    sr = sr_mod.get(parent.semiring, lib="np")
    zero = np.asarray(sr.zero, sr.dtype)
    for transpose in (False, True):
        pidx = _csr_lookup(parent, transpose)
        if pidx is None:
            continue
        a, b = (1, 0) if transpose else (0, 1)
        dsrc = coords[:, a]
        ddst = coords[:, b]
        w = pidx.w.copy()
        n_rows = len(pidx.counts)
        for s, t in zip(dsrc, ddst):
            if not (0 <= s < n_rows):
                continue
            lo = pidx.starts[s]
            hi = lo + pidx.counts[s]
            seg = pidx.dst[lo:hi]
            w[lo:hi] = np.where(seg == t, zero, w[lo:hi])
        xw = pidx.xw
        if len(pidx.xsrc):
            hit = np.zeros(len(pidx.xsrc), bool)
            for s, t in zip(dsrc, ddst):
                hit |= (pidx.xsrc == s) & (pidx.xdst == t)
            xw = np.where(hit, zero, pidx.xw)
        _csr_store(child,
                   _CsrIndex(pidx.counts, pidx.starts, pidx.src,
                             pidx.dst, w, pidx.xsrc, pidx.xdst, xw),
                   transpose)


def _batched_frontier_fixpoint(edges, init, max_iters, *, warm=None):
    """Host worklist mode for a (B, n) init: one worklist per source.

    The frontier representation is inherently per-source (each row has
    its own changed-tuple set), so batching is a host loop; the batched
    hot path is ``mode="jit"``.  Returns stacked results, a (B,) iters
    vector, and the per-source FrontierStats list.
    """
    ys, iters, stats = [], [], []
    rows = (np.asarray(init) if warm is None
            else zip(np.asarray(warm[0]), np.asarray(warm[1])))
    for row in rows:
        y, _, it, st = _frontier_fixpoint(
            edges, None if warm is not None else row, max_iters,
            warm=row if warm is not None else None)
        ys.append(y)
        iters.append(it)
        stats.append(st)
    return jnp.stack(ys), np.asarray(iters, np.int32), stats


def _frontier_chunk(edges, y0, d0, it0, budget: int):
    """Budgeted worklist rounds over a ``(B, n)`` carry — the frontier
    runner's ``run_chunk`` body.  One worklist per row (the frontier
    representation is inherently per-source); per-row iteration counting
    matches the staged chunk exactly (a row only counts rounds in which
    its Δ was live)."""
    y0 = np.asarray(y0)
    d0 = np.asarray(d0)
    it0 = np.asarray(it0, np.int32)
    ys, ds, its = [], [], []
    for j in range(y0.shape[0]):
        y, d, it, _ = _frontier_fixpoint(edges, None, budget,
                                         warm=(y0[j], d0[j]))
        ys.append(np.asarray(y))
        ds.append(np.asarray(d))
        its.append(int(it0[j]) + it)
    return np.stack(ys), np.stack(ds), np.asarray(its, np.int32)


def _frontier_fixpoint(edges: SparseRelation, init, max_iters: int, *,
                       warm=None):
    sr = sr_mod.get(edges.semiring, lib="np")
    idx = csr_index(edges)
    counts, starts = idx.counts, idx.starts
    dst, w = idx.dst, idx.w
    n_out = edges.shape[1]

    zero = np.asarray(sr.zero, sr.dtype)
    if warm is None:
        x0 = np.full(n_out, sr.zero, sr.dtype)
        y = x0.copy()
        d = sr.minus(np.asarray(init, sr.dtype), x0)  # δ of constant term
    else:
        y = np.asarray(warm[0], sr.dtype).copy()
        d = np.asarray(warm[1], sr.dtype)

    stats = FrontierStats([], [])
    iters = 0
    live = d != zero if sr.name != "bool" else d
    while bool(live.any()) and iters < max_iters:
        frontier = np.flatnonzero(live)
        dvals = d[frontier]
        y = sr.add(y, d)                       # Y ← Y ⊕ Δ
        # δF(Δ): expand only the frontier's adjacency rows
        deg = counts[frontier]
        rep = np.repeat(np.arange(len(frontier)), deg)
        derived = np.full(n_out, sr.zero, sr.dtype)
        if len(rep):
            run_off = np.arange(len(rep)) - np.repeat(
                np.concatenate([[0], np.cumsum(deg)[:-1]]), deg)
            esel = starts[frontier[rep]] + run_off
            cand_dst = dst[esel]
            cand_val = sr.mul(dvals[rep], w[esel])
            _combine_at(sr.name, derived, cand_dst, cand_val)
        expanded = len(rep)
        if len(idx.xsrc):
            # the unsorted apply_delta overlay: scan is O(nnz(Δ)) / round
            m = live[idx.xsrc]
            if m.any():
                _combine_at(sr.name, derived, idx.xdst[m],
                            sr.mul(d[idx.xsrc[m]], idx.xw[m]))
                expanded += int(m.sum())
        d = sr.minus(derived, y)               # Δ ← δF(Δ) ⊖ (Y ⊕ Δ)
        stats.frontier_sizes.append(int(len(frontier)))
        stats.edges_expanded.append(expanded)
        live = d != zero if sr.name != "bool" else d
        iters += 1
    # (y, d) at loop exit is an exact resumable carry: y is the updated
    # pre-fixpoint and d its still-pending delta — zero when converged
    return jnp.asarray(y), d, iters, stats


def _combine_at(sr_name: str, out: np.ndarray, idx, vals) -> None:
    sr_mod.NP_COMBINE[sr_name].at(out, idx, vals)
