"""COO semiring tensors with fixed-capacity padded buffers.

A :class:`SparseRelation` stores an S-relation (paper Sec. 2) as a
coordinate list instead of a dense array: ``coords[(cap, r)]`` holds the
keys of the non-0̄ tuples, ``values[(cap,)]`` their semiring values.  The
buffer capacity is **static** so the type is a jax pytree usable under
``jit``/``pjit``/``lax.while_loop``; the live-tuple count ``nnz`` is a
traced scalar.  Padding rows are self-neutralizing twice over:

* padded coordinates hold the out-of-range sentinel ``shape[axis]``, so
  every scatter with ``mode="drop"`` ignores them;
* padded values hold 0̄, so even a clipped gather contributes the ⊕
  identity.

Host-side constructors (``from_dense`` / ``from_coo``) run in numpy and
coalesce duplicate coordinates with ⊕; on-device consumers therefore never
need data-dependent compaction.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as sr_mod

Array = jnp.ndarray

#: per-semiring combining scatter for materialization (⊕ at duplicate keys)
_NP_COMBINE = sr_mod.NP_COMBINE


def _is_np(x) -> bool:
    return isinstance(x, np.ndarray)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseRelation:
    """A semiring S-relation in padded COO form.

    ``coords``/``values``/``nnz`` are array leaves (np or jnp); ``shape``
    and ``semiring`` are static aux data.
    """

    coords: Array  # (capacity, arity) int32
    values: Array  # (capacity,) semiring dtype
    nnz: Array     # () int32 — number of live (non-padding) rows
    shape: tuple[int, ...]
    semiring: str

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        return (self.coords, self.values, self.nnz), (self.shape,
                                                      self.semiring)

    @classmethod
    def tree_unflatten(cls, aux, children):
        coords, values, nnz = children
        shape, semiring = aux
        return cls(coords, values, nnz, shape, semiring)

    # -- basics ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.coords.shape[0])

    @property
    def arity(self) -> int:
        return int(self.coords.shape[1])

    @property
    def lib(self) -> str:
        return "np" if _is_np(self.values) else "jnp"

    def sr(self) -> sr_mod.Semiring:
        return sr_mod.get(self.semiring, lib=self.lib)

    def density(self) -> float:
        """Live fraction of the dense key space (host-side)."""
        total = float(np.prod(self.shape)) or 1.0
        return float(np.asarray(self.nnz)) / total

    def __repr__(self) -> str:
        return (f"SparseRelation({self.semiring}{list(self.shape)}, "
                f"nnz≤{self.capacity}, lib={self.lib})")

    # -- conversions -------------------------------------------------------
    def to_dense(self):
        """Materialize as a dense S-relation (⊕-combining duplicates)."""
        sr = self.sr()
        if self.lib == "np":
            out = np.full(self.shape, sr.zero, sr.dtype)
            k = int(self.nnz)
            idx = tuple(np.asarray(self.coords[:k]).T)
            _NP_COMBINE[self.semiring].at(out, idx, np.asarray(
                self.values[:k]))
            return out
        base = jnp.full(self.shape, sr.zero, sr.dtype)
        idx = tuple(self.coords.T)
        return sr_mod.scatter_op(self.semiring, base.at[idx])(
            self.values, mode="drop")

    def as_jnp(self) -> "SparseRelation":
        return SparseRelation(jnp.asarray(self.coords),
                              jnp.asarray(self.values),
                              jnp.asarray(self.nnz, jnp.int32),
                              self.shape, self.semiring)

    def as_np(self) -> "SparseRelation":
        return SparseRelation(np.asarray(self.coords),
                              np.asarray(self.values),
                              np.asarray(self.nnz, np.int32),
                              self.shape, self.semiring)

    def transpose(self, axes: tuple[int, ...] | None = None
                  ) -> "SparseRelation":
        axes = axes or tuple(reversed(range(self.arity)))
        xp = np if self.lib == "np" else jnp
        coords = xp.stack([self.coords[:, a] for a in axes], axis=1)
        shape = tuple(self.shape[a] for a in axes)
        return SparseRelation(coords, self.values, self.nnz, shape,
                              self.semiring)

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_coo(cls, coords, values, shape, semiring: str, *,
                 capacity: int | None = None,
                 lib: str = "jnp") -> "SparseRelation":
        """Build from host coordinate/value arrays (coalesces duplicates,
        drops explicit 0̄ entries, pads to ``capacity``)."""
        sr = sr_mod.get(semiring, lib="np")
        coords = np.asarray(coords, np.int64).reshape(-1, len(shape))
        values = np.asarray(values, sr.dtype).reshape(-1)
        assert len(coords) == len(values), (coords.shape, values.shape)
        # coalesce: ⊕-combine duplicate keys
        if len(coords):
            uniq, inv = np.unique(coords, axis=0, return_inverse=True)
            if len(uniq) != len(coords):
                merged = np.full(len(uniq), sr.zero, sr.dtype)
                _NP_COMBINE[semiring].at(merged, inv.reshape(-1), values)
                coords, values = uniq, merged
        # drop explicit zeros (0̄ tuples are absent by definition)
        if len(values):
            live = values != sr.zero if semiring != "bool" else values
            coords, values = coords[live], values[live]
        nnz = len(values)
        cap = capacity if capacity is not None else max(1, nnz)
        if nnz > cap:
            raise ValueError(f"nnz {nnz} exceeds capacity {cap}")
        pad = cap - nnz
        if pad:
            sentinel = np.tile(np.asarray(shape, np.int64), (pad, 1))
            coords = np.concatenate([coords, sentinel])
            values = np.concatenate(
                [values, np.full(pad, sr.zero, sr.dtype)])
        out = cls(coords.astype(np.int32), values,
                  np.asarray(nnz, np.int32), tuple(shape), semiring)
        return out if lib == "np" else out.as_jnp()

    @classmethod
    def from_dense(cls, arr, semiring: str, *,
                   capacity: int | None = None,
                   lib: str | None = None) -> "SparseRelation":
        lib = lib or ("np" if _is_np(arr) else "jnp")
        sr = sr_mod.get(semiring, lib="np")
        host = np.asarray(arr)
        coords = np.argwhere(host if semiring == "bool"
                             else host != sr.zero)
        values = host[tuple(coords.T)]
        return cls.from_coo(coords, values, host.shape, semiring,
                            capacity=capacity, lib=lib)

    # -- streaming updates -------------------------------------------------
    def apply_delta(self, coords, values=None) -> "SparseRelation":
        """⊕-merge a batch of tuple updates (host-side, O(nnz(Δ))).

        Appends the delta rows into the padding slots when they fit
        (capacity, and therefore every staged consumer's trace, is
        unchanged — the compile caches keep hitting); beyond capacity the
        buffers are re-padded at the next power-of-two capacity ≥ the new
        live count (amortized-O(1) doubling, one retrace per doubling).

        Appended duplicates of live keys are *not* coalesced: every
        consumer (``to_dense`` scatter, segment-reduce contraction) is
        ⊕-combining, and ⊗ distributes over ⊕, so an appended row is
        exactly the ⊕-merge ``E′ = E ⊕ Δ``.  For trop/minplus that makes
        a weight decrease a plain append; a weight *increase* cannot be
        expressed this way (⊕ = min absorbs it) — that is the
        non-monotone case callers must route to a rebuild.

        ``values=None`` fills 1̄ per tuple (bool edge insertions).
        """
        sr = sr_mod.get(self.semiring, lib="np")
        coords = np.asarray(coords, np.int64).reshape(-1, self.arity)
        if values is None:
            values = np.full(len(coords), sr.one, sr.dtype)
        values = np.asarray(values, sr.dtype).reshape(-1)
        assert len(coords) == len(values), (coords.shape, values.shape)
        if np.any(coords < 0) or np.any(coords >= np.asarray(self.shape)):
            raise ValueError("delta coordinates out of range for shape "
                             f"{self.shape}")
        # explicit 0̄ rows are ⊕-identities — drop them up front
        live = values if self.semiring == "bool" else values != sr.zero
        coords, values = coords[live], values[live]
        host = self.as_np()
        k, d = int(host.nnz), len(values)
        if d == 0:
            return self
        need = k + d
        if need <= self.capacity:
            new_coords = host.coords.copy()
            new_values = host.values.copy()
            new_coords[k:need] = coords
            new_values[k:need] = values
            out = SparseRelation(new_coords, new_values,
                                 np.asarray(need, np.int32), self.shape,
                                 self.semiring)
        else:
            # doubling re-pad: a plain prefix-preserving copy, *not* a
            # from_coo re-coalesce — appended duplicates are ⊕-merged by
            # every consumer, and an O(nnz log nnz) re-sort here would
            # make a one-edge update cost as much as a rebuild
            cap = max(1, self.capacity)
            while cap < need:
                cap <<= 1
            pad = cap - need
            sentinel = np.tile(np.asarray(self.shape, np.int64), (pad, 1))
            new_coords = np.concatenate(
                [host.coords[:k], coords, sentinel]).astype(np.int32)
            new_values = np.concatenate(
                [host.values[:k], values,
                 np.full(pad, sr.zero, sr.dtype)])
            out = SparseRelation(new_coords, new_values,
                                 np.asarray(need, np.int32), self.shape,
                                 self.semiring)
        out = out if self.lib == "np" else out.as_jnp()
        if self.arity == 2:
            # extend any cached host CSR adjacency with an O(nnz(Δ))
            # overlay so warm frontier solves never re-sort (DESIGN.md §5)
            from repro.sparse import fixpoint as fx
            fx.register_delta(self, out, coords, values)
        return out

    def _flat_keys(self, coords) -> np.ndarray:
        """Row-major flattened int64 key per coordinate tuple."""
        coords = np.asarray(coords, np.int64).reshape(-1, self.arity)
        return np.ravel_multi_index(tuple(coords.T), self.shape,
                                    mode="clip")

    def delete_keys(self, coords) -> "SparseRelation":
        """Remove the given keys entirely (host-side, O(nnz) vectorized
        mask + stable compaction at the same capacity — no re-sort, no
        re-coalesce).  Deletion is *not* a ⊕-merge — it is the
        non-monotone mutation; callers owning warm fixpoint state must
        repair it via a synthesized maintenance rule or recompute from
        scratch (see :mod:`repro.incremental.maintenance`, DESIGN.md §11).

        Every live copy of a deleted key is removed, including
        un-coalesced duplicates appended by :meth:`apply_delta`.
        """
        coords = np.asarray(coords, np.int64).reshape(-1, self.arity)
        host = self.as_np()
        k = int(host.nnz)
        if k == 0 or len(coords) == 0:
            return self
        gone = self._flat_keys(coords)
        keep = ~np.isin(self._flat_keys(host.coords[:k]), gone)
        kept = int(keep.sum())
        if kept == k:
            return self
        pad = self.capacity - kept
        sentinel = np.tile(np.asarray(self.shape, np.int64), (pad, 1))
        sr = sr_mod.get(self.semiring, lib="np")
        new_coords = np.concatenate(
            [host.coords[:k][keep], sentinel]).astype(np.int32)
        new_values = np.concatenate(
            [host.values[:k][keep], np.full(pad, sr.zero, sr.dtype)])
        out = SparseRelation(new_coords, new_values,
                             np.asarray(kept, np.int32), self.shape,
                             self.semiring)
        out = out if self.lib == "np" else out.as_jnp()
        if self.arity == 2:
            # hand the child a 0̄-poisoned copy of any cached CSR index so
            # warm frontier/maintenance solves never re-sort (DESIGN.md §11)
            from repro.sparse import fixpoint as fx
            fx.register_delete(self, out, coords)
        return out

    def union(self, other: "SparseRelation", *,
              capacity: int | None = None) -> "SparseRelation":
        """⊕-merge two sparse relations (host-side, coalescing)."""
        assert self.shape == other.shape and self.semiring == other.semiring
        a, b = self.as_np(), other.as_np()
        ka, kb = int(a.nnz), int(b.nnz)
        return SparseRelation.from_coo(
            np.concatenate([a.coords[:ka], b.coords[:kb]]),
            np.concatenate([a.values[:ka], b.values[:kb]]),
            self.shape, self.semiring, capacity=capacity, lib=self.lib)
