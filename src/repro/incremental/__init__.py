"""Incremental fixpoint maintenance (DESIGN.md §5 and §11).

Keeps fixpoint solutions warm across database mutations instead of
recomputing from ⊥ on every change:

* :class:`DeltaLog` — a typed log of streaming relation updates:
  ⊕-merge edge insertions (and monotone weight decreases for
  trop/minplus, where ⊕ = min absorbs them) plus the non-monotone
  mutations — explicit deletions and weight increases.
* :func:`delta_restart_fixpoint` — re-converge ``x = init ⊕ x ⊗ E′``
  from the previous solution ``y*``, seeding the GSN frontier with only
  the rows reachable from touched edges (``d₀ = (y* ⊗ ΔE) ⊖ y*``,
  O(nnz(Δ))); exactness is guaranteed by semiring monotonicity.  A 2-D
  ``(B, n)`` previous solution repairs a whole batch of warm answers in
  one SpMM pass per round (DESIGN.md §5).
* :func:`maintain_nonmonotone` / :func:`synthesize_maintenance`
  (:mod:`repro.incremental.maintenance`) — the non-monotone repair: a
  CEGIS loop over a small ⊕/⊗/⊖/recount rule grammar synthesizes, and
  a probe-based verifier certifies, the maintenance program
  ``maintain(y*, ΔE) ≡ fixpoint(E ⊖ ΔE)``; the e-graph-normalized
  winner is cached per (program signature, semiring, update op) and
  executed as a warm-start carry — reset the support cone, recount its
  in-edges, resume GSN (DESIGN.md §11).
* :func:`refresh_program` — the policy layer: applies a
  :class:`DeltaLog` through :meth:`repro.core.engine.Database.
  apply_delta`, asks the cost-based planner (``objective="incremental"``)
  whether delta-restart (monotone logs) or the synthesized maintenance
  rule (deletes / weight increases) beats full recomputation, and falls
  back to a full recompute — with an explicit reason — whenever
  synthesis times out, verification fails, the previous solution is
  missing, or the delta is large enough that repairing loses.
"""

from repro.incremental.delta import DeltaEntry, DeltaLog
from repro.incremental.maintenance import (MaintenanceRule, cached_rule,
                                           ensure_rule,
                                           maintain_nonmonotone,
                                           synthesize_maintenance)
from repro.incremental.restart import (RefreshReport, delta_restart_fixpoint,
                                       delta_seed, refresh_program)

__all__ = [
    "DeltaEntry", "DeltaLog", "MaintenanceRule", "RefreshReport",
    "cached_rule", "delta_seed", "delta_restart_fixpoint", "ensure_rule",
    "maintain_nonmonotone", "refresh_program", "synthesize_maintenance",
]
