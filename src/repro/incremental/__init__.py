"""Incremental fixpoint maintenance (DESIGN.md §5).

Keeps fixpoint solutions warm across database mutations instead of
recomputing from ⊥ on every change:

* :class:`DeltaLog` — a typed log of streaming relation updates:
  ⊕-merge edge insertions (and monotone weight decreases for
  trop/minplus, where ⊕ = min absorbs them) plus explicit deletions,
  which are the non-monotone case.
* :func:`delta_restart_fixpoint` — re-converge ``x = init ⊕ x ⊗ E′``
  from the previous solution ``y*``, seeding the GSN frontier with only
  the rows reachable from touched edges (``d₀ = (y* ⊗ ΔE) ⊖ y*``,
  O(nnz(Δ))); exactness is guaranteed by semiring monotonicity.  A 2-D
  ``(B, n)`` previous solution repairs a whole batch of warm answers in
  one SpMM pass per round.
* :func:`refresh_program` — the policy layer: applies a
  :class:`DeltaLog` through :meth:`repro.core.engine.Database.
  apply_delta`, asks the cost-based planner (``objective="incremental"``)
  whether delta-restart beats full recomputation, and falls back to a
  full recompute — with an explicit reason — for non-monotone updates,
  missing previous solutions, or deltas large enough that restarting
  loses.
"""

from repro.incremental.delta import DeltaEntry, DeltaLog
from repro.incremental.restart import (RefreshReport, delta_restart_fixpoint,
                                       delta_seed, refresh_program)

__all__ = [
    "DeltaEntry", "DeltaLog", "RefreshReport", "delta_seed",
    "delta_restart_fixpoint", "refresh_program",
]
