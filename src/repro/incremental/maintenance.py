"""Synthesized ⊖/recount maintenance for non-monotone updates (DESIGN.md §11).

Deleting an edge (or increasing its weight) voids the pre-fixpoint
property that delta-restart (DESIGN.md §5) rides on: the old solution
``y*`` may *over-derive* under the shrunk operator, and on a plain
semiring there is no subtraction to cancel the lost derivations with.
On the idempotent complete lattices (𝔹, trop, maxplus) an exact repair
still exists, but its shape is a program, not a formula — which seeds to
distrust, how far the distrust propagates, and what to recount.  Rather
than hand-writing that program, this module *synthesizes* it the same
way the rest of the repo synthesizes H from F and G (paper Sec. 4–5):

* a small **rule grammar** over ⊕/⊗/⊖/recount primitives — terms
  ``recount(cone(seed(Δ)))`` with seeds ∈ {touched, supported,
  unsupported} and cones ∈ {seeds, one_hop, tight, forward, all};
* a **CEGIS loop**: candidates are enumerated cheapest-first, replayed
  on adversarial + randomized probes (:func:`repro.core.verify.
  sample_update_probes`) against a from-scratch ground truth, and every
  refutation is kept as a counterexample that future candidates must
  pass first (the cyclic probes are what kill DRed-style support
  counting);
* **e-graph normalization** (:func:`repro.core.egraph.normalize` under
  :data:`repro.core.egraph.MAINTENANCE_RULES`) canonicalizes each
  candidate and rejects by *proof* the degenerate full-cone rule, whose
  recount collapses to a cold fixpoint;
* the verified winner is **cached** per (program signature, semiring,
  update op) so a serve loop synthesizes once and repairs forever.

The winning rule on all three lattices is ``recount(cone_tight(
seed_supported(Δ)))``: distrust the endpoints whose deleted in-edge
actually carried their value (*supported* seeds), grow the distrust
through *tight* surviving edges (``y*[dst] = y*[src] ⊗ w``), reset the
cone to 0̄, recount its in-edges once against the intact exterior, and
resume the ordinary GSN loop from that carry.  Everything outside the
cone keeps a valid support chain, so the carry is a pre-fixpoint below
``lfp F′`` and the resume converges to the exact from-scratch answer
(correctness argument in DESIGN.md §11).  Semirings without ⊖ (nat,
real) record a synthesis failure and the callers fall back to a full
recompute — semantics never change, only speed.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import egraph
from repro.core import semiring as sr_mod
from repro.core import verify
from repro.sparse import fixpoint as fx
from repro.sparse.coo import SparseRelation
from repro.sparse.fixpoint import FixpointState, fixpoint

# -- rule grammar -----------------------------------------------------------

#: seed selectors: which update endpoints to distrust.
#: * ``touched`` — every dst of an updated edge;
#: * ``supported`` — only dsts whose deleted edge was tight under y*
#:   (it actually carried the stored value);
#: * ``unsupported`` — supported dsts whose remaining in-edges carry no
#:   support (DRed-style counting — *unsound* on cyclic support, kept in
#:   the grammar precisely so CEGIS refutes it with the cycle probes).
SEED_KINDS = ("supported", "touched", "unsupported")

#: cone selectors: how far the distrust propagates from the seeds.
#: ``seeds``/``one_hop`` are unsound (effects chain), ``tight`` is the
#: minimal sound closure, ``forward`` a sound over-approximation, and
#: ``all`` the degenerate whole-universe cone (≡ cold fixpoint —
#: rejected by e-graph proof, not by probing).
CONE_KINDS = ("seeds", "one_hop", "tight", "forward", "all")

_SEED_COST = {"supported": 0, "touched": 1, "unsupported": 2}
_CONE_COST = {"seeds": 0, "one_hop": 1, "tight": 2, "forward": 3, "all": 4}


@dataclasses.dataclass(frozen=True)
class MaintenanceRule:
    """One (possibly verified) maintenance program from the grammar."""

    seeds: str
    cone: str
    semiring: str
    op: str                       # "delete" | "increase"
    verified: bool
    reason: str                   # why verified / why rejected
    term: tuple = ()              # normalized s-expression
    probes: int = 0               # ground-truth comparisons passed
    refuted: tuple = ()           # ((seeds, cone, probe-name), ...) trail

    @property
    def name(self) -> str:
        """The display name ``explain()`` and reports surface."""
        return f"⊖-recount[seed={self.seeds}, cone={self.cone}]"


def rule_term(seeds: str, cone: str) -> tuple:
    return ("recount", (f"cone_{cone}", (f"seed_{seeds}", "delta")))


def _candidates():
    cands = [(s, c) for c in CONE_KINDS for s in SEED_KINDS]
    cands.sort(key=lambda sc: (_CONE_COST[sc[1]], _SEED_COST[sc[0]]))
    return cands


# -- rule cache -------------------------------------------------------------

_RULE_CACHE: dict[tuple[str, str, str], MaintenanceRule] = {}


def cached_rule(signature: str, semiring: str, op: str
                ) -> MaintenanceRule | None:
    """The cached synthesis outcome for this (program, semiring, op) —
    positive *or* negative; ``None`` means never attempted.  The planner
    consults this without side effects; :func:`ensure_rule` populates it."""
    return _RULE_CACHE.get((signature, semiring, op))


def clear_rule_cache() -> None:
    _RULE_CACHE.clear()


def ensure_rule(signature: str, semiring: str, op: str = "delete", *,
                budget_s: float = 5.0, probes: int = 8,
                seed: int = 0) -> MaintenanceRule:
    """Return the cached rule for this key, synthesizing (and caching the
    outcome, including failures) on a miss."""
    key = (signature, semiring, op)
    rule = _RULE_CACHE.get(key)
    if rule is None:
        rule = synthesize_maintenance(semiring, op, budget_s=budget_s,
                                      probes=probes, seed=seed)
        _RULE_CACHE[key] = rule
    return rule


# -- CEGIS ------------------------------------------------------------------


def synthesize_maintenance(semiring: str, op: str = "delete", *,
                           budget_s: float = 5.0, probes: int = 8,
                           seed: int = 0) -> MaintenanceRule:
    """CEGIS over the rule grammar: enumerate cheapest-first, reject the
    degenerate cone by e-graph proof, replay survivors on accumulated
    counterexamples before fresh probes, and return the first candidate
    whose repairs match the from-scratch ground truth everywhere."""
    sr = sr_mod.get(semiring, lib="np")
    if sr.minus is None:
        return MaintenanceRule(
            "-", "-", semiring, op, False,
            f"semiring {semiring} has no ⊖ (not an idempotent complete "
            f"lattice) — maintenance carries are inexpressible; full "
            f"recompute is the only exact refresh")
    if op == "increase" and semiring == "bool":
        return MaintenanceRule(
            "-", "-", semiring, op, False,
            "weight increase is not expressible on 𝔹 (edges are "
            "unweighted) — record it as delete ⊕ insert instead")
    rng = np.random.default_rng(seed)
    pool = verify.sample_update_probes(semiring, rng, probes, op=op)
    counterexamples: list[verify.UpdateProbe] = []
    refuted: list[tuple[str, str, str]] = []
    deadline = time.monotonic() + budget_s
    for seeds, cone in _candidates():
        term = egraph.normalize(rule_term(seeds, cone))
        if term == "cold_fixpoint" or "univ" in _leaves(term):
            refuted.append((seeds, cone,
                            "egraph: normalizes to cold_fixpoint "
                            "(≡ full recompute)"))
            continue
        if time.monotonic() > deadline:
            return MaintenanceRule(
                seeds, cone, semiring, op, False,
                f"synthesis budget ({budget_s:.1f}s) exhausted after "
                f"{len(refuted)} refutations — falling back to full "
                f"recompute", term, 0, tuple(refuted))
        cand = MaintenanceRule(seeds, cone, semiring, op, False, "",
                               term)
        bad = _first_failure(cand, counterexamples) \
            or _first_failure(cand, pool)
        if bad is not None:
            if bad not in counterexamples:
                counterexamples.append(bad)
            refuted.append((seeds, cone, f"counterexample: {bad.name}"))
            continue
        checked = len(counterexamples) + len(pool)
        return MaintenanceRule(
            seeds, cone, semiring, op, True,
            f"verified on {checked} probe(s) "
            f"({len(counterexamples)} CEGIS counterexample(s) reused)",
            term, checked, tuple(refuted))
    return MaintenanceRule(
        "-", "-", semiring, op, False,
        f"no candidate in the {len(_candidates())}-rule grammar "
        f"survived verification", (), 0, tuple(refuted))


def _leaves(term) -> set:
    if isinstance(term, str):
        return {term}
    out = set()
    for c in term[1:]:
        out |= _leaves(c)
    return out


def _first_failure(rule: MaintenanceRule, probes
                   ) -> verify.UpdateProbe | None:
    """Replay ``rule`` on each probe against the from-scratch ground
    truth (sound refutation: a mismatch is a real counterexample)."""
    for p in probes:
        if not _check_probe(rule, p):
            return p
    return None


def _check_probe(rule: MaintenanceRule, p: verify.UpdateProbe) -> bool:
    # stamp the candidate executable for the replay: CEGIS is exactly the
    # process that decides whether the stamp is deserved
    rule = dataclasses.replace(rule, verified=True,
                               reason="candidate under CEGIS replay")
    old = p.edges
    dvals = _gather_values(old, p.coords)
    new = old.delete_keys(p.coords)
    merge = None
    if rule.op == "increase" and p.new_values is not None:
        new = new.apply_delta(p.coords, p.new_values)
        merge = SparseRelation.from_coo(p.coords, p.new_values,
                                        old.shape, old.semiring, lib="np")
    y_star, _ = fixpoint(old, p.init, mode="frontier", max_iters=512)
    y_true, _ = fixpoint(new, p.init, mode="frontier", max_iters=512)
    y_got, _ = maintain_nonmonotone(new, p.coords, dvals,
                                    np.asarray(y_star), p.init, rule,
                                    merge_delta=merge, max_iters=512,
                                    mode="frontier")
    return verify.values_equal(np.asarray(y_got), np.asarray(y_true))


def _gather_values(rel: SparseRelation, coords) -> np.ndarray:
    """Old stored values at ``coords`` (0̄ where absent) — what the
    tightness test of a deleted edge is evaluated against."""
    sr = sr_mod.get(rel.semiring, lib="np")
    host = rel.as_np()
    k = int(host.nnz)
    out = np.full(len(np.asarray(coords).reshape(-1, rel.arity)),
                  sr.zero, sr.dtype)
    if k == 0:
        return out
    keys = host._flat_keys(host.coords[:k])
    want = host._flat_keys(coords)
    order = np.argsort(keys, kind="stable")
    sk, sv = keys[order], host.values[:k][order]
    lo = np.searchsorted(sk, want, "left")
    hi = np.searchsorted(sk, want, "right")
    for i in range(len(want)):  # |Δ| is small; duplicates ⊕-combine
        if hi[i] > lo[i]:
            v = sv[lo[i]]
            for j in range(lo[i] + 1, hi[i]):
                v = sr.add(v, sv[j])
            out[i] = v
    return out


# -- executor ---------------------------------------------------------------


def maintain_nonmonotone(edges_new: SparseRelation, deleted_coords,
                         deleted_values, prev, init,
                         rule: MaintenanceRule, *, merge_delta=None,
                         max_iters: int = 10_000, mode: str = "auto"):
    """Repair ``y* = lfp(x ↦ init ⊕ x ⊗ E)`` after the non-monotone
    update that produced ``edges_new`` from ``E``, using a verified
    maintenance ``rule``:

    1. **seed** — select the distrusted endpoints of the deleted edges
       (``deleted_coords``/``deleted_values`` are the *old* keys and
       stored values; tightness is judged against ``prev``);
    2. **cone** — close the seeds under the rule's cone relation over
       ``edges_new`` (tight edges walk the cached forward CSR; deleted
       entries are 0̄-poisoned there, so they can never carry support);
    3. **reset ⊕ recount** — ``y₀ = prev`` outside the cone, 0̄ on it;
       ``d₀ = F′(y₀) ⊖ y₀`` is recounted over the cone's in-edges alone
       (transposed CSR, :func:`repro.sparse.fixpoint.csr_index` with
       ``transpose=True``) — in-cone contributions vanish at 0̄, so one
       pass against the intact exterior is exact;
    4. **resume** — hand ``(y₀, d₀)`` to the unified GSN entrypoint
       (:func:`repro.sparse.fixpoint.fixpoint`) as an ordinary warm
       carry.  Any ⊕-merges riding in the same update batch seed extra
       frontier via :func:`repro.incremental.restart.delta_seed` on top
       (idempotent ⊕ makes the overlap harmless).

    ``prev``/``init`` may be ``(n,)`` or a ``(B, n)`` pack of warm
    solutions with per-row inits (the serve loop's batched repair).
    Returns ``(y′*, iters)`` like :func:`delta_restart_fixpoint`.
    """
    if not rule.verified:
        raise ValueError(f"refusing to execute unverified rule "
                         f"{rule.name}: {rule.reason}")
    sr = sr_mod.get(edges_new.semiring, lib="np")
    prev = np.asarray(prev, sr.dtype)
    init = np.asarray(init, sr.dtype)
    batched = prev.ndim == 2
    rows = prev if batched else prev[None]
    inits = init if batched else init[None]
    assert inits.shape == rows.shape, (inits.shape, rows.shape)
    coords = np.asarray(deleted_coords, np.int64).reshape(-1, 2)
    dvals = np.asarray(deleted_values, sr.dtype).reshape(-1)
    y0 = np.empty_like(rows)
    d0 = np.full(rows.shape, sr.zero, sr.dtype)
    for b in range(rows.shape[0]):
        cone = _cone(rule, rows[b], coords, dvals, edges_new, sr)
        y0[b] = rows[b]
        y0[b, cone] = sr.zero
        if len(cone):
            d0[b, cone] = _recount(cone, y0[b], inits[b], edges_new, sr)
    if merge_delta is not None and int(np.asarray(merge_delta.nnz)):
        from repro.incremental.restart import delta_seed
        d0 = sr.add(d0, delta_seed(merge_delta, y0, backend="np"))
    st = FixpointState(y0, d0, np.zeros(rows.shape[0], np.int32),
                       edges_new.semiring, batched)
    return fixpoint(edges_new, state=st, max_iters=max_iters, mode=mode)


def _tight_mask(y: np.ndarray, src, w, dst, sr) -> np.ndarray:
    """Which edges (src, w, dst) carry their dst's stored value."""
    if sr.name == "bool":
        return y[src] & np.asarray(w, bool) & y[dst]
    return (y[dst] != sr.zero) & (y[dst] == sr.mul(y[src], w))


def _cone(rule: MaintenanceRule, y: np.ndarray, coords, dvals,
          edges_new: SparseRelation, sr) -> np.ndarray:
    src, dst = coords[:, 0], coords[:, 1]
    if rule.seeds == "touched":
        seeds = np.unique(dst)
    else:
        sup = _tight_mask(y, src, dvals, dst, sr)
        seeds = np.unique(dst[sup])
        if rule.seeds == "unsupported" and len(seeds):
            # DRed-style: drop seeds that still have a tight in-edge in
            # the new graph (unsound on cyclic support — the grammar
            # keeps it so the cycle probes can refute it)
            tidx = fx.csr_index(edges_new, transpose=True)
            keep = []
            for a in seeds:
                lo, hi = tidx.starts[a], tidx.starts[a] + tidx.counts[a]
                z, w = tidx.dst[lo:hi], tidx.w[lo:hi]
                alive = bool(_tight_mask(y, z, w, np.full(len(z), a),
                                         sr).any())
                if len(tidx.xsrc) and not alive:
                    m = tidx.xsrc == a
                    alive = bool(_tight_mask(
                        y, tidx.xdst[m], tidx.xw[m],
                        np.full(int(m.sum()), a), sr).any())
                if not alive:
                    keep.append(a)
            seeds = np.asarray(keep, np.int64)
    n = edges_new.shape[1]
    seeds = seeds[(seeds >= 0) & (seeds < n)]
    if rule.cone == "seeds" or len(seeds) == 0:
        return seeds
    if rule.cone == "all":
        return np.arange(n)
    idx = fx.csr_index(edges_new)
    visited = np.zeros(n, bool)
    visited[seeds] = True
    frontier = seeds
    hops = 0
    while len(frontier):
        deg = idx.counts[frontier]
        rep = np.repeat(np.arange(len(frontier)), deg)
        nxt = np.zeros(0, np.int64)
        if len(rep):
            run = np.arange(len(rep)) - np.repeat(
                np.concatenate([[0], np.cumsum(deg)[:-1]]), deg)
            esel = idx.starts[frontier[rep]] + run
            a, b, w = frontier[rep], idx.dst[esel], idx.w[esel]
            follow = _follow_mask(rule.cone, y, a, w, b, sr)
            nxt = b[follow]
        if len(idx.xsrc):
            m = visited[idx.xsrc] if hops else np.isin(idx.xsrc, frontier)
            m &= ~visited[idx.xdst]
            if m.any():
                follow = _follow_mask(rule.cone, y, idx.xsrc[m],
                                      idx.xw[m], idx.xdst[m], sr)
                nxt = np.concatenate([nxt, idx.xdst[m][follow]])
        nxt = np.unique(nxt)
        nxt = nxt[~visited[nxt]]
        visited[nxt] = True
        frontier = nxt
        hops += 1
        if rule.cone == "one_hop" and hops >= 1:
            break
    return np.flatnonzero(visited)


def _follow_mask(cone: str, y, src, w, dst, sr) -> np.ndarray:
    if cone == "tight":
        return _tight_mask(y, src, w, dst, sr)
    # one_hop / forward: any surviving (non-0̄) edge propagates
    return (np.asarray(w, bool) if sr.name == "bool"
            else np.asarray(w) != sr.zero)


def _recount(cone: np.ndarray, y0: np.ndarray, init: np.ndarray,
             edges_new: SparseRelation, sr) -> np.ndarray:
    """``d₀[a] = init[a] ⊕ ⊕_z y₀[z] ⊗ E′[z, a]`` for each cone vertex
    ``a`` — one pass over the cone's in-edges via the transposed CSR.
    In-cone sources hold 0̄ in ``y₀`` and annihilate under ⊗, so only
    the intact exterior contributes, which is exactly ``F′(y₀)`` there."""
    tidx = fx.csr_index(edges_new, transpose=True)
    raw = np.asarray(init, sr.dtype)[cone].copy()
    deg = tidx.counts[cone]
    rep = np.repeat(np.arange(len(cone)), deg)
    if len(rep):
        run = np.arange(len(rep)) - np.repeat(
            np.concatenate([[0], np.cumsum(deg)[:-1]]), deg)
        esel = tidx.starts[cone[rep]] + run
        sr_mod.NP_COMBINE[sr.name].at(
            raw, rep, sr.mul(y0[tidx.dst[esel]], tidx.w[esel]))
    if len(tidx.xsrc):
        loc = np.full(len(y0), -1, np.int64)
        loc[cone] = np.arange(len(cone))
        m = loc[tidx.xsrc] >= 0
        if m.any():
            sr_mod.NP_COMBINE[sr.name].at(
                raw, loc[tidx.xsrc[m]],
                sr.mul(y0[tidx.xdst[m]], tidx.xw[m]))
    return raw
