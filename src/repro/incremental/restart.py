"""Delta-restart semi-naive maintenance (DESIGN.md §5).

The vector fixpoint ``x = init ⊕ x ⊗ E`` was solved once; then the graph
mutated monotonically: ``E′ = E ⊕ ΔE``.  Because ⊗ distributes over ⊕
and the old solution ``y*`` satisfies ``y* = init ⊕ y* ⊗ E``,

    F′(y*) = init ⊕ y* ⊗ E′ = y* ⊕ (y* ⊗ ΔE)

so ``y*`` is a *pre-fixpoint* of the new ICO (``y* ≤ F′(y*)``) and its
pending delta restricted to the touched edges,

    d₀ = F′(y*) ⊖ y* = (y* ⊗ ΔE) ⊖ y*,

costs O(nnz(Δ)) to derive — not O(nnz(E)).  GSN iteration from
``(y*, d₀)`` under ``E′`` converges to the least fixpoint above ``y*``,
which by monotonicity (``y* ≤ lfp F′``) is exactly ``lfp F′`` — the
from-scratch answer, reached while expanding only the affected region.
Non-monotone updates (deletions) void the pre-fixpoint property; they
fall back to a full recompute with an explicit reason.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner, vectorize
from repro.core import semiring as sr_mod
from repro.incremental.delta import DeltaLog
from repro.sparse import contract
from repro.sparse.coo import SparseRelation
from repro.sparse.fixpoint import FixpointState, fixpoint


def delta_seed(delta: SparseRelation, prev, *, backend: str = "np"):
    """``d₀ = (y* ⊗ ΔE) ⊖ y*`` — the pending delta of the old solution
    under the mutated operator, derived from the touched edges alone.

    ``prev`` may be ``(n,)`` or a ``(B, n)`` pack of warm solutions (the
    batched repair path: one SpMM over Δ seeds every row at once).
    ``backend="np"`` computes eagerly on the host (the frontier runner's
    world); ``"jnp"`` stays on device for the staged runner.
    """
    if backend == "np":
        sr = sr_mod.get(delta.semiring, lib="np")
        h = delta.as_np()
        k = int(h.nnz)
        src = h.coords[:k, 0].astype(np.int64)
        dst = h.coords[:k, 1].astype(np.int64)
        w = h.values[:k]
        prev = np.asarray(prev, sr.dtype)
        derived = np.full(prev.shape, sr.zero, sr.dtype)
        if prev.ndim == 1:
            sr_mod.NP_COMBINE[sr.name].at(
                derived, dst, sr.mul(prev[src], w))
        else:
            b = prev.shape[0]
            sr_mod.NP_COMBINE[sr.name].at(
                derived, (np.arange(b)[:, None], dst[None, :]),
                sr.mul(prev[:, src], w[None, :]))
        return sr.minus(derived, prev)
    sr = sr_mod.get(delta.semiring)
    prev = jnp.asarray(prev)
    d = delta.as_jnp()
    derived = (contract.vspm(prev, d) if prev.ndim == 1
               else contract.mspm(prev, d))
    return sr.minus(derived, prev)


def delta_restart_fixpoint(edges: SparseRelation, delta: SparseRelation,
                           prev, *, max_iters: int = 10_000,
                           mode: str = "auto"):
    """Repair ``y* = lfp(x ↦ init ⊕ x ⊗ E)`` after the monotone update
    ``E′ = E ⊕ ΔE``:  seed ``d₀`` from ``delta`` (O(nnz(Δ))), then
    re-converge with the ordinary GSN loop under ``edges`` (= E′,
    post-update).  Exact for monotone updates on idempotent-lattice
    semirings (module docstring); the caller is responsible for routing
    non-monotone mutations to a full recompute (:func:`refresh_program`
    does this automatically).

    ``prev`` of shape ``(B, n)`` repairs B warm solutions in one batched
    pass — ``mode="jit"`` advances all rows with a single SpMM per round.
    Returns ``(y′*, iters)`` where ``iters`` counts only resumed rounds
    (0 when the update does not change the solution at all).
    """
    assert edges.semiring == delta.semiring, (edges, delta)
    assert edges.shape == delta.shape, (edges.shape, delta.shape)
    if mode == "auto":
        mode = "frontier" if jax.default_backend() == "cpu" else "jit"
    if mode == "frontier" and np.ndim(prev) == 2:
        # host worklists are per-row; the batched repair hot path is the
        # staged SpMM loop
        mode = "jit"
    backend = "np" if mode == "frontier" else "jnp"
    d0 = delta_seed(delta, prev, backend=backend)
    batched = np.ndim(prev) == 2
    y0 = prev if batched else np.asarray(prev)[None]
    d0 = d0 if batched else np.asarray(d0)[None]
    st = FixpointState(y0, d0, np.zeros(np.shape(y0)[0], np.int32),
                       edges.semiring, batched)
    return fixpoint(edges, state=st, max_iters=max_iters, mode=mode)


# --------------------------------------------------------------------------
# Policy layer: plan → (delta-restart | full recompute)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RefreshReport:
    """How one refresh was executed and why."""

    strategy: str                 # "delta_restart" | "full"
    reason: str
    iters: int = 0
    delta_nnz: int = 0
    plan: object | None = None    # the consulted ExecutionPlan, if any


def refresh_program(prog, db, prev, log: DeltaLog, *, hints=None,
                    max_iters: int = 10_000, mode: str = "auto"):
    """Apply ``log`` to ``db`` and return the fresh answer, delta-
    restarting from ``prev`` when the planner prices it cheaper.

    Returns ``(answer, updated_db, RefreshReport)``.  ``prev`` is the
    program's previous answer on ``db`` (``None`` → full recompute).
    The decision is the cost-based planner's
    (``objective="incremental"``): delta-restart is considered at
    O(nnz(Δ) · affected-trip-count) against every full-recompute
    candidate, so large deltas naturally fall back.  Non-monotone logs
    and logs touching relations outside the linear operator fall back
    with an explicit reason.
    """
    db2 = db.apply_delta(log)
    ph = planner.PlanHints.of(hints, defaults=prog.sort_hints)
    hints = dict(ph.sorts)

    ok, why = log.monotone()
    if not ok:
        return _full(prog, db2, log, why, max_iters)
    if prev is None:
        return _full(prog, db2, log, "no previous solution to restart "
                     "from", max_iters)

    plan = planner.plan_program(prog, db2, ph,
                                objective="incremental",
                                delta_nnz=log.nnz(), max_iters=max_iters)
    sp = plan.strata[0] if plan.strata else None
    if sp is None or sp.runner != "delta_restart":
        reason = "planner: full recompute priced cheaper" if sp is None \
            or "delta_restart" in sp.considered else \
            f"planner: {sp.rejected.get('delta_restart', 'infeasible')}"
        return _full(prog, db2, log, reason, max_iters, plan=plan)

    a = vectorize.edge_atom(sp.vf)
    touched = log.touched()
    if a is None or touched - {a.name}:
        extra = sorted(touched - ({a.name} if a else set()))
        return _full(prog, db2, log,
                     f"delta touches relations outside the linear "
                     f"operator ({extra}) — the init term may have "
                     f"changed", max_iters, plan=plan)
    if vectorize.init_reads(sp.vf, a.name):
        return _full(prog, db2, log,
                     f"edge relation {a.name} also feeds the init term — "
                     f"a delta seed from y* ⊗ ΔE alone would miss its "
                     f"contribution", max_iters, plan=plan)

    rel = db2.relations[a.name]
    delta = log.merged(a.name, rel.shape, rel.semiring
                       if isinstance(rel, SparseRelation)
                       else db2.schema[a.name].semiring)
    if tuple(a.args) != sp.vf.edge.head:
        delta = delta.transpose()
    delta = vectorize._sparse_into_semiring(delta, sp.vf.semiring)
    edges = planner.materialize_edges(plan, db2, hints)
    y, iters = delta_restart_fixpoint(edges, delta, prev,
                                      max_iters=max_iters, mode=mode)
    rep = RefreshReport("delta_restart", sp.reason, int(np.asarray(iters)),
                        log.nnz(), plan)
    return y, db2, rep


def _full(prog, db2, log, reason, max_iters, *, plan=None):
    from repro.core.program import run_program

    out, stats = run_program(prog, db2, max_iters=max_iters)
    return out, db2, RefreshReport("full", reason,
                                   int(sum(stats.iterations)), log.nnz(),
                                   plan)
