"""Delta-restart semi-naive maintenance (DESIGN.md §5).

The vector fixpoint ``x = init ⊕ x ⊗ E`` was solved once; then the graph
mutated monotonically: ``E′ = E ⊕ ΔE``.  Because ⊗ distributes over ⊕
and the old solution ``y*`` satisfies ``y* = init ⊕ y* ⊗ E``,

    F′(y*) = init ⊕ y* ⊗ E′ = y* ⊕ (y* ⊗ ΔE)

so ``y*`` is a *pre-fixpoint* of the new ICO (``y* ≤ F′(y*)``) and its
pending delta restricted to the touched edges,

    d₀ = F′(y*) ⊖ y* = (y* ⊗ ΔE) ⊖ y*,

costs O(nnz(Δ)) to derive — not O(nnz(E)).  GSN iteration from
``(y*, d₀)`` under ``E′`` converges to the least fixpoint above ``y*``,
which by monotonicity (``y* ≤ lfp F′``) is exactly ``lfp F′`` — the
from-scratch answer, reached while expanding only the affected region.
Non-monotone updates (deletions, weight increases) void the
pre-fixpoint property; :func:`refresh_program` routes them through a
CEGIS-verified ⊖/recount maintenance rule
(:mod:`repro.incremental.maintenance`, DESIGN.md §11) when synthesis
succeeds and the planner prices it under a full recompute, and falls
back to the full recompute with an explicit reason otherwise.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner, vectorize
from repro.core import semiring as sr_mod
from repro.incremental.delta import DeltaLog
from repro.sparse import contract
from repro.sparse.coo import SparseRelation
from repro.sparse.fixpoint import FixpointState, fixpoint


def delta_seed(delta: SparseRelation, prev, *, backend: str = "np"):
    """``d₀ = (y* ⊗ ΔE) ⊖ y*`` — the pending delta of the old solution
    under the mutated operator, derived from the touched edges alone.

    ``prev`` may be ``(n,)`` or a ``(B, n)`` pack of warm solutions (the
    batched repair path: one SpMM over Δ seeds every row at once).
    ``backend="np"`` computes eagerly on the host (the frontier runner's
    world); ``"jnp"`` stays on device for the staged runner.
    """
    if backend == "np":
        sr = sr_mod.get(delta.semiring, lib="np")
        h = delta.as_np()
        k = int(h.nnz)
        src = h.coords[:k, 0].astype(np.int64)
        dst = h.coords[:k, 1].astype(np.int64)
        w = h.values[:k]
        prev = np.asarray(prev, sr.dtype)
        derived = np.full(prev.shape, sr.zero, sr.dtype)
        if prev.ndim == 1:
            sr_mod.NP_COMBINE[sr.name].at(
                derived, dst, sr.mul(prev[src], w))
        else:
            b = prev.shape[0]
            sr_mod.NP_COMBINE[sr.name].at(
                derived, (np.arange(b)[:, None], dst[None, :]),
                sr.mul(prev[:, src], w[None, :]))
        return sr.minus(derived, prev)
    sr = sr_mod.get(delta.semiring)
    prev = jnp.asarray(prev)
    d = delta.as_jnp()
    derived = (contract.vspm(prev, d) if prev.ndim == 1
               else contract.mspm(prev, d))
    return sr.minus(derived, prev)


def delta_restart_fixpoint(edges: SparseRelation, delta: SparseRelation,
                           prev, *, max_iters: int = 10_000,
                           mode: str = "auto"):
    """Repair ``y* = lfp(x ↦ init ⊕ x ⊗ E)`` after the monotone update
    ``E′ = E ⊕ ΔE``:  seed ``d₀`` from ``delta`` (O(nnz(Δ))), then
    re-converge with the ordinary GSN loop under ``edges`` (= E′,
    post-update).  Exact for monotone updates on idempotent-lattice
    semirings (module docstring); the caller is responsible for routing
    non-monotone mutations to a full recompute (:func:`refresh_program`
    does this automatically).

    ``prev`` of shape ``(B, n)`` repairs B warm solutions in one batched
    pass — ``mode="jit"`` advances all rows with a single SpMM per round.
    Returns ``(y′*, iters)`` where ``iters`` counts only resumed rounds
    (0 when the update does not change the solution at all).
    """
    assert edges.semiring == delta.semiring, (edges, delta)
    assert edges.shape == delta.shape, (edges.shape, delta.shape)
    if mode == "auto":
        mode = "frontier" if jax.default_backend() == "cpu" else "jit"
    if mode == "frontier" and np.ndim(prev) == 2:
        # host worklists are per-row; the batched repair hot path is the
        # staged SpMM loop
        mode = "jit"
    backend = "np" if mode == "frontier" else "jnp"
    d0 = delta_seed(delta, prev, backend=backend)
    batched = np.ndim(prev) == 2
    y0 = prev if batched else np.asarray(prev)[None]
    d0 = d0 if batched else np.asarray(d0)[None]
    st = FixpointState(y0, d0, np.zeros(np.shape(y0)[0], np.int32),
                       edges.semiring, batched)
    return fixpoint(edges, state=st, max_iters=max_iters, mode=mode)


# --------------------------------------------------------------------------
# Policy layer: plan → (delta-restart | full recompute)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RefreshReport:
    """How one refresh was executed and why."""

    strategy: str        # "delta_restart" | "synth_maintenance" | "full"
    reason: str
    iters: int = 0
    delta_nnz: int = 0
    plan: object | None = None    # the consulted ExecutionPlan, if any
    rule: object | None = None    # the MaintenanceRule executed, if any


def refresh_program(prog, db, prev, log: DeltaLog, *, hints=None,
                    max_iters: int = 10_000, mode: str = "auto",
                    synth_budget_s: float = 5.0):
    """Apply ``log`` to ``db`` and return the fresh answer, repairing
    ``prev`` in place when the planner prices that cheaper.

    Returns ``(answer, updated_db, RefreshReport)``.  ``prev`` is the
    program's previous answer on ``db`` (``None`` → full recompute).
    The decision is the cost-based planner's
    (``objective="incremental"``): a monotone log considers
    delta-restart at O(nnz(Δ) · affected-trip-count) against every
    full-recompute candidate (DESIGN.md §5); a non-monotone log
    (deletes / weight increases) first ensures a CEGIS-verified
    maintenance rule for (program signature, semiring, op) — synthesized
    once within ``synth_budget_s``, then cached — and considers the
    ``synth_maintenance`` repair instead (DESIGN.md §11).  Whenever
    synthesis fails, verification is refused, the planner prices the
    repair out, or the log touches relations outside the linear
    operator, the refresh falls back to a full recompute with the
    recorded reason — semantics never change.
    """
    ph = planner.PlanHints.of(hints, defaults=prog.sort_hints)
    hints = dict(ph.sorts)

    nm_op = log.nonmonotone_op()
    if nm_op is not None:
        return _refresh_nonmonotone(prog, db, prev, log, nm_op, ph,
                                    hints, max_iters, mode,
                                    synth_budget_s)
    db2 = db.apply_delta(log)
    if prev is None:
        return _full(prog, db2, log, "no previous solution to restart "
                     "from", max_iters)

    plan = planner.plan_program(prog, db2, ph,
                                objective="incremental",
                                delta_nnz=log.nnz(), max_iters=max_iters)
    sp = plan.strata[0] if plan.strata else None
    if sp is None or sp.runner != "delta_restart":
        reason = "planner: full recompute priced cheaper" if sp is None \
            or "delta_restart" in sp.considered else \
            f"planner: {sp.rejected.get('delta_restart', 'infeasible')}"
        return _full(prog, db2, log, reason, max_iters, plan=plan)

    bail = _outside_operator(sp.vf, log)
    if bail is not None:
        return _full(prog, db2, log, bail, max_iters, plan=plan)

    a = vectorize.edge_atom(sp.vf)
    delta = _oriented(log.merged(a.name, *_rel_frame(db2, a.name)),
                      a, sp.vf)
    edges = planner.materialize_edges(plan, db2, hints)
    y, iters = delta_restart_fixpoint(edges, delta, prev,
                                      max_iters=max_iters, mode=mode)
    rep = RefreshReport("delta_restart", sp.reason, int(np.asarray(iters)),
                        log.nnz(), plan)
    return y, db2, rep


def _refresh_nonmonotone(prog, db, prev, log, nm_op, ph, hints,
                         max_iters, mode, synth_budget_s):
    """The delete/increase path: synthesize-or-recall the maintenance
    rule, let the planner price it, gather the *old* stored values of
    the removed keys before mutating, and execute the verified repair."""
    from repro.incremental import maintenance

    if prev is None:
        return _full(prog, db.apply_delta(log),
                     log, "no previous solution to restart from",
                     max_iters)
    try:
        vf = vectorize.vector_form(prog)
    except ValueError as e:
        return _full(prog, db.apply_delta(log), log,
                     f"{nm_op} maintenance needs the vector form: {e}",
                     max_iters)
    bail = _outside_operator(vf, log)
    if bail is not None:
        return _full(prog, db.apply_delta(log), log, bail, max_iters)

    rule_op = "delete" if nm_op == "mixed" else nm_op
    rule = maintenance.ensure_rule(vf.signature, vf.semiring, rule_op,
                                   budget_s=synth_budget_s)

    # the removed keys' *old* stored values decide which deletions were
    # support-carrying — gather them before apply_delta drops them
    a = vectorize.edge_atom(vf)
    rcoords = log.removed_coords(a.name)
    removed = _oriented(_removed_rel(db, a.name, rcoords), a, vf)

    db2 = db.apply_delta(log)
    plan = planner.plan_program(prog, db2, ph,
                                objective="incremental",
                                delta_nnz=log.nnz(), delta_op=rule_op,
                                max_iters=max_iters)
    sp = plan.strata[0] if plan.strata else None
    if sp is None or sp.runner != "synth_maintenance":
        reason = "planner: full recompute priced cheaper" if sp is None \
            or "synth_maintenance" in sp.considered else \
            f"planner: {sp.rejected.get('synth_maintenance', 'infeasible')}"
        return _full(prog, db2, log, reason, max_iters, plan=plan)

    merged = log.merged(a.name, *_rel_frame(db2, a.name))
    merged = _oriented(merged, a, vf) if int(np.asarray(merged.nnz)) \
        else None
    edges = planner.materialize_edges(plan, db2, hints)
    init = np.asarray(vectorize.init_vector(vf, db2, hints,
                                            backend="np"))
    rh = removed.as_np()
    k = int(rh.nnz)
    y, iters = maintenance.maintain_nonmonotone(
        edges, rh.coords[:k], rh.values[:k], prev, init, rule,
        merge_delta=merged, max_iters=max_iters, mode=mode)
    rep = RefreshReport("synth_maintenance", sp.reason,
                        int(np.asarray(iters)), log.nnz(), plan, rule)
    return y, db2, rep


def _outside_operator(vf, log: DeltaLog) -> str | None:
    """The shared feasibility guards of both maintenance strategies."""
    a = vectorize.edge_atom(vf)
    touched = log.touched()
    if a is None or touched - {a.name}:
        extra = sorted(touched - ({a.name} if a else set()))
        return (f"delta touches relations outside the linear operator "
                f"({extra}) — the init term may have changed")
    if vectorize.init_reads(vf, a.name):
        return (f"edge relation {a.name} also feeds the init term — a "
                f"delta seed from y* ⊗ ΔE alone would miss its "
                f"contribution")
    return None


def _rel_frame(db, name: str) -> tuple:
    rel = db.relations[name]
    return rel.shape, (rel.semiring if isinstance(rel, SparseRelation)
                       else db.schema[name].semiring)


def _oriented(delta: SparseRelation, a, vf) -> SparseRelation:
    if tuple(a.args) != vf.edge.head:
        delta = delta.transpose()
    return vectorize._sparse_into_semiring(delta, vf.semiring)


def _removed_rel(db, name: str, coords) -> SparseRelation:
    """The removed keys with their old stored values, as a sparse Δ in
    the relation's own frame (keys absent from the relation carry 0̄ and
    coalesce away — deleting a non-edge repairs nothing)."""
    from repro.incremental.maintenance import _gather_values
    rel = db.relations[name]
    shape, semiring = _rel_frame(db, name)
    if isinstance(rel, SparseRelation):
        vals = _gather_values(rel, coords)
    else:
        host = np.asarray(rel)
        vals = host[tuple(np.asarray(coords, np.int64).T)]
    return SparseRelation.from_coo(coords, vals, shape, semiring,
                                   lib="np")


def _full(prog, db2, log, reason, max_iters, *, plan=None):
    from repro.core.program import run_program

    out, stats = run_program(prog, db2, max_iters=max_iters)
    return out, db2, RefreshReport("full", reason,
                                   int(sum(stats.iterations)), log.nnz(),
                                   plan)
