"""DeltaLog: a typed log of streaming relation updates.

Each entry is one batch of tuple updates against one named relation.
Two operations exist, chosen so the *monotone* case is syntactically
recognizable without looking at the stored data:

* ``merge`` — the ⊕-merge ``R′ = R ⊕ Δ``.  Always monotone in the
  semiring order (``R′ ⊒ R``): boolean edge insertion (∨), tropical
  weight decrease (min — inserting a weight *above* the stored one is
  silently absorbed, which is still monotone, just a no-op), counting
  increments (+).  Delta-restart maintenance (DESIGN.md §5) re-converges
  the old fixpoint under merges without recomputing.
* ``delete`` — remove keys outright.  Not expressible as ⊕ on any of
  our semirings, hence non-monotone: the old solution may over-derive
  and warm restart is unsound.  :func:`repro.incremental.refresh_program`
  repairs these through a CEGIS-verified ⊖/recount maintenance rule
  (:mod:`repro.incremental.maintenance`, DESIGN.md §11) when one exists
  for the program's (signature, semiring, op), and falls back to a full
  recompute with a recorded reason otherwise.
* ``increase`` — replace stored values with *larger* ones (a tropical
  weight increase).  ⊕ = min would silently absorb it, so it is the
  other non-monotone mutation: recorded as delete-the-old ⊕ insert-the-
  new and routed through the same synthesized maintenance path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse.coo import SparseRelation


@dataclasses.dataclass(frozen=True)
class DeltaEntry:
    """One batch of updates against one relation."""

    relation: str
    coords: np.ndarray           # (k, arity) int
    values: np.ndarray | None    # (k,) semiring values; None → 1̄ each
    op: str                      # "merge" | "delete" | "increase"

    @property
    def size(self) -> int:
        return len(self.coords)


class DeltaLog:
    """An append-only log of updates, consumable by
    :meth:`repro.core.engine.Database.apply_delta` and the delta-restart
    machinery (:mod:`repro.incremental.restart`)."""

    def __init__(self) -> None:
        self.entries: list[DeltaEntry] = []

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:
        per = {}
        for e in self.entries:
            per[e.relation] = per.get(e.relation, 0) + e.size
        return f"DeltaLog({per})"

    # -- recording -----------------------------------------------------------
    def insert(self, relation: str, coords, values=None) -> "DeltaLog":
        """⊕-merge tuples into ``relation`` (edge insertions; for
        trop/minplus the same call records a monotone weight decrease,
        since ⊕ = min).  Returns ``self`` for chaining."""
        coords = np.atleast_2d(np.asarray(coords, np.int64))
        if values is not None:
            values = np.asarray(values).reshape(-1)
            assert len(values) == len(coords), (coords.shape, values.shape)
        self.entries.append(DeltaEntry(relation, coords, values, "merge"))
        return self

    def delete(self, relation: str, coords) -> "DeltaLog":
        """Remove keys from ``relation`` — the non-monotone mutation."""
        coords = np.atleast_2d(np.asarray(coords, np.int64))
        self.entries.append(DeltaEntry(relation, coords, None, "delete"))
        return self

    def increase(self, relation: str, coords, values) -> "DeltaLog":
        """Replace the stored values at ``coords`` with the (larger)
        ``values`` — a tropical weight increase, the mutation ⊕ = min
        would silently absorb.  Semantically delete-then-insert; the
        maintenance path seeds from the deleted old values and merges
        the new ones (DESIGN.md §11)."""
        coords = np.atleast_2d(np.asarray(coords, np.int64))
        values = np.asarray(values).reshape(-1)
        assert len(values) == len(coords), (coords.shape, values.shape)
        self.entries.append(DeltaEntry(relation, coords, values,
                                       "increase"))
        return self

    # -- classification ------------------------------------------------------
    def monotone(self) -> tuple[bool, str | None]:
        """Whether every entry is a ⊕-merge (so the post-update least
        fixpoint dominates the old one and delta-restart is exact);
        otherwise the human-readable reason for the full-recompute
        fallback."""
        for e in self.entries:
            if e.op != "merge":
                return False, (f"{e.op} of {e.size} key(s) from "
                               f"{e.relation} is non-monotone (not a "
                               f"⊕-merge) — restarting from the old "
                               f"solution could over-derive")
        return True, None

    def nonmonotone_op(self) -> str | None:
        """The update-op class the maintenance rule cache is keyed on:
        ``None`` for all-merge logs, else ``"delete"``/``"increase"``
        when one kind of non-monotone entry appears, ``"mixed"`` when
        both do (repaired with the delete rule plus merge seeding)."""
        ops = {e.op for e in self.entries} - {"merge"}
        if not ops:
            return None
        return ops.pop() if len(ops) == 1 else "mixed"

    def touched(self) -> set[str]:
        return {e.relation for e in self.entries}

    def nnz(self, relation: str | None = None) -> int:
        """Total updated-tuple count (optionally for one relation) —
        the nnz(Δ) the planner prices ``objective="incremental"`` with."""
        return sum(e.size for e in self.entries
                   if relation is None or e.relation == relation)

    # -- materialization -----------------------------------------------------
    def removed_coords(self, relation: str) -> np.ndarray:
        """Keys whose stored value stops holding: ``delete`` entries
        plus the old keys of ``increase`` entries (an increase is
        delete-the-old ⊕ insert-the-new).  What the maintenance rule's
        seed selector distrusts (DESIGN.md §11)."""
        coords = [e.coords for e in self.entries
                  if e.relation == relation
                  and e.op in ("delete", "increase")]
        if not coords:
            return np.zeros((0, 2), np.int64)
        return np.concatenate(coords)

    def merged(self, relation: str, shape, semiring: str, *,
               lib: str = "np") -> SparseRelation:
        """All ⊕-contributing entries for ``relation`` coalesced into
        one sparse Δ relation (the seed operand of delta-restart):
        ``merge`` entries plus the *new* values of ``increase`` entries
        (their old keys come back via :meth:`removed_coords`)."""
        sr = sr_mod.get(semiring, lib="np")
        coords, values = [], []
        for e in self.entries:
            if e.relation != relation or e.op not in ("merge",
                                                      "increase"):
                continue
            coords.append(e.coords)
            values.append(np.full(e.size, sr.one, sr.dtype)
                          if e.values is None
                          else np.asarray(e.values, sr.dtype))
        if not coords:
            coords = [np.zeros((0, len(shape)), np.int64)]
            values = [np.zeros((0,), sr.dtype)]
        return SparseRelation.from_coo(
            np.concatenate(coords), np.concatenate(values), tuple(shape),
            semiring, lib=lib)
