"""Cost-based execution planning: one ``plan → explain → execute`` pipeline.

DESIGN.md §4.  The FGH rewrite produces a *program*; which physical
runner executes each stratum — dense naive, dense GSN
(:func:`repro.core.fixpoint.seminaive_fixpoint`), the sparse jit/frontier
vector runners (:mod:`repro.sparse.fixpoint`), or the vectorized
``x = init ⊕ x ⊗ E`` SpMV/SpMM step (split by :mod:`repro.core.vectorize`)
— and which storage each relation should use, is a classic physical-plan
decision.  It used to be made ad hoc at three sites: ``run_program``'s
mode strings, the serve loop's bespoke vector-form routing, and host-side
``Database.adapt`` calls.  Now :func:`plan_program` makes it once,
:func:`explain` renders it, and :func:`execute_plan` /
:func:`compile_batched` execute it.

Cost model: an analytic O(n²)-vs-O(nnz(E)) × trip-count estimate by
default, or ``cost_model="hlo"`` which stages each candidate's
per-iteration step function and walks its optimized HLO with
:func:`repro.launch.hlo_cost.staged_cost` — the same trip-count-aware
walker the AOT dry-runs (:mod:`repro.launch.dryrun`,
:mod:`repro.launch.datalog_dryrun`) report from.

Storage is folded into planning: the hysteresis thresholds of
:mod:`repro.sparse.adaptive` (via :func:`repro.sparse.adaptive.decide`)
pick a per-relation representation for every binary relation a stratum
reads, replacing host-side ``Database.adapt`` calls between strata.

Plan identity: ``ExecutionPlan.signature`` is a stable hash of the
per-stratum (runner, IDB shapes/semirings, linear-operator signature or
stratum structure, storage decisions) — the serve loop keys its compile
cache on ``(plan.signature, batch_bucket)``.  Staged-executable caching
inside :func:`execute_plan` keys on :func:`db_fingerprint`, a
weakref-token fingerprint of the relation arrays (never raw ``id()``,
which can be recycled after GC and silently serve a stale staged
fixpoint).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
import warnings
import weakref
from typing import Callable, Mapping

import jax
import numpy as np

from repro.core import engine, ir, vectorize
from repro.core import semiring as sr_mod
from repro.sparse import adaptive
from repro.sparse.coo import SparseRelation

#: physical runners, in tie-break preference order (earlier wins ties).
#: "delta_restart" is the incremental-maintenance strategy (DESIGN.md §5):
#: it resumes the previous solution instead of recomputing, so at equal
#: priced cost it can only do less work — hence it leads the order.
#: "synth_maintenance" is its non-monotone sibling (DESIGN.md §11): a
#: CEGIS-verified ⊖/recount rule repairing deletes/weight-increases from
#: the warm solution; it is only *considered* under
#: ``objective="incremental"`` with a non-merge ``delta_op`` and a
#: verified rule already in the maintenance cache.  Both are executed by
#: :func:`repro.incremental.refresh_program` (or the serve loop), never
#: by :func:`execute_plan` (which has no previous solution to restart
#: from).
RUNNERS = ("synth_maintenance", "delta_restart", "sparse_sharded",
           "sparse_frontier_pallas", "sparse_jit", "sparse_frontier",
           "vector_dense", "dense_gsn", "dense_naive", "dense_host")

#: single-device runners that execute the vector equation
#: ``x = init ⊕ x ⊗ E``.  "sparse_frontier_pallas" is the fused-kernel
#: SpMM backend (kernels/coo_spmm.py, DESIGN.md §9): the same staged GSN
#: loop as "sparse_jit" with the gather→⊗→segment-⊕ advance fused into
#: one pass — a Pallas kernel on TPU, bit-packed host rounds for 𝔹 on
#: CPU (see :func:`spmm_exec_backend`).
VECTOR_RUNNERS = ("sparse_jit", "sparse_frontier", "sparse_frontier_pallas",
                  "vector_dense")

#: every vector-equation runner the serve loop can batch — the
#: single-device three plus the graph-axis sharded SpMM loop
#: (:mod:`repro.distributed.datalog`, DESIGN.md §6)
BATCHED_RUNNERS = VECTOR_RUNNERS + ("sparse_sharded",)

#: legacy ``run_program`` mode strings → forced runners; any *other*
#: unknown string keeps the historical "host loop with stats" behaviour
LEGACY_MODES = {"naive": "dense_naive", "seminaive": "dense_gsn",
                "host": "dense_host"}

#: max trip-count the analytic model will predict (deep chains saturate)
_TRIP_CAP = 64

#: staged-executable cache entries kept per Program object
_CACHE_MAX = 512


# --------------------------------------------------------------------------
# Stable relation fingerprints (the plan-cache key fix)
# --------------------------------------------------------------------------

_fp_tokens: dict[int, tuple[int, object]] = {}
_fp_counter = itertools.count()


def _token(obj) -> int:
    """A process-unique token for ``obj`` that is *never* recycled.

    ``id(obj)`` alone is unsafe as a cache key: CPython reuses addresses
    after GC, so a fresh relation array can silently alias a dead one's
    cache entry.  Here the id is only a lookup hint — a weakref callback
    evicts the entry the moment the referent dies, so a recycled id is
    issued a fresh token.  (All our leaf types — numpy arrays, jax
    arrays, :class:`SparseRelation` — support weakrefs; a non-weakrefable
    object gets a fresh token on every call, trading cache hits for
    guaranteed staleness-freedom.)
    """
    key = id(obj)
    ent = _fp_tokens.get(key)
    if ent is not None and ent[1]() is not obj:
        ent = None  # id recycled before the callback ran
    if ent is None:
        tok = next(_fp_counter)

        def _evict(ref, k=key):
            # only evict our own entry — a late callback from the dead
            # object must not pop a fresh entry at the recycled id
            cur = _fp_tokens.get(k)
            if cur is not None and cur[1] is ref:
                _fp_tokens.pop(k, None)

        try:
            ref = weakref.ref(obj, _evict)
        except TypeError:
            # non-weakrefable leaf: no death notification is possible, so
            # never memoize — a fresh token per call can only cause cache
            # misses, never a stale hit on a recycled id
            return tok
        _fp_tokens[key] = (tok, ref)
        return tok
    return ent[0]


def value_fingerprint(v) -> tuple:
    """Stable fingerprint of one stored relation: shape/dtype/semiring
    plus the weakref token of the backing buffer(s)."""
    if isinstance(v, SparseRelation):
        return ("coo", v.shape, v.semiring, _token(v.coords),
                _token(v.values))
    return (_token(v), tuple(getattr(v, "shape", ())),
            str(getattr(v, "dtype", type(v).__name__)))


def db_fingerprint(db: engine.Database, names=None) -> tuple:
    """Fingerprint of (a subset of) a database's relations, plus its sort
    domains — staged fixpoints bake domain sizes into output shapes even
    when no relation array reflects them."""
    if names is None:
        names = db.relations
    return (tuple(sorted(db.domains.items())),
            tuple((n, value_fingerprint(db.relations[n]))
                  for n in sorted(names) if n in db.relations))


# --------------------------------------------------------------------------
# Plan data model
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanHints:
    """Typed planning hints — the one structured object threaded through
    :func:`plan_program` / :func:`plan_for` / :func:`execute_plan`
    (DESIGN.md §10).

    ``sorts`` maps variable names to sort names, overriding
    ``Program.sort_hints`` (this is what the old loose ``hints`` dicts
    carried; a plain mapping is still accepted everywhere with a
    ``DeprecationWarning``).  ``adaptive=True`` turns on mid-fixpoint
    re-planning in :func:`execute_plan`: chunkable vector strata run
    under :func:`repro.core.runners.adaptive_fixpoint` and may switch
    runners at chunk boundaries.  ``replan`` overrides the default
    :class:`repro.sparse.adaptive.ReplanPolicy` (hysteresis, chunk
    size, switch bounds).
    """

    sorts: Mapping[str, str] = dataclasses.field(default_factory=dict)
    adaptive: bool = False
    replan: object | None = None

    def __post_init__(self):
        for k, v in dict(self.sorts).items():
            if not isinstance(k, str) or not isinstance(v, str):
                raise TypeError(f"PlanHints.sorts maps variable names to "
                                f"sort names, got {k!r}: {v!r}")
        if self.replan is not None and \
                not isinstance(self.replan, adaptive.ReplanPolicy):
            raise TypeError(f"PlanHints.replan must be a ReplanPolicy, "
                            f"got {type(self.replan).__name__}")

    @classmethod
    def of(cls, hints, *, defaults=None) -> "PlanHints":
        """Normalize a caller-supplied ``hints``: ``None`` falls back to
        ``defaults`` (the program's ``sort_hints``), a :class:`PlanHints`
        passes through, and a legacy mapping is wrapped with a
        deprecation warning."""
        if hints is None:
            return cls(sorts=dict(defaults or {}))
        if isinstance(hints, cls):
            return hints
        if isinstance(hints, Mapping):
            warnings.warn("loose hints dicts are deprecated; pass "
                          "planner.PlanHints(sorts={...})",
                          DeprecationWarning, stacklevel=3)
            return cls(sorts=dict(hints))
        raise TypeError(f"hints must be a PlanHints or a mapping, got "
                        f"{type(hints).__name__}")

    def cache_key(self) -> tuple:
        return (tuple(sorted(dict(self.sorts).items())), self.adaptive,
                self.replan)


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Per-iteration work × predicted trip count for one candidate."""

    flops_per_iter: float
    bytes_per_iter: float
    trips: int
    source: str = "analytic"  # "analytic" | "hlo"

    @property
    def total(self) -> float:
        return self.flops_per_iter * self.trips


@dataclasses.dataclass
class ShardedCostModel:
    """Measured constants behind the ``sparse_sharded`` candidate
    (DESIGN.md §8, calibrated against ``BENCH_sharded.json``).

    Sharding pays a fixed per-iteration toll — D synchronizing
    collectives plus the exchanged frontier bytes — so it only wins
    once per-device work dwarfs that toll.  ``min_work_per_device`` is
    the measured crossover: below it the partition is *rejected*
    outright (the PR-5 model picked sharding where one device was
    30–50× faster).  Above it, the candidate is priced with its sync
    and byte terms so close calls still compare honestly.  The
    BENCH_sharded.json sweep with the Δ-sparse exchange measures D=8
    already winning ~1.4× at 1.1e5 work/device/iter, so the floor sits
    well under that; toy graphs (≲1e4 work/device) stay single-device.
    Tests monkeypatch the fields to pin either side of the crossover.
    """

    #: (nnz + n)/D per iteration below which sharding cannot recoup its
    #: collective overhead — from the BENCH_sharded.json crossover sweep
    min_work_per_device: float = 2.0e4
    #: flop-equivalent cost of one synchronizing collective per device
    sync_flops_per_device: float = 1.0e4
    #: flop-equivalent cost per exchanged byte
    byte_flops: float = 0.05

    def sync_flops(self, d: int, backend: str) -> float:
        # host-simulated devices share cores: collectives serialize,
        # so the toll grows ~D per participant instead of staying flat
        scale = d if backend == "cpu" else 1
        return self.sync_flops_per_device * d * scale


#: module-level so tests and calibration sweeps can patch it in place
SHARDED_COST = ShardedCostModel()


@dataclasses.dataclass
class SpmmKernelModel:
    """Measured constants behind the ``sparse_frontier_pallas`` candidate
    (DESIGN.md §9, calibrated against ``BENCH_kernels.json``).

    The fused SpMM's win is per-iteration memory traffic, so it is
    priced as the jnp step scaled by the measured per-iteration speedup
    — ``hlo_cost.staged_cost`` prices the jnp step under
    ``cost_model="hlo"`` and the analytic model otherwise; this model
    supplies the scale and the crossover floor on top (the
    ``SHARDED_COST`` pattern).  On CPU the backend is the bit-packed
    host loop, measured 27× per-iteration for 𝔹 at the 50k-vertex
    B=64 serve shape (the 8× default leaves headroom for shallow
    fixpoints, where geometry planning amortizes over fewer rounds);
    f32 lattices (trop/maxplus) measured *slower* fused than the jnp
    scatter loop on CPU, so they get no win and stay on jnp — that IS
    the measured crossover, not a gap.  Tests monkeypatch the fields to
    pin both sides.
    """

    #: nnz(E) below which geometry planning + packing outweigh the
    #: per-iteration win (small graphs converge in ~ms either way)
    min_nnz: float = 4096.0
    #: measured per-iteration speedup of the host fused backend, per
    #: semiring; absent semirings measured no win on CPU
    host_speedup: dict = dataclasses.field(
        default_factory=lambda: {"bool": 8.0})
    #: per-iteration speedup credited to the fused Pallas kernel on TPU
    #: (one HBM pass instead of three for gather/⊗/scatter)
    tpu_speedup: float = 2.0

    def speedup(self, semiring: str, backend: str) -> float:
        """Measured per-iteration win on this platform; ≤ 1 ⇒ no win."""
        if backend == "tpu":
            return self.tpu_speedup
        return float(self.host_speedup.get(semiring, 0.0))


#: module-level so tests and calibration sweeps can patch it in place
SPMM_COST = SpmmKernelModel()


def spmm_exec_backend(runner: str = "sparse_frontier_pallas") -> str:
    """Resolve a runner's SpMM execution backend on this host.

    The ``sparse_frontier_pallas`` runner compiles the fused Pallas
    kernel on TPU (and under interpret forcing, so CI exercises the
    kernel path) and falls back to the fused host loop elsewhere; every
    other runner keeps the traceable jnp composition.  Serve-side
    kernel caches key on this value.
    """
    if runner != "sparse_frontier_pallas":
        return "jnp"
    from repro.kernels import ops as kops
    if jax.default_backend() == "tpu" or kops._FORCE_INTERPRET:
        return "pallas"
    return "fused"


@dataclasses.dataclass
class StratumPlan:
    """The physical choice for one fixpoint stratum."""

    index: int
    idbs: tuple[str, ...]
    runner: str
    reason: str
    storage: dict[str, str]        # relation → target repr (changes only)
    storage_notes: dict[str, str]  # relation → human-readable decision
    reads: tuple[str, ...]         # relation names this stratum consumes
    cost: CostEstimate | None
    considered: dict[str, CostEstimate]
    rejected: dict[str, str]
    vf: vectorize.VectorForm | None = None
    edges_override: object | None = None
    partition: str | None = None   # sparse_sharded: the graph-axis split
    #: trace of the last *adaptive* execution of this stratum (a
    #: :class:`repro.core.runners.AdaptiveRun`) — populated by
    #: :func:`execute_plan` under ``PlanHints(adaptive=True)`` and
    #: rendered by :func:`explain`; ``None`` until then, so static
    #: plans render byte-identically to the pre-§10 planner
    switch_log: object | None = None


@dataclasses.dataclass
class ExecutionPlan:
    """A fully-decided physical plan for a :class:`~repro.core.program.
    Program` against one database shape."""

    program: str
    objective: str
    mode: str                 # "auto" or the forcing mode string
    strata: list[StratumPlan]
    outputs: tuple[str, ...]
    has_post: bool
    signature: str
    #: the graph mesh this plan was priced against — a jax Mesh with a
    #: "graph" axis (executable), or a plain int D (planning/explain
    #: only; execution resolves a local mesh of that size).  ``None``
    #: plans are single-device and identical to the pre-§6 planner.
    mesh: object | None = None
    #: execute with mid-fixpoint re-planning (from PlanHints.adaptive)
    adaptive: bool = False
    #: the ReplanPolicy to execute under (from PlanHints.replan)
    replan: object | None = None


# --------------------------------------------------------------------------
# Planning
# --------------------------------------------------------------------------


def plan_program(prog, db: engine.Database, hints=None, *,
                 objective: str = "latency", mode: str = "auto",
                 max_iters: int = 10_000, cost_model: str = "analytic",
                 edges=None, adapt_storage: bool = True,
                 require_vector: bool = False,
                 delta_nnz: int | None = None,
                 delta_op: str = "merge",
                 mesh=None) -> ExecutionPlan:
    """Choose a physical runner + storage for every stratum of ``prog``.

    ``objective`` is "latency" (one query; host frontier worklists are in
    play on CPU), "throughput" (batched serving; only staged runners), or
    "incremental" (a warm previous solution exists and ``delta_nnz``
    tuples just changed — the "delta_restart" strategy is priced at
    O(nnz(Δ) · affected-trip-count) against every full-recompute
    candidate, DESIGN.md §5).  ``delta_op`` classifies the update for
    the incremental objective: ``"merge"`` (monotone ⊕, the default)
    keeps delta-restart in play, while ``"delete"``/``"increase"``/
    ``"mixed"`` reject it with a recorded reason and instead consider
    the "synth_maintenance" runner whenever a CEGIS-verified ⊖/recount
    rule for (program signature, semiring, op) is already cached
    (:func:`repro.incremental.maintenance.cached_rule`; planning never
    synthesizes — callers run :func:`repro.incremental.maintenance.
    ensure_rule` first, see DESIGN.md §11).  ``mode`` other than "auto"
    forces a runner on every stratum (legacy ``run_program`` strings
    compile to forced plans).  ``edges`` overrides the extracted linear
    operator of a single-stratum vector program (the serve loop's
    weighted-COO escape hatch).  ``adapt_storage=False`` pins every
    relation to its caller-chosen representation.  ``require_vector=True``
    raises ``ValueError`` with the recorded rejection reason when
    stratum 0 cannot take a vector runner (the serve loop can only batch
    the vector equation).

    ``mesh`` adds the device dimension (DESIGN.md §6): a jax Mesh with a
    ``("graph",)`` axis — or a plain int D for planning-only — makes the
    row-partitioned ``sparse_sharded`` runner a candidate, priced at
    per-shard nnz work plus the per-iteration frontier all-gather, and
    rejected with a recorded reason on single-device meshes or dense
    operators.  ``mesh=None`` plans are byte-identical to before.

    ``hints`` is a :class:`PlanHints` (legacy mappings of sort overrides
    are accepted with a ``DeprecationWarning``); ``PlanHints(
    adaptive=True)`` marks the plan for mid-fixpoint re-planning at
    execution (DESIGN.md §10).
    """
    if objective not in ("latency", "throughput", "incremental"):
        raise ValueError(f"unknown objective {objective!r}")
    ph = PlanHints.of(hints, defaults=prog.sort_hints)
    hints = dict(ph.sorts)
    if mesh is not None:
        from repro.distributed.datalog import mesh_size
        mesh_size(mesh)  # validate early: needs a "graph" axis / D ≥ 1
    forced = None
    if mode != "auto":
        forced = mode if mode in RUNNERS else \
            LEGACY_MODES.get(mode, "dense_host")
        if forced in ("delta_restart", "synth_maintenance"):
            raise ValueError(
                f"{forced} cannot be forced by mode= — it needs a "
                "previous solution; use objective='incremental' and "
                "repro.incremental.refresh_program")
        if forced == "sparse_sharded" and mesh is None:
            raise ValueError(
                "sparse_sharded needs a graph mesh — pass mesh= "
                "(launch.mesh.make_graph_mesh) alongside the forced mode")
    plans = []
    for si, stratum in enumerate(prog.strata):
        plans.append(_plan_stratum(
            prog, stratum, si, db, hints, objective=objective,
            forced=forced, cost_model=cost_model,
            edges=edges if si == 0 else None,
            adapt_storage=adapt_storage and forced is None,
            max_iters=max_iters,
            delta_nnz=delta_nnz if si == 0 else None,
            delta_op=delta_op, mesh=mesh))
    plan = ExecutionPlan(
        prog.name, objective, mode, plans,
        tuple(r.head for r in prog.outputs), prog.post is not None,
        _plan_signature(prog, db, plans), mesh=mesh,
        adaptive=ph.adaptive, replan=ph.replan)
    if require_vector:
        sp = plan.strata[0] if plan.strata else None
        if sp is None or sp.runner not in BATCHED_RUNNERS:
            why = "program has no fixpoint stratum" if sp is None \
                else _vector_rejection(sp.rejected)
            raise ValueError(f"{prog.name}: {why}")
    return plan


def _vector_rejection(rejected: Mapping[str, str]) -> str:
    """The most informative recorded reason why no vector runner was
    feasible — one helper so require_vector and the edges-override guard
    report the same infeasibility identically."""
    return (rejected.get("sparse_jit") or rejected.get("vector_dense")
            or "no vector-form runner is feasible")


def plan_for(prog, db: engine.Database, *, mode: str = "auto",
             max_iters: int = 10_000, objective: str = "latency",
             hints=None) -> ExecutionPlan:
    """Memoized :func:`plan_program` for repeated ``run_program`` calls:
    plans are cached on the Program object, keyed by the database
    fingerprint (stable across GC — see :func:`db_fingerprint`) and the
    normalized :class:`PlanHints`."""
    ph = PlanHints.of(hints, defaults=prog.sort_hints)
    cache = prog.__dict__.setdefault("_plan_cache", {})
    reads: set[str] = set()
    for stratum in prog.strata:
        reads |= _referenced(stratum)
    key = ("plan", mode, objective, max_iters, jax.default_backend(),
           ph.cache_key(), db_fingerprint(db, reads & set(db.relations)))
    plan = _cache_get(cache, key)
    if plan is None:
        plan = cache[key] = plan_program(prog, db, ph, mode=mode,
                                         objective=objective,
                                         max_iters=max_iters)
    return plan


def _cache_get(cache: dict, key):
    """Cache lookup that refreshes recency: the eviction loop in
    :func:`execute_plan` pops insertion-order-oldest entries, so a hit
    must move its entry to the end or steady-state reuse would evict
    exactly the entries being reused."""
    if key in cache:
        cache[key] = cache.pop(key)
        return cache[key]
    return None


def _referenced(stratum) -> set[str]:
    names: set[str] = set()
    exprs = [r.body for r in stratum.rules.values()]
    if stratum.init:
        exprs.extend(stratum.init.values())
    for e in exprs:
        for t in e.terms:
            for a in t.atoms:
                if isinstance(a, ir.RelAtom):
                    names.add(a.name)
    return names


def _edge_rel_name(vf: vectorize.VectorForm) -> str | None:
    """Relation name behind the sparse-preserving fast path of
    :func:`repro.core.vectorize.edge_operator` (the shared
    :func:`repro.core.vectorize.edge_atom` predicate)."""
    a = vectorize.edge_atom(vf)
    return a.name if a is not None else None


def _trip_estimate(n: int, nnz: float, cap: int = _TRIP_CAP) -> int:
    """Heuristic fixpoint depth: ≈ diameter of a random graph with the
    observed average degree, clipped to [3, ``cap``]."""
    deg = nnz / max(n, 1)
    if deg <= 1.0:
        return cap
    return int(min(cap, max(
        3, math.ceil(math.log(max(n, 2)) / math.log(deg)))))


def _term_flops(term: ir.Term, sorts: Mapping[str, str],
                db: engine.Database, planned: Mapping[str, str],
                densities: Mapping[str, float]) -> float:
    """Work of one sum-product term ≈ the broadcast join size, scaled by
    the density of any sparse-stored binary relation in it (the engine's
    SpMV/SpMM path does O(nnz) work instead of O(n²))."""
    vs = sorted(term.vars())
    size = 1.0
    for v in vs:
        size *= float(db.dom(sorts.get(v, "id")))
    scale = 1.0
    for a in term.atoms:
        if (isinstance(a, ir.RelAtom) and planned.get(a.name) == "sparse"
                and a.name in densities):
            scale = min(scale, max(densities[a.name], 1e-12))
    return max(size * scale, 1.0)


def _plan_stratum(prog, stratum, si, db, hints, *, objective, forced,
                  cost_model, edges, adapt_storage, max_iters,
                  delta_nnz=None, delta_op="merge",
                  mesh=None) -> StratumPlan:
    # ``reads`` keeps every referenced relation name — including IDBs of
    # *earlier strata*, which exist only at execution time; the executor
    # fingerprints the input database over the union of all strata's
    # reads, so a later stratum's cache key still varies with the EDBs
    # that feed it.
    reads = tuple(sorted(_referenced(stratum)))
    if forced is not None:
        # a forced runner needs no candidate enumeration — skip density
        # transfers, sort inference, and vector-form splitting (the CEGIS
        # verifier forces "naive" on every candidate × sample db)
        return _forced_stratum_plan(prog, stratum, si, forced, reads,
                                    edges, mesh=mesh)

    # -- storage folding (adaptive density thresholds, DESIGN.md §2/§4) ----
    storage: dict[str, str] = {}
    notes: dict[str, str] = {}
    densities: dict[str, float] = {}
    for name in (n for n in reads if n in db.relations):
        arr = db.relations[name]
        arity = arr.arity if isinstance(arr, SparseRelation) else np.ndim(arr)
        if arity != 2:
            continue  # only binary relations have sparse contraction paths
        d = adaptive.density(arr, db.schema[name].semiring)
        densities[name] = d
        cur = db.storage_of(name)
        target = adaptive.decide(d, cur) if adapt_storage else cur
        if target != cur:
            storage[name] = target
            bound = (f"< {adaptive.SPARSIFY_BELOW:g}" if target == "sparse"
                     else f"> {adaptive.DENSIFY_ABOVE:g}")
            notes[name] = f"{cur}→{target} (density {d:.3g} {bound})"
    planned = {name: storage.get(name, db.storage_of(name))
               for name in reads}

    shapes = {n: tuple(db.dom(s) for s in prog.schema[n].sorts)
              for n in stratum.idbs}
    state = float(sum(float(np.prod(s)) for s in shapes.values()))
    nnz_total = sum(densities[n] *
                    float(np.prod(_rel_shape(db.relations[n])))
                    for n in densities)
    n_dom = int(max((d for s in shapes.values() for d in s), default=1))

    considered: dict[str, CostEstimate] = {}
    rejected: dict[str, str] = {}

    # -- vector-equation feasibility (also pins the trip estimate) ---------
    vf = None
    if len(prog.strata) != 1:
        why = "multi-stratum program (the vector equation covers exactly " \
              "one stratum)"
        for r in VECTOR_RUNNERS:
            rejected[r] = why
    else:
        try:
            vf = vectorize.vector_form(prog)
        except ValueError as e:
            for r in VECTOR_RUNNERS:
                rejected[r] = str(e)
    if vf is not None:
        sr = sr_mod.get(vf.semiring)
        if sr.minus is None:
            why = (f"semiring {vf.semiring} lacks ⊖ — the vector GSN "
                   f"runners need an idempotent lattice")
            for r in VECTOR_RUNNERS:
                rejected[r] = why
            vf = None
    e_nnz = None
    n_vec = n_dom
    if vf is not None:
        n_vec = db.dom(vf.out_sort)
        if edges is not None:
            if isinstance(edges, SparseRelation):
                e_nnz = float(np.asarray(edges.as_np().nnz))
            # a dense override keeps the vector_dense candidate below
        else:
            ename = _edge_rel_name(vf)
            if (ename is not None and ename in db.relations
                    and planned.get(ename) == "sparse"):
                arr = db.relations[ename]
                if isinstance(arr, SparseRelation):
                    e_nnz = float(np.asarray(arr.as_np().nnz))
                else:
                    e_nnz = densities[ename] * float(
                        np.prod(_rel_shape(arr)))

    # one trip estimate for the whole stratum: every runner executes the
    # same fixpoint, so candidates must never be priced with different
    # iteration counts.  The linear operator's nnz is the best degree
    # signal when available; the all-relations total is the fallback.
    trip_cap = int(max(1, min(_TRIP_CAP, max_iters)))
    if e_nnz is not None:
        trips = _trip_estimate(n_vec, e_nnz, trip_cap)
    else:
        trips = _trip_estimate(n_dom,
                               nnz_total if nnz_total else n_dom * 8.0,
                               trip_cap)

    # -- dense engine candidates ------------------------------------------
    naive_f = state
    gsn_f = state
    for rule in stratum.rules.values():
        sorts = engine.infer_var_sorts(rule.body, prog.schema, hints)
        for t in rule.body.terms:
            f = _term_flops(t, sorts, db, planned, densities)
            naive_f += f
            if any(isinstance(a, ir.RelAtom) and a.name in stratum.rules
                   for a in t.atoms):
                gsn_f += f
    considered["dense_naive"] = CostEstimate(naive_f, 4.0 * naive_f, trips)
    no_minus = [n for n in stratum.idbs
                if sr_mod.get(prog.schema[n].semiring).minus is None]
    if not stratum.is_linear():
        rejected["dense_gsn"] = "non-linear recursion (δF needs a linear " \
                                "program)"
    elif no_minus:
        rejected["dense_gsn"] = (
            f"semiring {prog.schema[no_minus[0]].semiring} lacks ⊖ — GSN "
            f"needs an idempotent lattice")
    else:
        considered["dense_gsn"] = CostEstimate(gsn_f, 4.0 * gsn_f, trips)

    # -- vector-equation candidates ---------------------------------------
    if vf is not None:
        n = n_vec
        if e_nnz is not None:
            # staged loop: a full O(nnz) vspm re-derivation per iteration
            considered["sparse_jit"] = CostEstimate(
                e_nnz + n, 12.0 * e_nnz + 4.0 * n, trips)
            # host worklist: O(nnz) *total* edge expansions (each vertex
            # settles ~once) plus an O(n) Δ-scan per round
            considered["sparse_frontier"] = CostEstimate(
                e_nnz / trips + n, 12.0 * e_nnz / trips + 4.0 * n, trips)
            rejected["vector_dense"] = ("linear operator is sparse — the "
                                        "SpMV/SpMM runners cover it")
        else:
            considered["vector_dense"] = CostEstimate(
                float(n) * n + n, 4.0 * (float(n) * n + n), trips)
            why = "linear operator materializes dense (no sparse binary " \
                  "EDB fast path)"
            rejected["sparse_jit"] = why
            rejected["sparse_frontier"] = why

    # -- graph-axis sharded candidate (DESIGN.md §6/§8) --------------------
    # row-partitioned SpMM under shard_map with the Δ-sparse frontier
    # exchange: per-iteration critical-path work is the balanced shard's
    # frontier-proportional expansion (amortized e_nnz/trips, like the
    # host worklist) plus its O(n/D) carry update — but every iteration
    # also pays D synchronizing collectives and the exchanged bytes.
    # The mesh is an *offer*, not an instruction: below the measured
    # crossover the candidate is rejected so the single-device runners
    # keep regimes they win (the old always-shard policy was the
    # BENCH_sharded.json 30–50× mispick).
    partition = None
    if mesh is not None:
        if vf is None:
            rejected["sparse_sharded"] = _vector_rejection(rejected)
        else:
            from repro.distributed.datalog import mesh_size
            d_ax = mesh_size(mesh)
            nb = -(-n_vec // d_ax)
            if d_ax < 2:
                rejected["sparse_sharded"] = (
                    "graph mesh has a single device — the single-device "
                    "runners cover it")
            elif e_nnz is None:
                rejected["sparse_sharded"] = (
                    "linear operator materializes dense (no sparse "
                    "binary EDB fast path)")
            else:
                cm = SHARDED_COST
                work_dev = (e_nnz + n_vec) / d_ax
                if work_dev < cm.min_work_per_device:
                    rejected["sparse_sharded"] = (
                        f"below the sharding crossover: "
                        f"≈{work_dev:.3g} work/device/iter < "
                        f"{cm.min_work_per_device:g} measured minimum "
                        f"(BENCH_sharded.json) — one device wins")
                else:
                    itemsize = np.dtype(
                        sr_mod.get(vf.semiring).dtype).itemsize
                    dense_b = float(itemsize) * n_vec * (d_ax - 1)
                    delta_b = ((4.0 + itemsize) * (n_vec / trips)
                               * (d_ax - 1))
                    xbytes = min(dense_b, delta_b)
                    sync = cm.sync_flops(d_ax, jax.default_backend())
                    considered["sparse_sharded"] = CostEstimate(
                        e_nnz / trips + n_vec / d_ax + sync
                        + cm.byte_flops * xbytes,
                        12.0 * e_nnz / (trips * d_ax) + xbytes,
                        trips)
                    partition = (
                        f"graph axis D={d_ax} × {nb} dst rows/shard; "
                        f"nnz(E)={int(e_nnz)} "
                        f"(≈{-(-int(e_nnz) // d_ax)}/shard); "
                        f"Δ-exchange ≈{int(xbytes)} B/iter "
                        f"(dense all-gather {int(dense_b)} B)")

    # -- fused-kernel SpMM candidate (DESIGN.md §9) ------------------------
    # the staged GSN loop with the gather→⊗→segment-⊕ advance fused into
    # one pass over edge tiles (kernels/coo_spmm.py).  Offered for
    # batched serving only: the kernel's measured win is amortized
    # across B query lanes, while single-shot latency already belongs to
    # the frontier worklist.  When an offered mesh clears the sharding
    # crossover the partition wins outright — the fused kernel is a
    # single-device backend and has no measured number against D
    # devices.
    if vf is not None:
        if objective != "throughput":
            rejected["sparse_frontier_pallas"] = (
                "fused-kernel SpMM is a batched-serving backend "
                "(objective='throughput') — single-shot latency keeps "
                "the worklist/staged runners")
        elif e_nnz is None:
            rejected["sparse_frontier_pallas"] = (
                "linear operator materializes dense (no sparse binary "
                "EDB fast path)")
        elif "sparse_sharded" in considered:
            rejected["sparse_frontier_pallas"] = (
                "graph-axis sharding clears its crossover — the fused "
                "kernel is single-device and is not priced against a "
                "D-device mesh")
        else:
            cm_k = SPMM_COST
            sp_up = cm_k.speedup(vf.semiring, jax.default_backend())
            if sp_up <= 1.0:
                rejected["sparse_frontier_pallas"] = (
                    f"no measured fused-kernel win for {vf.semiring} on "
                    f"{jax.default_backend()} — the jnp scatter loop is "
                    f"already bandwidth-bound (BENCH_kernels.json)")
            elif e_nnz < cm_k.min_nnz:
                rejected["sparse_frontier_pallas"] = (
                    f"below the fused-kernel crossover: "
                    f"nnz(E)={int(e_nnz)} < {cm_k.min_nnz:g} measured "
                    f"minimum (BENCH_kernels.json) — geometry planning "
                    f"outweighs the per-iteration win")
            else:
                considered["sparse_frontier_pallas"] = CostEstimate(
                    (e_nnz + n_vec) / sp_up + n_vec,
                    (12.0 * e_nnz + 4.0 * n_vec) / sp_up, trips)

    # the host worklist only pays off for single-shot latency on a CPU
    # host; batched serving and accelerators want the staged SpMM loop
    frontier_ok = (objective in ("latency", "incremental")
                   and jax.default_backend() == "cpu")
    if "sparse_frontier" in considered and not frontier_ok:
        rejected["sparse_frontier"] = ("host worklist loses to the staged "
                                       "while_loop off-CPU / for batches")
        del considered["sparse_frontier"]
    if objective == "throughput" and \
            any(r in considered for r in VECTOR_RUNNERS):
        for r in ("dense_naive", "dense_gsn"):
            if r in considered:
                rejected[r] = ("not batchable — throughput serving packs "
                               "sources into one vector fixpoint")
                del considered[r]
    if edges is not None:
        # the caller supplied the linear operator; only the vector
        # runners consult it — a dense engine pick would silently run
        # over the database's own relations instead
        for r in ("dense_naive", "dense_gsn"):
            if r in considered:
                rejected[r] = ("edges override requires a vector runner "
                               "(the engine paths read the stored "
                               "relations, not the override)")
                del considered[r]
        if not considered:
            raise ValueError(f"{prog.name}: edges override cannot be "
                             f"honored: {_vector_rejection(rejected)}")

    # -- incremental maintenance: delta-restart / synth_maintenance --------
    # priced at O(nnz(Δ) · affected-trip-count): the warm repair seeds
    # its frontier from the nnz(Δ) touched edges, and per round the
    # affected region grows by ~the average degree, never beyond nnz(E)
    # (full-recompute per-round work).  Only offered under
    # objective="incremental" so latency/throughput plans are unchanged.
    # Monotone ⊕-merges take "delta_restart" (DESIGN.md §5); deletes and
    # weight increases void its pre-fixpoint property and instead take
    # "synth_maintenance" — but only when a CEGIS-verified ⊖/recount
    # rule is already cached for (signature, semiring, op); planning has
    # no side effects, so it never synthesizes one (DESIGN.md §11).
    synth_rule = None
    if objective == "incremental":
        if delta_nnz is None:
            rejected["delta_restart"] = (
                "no update delta recorded — pass delta_nnz "
                "(repro.incremental.refresh_program does)")
            rejected["synth_maintenance"] = rejected["delta_restart"]
        elif vf is None:
            rejected["delta_restart"] = _vector_rejection(rejected)
            rejected["synth_maintenance"] = rejected["delta_restart"]
        elif e_nnz is None:
            rejected["delta_restart"] = (
                "linear operator materializes dense — delta seeding "
                "needs the sparse fast path")
            rejected["synth_maintenance"] = rejected["delta_restart"]
        elif delta_op == "merge":
            deg = max(1.0, e_nnz / max(n_vec, 1))
            affected = min(float(e_nnz), float(delta_nnz) * deg)
            considered["delta_restart"] = CostEstimate(
                affected + 1.0, 12.0 * affected, trips)
            rejected["synth_maintenance"] = (
                "update is a monotone ⊕-merge — delta-restart needs no "
                "synthesized ⊖/recount rule")
        else:
            rejected["delta_restart"] = (
                f"{delta_op} is non-monotone (not a ⊕-merge) — the old "
                f"solution is no pre-fixpoint of the new operator and a "
                f"warm restart could over-derive (DESIGN.md §11)")
            from repro.incremental import maintenance as _mt
            rule = _mt.cached_rule(vf.signature, vf.semiring, delta_op)
            if rule is None:
                rejected["synth_maintenance"] = (
                    f"no maintenance rule cached for ({vf.semiring}, "
                    f"{delta_op}) — run repro.incremental.maintenance."
                    f"ensure_rule first")
            elif not rule.verified:
                rejected["synth_maintenance"] = (
                    f"rule synthesis failed: {rule.reason}")
            else:
                synth_rule = rule
                # seeds ≤ nnz(Δ); the tight cone grows by ~deg per hop
                # and its in-edge recount re-reads each cone vertex's
                # in-adjacency once — a constant factor over the
                # delta-restart frontier estimate
                deg = max(1.0, e_nnz / max(n_vec, 1))
                affected = min(float(e_nnz), float(delta_nnz) * deg)
                considered["synth_maintenance"] = CostEstimate(
                    2.0 * affected + 1.0, 16.0 * affected, trips)

    if cost_model == "hlo":
        considered = _hlo_costs(considered, prog, stratum, db, hints, vf,
                                edges, trips, storage)

    # -- selection ---------------------------------------------------------
    pref = list(RUNNERS)
    if frontier_ok:
        pref.remove("sparse_frontier")
        pref.insert(0, "sparse_frontier")
    runner = min(considered,
                 key=lambda k: (considered[k].total, pref.index(k)))
    cost = considered[runner]
    reason = (f"min est. total flops among "
              f"{len(considered)} feasible candidates")
    if runner == "sparse_frontier":
        reason += " (cpu host ⇒ frontier worklist)"
    if runner == "delta_restart":
        reason += (f" (warm restart: nnz(Δ)={int(delta_nnz)} seeds the "
                   f"frontier)")
    if runner == "synth_maintenance":
        reason += (f" (synthesized rule {synth_rule.name} repairs the "
                   f"{delta_op} in-place: {synth_rule.reason})")
    return StratumPlan(si, tuple(stratum.idbs), runner, reason, storage,
                       notes, reads, cost, considered, rejected, vf, edges,
                       partition if runner == "sparse_sharded" else None)


def _forced_stratum_plan(prog, stratum, si, forced, reads, edges, *,
                         mesh=None) -> StratumPlan:
    """Legacy-mode plans: the runner is predetermined, storage stays as
    the caller chose it, no candidates are priced.  Infeasibility (e.g.
    forcing GSN on a non-linear stratum) surfaces at execution time with
    the historical error, exactly as the pre-planner code did."""
    vf = None
    partition = None
    if forced in BATCHED_RUNNERS:
        if len(prog.strata) != 1:
            raise ValueError(
                f"{prog.name}: cannot force runner {forced!r}: "
                f"multi-stratum program")
        try:
            vf = vectorize.vector_form(prog)
        except ValueError as e:
            raise ValueError(
                f"{prog.name}: cannot force runner {forced!r}: {e}")
        if forced == "sparse_sharded":
            from repro.distributed.datalog import mesh_size
            partition = f"graph axis D={mesh_size(mesh)} (forced)"
    elif edges is not None:
        raise ValueError(
            f"{prog.name}: edges override cannot be honored by forced "
            f"runner {forced!r} — the dense engine paths read the stored "
            f"relations, not the override")
    return StratumPlan(si, tuple(stratum.idbs), forced,
                       f"forced by mode={forced!r}", {}, {}, reads,
                       None, {}, {}, vf, edges, partition)


def _rel_shape(arr):
    return arr.shape if isinstance(arr, SparseRelation) else \
        np.shape(arr)


def _hlo_costs(considered, prog, stratum, db, hints, vf, edges, trips,
               storage):
    """Re-price each feasible candidate by staging its per-iteration step
    and walking the optimized HLO (:func:`repro.launch.hlo_cost.
    staged_cost`).  Falls back to the analytic estimate per candidate."""
    from repro.core import program as prog_mod
    from repro.launch import hlo_cost
    out = dict(considered)
    db2 = db
    for name, target in storage.items():
        db2 = db2.with_storage(name, target)

    def price(runner):
        if runner in ("dense_naive", "dense_gsn"):
            ico = (prog_mod.make_ico(stratum, db2, hints)
                   if runner == "dense_naive"
                   else prog_mod.make_delta_ico(stratum, db2, hints))
            x0 = prog_mod.zero_state(stratum, db2)
            c = hlo_cost.staged_cost(ico, x0)
        elif runner in ("sparse_jit", "sparse_frontier"):
            from repro.sparse import contract
            e = _materialize_edges(vf, db2, hints, override=edges)
            sr = sr_mod.get(vf.semiring)
            d0 = sr.zeros((db2.dom(vf.out_sort),))
            c = hlo_cost.staged_cost(
                lambda d: contract.vspm(d, e), d0)
        else:  # vector_dense
            from repro.kernels import ops as kops
            e = _materialize_edges(vf, db2, hints, override=edges,
                                   densify=True)
            sr = sr_mod.get(vf.semiring)
            d0 = sr.zeros((1, db2.dom(vf.out_sort)))
            c = hlo_cost.staged_cost(
                lambda d: kops.semiring_matmul(sr, d, e), d0)
        return CostEstimate(max(c.flops, 1.0), c.bytes, trips, "hlo")

    for runner in list(out):
        if runner in ("delta_restart", "synth_maintenance",
                      "sparse_sharded", "sparse_frontier_pallas"):
            # none has a single-device staged step to walk (the sharded
            # per-iteration HLO is per-shard; the fused kernel's
            # geometry is host-planned) — analytic stands, except the
            # fused kernel which re-derives from the walked jnp step
            continue
        try:
            out[runner] = price(runner)
        except Exception:  # noqa: BLE001 — keep the analytic estimate
            pass
    if "sparse_frontier_pallas" in out:
        # price the fused kernel as the hlo-walked jnp step scaled by
        # its measured per-iteration win (SPMM_COST), keeping the two
        # candidates on the same footing under cost_model="hlo"
        base = out.get("sparse_jit")
        if base is not None and base.source == "hlo":
            s = max(SPMM_COST.speedup(vf.semiring,
                                      jax.default_backend()), 1.0)
            out["sparse_frontier_pallas"] = CostEstimate(
                base.flops_per_iter / s, base.bytes_per_iter / s,
                trips, "hlo")
    return out


def _plan_signature(prog, db, plans) -> str:
    parts = []
    for sp, stratum in zip(plans, prog.strata):
        shapes = tuple((n, prog.schema[n].semiring,
                        tuple(db.dom(s) for s in prog.schema[n].sorts))
                       for n in sp.idbs)
        core = sp.vf.signature if sp.vf is not None else \
            _stratum_hash(stratum)
        parts.append((sp.runner, shapes, core,
                      tuple(sorted(sp.storage.items()))))
    payload = repr((tuple(r.head for r in prog.outputs), parts))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def _stratum_hash(stratum) -> str:
    payload = repr(sorted((n, repr(r.body))
                          for n, r in stratum.rules.items()))
    if stratum.init:
        payload += repr(sorted((n, repr(e))
                               for n, e in stratum.init.items()))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Explain
# --------------------------------------------------------------------------


def explain(plan: ExecutionPlan) -> str:
    """Stable, golden-testable rendering of an :class:`ExecutionPlan`."""
    lines = [f"plan {plan.program}  mode={plan.mode}  "
             f"objective={plan.objective}  signature={plan.signature}"]
    for sp in plan.strata:
        lines.append(f"  stratum {sp.index}  runner={sp.runner}  "
                     f"idbs={','.join(sp.idbs)}")
        lines.append(f"    reason      {sp.reason}")
        if sp.partition is not None:
            lines.append(f"    partition   {sp.partition}")
        for name in sorted(sp.storage):
            lines.append(f"    storage     {name}: {sp.storage_notes[name]}")
        if sp.cost is not None:
            c = sp.cost
            lines.append(f"    cost        {c.flops_per_iter:.3g} flops/iter"
                         f" × {c.trips} iters  [{c.source}]")
        if sp.considered:
            body = "  ".join(
                f"{k}={v.total:.3g}" for k, v in
                sorted(sp.considered.items(),
                       key=lambda kv: (kv[1].total, kv[0])))
            lines.append(f"    considered  {body}")
        for k in sorted(sp.rejected):
            lines.append(f"    rejected    {k}: {sp.rejected[k]}")
        if sp.switch_log is not None:
            # only present after an adaptive execution (DESIGN.md §10);
            # plans that never executed adaptively render byte-
            # identically to the static planner (golden tests)
            t = sp.switch_log
            lines.append(
                f"    adaptive    {len(t.chunks)} chunks × "
                f"{t.policy.chunk_iters} iters, {len(t.switches)} "
                f"switches, finished on {t.final_runner}")
            for ev in t.switches:
                lines.append(
                    f"    switch      chunk {ev.chunk} @ iter "
                    f"{ev.iteration}: {ev.from_runner} → {ev.to_runner}"
                    f"  (frontier nnz={ev.frontier_nnz}, density="
                    f"{ev.density:.3g}, est {ev.est_from:.3g} → "
                    f"{ev.est_to:.3g} ns/iter)")
    outs = " ← ".join(plan.outputs) if plan.outputs else "(fixpoint state)"
    post = "  + host post-epilogue" if plan.has_post else ""
    lines.append(f"  outputs    {outs}{post}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# Execution
# --------------------------------------------------------------------------


def execute_plan(plan: ExecutionPlan, prog, db: engine.Database, *,
                 max_iters: int = 10_000, hints=None):
    """Run ``prog`` under ``plan``; returns ``(answer, RunStats)``.

    Staged executables, initial states, storage conversions, and
    materialized linear operators are cached on the Program object keyed
    by stable database fingerprints, so a cache hit skips `make_ico` /
    `init_state` / `edge_operator` construction entirely.

    ``hints`` (a :class:`PlanHints`; legacy mappings warn) defaults to
    the program's own sort hints.  Adaptive re-planning runs when either
    the plan or the hints asks for it: chunkable vector strata execute
    via :func:`repro.core.runners.adaptive_fixpoint`, their switch
    history lands on ``StratumPlan.switch_log``, and ``explain(plan)``
    renders it afterwards.
    """
    from repro.core import program as prog_mod
    ph = PlanHints.of(hints, defaults=prog.sort_hints)
    hints = dict(ph.sorts)
    adaptive_exec = bool(plan.adaptive or ph.adaptive)
    replan = ph.replan if ph.replan is not None else plan.replan
    cache = prog.__dict__.setdefault("_plan_cache", {})
    iters_log: list[int] = []
    # one fingerprint of the *input* database anchors every stratum's
    # staged-cache key: stratum outputs are deterministic functions of
    # the EDBs, so later strata reuse their staged closures across runs
    # even though each run materializes fresh intermediate arrays (keying
    # on those would make every later stratum a guaranteed cache miss)
    all_reads: set[str] = set()
    for sp in plan.strata:
        all_reads |= set(sp.reads)
    base_fp = db_fingerprint(db, all_reads)
    cur_db = db
    for sp, stratum in zip(plan.strata, prog.strata):
        cur_db = _apply_storage(sp, cur_db, cache)
        state, iters = _run_stratum(sp, stratum, prog, cur_db, hints,
                                    cache, max_iters, base_fp,
                                    mesh=plan.mesh,
                                    adaptive_exec=adaptive_exec,
                                    replan=replan)
        iters_log.append(int(iters))
        cur_db = cur_db.with_relations(state)
    out = None
    for rule in prog.outputs:
        out = engine.eval_ssp(rule.body, cur_db, hints)
        cur_db = cur_db.with_relations({rule.head: out})
    if prog.post is not None:
        out = prog.post(out, cur_db)
    while len(cache) > _CACHE_MAX:
        cache.pop(next(iter(cache)))
    return out, prog_mod.RunStats(iters_log, plan.mode, plan)


def _apply_storage(sp: StratumPlan, db: engine.Database, cache):
    """Apply the plan's per-relation storage decisions, memoizing each
    converted array so repeated executions reuse one stable object (and
    therefore one stable fingerprint)."""
    for name, target in sp.storage.items():
        arr = db.relations.get(name)
        if arr is None or db.storage_of(name) == target:
            continue
        key = ("storage", name, target, value_fingerprint(arr))
        conv = _cache_get(cache, key)
        if conv is None:
            conv = db.with_storage(name, target).relations[name]
            cache[key] = conv
        db = db.with_relations({name: conv})
    return db


def _materialize_edges(vf, db, hints, *, override=None, densify=False):
    """The linear operator E, cast into the equation's semiring; sparse
    operators land as jnp COO ready for the SpMV/SpMM runners."""
    e = override if override is not None else \
        vectorize.edge_operator(vf, db, hints)
    if isinstance(e, SparseRelation):
        e = vectorize._sparse_into_semiring(e, vf.semiring)
        e = e.to_dense() if densify else e.as_jnp()
    return e


def _mesh_key(mesh):
    """Hashable identity of a (graph) mesh for the staged-runner cache:
    axis layout plus the concrete device ids (an int-D planning mesh
    resolves to the local devices at execution)."""
    from jax.sharding import Mesh
    if isinstance(mesh, Mesh):
        return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
                tuple(d.id for d in mesh.devices.flat))
    return int(mesh)


def exec_mesh(plan: ExecutionPlan):
    """The concrete Mesh a ``sparse_sharded`` plan executes on: the
    plan's own Mesh, or — when planning used a plain int D — a local
    graph mesh of that size (needs ≥ D local devices)."""
    from jax.sharding import Mesh
    if isinstance(plan.mesh, Mesh):
        return plan.mesh
    if plan.mesh is None:
        raise ValueError(f"{plan.program}: sparse_sharded plan has no "
                         f"mesh — re-plan with mesh=")
    from repro.launch.mesh import make_graph_mesh
    return make_graph_mesh(int(plan.mesh))


def _resolve_mesh(mesh, *, required: bool):
    """Concrete Mesh for execution: pass a Mesh through, resolve a plain
    int D against the local devices.  ``required=False`` (the adaptive
    candidate set on a non-sharded plan) tolerates unresolvable meshes —
    the sharded candidate just drops out."""
    if mesh is None:
        return None
    from jax.sharding import Mesh
    if isinstance(mesh, Mesh):
        return mesh
    from repro.launch.mesh import make_graph_mesh
    try:
        return make_graph_mesh(int(mesh))
    except Exception:
        if required:
            raise
        return None


def _run_stratum(sp, stratum, prog, cur_db, hints, cache, max_iters,
                 base_fp, *, mesh=None, adaptive_exec=False, replan=None):
    from repro.core import runners as runners_mod

    if sp.runner == "delta_restart":
        raise ValueError(
            f"{prog.name}: delta_restart plans carry no previous "
            f"solution to restart from — execute them via "
            f"repro.incremental.refresh_program")
    runner = runners_mod.get(sp.runner)
    key = (sp.index, sp.runner, max_iters, base_fp,
           tuple(sorted(sp.storage.items())),
           None if sp.edges_override is None
           else value_fingerprint(sp.edges_override),
           None if mesh is None else _mesh_key(mesh))
    ent = _cache_get(cache, key)

    if sp.runner in BATCHED_RUNNERS:
        if ent is None:
            vf = sp.vf
            edges = _materialize_edges(
                vf, cur_db, hints, override=sp.edges_override,
                densify=sp.runner == "vector_dense")
            if sp.runner != "vector_dense" and \
                    not isinstance(edges, SparseRelation):
                edges = SparseRelation.from_dense(
                    np.asarray(edges), vf.semiring).as_jnp()
            init = vectorize.init_vector(vf, cur_db, hints)
            m = _resolve_mesh(mesh,
                              required=sp.runner == "sparse_sharded")
            ctx = runners_mod.make_context(edges, init, vf.semiring,
                                           max_iters, mesh=m)
            ent = (runner.full_fn(ctx), runner.operand(ctx), ctx)
            cache[key] = ent
        fn, operand, ctx = ent
        if adaptive_exec and runner.chunkable:
            x, iters, trace = runners_mod.adaptive_fixpoint(
                ctx, start=sp.runner, candidates=tuple(sp.considered),
                policy=replan)
            sp.switch_log = trace
        else:
            x, iters = fn(operand, ctx.init)
        return {sp.idbs[0]: x}, int(np.asarray(iters))

    if ent is None:
        ent = runner.stratum_fn(stratum, cur_db, hints, max_iters)
        cache[key] = ent
    fn, x0 = ent
    x, iters = fn(x0)
    return x, int(np.asarray(iters))


# --------------------------------------------------------------------------
# Batched serving hooks (the serve loop's side of the pipeline)
# --------------------------------------------------------------------------


def materialize_edges(plan: ExecutionPlan, db: engine.Database,
                      hints=None, *, override=None):
    """The linear operator for stratum 0, ready for
    :func:`compile_batched` (sparse COO on device, or a dense matrix)."""
    sp = plan.strata[0]
    return _materialize_edges(sp.vf, db, hints,
                              override=override
                              if override is not None
                              else sp.edges_override,
                              densify=sp.runner == "vector_dense")


def source_init(plan: ExecutionPlan, prog, db: engine.Database, *,
                hints=None, backend: str = "jnp"):
    """Vector-form a per-source program, verify it kept the plan's linear
    operator, and evaluate its O(n) init terms."""
    vf = vectorize.vector_form(prog)
    base = plan.strata[0].vf
    if vf.signature != base.signature:
        raise ValueError(
            f"{plan.program}: source program changed the linear operator "
            f"({vf.signature} != {base.signature}) — sources must only "
            f"move the init term")
    return vectorize.init_vector(vf, db, hints, backend=backend)


def compile_batched(plan: ExecutionPlan, *,
                    max_iters: int = 10_000) -> Callable:
    """A jitted ``run(edges, init)`` over a ``(B, n)`` init pack for
    stratum 0's runner — the serve loop's compiled unit, cached by the
    caller under ``(plan.signature, B-bucket)``."""
    from repro.core import runners as runners_mod

    sp = plan.strata[0]
    if sp.runner not in BATCHED_RUNNERS:
        raise ValueError(f"{plan.program}: runner {sp.runner!r} has no "
                         f"batched form")
    return runners_mod.get(sp.runner).batched_fn(plan, max_iters)
