"""The FGH optimizer (paper Fig. 6): Π₁(F, G) + Γ  →  Π₂(H).

Pipeline, mirroring the paper's architecture:

1. **Invariant inference** (invariants.py) — symbolic execution + probe
   identities; verified invariants become term-rewrite rules.
2. **Rule-based synthesis** (Sec. 6.1) — compute P₁ = normalize(G(F(X)))
   symbolically, then *denormalize*: rewrite P₁ using the view V = G(X) by
   sub-multiset matching of G's sum-product into each P₁ term (query
   rewriting using views).  Invariant rewrites extend the reachable forms
   (beyond magic).  Fails over to —
3. **CEGIS** (synthesis.py, Sec. 6.2) — counterexample-guided enumeration
   of the grammar Σ.
4. **Verification** — orbit/bounded-model check of the candidate H, plus a
   final whole-program Π₁ ≡ Π₂ answer comparison.
5. **GSN** — the optimized program runs under generalized semi-naive
   evaluation when its semiring is an idempotent lattice (Sec. 3.1; applied
   by the fixpoint runner, pattern-style, exactly as the paper does).
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core import invariants as inv_mod
from repro.core import ir, synthesis, verify
from repro.core.ir import C, RelAtom, Term
from repro.core.program import Program, Rule, Stratum


@dataclasses.dataclass
class OptimizationReport:
    ok: bool
    method: str | None                 # 'rule' | 'cegis'
    h_body: ir.SSP | None
    program: Program | None            # Π₂
    invariants: list
    stats: dict


# --------------------------------------------------------------------------
# Sub-multiset pattern matching (shared by denormalization + inv rewrites)
# --------------------------------------------------------------------------


def _unify_args(p_args, t_args, sigma, pattern_bound):
    """Extend sigma mapping pattern args -> term args; None on clash."""
    sigma = dict(sigma)
    for pa, ta in zip(p_args, t_args):
        if isinstance(pa, C):
            if not (isinstance(ta, C) and ta.value == pa.value):
                return None
        else:
            if pa in sigma:
                if sigma[pa] != ta:
                    return None
            else:
                sigma[pa] = ta
    return sigma


def _atoms_match(pa, ta) -> bool:
    if type(pa) is not type(ta):
        return False
    if isinstance(pa, RelAtom):
        return (pa.name == ta.name and pa.cast == ta.cast and pa.neg == ta.neg)
    if isinstance(pa, ir.PredAtom):
        return pa.pred == ta.pred
    if isinstance(pa, ir.ValAtom):
        return True
    if isinstance(pa, ir.ConstAtom):
        return pa.value == ta.value
    return False


def match_pattern(pattern_atoms, pattern_bound, term: Term):
    """Yield (sigma, used_indices) for injective sub-multiset matches of the
    pattern into ``term``.  Pattern-bound vars must map (injectively) onto
    term-bound vars that occur *only* inside the matched atoms."""
    t_atoms = list(term.atoms)

    def rec(pi, sigma, used):
        if pi == len(pattern_atoms):
            # bound-var containment checks
            img = {}
            for pv in pattern_bound:
                if pv in sigma:
                    tv = sigma[pv]
                    if isinstance(tv, C) or tv not in term.bound:
                        return
                    img[pv] = tv
            if len(set(img.values())) != len(img):
                return
            outside = set()
            for k, a in enumerate(t_atoms):
                if k not in used:
                    outside.update(ir.atom_vars(a))
            if any(tv in outside for tv in img.values()):
                return
            yield dict(sigma), frozenset(used)
            return
        pa = pattern_atoms[pi]
        p_args = (pa.args if hasattr(pa, "args")
                  else ((pa.var,) if isinstance(pa, ir.ValAtom) else ()))
        for k, ta in enumerate(t_atoms):
            if k in used or not _atoms_match(pa, ta):
                continue
            t_args = (ta.args if hasattr(ta, "args")
                      else ((ta.var,) if isinstance(ta, ir.ValAtom) else ()))
            s2 = _unify_args(p_args, t_args, sigma, pattern_bound)
            if s2 is not None:
                yield from rec(pi + 1, s2, used | {k})

    yield from rec(0, {}, set())


def rewrite_with_invariant(term: Term, inv, sr_name: str):
    """Apply L→R (and R→L) of an invariant to ``term``; yields new terms."""
    for lhs, rhs in ((inv.lhs, inv.rhs), (inv.rhs, inv.lhs)):
        for sigma, used in match_pattern(lhs.atoms, lhs.bound, term):
            remaining = tuple(a for k, a in enumerate(term.atoms)
                              if k not in used)
            consumed = {sigma[v] for v in lhs.bound if v in sigma}
            # fresh names for rhs bound vars
            sub = dict(sigma)
            new_bound = []
            for bv in rhs.bound:
                if bv not in sub:
                    fv = ir.fresh_var(bv)
                    sub[bv] = fv
                    new_bound.append(fv)
            new_atoms = tuple(a.rename(sub) for a in rhs.atoms)
            bound = tuple(b for b in term.bound if b not in consumed) \
                + tuple(new_bound)
            nt = ir.normalize_term(Term(remaining + new_atoms, bound), sr_name)
            if nt is not None:
                yield nt


# --------------------------------------------------------------------------
# Rule-based synthesis: denormalization via view matching (Sec. 6.1)
# --------------------------------------------------------------------------


def _term_variants(term: Term, invs, sr_name: str, depth: int = 2):
    seen = {ir.canonical_term(term, ()): term}
    frontier = [term]
    for _ in range(depth):
        nxt = []
        for t in frontier:
            for inv in invs:
                for nt in rewrite_with_invariant(t, inv, sr_name):
                    k = ir.canonical_term(nt, ())
                    if k not in seen:
                        seen[k] = nt
                        nxt.append(nt)
        frontier = nxt
        if not frontier:
            break
    return list(seen.values())


def rule_based_synthesis(task: verify.FGHTask, invs,
                         ) -> tuple[ir.SSP | None, dict]:
    t0 = time.perf_counter()
    stats = {"variants_explored": 0}
    if len(task.outputs) != 1:
        return None, {**stats, "why": "chained G", "time_s": 0.0}
    g = task.outputs[0].body
    if len(g.terms) != 1:
        return None, {**stats, "why": "multi-term G", "time_s": 0.0}
    defs = {n: r.body for n, r in task.stratum.rules.items()}
    try:
        p1 = ir.substitute_defs(g, defs)
    except ir.NonIdempotentCast:
        return None, {**stats, "why": "non-idempotent cast",
                      "time_s": time.perf_counter() - t0}

    g_term = g.terms[0]
    idbs = set(task.stratum.rules)
    y = task.y_name

    def has_x(t: Term) -> bool:
        return any(isinstance(a, RelAtom) and a.name in idbs for a in t.atoms)

    h_terms = []
    for t in p1.terms:
        if not has_x(t):
            h_terms.append(t)
            continue
        matched = None
        variants = _term_variants(t, invs, p1.semiring)
        stats["variants_explored"] += len(variants)
        for tv in variants:
            for sigma, used in match_pattern(g_term.atoms, g_term.bound, tv):
                rest = tuple(a for k, a in enumerate(tv.atoms) if k not in used)
                if any(isinstance(a, RelAtom) and a.name in idbs for a in rest):
                    continue  # leftover X outside the view: not total
                consumed = {sigma[v] for v in g_term.bound if v in sigma}
                y_args = tuple(sigma.get(hv, hv) for hv in g.head)
                bound = tuple(b for b in tv.bound if b not in consumed)
                matched = Term((RelAtom(y, y_args),) + rest, bound)
                break
            if matched is not None:
                break
        if matched is None:
            return None, {**stats, "why": f"unmatched term: {ir.term_str(t)}",
                          "time_s": time.perf_counter() - t0}
        h_terms.append(matched)

    h = ir.normalize(ir.SSP(g.head, tuple(h_terms), g.semiring))
    stats["time_s"] = time.perf_counter() - t0
    return h, stats


# --------------------------------------------------------------------------
# Π₂ assembly + the full optimizer
# --------------------------------------------------------------------------


def make_gh_program(task: verify.FGHTask, h_body: ir.SSP,
                    post=None) -> Program:
    y = task.y_name
    idbs = set(task.stratum.rules)
    init = None
    if len(task.outputs) == 1:
        g = task.outputs[0].body
        init_terms = tuple(
            t for t in g.terms
            if not any(isinstance(a, RelAtom) and a.name in idbs
                       for a in t.atoms))
        if init_terms:
            init = {y: ir.SSP(g.head, init_terms, g.semiring)}
    stratum = Stratum({y: Rule(y, h_body)}, init=init)
    out = Rule(f"{y}__ans", ir.SSP(
        h_body.head, (Term((RelAtom(y, h_body.head),), ()),),
        h_body.semiring))
    hints = dict(task.sort_hints)
    hints.update(zip(h_body.head, task.schema[y].sorts))
    return Program(f"{task.name}_fgh", task.schema, [stratum], [out],
                   post=post, sort_hints=hints)


def optimize(task: verify.FGHTask, *, rng: np.random.Generator | None = None,
             infer_invs: bool = True, cegis_kwargs: dict | None = None,
             post=None) -> OptimizationReport:
    rng = rng or np.random.default_rng(0)
    t_start = time.perf_counter()
    invs: list = []
    inv_stats: dict = {"time_s": 0.0, "candidates": 0}
    if infer_invs:
        invs, inv_stats = inv_mod.infer_invariants(task, rng=rng)

    stats: dict = {"invariant_inference": inv_stats}

    h, rb_stats = rule_based_synthesis(task, invs)
    stats["rule_based"] = rb_stats
    method = None
    if h is not None:
        res = verify.verify_h(task, h, rng=rng)
        if res.ok:
            method = "rule"
        else:
            stats["rule_based"]["why"] = "verification failed"
            h = None
    if h is None:
        cres = synthesis.synthesize(task, rng=rng, **(cegis_kwargs or {}))
        stats["cegis"] = cres.stats
        if cres.ok:
            h, method = cres.h_body, "cegis"

    if h is None:
        stats["total_time_s"] = time.perf_counter() - t_start
        return OptimizationReport(False, None, None, None, invs, stats)

    prog = make_gh_program(task, h, post=post)
    stats["total_time_s"] = time.perf_counter() - t_start
    return OptimizationReport(True, method, h, prog, invs, stats)
