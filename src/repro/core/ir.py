"""Symbolic IR for Datalog° queries: sum-sum-product normal forms.

A query body is a *sum-sum-product* (SSP) expression (paper Eq. (2)):

    Q(x₁..x_k) := T₁ ⊕ T₂ ⊕ ... ⊕ T_q          (q terms)
    T_i        := ⊕_{bound vars} A₁ ⊗ ... ⊗ A_m  (sum-product, Eq. (1))

where each atom A is a (possibly cast) relational atom, an interpreted
predicate ``[p(x,..)]``, a numeric value atom, or a semiring constant.

This module implements the pieces of the paper's Sec. 5.1 rule-based layer:

* substitution of IDB definitions into a query — computing ``G(F(X))``
  symbolically (exact for same-semiring substitution by distributivity, and
  for 𝔹→S casts when S has idempotent ⊕; otherwise raises and the numeric
  CEGIS path takes over, mirroring the paper's Fig. 10 split),
* normalization via the axioms (23)–(25): flattening of ⊕, pushing ⊗ over ⊕,
  and equality-predicate elimination ``⊕_x A(x)⊗[x=y] = A(y)``,
* canonicalization + isomorphism checking of normal forms (the paper's
  "Rule-based Test", Eq. (22)).

Variables are strings; constants in argument positions use :class:`C`.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Mapping, Sequence

from repro.core import semiring as sr_mod

# --------------------------------------------------------------------------
# Arguments, schemas
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, order=True)
class C:
    """A constant in an argument position, e.g. TC(a, y) with a = C(0)."""

    value: int

    def __repr__(self) -> str:
        return f"C({self.value})"


Arg = "str | C"


@dataclasses.dataclass(frozen=True)
class RelSchema:
    """Declared sorts + value semiring of a relation symbol."""

    sorts: tuple[str, ...]
    semiring: str  # value space of the relation


class Schema(dict):
    """name -> RelSchema; shared by EDBs and IDBs."""

    def declare(self, name: str, sorts: Sequence[str], semiring: str) -> None:
        self[name] = RelSchema(tuple(sorts), semiring)

    def arity(self, name: str) -> int:
        return len(self[name].sorts)


# --------------------------------------------------------------------------
# Atoms
# --------------------------------------------------------------------------

# Interpreted predicates are named, closed over constant parameters, and are
# evaluated densely by the engine over index grids (engine.py).  Keeping them
# as (name, params) pairs makes atoms hashable/serializable for e-graphs and
# canonical forms.
PREDICATES = {
    "eq": 2,      # x = y
    "neq": 2,     # x ≠ y
    "lt": 2,      # x < y
    "le": 2,      # x ≤ y
    "sum3": 3,    # x = y + z         (value sorts)
    "succ": 2,    # x = y + 1
    "winlt": 2,   # 1 ≤ x < y       (paper's WS window guard)
}


@dataclasses.dataclass(frozen=True)
class RelAtom:
    """R(args); ``cast`` marks the 𝔹→S cast [R(args)] (paper's [-]₀̄¹̄);
    ``neg`` marks stratified negation [¬R(args)] (legal only on relations
    from earlier strata / EDBs, enforced by the program builder)."""

    name: str
    args: tuple
    cast: bool = False
    neg: bool = False

    def rename(self, sub: Mapping) -> "RelAtom":
        return RelAtom(self.name, _map_args(self.args, sub), self.cast,
                       self.neg)

    def key(self) -> tuple:
        return ("R", self.name, self.cast, self.neg, _arg_keys(self.args))


@dataclasses.dataclass(frozen=True)
class PredAtom:
    """[p(args)] — boolean interpreted predicate cast into the semiring."""

    pred: str
    args: tuple

    def __post_init__(self):
        assert self.pred in PREDICATES, self.pred
        assert len(self.args) == PREDICATES[self.pred], (self.pred, self.args)

    def rename(self, sub: Mapping) -> "PredAtom":
        return PredAtom(self.pred, _map_args(self.args, sub))

    def key(self) -> tuple:
        return ("P", self.pred, _arg_keys(self.args))


@dataclasses.dataclass(frozen=True)
class ValAtom:
    """The numeric value of a key variable, as a semiring element.

    E.g. ``⊕_v v ⊗ [L(x,v)]`` (paper Example 2.1) uses ValAtom("v").
    """

    var: str

    def rename(self, sub: Mapping) -> "ValAtom":
        v = sub.get(self.var, self.var)
        if isinstance(v, C):
            return ConstAtom(float(v.value))  # type: ignore[return-value]
        return ValAtom(v)

    def key(self) -> tuple:
        return ("V", self.var)


@dataclasses.dataclass(frozen=True)
class ConstAtom:
    """A semiring constant, e.g. the 100 in APSP100 (Example 5.1)."""

    value: float

    def rename(self, sub: Mapping) -> "ConstAtom":
        return self

    def key(self) -> tuple:
        return ("C", self.value)


#: Interpreted *value* functions over key variables (paper Appendix A's
#: user-defined helper functions); used e.g. by BC's σ·σ/σ term.
VALUE_FNS = {
    "mulratio": 3,  # (a, b, c) -> a*b / max(c, 1)
    "plus1": 1,     # (a,) -> a + 1
}


@dataclasses.dataclass(frozen=True)
class ValFnAtom:
    """fn(args) as a semiring element (interpreted function atom)."""

    fn: str
    args: tuple

    def __post_init__(self):
        assert self.fn in VALUE_FNS, self.fn
        assert len(self.args) == VALUE_FNS[self.fn]

    def rename(self, sub: Mapping) -> "ValFnAtom":
        return ValFnAtom(self.fn, _map_args(self.args, sub))

    def key(self) -> tuple:
        return ("F", self.fn, _arg_keys(self.args))


Atom = "RelAtom | PredAtom | ValAtom | ConstAtom"


def _map_args(args: tuple, sub: Mapping) -> tuple:
    out = []
    for a in args:
        if isinstance(a, C):
            out.append(a)
        else:
            out.append(sub.get(a, a))
    return tuple(out)


def _arg_keys(args: tuple) -> tuple:
    return tuple(("c", a.value) if isinstance(a, C) else ("v", a) for a in args)


def atom_vars(atom) -> tuple[str, ...]:
    if isinstance(atom, (RelAtom, PredAtom, ValFnAtom)):
        return tuple(a for a in atom.args if not isinstance(a, C))
    if isinstance(atom, ValAtom):
        return (atom.var,)
    return ()


# --------------------------------------------------------------------------
# Terms and SSP expressions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Term:
    """⊕_{bound} A₁ ⊗ ... ⊗ A_m  (a sum-product, paper Eq. (1))."""

    atoms: tuple
    bound: tuple[str, ...]  # summed-out variables

    def vars(self) -> set[str]:
        vs: set[str] = set()
        for a in self.atoms:
            vs.update(atom_vars(a))
        return vs

    def free_vars(self) -> set[str]:
        return self.vars() - set(self.bound)

    def rename(self, sub: Mapping) -> "Term":
        # bound vars must not be captured: callers rename bound vars fresh
        # *before* applying head substitutions.
        return Term(tuple(a.rename(sub) for a in self.atoms),
                    tuple(sub.get(b, b) for b in self.bound))


@dataclasses.dataclass(frozen=True)
class SSP:
    """A sum-sum-product expression with a distinguished head var tuple."""

    head: tuple[str, ...]
    terms: tuple[Term, ...]
    semiring: str

    def rename_head(self, new_head: Sequence) -> "SSP":
        """Substitute head vars by ``new_head`` args (vars or constants)."""
        assert len(new_head) == len(self.head)
        sub = dict(zip(self.head, new_head))
        out_terms = []
        for t in self.terms:
            t = _freshen_bound(t, avoid=set(map(str, new_head)) | t.free_vars())
            out_terms.append(t.rename(sub))
        new_head_vars = tuple(h for h in new_head if not isinstance(h, C))
        return SSP(tuple(new_head_vars), tuple(out_terms), self.semiring)

    def map_terms(self, fn) -> "SSP":
        return SSP(self.head, tuple(fn(t) for t in self.terms), self.semiring)


_FRESH_COUNTER = itertools.count()


def fresh_var(prefix: str = "z") -> str:
    return f"{prefix}%{next(_FRESH_COUNTER)}"


def _freshen_bound(t: Term, avoid: set[str]) -> Term:
    sub = {}
    for b in t.bound:
        if b in avoid:
            sub[b] = fresh_var(b.split("%")[0])
    if not sub:
        return t
    return t.rename(sub)


# --------------------------------------------------------------------------
# Normalization (axioms (23)-(25) of Sec. 5.1)
# --------------------------------------------------------------------------


def normalize_term(t: Term, sr_name: str) -> Term | None:
    """Equality elimination + constant folding inside one sum-product.

    Returns None if the term is identically 0̄ (e.g. contains [c≠c] or 0̄).
    """
    sr = sr_mod.get(sr_name)
    atoms = list(t.atoms)
    bound = list(t.bound)

    changed = True
    while changed:
        changed = False
        for i, a in enumerate(atoms):
            if isinstance(a, PredAtom) and a.pred == "eq":
                x, y = a.args
                if x == y and not isinstance(x, C):
                    atoms.pop(i); changed = True; break
                if isinstance(x, C) and isinstance(y, C):
                    if x.value == y.value:
                        atoms.pop(i)
                    else:
                        return None
                    changed = True; break
                # axiom (25): eliminate a bound variable via [x = y]
                tgt = src = None
                if not isinstance(x, C) and x in bound:
                    src, tgt = x, y
                elif not isinstance(y, C) and y in bound:
                    src, tgt = y, x
                if src is not None:
                    atoms.pop(i)
                    bound.remove(src)
                    sub = {src: tgt}
                    atoms = [a2.rename(sub) for a2 in atoms]
                    changed = True
                    break
            elif isinstance(a, PredAtom) and a.pred == "neq":
                x, y = a.args
                if x == y:
                    return None
                if isinstance(x, C) and isinstance(y, C):
                    if x.value == y.value:
                        return None
                    atoms.pop(i); changed = True; break

    # value-arithmetic folds (exact when ⊗ is numeric +, i.e. Trop/Tropʳ):
    #   ⊕_d val(d)⊗[d = d1+d2]⊗R  =  val(d1)⊗val(d2)⊗R    (single witness)
    #   ⊕_t val(t)⊗[t = s+1]⊗R    =  val(s)⊗1⊗R
    if sr.name in ("trop", "maxplus"):
        changed = True
        while changed:
            changed = False
            for i, a in enumerate(atoms):
                if not (isinstance(a, PredAtom) and a.pred in ("sum3", "succ")):
                    continue
                d = a.args[0]
                if isinstance(d, C) or d not in bound:
                    continue
                occurrences = [j for j, b2 in enumerate(atoms)
                               if j != i and d in atom_vars(b2)]
                if len(occurrences) != 1:
                    continue
                j = occurrences[0]
                if not isinstance(atoms[j], ValAtom):
                    continue
                repl: list = []
                for arg in a.args[1:]:
                    repl.append(ConstAtom(float(arg.value))
                                if isinstance(arg, C) else ValAtom(arg))
                if a.pred == "succ":
                    repl.append(ConstAtom(1.0))
                atoms = [b2 for k2, b2 in enumerate(atoms)
                         if k2 not in (i, j)] + repl
                bound.remove(d)
                changed = True
                break

    # constant folding
    const = sr.one
    kept = []
    for a in atoms:
        if isinstance(a, ConstAtom):
            if a.value == sr.zero:
                return None
            if a.value == sr.one:
                continue
            const = _sr_mul_scalar(sr, const, a.value)
        else:
            kept.append(a)
    if const != sr.one or not kept:
        kept.append(ConstAtom(const))

    # dedup idempotent atoms: predicates & casts are {0̄,1̄}-valued, hence
    # ⊗-idempotent in every semiring; plain relational atoms only in 𝔹.
    seen = set()
    dedup = []
    for a in kept:
        idem = isinstance(a, PredAtom) or (
            isinstance(a, RelAtom) and (a.cast or sr_name == "bool"))
        k = a.key()
        if idem and k in seen:
            continue
        seen.add(k)
        dedup.append(a)

    # drop bound vars that no longer occur (their sum contributes a domain
    # factor only in non-idempotent semirings — keep a guard there).
    used = set()
    for a in dedup:
        used.update(atom_vars(a))
    new_bound = tuple(b for b in bound if b in used)
    if len(new_bound) != len(bound) and not sr.idempotent:
        # ⊕_x 1̄ = |domain| ≠ 1̄ in e.g. ℕ; mark with an explicit free sum.
        # Our programs never produce this; fail loudly rather than silently.
        raise ValueError("dangling bound var in non-idempotent semiring")
    return Term(tuple(dedup), new_bound)


def _sr_mul_scalar(sr, a: float, b: float) -> float:
    import numpy as np
    return float(np.asarray(sr.mul(np.asarray(a, np.float64), np.asarray(b, np.float64))))


def normalize(e: SSP) -> SSP:
    terms = []
    for t in e.terms:
        nt = normalize_term(t, e.semiring)
        if nt is not None:
            terms.append(nt)
    sr = sr_mod.get(e.semiring)
    if sr.idempotent:
        # ⊕-dedup of isomorphic terms
        seen = {}
        for t in terms:
            seen.setdefault(canonical_term(t, e.head), t)
        terms = list(seen.values())
    return SSP(e.head, tuple(terms), e.semiring)


# --------------------------------------------------------------------------
# Substitution: computing G(F(X)) symbolically
# --------------------------------------------------------------------------


class NonIdempotentCast(Exception):
    """Raised when a 𝔹-definition is substituted under a non-idempotent ⊕.

    The paper handles those cases (MLM, R) via CEGIS + constraints rather
    than by symbolic normalization; we mirror that split.
    """


def substitute_defs(e: SSP, defs: Mapping[str, SSP]) -> SSP:
    """Replace every atom whose name is in ``defs`` by its definition.

    Exact by distributivity for same-semiring substitution; exact for 𝔹→S
    casts when S.⊕ is idempotent (min/max/∨): [A ∨ B] = [A] ⊕ [B] and
    [∃z A] = ⊕_z [A] hold on {0̄,1̄}-valued casts.
    """
    target = sr_mod.get(e.semiring)
    out_terms: list[Term] = []
    for t in e.terms:
        # Substitute each *original* occurrence exactly once: atoms inserted
        # from a definition are frozen (the definition of a recursive IDB
        # mentions the IDB itself — that is the "X" of G(F(X))).
        expansions: list[tuple[tuple, tuple, tuple]] = [
            ((), t.atoms, t.bound)]  # (done_atoms, todo_atoms, bound)
        final: list[Term] = []
        while expansions:
            done, todo, bound = expansions.pop()
            if not todo:
                final.append(Term(done, bound))
                continue
            atom, rest = todo[0], todo[1:]
            if not (isinstance(atom, RelAtom) and atom.name in defs
                    and not atom.neg):
                expansions.append((done + (atom,), rest, bound))
                continue
            body = defs[atom.name]
            is_cast = body.semiring != e.semiring
            if is_cast:
                if not (body.semiring == "bool" and target.idempotent):
                    raise NonIdempotentCast(
                        f"cannot substitute {atom.name}:{body.semiring} "
                        f"under {e.semiring}")
            inst = body.rename_head(list(atom.args))
            avoid = set(bound)
            for a in done + rest:
                avoid.update(atom_vars(a))
            for bt in inst.terms:
                bt = _freshen_bound(bt, avoid=avoid)
                new_atoms = []
                for a in bt.atoms:
                    if is_cast and isinstance(a, RelAtom):
                        a = RelAtom(a.name, a.args, cast=True, neg=a.neg)
                    new_atoms.append(a)
                expansions.append((done + tuple(new_atoms), rest,
                                   bound + bt.bound))
        out_terms.extend(final)
    return normalize(SSP(e.head, tuple(out_terms), e.semiring))


# --------------------------------------------------------------------------
# Canonicalization & isomorphism (the Rule-based Test, Eq. (22))
# --------------------------------------------------------------------------

_MAX_BOUND_PERm = 7


def canonical_term(t: Term, head: tuple[str, ...]) -> tuple:
    """A canonical, bound-variable-renaming-invariant key for a term."""
    bound = [b for b in t.bound if b in t.vars()]
    if len(bound) > _MAX_BOUND_PERm:
        # fall back to a refinement-only key (sound for equality grouping,
        # may distinguish some isomorphic terms — never merges distinct ones)
        sub = {b: f"b{i}" for i, b in enumerate(sorted(bound))}
        return _term_key(t.rename(sub))
    best = None
    for perm in itertools.permutations(range(len(bound))):
        sub = {b: f"b{perm[i]}" for i, b in enumerate(bound)}
        key = _term_key(Term(tuple(a.rename(sub) for a in t.atoms),
                             tuple(sorted(sub.values()))))
        if best is None or key < best:
            best = key
    return best if best is not None else _term_key(t)


def _term_key(t: Term) -> tuple:
    return (tuple(sorted(a.key() for a in t.atoms)), tuple(sorted(t.bound)))


def canonical_ssp(e: SSP) -> tuple:
    e = normalize(e)
    keys = sorted(canonical_term(t, e.head) for t in e.terms)
    return (e.head, tuple(keys), e.semiring)


def isomorphic(a: SSP, b: SSP) -> bool:
    """Sound syntactic equality of normal forms up to bound-var renaming."""
    if a.semiring != b.semiring or len(a.head) != len(b.head):
        return False
    # align head variable names
    sub = dict(zip(b.head, a.head))
    b2 = SSP(a.head, tuple(
        _freshen_bound(t, avoid=set(a.head) | set(b.head)).rename(sub)
        for t in b.terms), b.semiring)
    return canonical_ssp(a) == canonical_ssp(b2)


# --------------------------------------------------------------------------
# Pretty-printing
# --------------------------------------------------------------------------


def atom_str(a) -> str:
    if isinstance(a, RelAtom):
        s = f"{a.name}({', '.join(map(_arg_str, a.args))})"
        return f"[{s}]" if a.cast else s
    if isinstance(a, PredAtom):
        return f"[{a.pred}({', '.join(map(_arg_str, a.args))})]"
    if isinstance(a, ValAtom):
        return f"val({a.var})"
    if isinstance(a, ValFnAtom):
        return f"{a.fn}({', '.join(map(_arg_str, a.args))})"
    return f"{a.value:g}"


def _arg_str(a) -> str:
    return f"'{a.value}'" if isinstance(a, C) else str(a)


def term_str(t: Term) -> str:
    body = " ⊗ ".join(atom_str(a) for a in t.atoms) or "1̄"
    if t.bound:
        return f"⊕_{{{','.join(t.bound)}}} {body}"
    return body


def ssp_str(e: SSP) -> str:
    head = f"({', '.join(e.head)})"
    return f"{head} := " + "  ⊕  ".join(term_str(t) for t in e.terms) + f"   [{e.semiring}]"
