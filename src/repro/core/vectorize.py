"""Lower FGH-optimized Π₂ programs to vector fixpoint equations.

The FGH rewrite turns all-pairs programs (BM/CC/SSSP/MLM, paper Sec. 3.1)
into *vector-shaped* GH-programs: a single linear stratum over a unary IDB
``x`` whose merged rule splits as

    x[y]  =  init[y]  ⊕  ⊕_z x[z] ⊗ E[z, y]

with ``init`` the non-recursive terms (they carry the query source
constant) and ``E`` the source-*independent* linear operator.  This module
performs that split symbolically so the serve loop (DESIGN.md §3) can

* reuse one compiled batched fixpoint and one edge operator across every
  source that shares the linear part (``VectorForm.signature`` is the
  compile-cache key component), and
* evaluate only the cheap O(n) ``init`` per request.

``edge_operator`` keeps a sparse EDB sparse (the COO relation feeds the
SpMM batched runner directly); anything more exotic — multiple linear
terms, interpreted predicates in the remainder — falls back to a dense
``engine.eval_ssp`` materialization of E.

The split is consumed by the cost-based planner (DESIGN.md §4): the
vector runners of :mod:`repro.core.planner` and the serve loop's batched
fixpoints are all built from a :class:`VectorForm`.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import engine, ir
from repro.core import semiring as sr_mod
from repro.core.program import Program

#: canonical name of the contracted (source-side) variable in ``edge``
Z = "__z"


@dataclasses.dataclass(frozen=True)
class VectorForm:
    """The split ``x = init ⊕ x ⊗ E`` of a vector-shaped Π₂ program."""

    idb: str
    semiring: str
    out_sort: str
    init: ir.SSP       # head (y,), no IDB atoms; carries source constants
    edge: ir.SSP       # head (Z, y): E[z, y] as an SSP over EDBs only
    signature: str     # stable source-independent hash of (edge, semiring)


def vector_form(prog: Program) -> VectorForm:
    """Split a Π₂ :class:`Program` into :class:`VectorForm`.

    Raises ``ValueError`` when the program is not vector-shaped (more than
    one stratum/rule, non-unary IDB, non-linear recursion, or a negated /
    cast recursive atom).
    """
    if len(prog.strata) != 1:
        raise ValueError(f"{prog.name}: need exactly one stratum, "
                         f"got {len(prog.strata)}")
    if prog.post is not None:
        raise ValueError(f"{prog.name}: host post-epilogues are not part "
                         f"of the vector equation — the fixpoint x* would "
                         f"be served unpostprocessed")
    stratum = prog.strata[0]
    if len(stratum.rules) != 1:
        raise ValueError(f"{prog.name}: need a single recursive IDB, "
                         f"got {tuple(stratum.rules)}")
    (idb,) = stratum.rules
    _check_identity_outputs(prog, idb)
    rule = stratum.rules[idb]
    body = rule.body
    if len(body.head) != 1:
        raise ValueError(f"{idb}: vector equations need a unary IDB head, "
                         f"got arity {len(body.head)}")
    (yvar,) = body.head
    sorts = prog.schema[idb].sorts
    if len(sorts) != 1:
        raise ValueError(f"{idb}: schema arity {len(sorts)} != 1")

    init_terms: list[ir.Term] = []
    edge_terms: list[ir.Term] = []
    for t in body.terms:
        rec = [a for a in t.atoms
               if isinstance(a, ir.RelAtom) and a.name == idb]
        if not rec:
            init_terms.append(t)
            continue
        if len(rec) > 1:
            raise ValueError(f"{idb}: non-linear term {ir.term_str(t)}")
        (a,) = rec
        if a.neg or a.cast:
            raise ValueError(f"{idb}: recursive atom must be plain, "
                             f"got {a}")
        if len(a.args) != 1 or isinstance(a.args[0], ir.C):
            raise ValueError(f"{idb}: recursive atom must bind one "
                             f"variable, got {a}")
        z = a.args[0]
        # The engine contracts every non-head variable, whether or not it
        # is annotated in ``t.bound`` (synthesized terms often carry an
        # empty annotation) — so "summed out" means "not the head var".
        if z == yvar:
            raise ValueError(f"{idb}: recursive variable {z} must be "
                             f"summed out in {ir.term_str(t)}")
        if Z in t.vars():
            raise ValueError(f"reserved variable {Z} already in use")
        rest = tuple(x for x in t.atoms if x is not a)
        renamed = tuple(x.rename({z: Z}) for x in rest)
        bound = tuple(v for v in t.bound if v != z)
        edge_terms.append(ir.Term(renamed, bound))

    if not edge_terms:
        raise ValueError(f"{idb}: no recursive term — nothing to iterate")

    # Y₀ terms from the GH-program's stratum init (make_gh_program) are
    # usually the same non-recursive terms again; ⊕ them in, deduplicating
    # so non-idempotent semirings don't double-count.
    if stratum.init and idb in stratum.init:
        seen = {ir.canonical_term(t, body.head) for t in init_terms}
        for t in stratum.init[idb].rename_head(body.head).terms:
            if ir.canonical_term(t, body.head) not in seen:
                init_terms.append(t)

    init = ir.SSP((yvar,), tuple(init_terms), body.semiring)
    edge = ir.SSP((Z, yvar), tuple(edge_terms), body.semiring)
    signature = _signature(edge, yvar, body.semiring, sorts[0])
    return VectorForm(idb, body.semiring, sorts[0], init, edge, signature)


def _check_identity_outputs(prog: Program, idb: str) -> None:
    """The served answer is the fixpoint x* itself, so the program's
    output chain must be a pure renaming chain ``ans(y) := x(y)`` —
    anything else (a join, a cast, a projection) would make the serve
    loop's answer diverge from ``run_program``."""
    prev = idb
    for r in prog.outputs:
        b = r.body
        atom = b.terms[0].atoms[0] if (
            len(b.terms) == 1 and len(b.terms[0].atoms) == 1) else None
        if not (isinstance(atom, ir.RelAtom) and atom.name == prev
                and not atom.neg and not atom.cast
                and tuple(atom.args) == tuple(b.head)
                and b.semiring == prog.schema[prev].semiring):
            raise ValueError(
                f"{prog.name}: output rule {r.head} is not the identity "
                f"on {prev} — the batched runner serves x* directly")
        prev = r.head


def _signature(edge: ir.SSP, yvar: str, semiring: str, sort: str) -> str:
    """Variable-renaming-invariant hash of the linear operator.

    Synthesized terms carry empty ``bound`` annotations and fresh-counter
    variable names that drift between fgh runs, and ``ir.canonical_term``
    canonicalizes only annotated bound vars — so every non-head variable
    is re-annotated as bound (making the canonical key permutation-
    invariant) and the head is renamed to fixed markers first.
    """
    head = (Z, "__y")
    keys = []
    for t in edge.terms:
        t2 = t.rename({yvar: "__y"})
        extra = tuple(sorted(v for v in t2.vars() if v not in head))
        keys.append(ir.canonical_term(ir.Term(t2.atoms, extra), head))
    payload = repr((sorted(keys), semiring, sort))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def init_vector(vf: VectorForm, db: engine.Database,
                hints=None, *, backend: str = "jnp"):
    """Evaluate the per-source constant term — a dense ``(n,)`` vector."""
    return engine.eval_ssp(vf.init, db, hints, backend=backend)


def edge_atom(vf: VectorForm) -> ir.RelAtom | None:
    """The single plain binary atom behind E's sparse fast path, if the
    linear operator is exactly one relation lookup — the one syntactic
    predicate shared by :func:`edge_operator` and the planner's sparsity
    costing (``repro.core.planner``), so plan and execution can never
    disagree about whether E stays sparse."""
    if len(vf.edge.terms) != 1:
        return None
    t = vf.edge.terms[0]
    if len(t.atoms) != 1 or not isinstance(t.atoms[0], ir.RelAtom):
        return None
    a = t.atoms[0]
    if a.neg or tuple(a.args) not in (vf.edge.head, vf.edge.head[::-1]):
        return None
    return a


def init_reads(vf: VectorForm, name: str) -> bool:
    """Whether the init term references relation ``name``.  A ⊕-merge
    into the linear operator's own relation then *also* changes the init
    vector, so a delta-restart seeded from ``y* ⊗ ΔE`` alone would miss
    the init contribution — the maintenance layers must fall back
    (DESIGN.md §5)."""
    return any(isinstance(a, ir.RelAtom) and a.name == name
               for t in vf.init.terms for a in t.atoms)


def edge_operator(vf: VectorForm, db: engine.Database, hints=None, *,
                  prefer_sparse: bool = True):
    """Materialize E[z, y] — sparse-preserving when the linear remainder
    is a single plain binary EDB atom stored as a SparseRelation.

    Returns either a :class:`~repro.sparse.coo.SparseRelation` (values
    cast into ``vf.semiring``) ready for the SpMM batched runner, or a
    dense ``(n, n)`` S-relation from ``engine.eval_ssp``.
    """
    from repro.sparse.coo import SparseRelation
    a = edge_atom(vf) if prefer_sparse else None
    if a is not None:
        arr = db.relations.get(a.name)
        if isinstance(arr, SparseRelation) and arr.arity == 2:
            rel = arr if tuple(a.args) == vf.edge.head \
                else arr.transpose()
            return _sparse_into_semiring(rel, vf.semiring)
    return engine.eval_ssp(vf.edge, db, hints)


def _sparse_into_semiring(rel, target: str):
    """Value-space view of a sparse relation in another semiring —
    the COO analogue of the engine's ``_rel_factor`` cast handling:
    𝔹 sources lift stored tuples to 1̄, float→float views pass finite
    values through (absent tuples are 0̄ in either space)."""
    if rel.semiring == target:
        return rel
    from repro.sparse.coo import SparseRelation
    src = sr_mod.get(rel.semiring, lib="np")
    dst = sr_mod.get(target, lib="np")
    host = rel.as_np()
    k = int(host.nnz)
    vals = np.full(rel.capacity, dst.zero, dst.dtype)
    if src.name == "bool":
        vals[:k] = np.where(host.values[:k], dst.one, dst.zero)
    else:
        vals[:k] = host.values[:k].astype(dst.dtype)
    out = SparseRelation(host.coords, vals, host.nnz, rel.shape, target)
    return out if rel.lib == "np" else out.as_jnp()
