"""Fixpoint runners: naive and generalized semi-naive (GSN) evaluation.

The paper's Sec. 3.1 shows GSN is an FGH-rewrite of the naive FG-program for
any complete distributive lattice with idempotent ⊕:

    naive:  X ← F(X)
    GSN:    Y ← Y ⊕ Δ;  Δ ← δF(Y, Δ) ⊖ (Y ⊕ Δ)

For *linear* programs F(X) = C ⊕ A(X) (A = the ⊕ of terms containing
exactly one IDB atom), distributivity gives the differential
``δF(Y, Δ) = A(Δ)`` — only the frontier is re-derived.  On TPU the Δ
relation is a dense masked tensor (DESIGN.md §2).

Both runners execute as a single ``jax.lax.while_loop`` under jit (so they
stage into one XLA program and can be pjit-sharded), with a host-loop
variant that reports per-iteration statistics for benchmarks.

:func:`batched_seminaive_fixpoint` is the multi-source mirror (DESIGN.md
§3): every state leaf carries a leading query-batch axis, all instances
advance in one while_loop, and convergence is tracked per row.

Which of these runners executes a given stratum is decided by the
cost-based planner (:mod:`repro.core.planner`, DESIGN.md §4).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import semiring as sr_mod

State = dict[str, jnp.ndarray]


def _tree_equal(a: State, b: State) -> jnp.ndarray:
    flags = [jnp.all(a[k] == b[k]) for k in a]
    out = flags[0]
    for f in flags[1:]:
        out = jnp.logical_and(out, f)
    return out


def naive_fixpoint(ico: Callable[[State], State], x0: State, *,
                   max_iters: int = 10_000) -> tuple[State, jnp.ndarray]:
    """Iterate X ← F(X) until X stops changing.  Returns (X*, iters)."""

    def cond(carry):
        x, prev_changed, it = carry
        return jnp.logical_and(prev_changed, it < max_iters)

    def body(carry):
        x, _, it = carry
        nx = ico(x)
        return nx, jnp.logical_not(_tree_equal(nx, x)), it + 1

    x, _, iters = jax.lax.while_loop(
        cond, body, (x0, jnp.asarray(True), jnp.asarray(0)))
    return x, iters


def seminaive_fixpoint(ico: Callable[[State], State],
                       delta_ico: Callable[[State], State],
                       x0: State, semirings: dict[str, sr_mod.Semiring], *,
                       max_iters: int = 10_000) -> tuple[State, jnp.ndarray]:
    """GSN evaluation.  ``delta_ico`` is δF: applies only the linear part
    A to the Δ state.  Requires idempotent ⊕ with a ⊖ (lattice) per IDB.
    """
    for name, sr in semirings.items():
        if sr.minus is None:
            raise ValueError(f"{name}: semiring {sr.name} lacks ⊖; "
                             "GSN needs an idempotent complete lattice")

    def minus(new: State, old: State) -> State:
        return {k: semirings[k].minus(new[k], old[k]) for k in new}

    def plus(a: State, b: State) -> State:
        return {k: semirings[k].add(a[k], b[k]) for k in a}

    d0 = minus(ico(x0), x0)

    def cond(carry):
        y, d, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    def nonzero(d: State) -> jnp.ndarray:
        flags = [jnp.any(d[k] != semirings[k].zero) for k in d]
        out = flags[0]
        for f in flags[1:]:
            out = jnp.logical_or(out, f)
        return out

    def body(carry):
        y, d, _, it = carry
        y_new = plus(y, d)
        d_new = minus(delta_ico(d), y_new)
        return y_new, d_new, nonzero(d_new), it + 1

    y, d, _, iters = jax.lax.while_loop(
        cond, body, (x0, d0, jnp.asarray(True), jnp.asarray(0)))
    return y, iters


def batched_seminaive_fixpoint(ico: Callable[[State], State],
                               delta_ico: Callable[[State], State],
                               x0: State,
                               semirings: dict[str, sr_mod.Semiring], *,
                               max_iters: int = 10_000,
                               ) -> tuple[State, jnp.ndarray]:
    """GSN over a batch of independent instances (DESIGN.md §3).

    Every leaf of ``x0`` carries a leading batch axis B (one row per
    query source) and ``ico``/``delta_ico`` operate on the batched state
    (build them with ``jax.vmap`` of a per-example ICO, or close over a
    batched init as the serve loop does).  All B instances advance inside
    one ``lax.while_loop`` with a per-row convergence mask; because ⊕ is
    idempotent and δF is linear, a converged row's Δ stays 0̄ and its Y
    stays fixed while other rows keep iterating, so each row's trajectory
    is iteration-for-iteration identical to its single-source run.

    Returns ``(Y*, iters)`` with ``iters`` a ``(B,)`` int32 vector of
    per-row iteration counts (``max_iters``-truncated rows report the
    truncation point, matching the scalar runner's behaviour).
    """
    for name, sr in semirings.items():
        if sr.minus is None:
            raise ValueError(f"{name}: semiring {sr.name} lacks ⊖; "
                             "GSN needs an idempotent complete lattice")
    b = next(iter(x0.values())).shape[0]
    for k, v in x0.items():
        if v.shape[0] != b:
            raise ValueError(f"{k}: batch axis mismatch "
                             f"({v.shape[0]} vs {b})")

    def minus(new: State, old: State) -> State:
        return {k: semirings[k].minus(new[k], old[k]) for k in new}

    def plus(a: State, bb: State) -> State:
        return {k: semirings[k].add(a[k], bb[k]) for k in a}

    def row_live(d: State) -> jnp.ndarray:
        flags = [jnp.any((d[k] != semirings[k].zero).reshape(b, -1), axis=1)
                 for k in d]
        out = flags[0]
        for f in flags[1:]:
            out = jnp.logical_or(out, f)
        return out

    d0 = minus(ico(x0), x0)

    def cond(carry):
        y, d, live, it_rows, it = carry
        return jnp.logical_and(jnp.any(live), it < max_iters)

    def body(carry):
        y, d, live, it_rows, it = carry
        y_new = plus(y, d)
        d_new = minus(delta_ico(d), y_new)
        return y_new, d_new, row_live(d_new), it_rows + live, it + 1

    y, _, _, it_rows, _ = jax.lax.while_loop(
        cond, body, (x0, d0, jnp.ones((b,), bool),
                     jnp.zeros((b,), jnp.int32), jnp.asarray(0)))
    return y, it_rows


def sparse_seminaive_fixpoint(edges, init, *, max_iters: int = 10_000,
                              mode: str = "auto"):
    """Frontier-based GSN over a sparse edge relation (DESIGN.md §2).

    Forwarded from :mod:`repro.sparse.fixpoint`: Δ is a sparse worklist
    of changed tuples; per-iteration cost is O(nnz) (staged mode) or
    O(Σ frontier degrees) (host worklist mode) instead of the dense
    runners' O(n²).
    """
    from repro.sparse.fixpoint import sparse_seminaive_fixpoint as impl
    return impl(edges, init, max_iters=max_iters, mode=mode)


def host_fixpoint(ico: Callable[[State], State], x0: State, *,
                  max_iters: int = 10_000) -> tuple[State, int]:
    """Python-loop variant (per-iteration visibility; used by benchmarks)."""
    x = {k: jnp.asarray(v) for k, v in x0.items()}
    step = jax.jit(ico)
    for it in range(max_iters):
        nx = step(x)
        same = all(bool(jnp.all(nx[k] == x[k])) for k in nx)
        x = nx
        if same:
            return x, it + 1
    return x, max_iters
