"""Dense tensor evaluation of Datalog° queries.

An S-relation over finite domains is a dense array of semiring values
(paper Sec. 2, "S-relations").  Evaluating a sum-product term is a semiring
tensor contraction; this module implements a greedy pairwise contraction
planner (an "einsum" over arbitrary semirings) with:

* a fast matmul path — `(∨,∧)` and `(+,×)` contractions lower to MXU-shaped
  `dot`; `(min,+)`/`(max,+)` route through `repro.kernels.ops`
  (Pallas on TPU, blocked jnp elsewhere),
* chunked broadcast-multiply-reduce for general contractions, bounding the
  materialized intermediate (TPU: VMEM-friendly; CPU: cache-friendly),
* early elimination of variables local to a single factor.

Two backends share the code path: ``backend="jnp"`` for staged/distributed
execution and ``backend="np"`` for the synthesizer/verifier's eager
micro-evaluations (numpy sidesteps per-op dispatch overhead; the CEGIS
loop runs thousands of tiny expressions).  The contraction planner is
the TPU-native analogue of a Datalog engine's join pipeline (DESIGN.md
§2); *which* relations arrive sparse vs dense is decided above it by the
cost-based execution planner (:mod:`repro.core.planner`, DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.core import semiring as sr_mod

# max elements materialized by one broadcast contraction before chunking
_CHUNK_ELEMS = 1 << 24


def _xp(backend: str):
    return np if backend == "np" else jnp


@dataclasses.dataclass
class Database:
    """EDB/IDB storage: name -> S-relation, plus sort domain sizes.

    A relation is either a dense array or a
    :class:`repro.sparse.coo.SparseRelation`; the per-relation storage
    tag (``storage_of``, DESIGN.md §2) is derived from the stored value
    so it can never go stale.  The evaluator routes sparse relations
    through the SpMV/SpMM contraction paths and densifies only where a
    plan step genuinely needs the dense form.
    """

    schema: ir.Schema
    domains: dict[str, int]
    relations: dict[str, object]

    def dom(self, sort: str) -> int:
        return self.domains[sort]

    def with_relations(self, extra: Mapping) -> "Database":
        rels = dict(self.relations)
        rels.update(extra)
        return Database(self.schema, self.domains, rels)

    # -- storage backends ---------------------------------------------------
    def storage_of(self, name: str) -> str:
        from repro.sparse.coo import SparseRelation
        if isinstance(self.relations.get(name), SparseRelation):
            return "sparse"
        return "dense"

    def with_storage(self, name: str, backend: str, *,
                     capacity: int | None = None) -> "Database":
        """Convert one relation to the requested backend."""
        from repro.sparse.coo import SparseRelation
        arr = self.relations[name]
        if backend == "sparse" and not isinstance(arr, SparseRelation):
            arr = SparseRelation.from_dense(
                arr, self.schema[name].semiring, capacity=capacity)
        elif backend == "dense" and isinstance(arr, SparseRelation):
            arr = arr.to_dense()
        rels = dict(self.relations)
        rels[name] = arr
        return Database(self.schema, self.domains, rels)

    # -- streaming updates --------------------------------------------------
    def apply_delta(self, delta) -> "Database":
        """Apply a :class:`repro.incremental.DeltaLog` (or any iterable of
        entries with ``relation``/``coords``/``values``/``op`` fields)
        and return the mutated database.

        ``op="merge"`` is the ⊕-merge ``R′ = R ⊕ Δ`` — a COO append for
        sparse relations (:meth:`SparseRelation.apply_delta`, capacity
        doubling beyond the padded buffer) and a ⊕-combining scatter for
        dense ones.  ``op="delete"`` removes keys outright and
        ``op="increase"`` replaces stored values with larger ones
        (delete-the-old ⊕ insert-the-new) — the non-monotone mutations;
        warm fixpoint state over the relation is repaired by a
        synthesized maintenance rule or recomputed (DESIGN.md §11).
        """
        from repro.sparse.coo import SparseRelation
        entries = getattr(delta, "entries", delta)
        rels = dict(self.relations)
        for ent in entries:
            arr = rels[ent.relation]
            if isinstance(arr, SparseRelation):
                if ent.op == "delete":
                    rels[ent.relation] = arr.delete_keys(ent.coords)
                elif ent.op == "increase":
                    rels[ent.relation] = arr.delete_keys(
                        ent.coords).apply_delta(ent.coords, ent.values)
                else:
                    rels[ent.relation] = arr.apply_delta(ent.coords,
                                                         ent.values)
                continue
            sr = sr_mod.get(self.schema[ent.relation].semiring,
                            lib="np" if isinstance(arr, np.ndarray)
                            else "jnp")
            coords = np.asarray(ent.coords, np.int64)
            coords = coords.reshape(-1, np.ndim(arr))
            idx = tuple(coords.T)
            if ent.op == "delete":
                if isinstance(arr, np.ndarray):
                    out = arr.copy()
                    out[idx] = sr.zero
                else:
                    out = arr.at[idx].set(sr.zero)
            elif ent.op == "increase":
                vals = np.asarray(ent.values, sr.dtype)
                if isinstance(arr, np.ndarray):
                    out = arr.copy()
                    out[idx] = vals
                else:
                    out = arr.at[idx].set(jnp.asarray(vals))
            else:
                vals = (np.full(len(coords), sr.one, sr.dtype)
                        if ent.values is None
                        else np.asarray(ent.values, sr.dtype))
                if isinstance(arr, np.ndarray):
                    out = arr.copy()
                    sr_mod.NP_COMBINE[sr.name].at(out, idx, vals)
                else:
                    out = sr_mod.scatter_op(sr.name, arr.at[idx])(
                        jnp.asarray(vals), mode="drop")
            rels[ent.relation] = out
        return Database(self.schema, self.domains, rels)

    def adapt(self, names=None) -> "Database":
        """Adaptive density switch: re-home each relation per the
        hysteresis thresholds in :mod:`repro.sparse.adaptive`."""
        from repro.sparse import adaptive
        rels = dict(self.relations)
        for name in (names if names is not None else list(rels)):
            rels[name] = adaptive.adapt_value(rels[name],
                                              self.schema[name].semiring)
        return Database(self.schema, self.domains, rels)

    def density(self, name: str) -> float:
        from repro.sparse import adaptive
        return adaptive.density(self.relations[name],
                                self.schema[name].semiring)


# --------------------------------------------------------------------------
# Sort inference
# --------------------------------------------------------------------------


def infer_var_sorts(e: ir.SSP, schema: ir.Schema,
                    hints: Mapping[str, str] | None = None) -> dict[str, str]:
    sorts: dict[str, str] = dict(hints or {})
    changed = True
    while changed:
        changed = False
        for t in e.terms:
            for a in t.atoms:
                if isinstance(a, ir.RelAtom):
                    rs = schema[a.name].sorts
                    for arg, s in zip(a.args, rs):
                        if not isinstance(arg, ir.C) and arg not in sorts:
                            sorts[arg] = s
                            changed = True
                elif isinstance(a, (ir.PredAtom, ir.ValFnAtom)):
                    # predicates equate the sorts of their arguments
                    known = [sorts[x] for x in a.args
                             if not isinstance(x, ir.C) and x in sorts]
                    if known:
                        for x in a.args:
                            if not isinstance(x, ir.C) and x not in sorts:
                                sorts[x] = known[0]
                                changed = True
    for t in e.terms:
        for v in t.vars():
            sorts.setdefault(v, _fallback_sort(v))
    for h in e.head:
        sorts.setdefault(h, _fallback_sort(h))
    return sorts


def _fallback_sort(v: str) -> str:
    # synthesizer-minted variables are sort-tagged ("pos$1"); default 'id'
    return v.split("$")[0] if "$" in v else "id"


# --------------------------------------------------------------------------
# Factors
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Factor:
    vars: tuple[str, ...]
    tensor: object

    @property
    def is_sparse(self) -> bool:
        from repro.sparse.coo import SparseRelation
        return isinstance(self.tensor, SparseRelation)


def _densify(t):
    from repro.sparse.coo import SparseRelation
    return t.to_dense() if isinstance(t, SparseRelation) else t


def _rel_factor(a: ir.RelAtom, db: Database, target: sr_mod.Semiring,
                xp) -> _Factor:
    arr = db.relations[a.name]
    schema = db.schema[a.name]
    from repro.sparse.coo import SparseRelation
    if isinstance(arr, SparseRelation):
        vars_only = [x for x in a.args if not isinstance(x, ir.C)]
        plain = (len(set(vars_only)) == len(a.args) and not a.neg
                 and arr.semiring == target.name and xp is not np)
        if plain and arr.arity == 2:
            # stays sparse: consumed by the SpMV/SpMM contraction paths
            return _Factor(tuple(vars_only), arr)
        arr = arr.to_dense()  # constants/diagonals/negation/casts: dense
    if xp is np and not isinstance(arr, np.ndarray):
        arr = np.asarray(arr)  # jnp-backed storage under an np evaluation
    # index out constant arguments (each collapses one axis)
    vars_out: list[str] = []
    axis = 0
    for arg in a.args:
        if isinstance(arg, ir.C):
            arr = xp.take(arr, arg.value, axis=axis)
        else:
            vars_out.append(arg)
            axis += 1
    # diagonal for repeated variables R(x, x)
    while len(set(vars_out)) != len(vars_out):
        seen: dict[str, int] = {}
        for i, v in enumerate(vars_out):
            if v in seen:
                arr = _diagonal(arr, seen[v], i, xp)
                vars_out = vars_out[:i] + vars_out[i + 1:]
                break
            seen[v] = i
    src_sr = sr_mod.get(schema.semiring, target.lib)
    if a.neg:
        if src_sr.name != "bool":
            raise TypeError(f"negation of non-boolean relation {a.name}")
        arr = xp.logical_not(arr)
    if a.cast or src_sr.name != target.name:
        if src_sr.name == "bool":
            arr = target.from_bool(arr)
        elif src_sr.name == target.name:
            pass
        else:
            # float→float semiring view: absent (0̄_src) stays absent
            # (0̄_dst), finite values pass through (e.g. Trop SP inside a
            # max-plus aggregate, Graph Radius Fig. 19).
            arr = xp.where(arr == src_sr.zero,
                           xp.asarray(target.zero, target.dtype),
                           arr.astype(target.dtype))
    return _Factor(tuple(vars_out), arr)


def _diagonal(arr, i: int, j: int, xp):
    arr = xp.moveaxis(arr, (i, j), (0, 1))
    d = xp.diagonal(arr, axis1=0, axis2=1)  # diag axis goes last
    d = xp.moveaxis(d, -1, 0)
    return xp.moveaxis(d, 0, i)


def _pred_array(a: ir.PredAtom, db: Database, sorts: Mapping[str, str],
                xp) -> _Factor:
    vs = [x for x in a.args if not isinstance(x, ir.C)]
    uniq = list(dict.fromkeys(vs))
    shape = tuple(db.dom(sorts[v]) for v in uniq)
    grids = {}
    for i, v in enumerate(uniq):
        g = xp.arange(shape[i], dtype=xp.int32)
        g = g.reshape([-1 if k == i else 1 for k in range(len(uniq))])
        grids[v] = g
    vals = [xp.asarray(x.value, xp.int32) if isinstance(x, ir.C) else grids[x]
            for x in a.args]
    p = a.pred
    if p == "eq":
        out = vals[0] == vals[1]
    elif p == "neq":
        out = vals[0] != vals[1]
    elif p == "lt":
        out = vals[0] < vals[1]
    elif p == "le":
        out = vals[0] <= vals[1]
    elif p == "sum3":
        out = vals[0] == vals[1] + vals[2]
    elif p == "succ":
        out = vals[0] == vals[1] + 1
    elif p == "winlt":
        out = (vals[0] >= 1) & (vals[0] < vals[1])
    else:  # pragma: no cover
        raise KeyError(p)
    out = xp.broadcast_to(out, shape)
    return _Factor(tuple(uniq), out)


def _valfn_array(a: ir.ValFnAtom, db: Database, sorts: Mapping[str, str],
                 xp) -> _Factor:
    """Interpreted value functions (IR.VALUE_FNS) as dense factors."""
    vs = [x for x in a.args if not isinstance(x, ir.C)]
    uniq = list(dict.fromkeys(vs))
    shape = tuple(db.dom(sorts[v]) for v in uniq)
    grids = {}
    for i, v in enumerate(uniq):
        g = xp.arange(shape[i], dtype=xp.float32)
        grids[v] = g.reshape([-1 if k2 == i else 1 for k2 in range(len(uniq))])
    vals = [xp.asarray(float(x.value), xp.float32) if isinstance(x, ir.C)
            else grids[x] for x in a.args]
    if a.fn == "mulratio":
        out = vals[0] * vals[1] / xp.maximum(vals[2], 1.0)
    elif a.fn == "plus1":
        out = vals[0] + 1.0
    else:  # pragma: no cover
        raise KeyError(a.fn)
    return _Factor(tuple(uniq), xp.broadcast_to(out, shape))


# --------------------------------------------------------------------------
# Pairwise contraction
# --------------------------------------------------------------------------


def _to_axes(f: _Factor, order: tuple[str, ...], xp):
    """Transpose + expand ``f.tensor`` so its axes follow ``order``."""
    perm = [f.vars.index(v) for v in order if v in f.vars]
    t = xp.transpose(f.tensor, perm)
    shape = []
    k = 0
    for v in order:
        if v in f.vars:
            shape.append(t.shape[k])
            k += 1
        else:
            shape.append(1)
    return t.reshape(shape)


def _np_matmul(sr, a, b):
    if sr.name == "bool":
        return (a.astype(np.float32) @ b.astype(np.float32)) > 0.5
    if sr.name in ("nat", "real"):
        return a.astype(np.float32) @ b.astype(np.float32)
    red = np.min if sr.name == "trop" else np.max
    return red(a[:, :, None] + b[None, :, :], axis=1)


def _sparse_matmul_path(sr, f1: _Factor, f2: _Factor, k: str) -> _Factor:
    """Sparse×dense (or dense×sparse) contraction over the single shared
    variable ``k`` via SpMV/SpMM — O(nnz) instead of O(n²)."""
    from repro.sparse import contract
    sp, dn = (f1, f2) if f1.is_sparse else (f2, f1)
    rel = sp.tensor
    k_ax = sp.vars.index(k)
    out_var = [v for v in sp.vars if v != k]
    dn_vars = [v for v in dn.vars if v != k]
    dense = dn.tensor
    if dense.ndim == 1:
        out = contract.spmv(rel, dense, transpose=(k_ax == 0))
        return _Factor(tuple(out_var), out)
    # dense matrix: contract k along its first axis
    if dn.vars[0] != k:
        dense = dense.T
    out = contract.spmm(rel, dense, transpose=(k_ax == 0))
    return _Factor(tuple(out_var + dn_vars), out)


def _matmul_path(sr, f1: _Factor, f2: _Factor, elim: set[str],
                 xp) -> _Factor | None:
    """(i?,k) x (k,j?) -> (i?,j?) contraction via semiring matmul."""
    if len(elim) != 1:
        return None
    (k,) = elim
    if k not in f1.vars or k not in f2.vars:
        return None
    if len(f1.vars) > 2 or len(f2.vars) > 2:
        return None
    a, b = f1, f2
    avars = [v for v in a.vars if v != k]
    bvars = [v for v in b.vars if v != k]
    if set(avars) & set(bvars):
        return None  # shared non-contracted var: not a plain matmul
    if a.is_sparse or b.is_sparse:
        if a.is_sparse and b.is_sparse:
            if a.tensor.lib == "np" and b.tensor.lib == "np":
                from repro.sparse import contract
                # align as (i,k) x (k,j): sparse join on k (host path)
                sa = a.tensor if a.vars[-1] == k else a.tensor.transpose()
                sb = b.tensor if b.vars[0] == k else b.tensor.transpose()
                merged = contract.spmspm(sa, sb)
                return _Factor(tuple(avars + bvars), merged.to_dense())
            # staged path: output nnz is data-dependent — densify the
            # operand with fewer stored tuples and keep the other
            # side's SpMM (capacity is the static nnz bound)
            small, big = ((a, b) if a.tensor.capacity
                          <= b.tensor.capacity else (b, a))
            small = _Factor(small.vars, _densify(small.tensor))
            return _sparse_matmul_path(sr, big, small, k)
        return _sparse_matmul_path(sr, a, b, k)
    at = a.tensor if a.vars[-1] == k else a.tensor.T
    bt = b.tensor if b.vars[0] == k else b.tensor.T
    a2 = at.reshape(-1, at.shape[-1]) if at.ndim == 2 else at.reshape(1, -1)
    b2 = bt.reshape(bt.shape[0], -1) if bt.ndim == 2 else bt.reshape(-1, 1)
    if xp is np:
        out = _np_matmul(sr, a2, b2)
    else:
        from repro.kernels import ops as kops
        out = kops.semiring_matmul(sr, a2, b2)
    out_vars = tuple(avars + bvars)
    shape = [at.shape[0]] if at.ndim == 2 else []
    shape += [bt.shape[1]] if bt.ndim == 2 else []
    return _Factor(out_vars, out.reshape(shape) if shape else out.reshape(()))


def _contract_pair(sr, f1: _Factor, f2: _Factor, elim: set[str],
                   xp) -> _Factor:
    mm = _matmul_path(sr, f1, f2, elim, xp)
    if mm is not None:
        return mm
    # general broadcast path needs dense operands
    if f1.is_sparse:
        f1 = _Factor(f1.vars, _densify(f1.tensor))
    if f2.is_sparse:
        f2 = _Factor(f2.vars, _densify(f2.tensor))
    out_vars = tuple([v for v in f1.vars if v not in elim] +
                     [v for v in f2.vars if v not in elim and v not in f1.vars])
    order = out_vars + tuple(sorted(elim))
    dims1 = dict(zip(f1.vars, f1.tensor.shape))
    dims2 = dict(zip(f2.vars, f2.tensor.shape))
    dims = {**dims2, **dims1}
    total = int(np.prod([dims[v] for v in order], dtype=np.int64)) if order else 1
    t1 = _to_axes(f1, order, xp)
    t2 = _to_axes(f2, order, xp)
    red_axes = tuple(range(len(out_vars), len(order)))
    if total <= _CHUNK_ELEMS or not out_vars:
        prod = sr.mul(t1, t2)
        if red_axes:
            prod = sr.add_reduce(prod, axis=red_axes)
        return _Factor(out_vars, xp.broadcast_to(
            prod, tuple(dims[v] for v in out_vars)))
    # chunk along the leading output axis to bound the intermediate
    n0 = dims[out_vars[0]]
    chunk = max(1, int(_CHUNK_ELEMS // max(1, total // n0)))
    pieces = []
    for s in range(0, n0, chunk):
        e = min(n0, s + chunk)
        s1 = t1[s:e] if t1.shape[0] != 1 else t1
        s2 = t2[s:e] if t2.shape[0] != 1 else t2
        prod = sr.mul(s1, s2)
        if red_axes:
            prod = sr.add_reduce(prod, axis=red_axes)
        pieces.append(xp.broadcast_to(
            prod, (e - s,) + tuple(dims[v] for v in out_vars[1:])))
    return _Factor(out_vars, xp.concatenate(pieces, axis=0))


# --------------------------------------------------------------------------
# Term / SSP evaluation
# --------------------------------------------------------------------------


def eval_term(t: ir.Term, head: tuple[str, ...], db: Database,
              sr: sr_mod.Semiring, sorts: Mapping[str, str], xp):
    head_vars = [h for h in head]
    factors: list[_Factor] = []
    scalar = sr.const(sr.one)
    for a in t.atoms:
        if isinstance(a, ir.RelAtom):
            factors.append(_rel_factor(a, db, sr, xp))
        elif isinstance(a, ir.PredAtom):
            f = _pred_array(a, db, sorts, xp)
            factors.append(_Factor(f.vars, sr.from_bool(f.tensor)))
        elif isinstance(a, ir.ValAtom):
            n = db.dom(sorts[a.var])
            factors.append(_Factor(
                (a.var,), sr.lift_value(xp.arange(n, dtype=xp.float32))))
        elif isinstance(a, ir.ValFnAtom):
            f = _valfn_array(a, db, sorts, xp)
            factors.append(_Factor(f.vars, sr.lift_value(f.tensor)))
        elif isinstance(a, ir.ConstAtom):
            scalar = sr.mul(scalar, sr.const(a.value))
        else:  # pragma: no cover
            raise TypeError(a)

    keep = set(head_vars)

    def occurrences(v: str) -> int:
        return sum(1 for f in factors if v in f.vars)

    # eliminate single-factor bound vars eagerly
    def sweep_local():
        for i, f in enumerate(factors):
            local = [v for v in f.vars if v not in keep and occurrences(v) == 1]
            if local:
                if f.is_sparse:
                    # ⊕ over an axis = SpMV against the all-1̄ vector
                    from repro.sparse import contract as sp_contract
                    ax = f.vars.index(local[0])
                    ones = sr.ones((f.tensor.shape[ax],))
                    nv = tuple(v for v in f.vars if v != local[0])
                    factors[i] = _Factor(nv, sp_contract.spmv(
                        f.tensor, ones, transpose=(ax == 0)))
                    return True
                axes = tuple(f.vars.index(v) for v in local)
                nv = tuple(v for v in f.vars if v not in local)
                factors[i] = _Factor(nv, sr.add_reduce(f.tensor, axis=axes))
                return True
        return False

    while sweep_local():
        pass

    while len(factors) > 1:
        # greedy: pick the pair with the most shared vars, tie-break on
        # smallest resulting broadcast size
        best = None
        for i in range(len(factors)):
            for j in range(i + 1, len(factors)):
                shared = set(factors[i].vars) & set(factors[j].vars)
                union = set(factors[i].vars) | set(factors[j].vars)
                dims = {**dict(zip(factors[j].vars, factors[j].tensor.shape)),
                        **dict(zip(factors[i].vars, factors[i].tensor.shape))}
                size = int(np.prod([dims[v] for v in union] or [1],
                                   dtype=np.int64))
                key = (-len(shared), size)
                if best is None or key < best[0]:
                    best = (key, i, j)
        _, i, j = best
        f1, f2 = factors[i], factors[j]
        others_vars = set()
        for k2, f in enumerate(factors):
            if k2 not in (i, j):
                others_vars.update(f.vars)
        elim = (set(f1.vars) | set(f2.vars)) - keep - others_vars
        merged = _contract_pair(sr, f1, f2, elim, xp)
        factors = [f for k2, f in enumerate(factors) if k2 not in (i, j)]
        factors.append(merged)
        while sweep_local():
            pass

    out_shape = tuple(db.dom(sorts[h]) for h in head_vars)
    if not factors:
        return xp.broadcast_to(xp.asarray(scalar, sr.dtype), out_shape)
    f = factors[0]
    if f.is_sparse:  # single uncontracted sparse atom: materialize
        f = _Factor(f.vars, _densify(f.tensor))
    rem = tuple(v for v in f.vars if v not in keep)
    if rem:
        axes = tuple(f.vars.index(v) for v in rem)
        f = _Factor(tuple(v for v in f.vars if v in keep),
                    sr.add_reduce(f.tensor, axis=axes))
    # align to head order, broadcasting head vars absent from the factor
    t_out = _to_axes(f, tuple(head_vars), xp)
    t_out = xp.broadcast_to(t_out, out_shape)
    t_out = sr.mul(t_out, scalar)
    return t_out.astype(sr.dtype)


def eval_ssp(e: ir.SSP, db: Database,
             sort_hints: Mapping[str, str] | None = None, *,
             backend: str = "jnp"):
    """Evaluate a normalized SSP expression to a dense S-relation."""
    xp = _xp(backend)
    sr = sr_mod.get(e.semiring, lib=backend)
    sorts = infer_var_sorts(e, db.schema, sort_hints)
    out_shape = tuple(db.dom(sorts[h]) for h in e.head)
    acc = xp.full(out_shape, sr.zero, sr.dtype)
    for t in e.terms:
        acc = sr.add(acc, eval_term(t, e.head, db, sr, sorts, xp))
    return acc
