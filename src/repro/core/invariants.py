"""Loop-invariant inference (paper Sec. 3.2 + Sec. 7, green boxes).

The paper symbolically executes F for 5 iterations, mines identities
satisfied by every iterate with an e-graph, and checks candidates with the
SMT solver.  We follow the same shape:

* symbolic execution — Xₜ₊₁ = normalize(F[X := Xₜ]) as SSP expressions over
  the EDBs (X₀ = the empty SSP);
* candidate mining — *probe* identities L(X) = R(X) instantiated from a
  template family (join-commutation probes ⊕_z E(x,z)X(z,y) =
  ⊕_z X(x,z)E(z,y) for each binary EDB, identity/containment probes);
  a candidate survives if L(Xₜ) ≅ R(Xₜ) (normal-form isomorphism, the
  e-graph's role) for every executed iterate;
* checking — surviving candidates are confirmed numerically on sampled
  orbits (the verifier's role; orbit states satisfy every invariant of F
  by construction, so this checks conditions (9)+(10) on those instances).

Verified invariants feed the rule-based synthesizer as term-rewrite rules
(the *beyond magic* optimization, Example 3.8).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import ir, verify
from repro.core.ir import RelAtom, Term


@dataclasses.dataclass(frozen=True)
class Invariant:
    """An identity  ⊕_{lhs.bound} Π lhs.atoms = ⊕_{rhs.bound} Π rhs.atoms
    that holds for every reachable X (free vars are shared)."""

    lhs: Term
    rhs: Term
    head: tuple[str, ...]

    def __str__(self) -> str:
        return f"{ir.term_str(self.lhs)}  ⇔  {ir.term_str(self.rhs)}"


def symbolic_orbit(task: verify.FGHTask, steps: int = 5) -> dict[str, list[ir.SSP]]:
    """Xₜ as SSP expressions over the EDBs, t = 0..steps."""
    orbits: dict[str, list[ir.SSP]] = {
        n: [ir.SSP(r.body.head, (), r.body.semiring)]
        for n, r in task.stratum.rules.items()}
    for _ in range(steps):
        defs = {n: orbits[n][-1] for n in orbits}
        for n, rule in task.stratum.rules.items():
            orbits[n].append(ir.substitute_defs(rule.body, defs))
    return orbits


def _commutation_probes(task: verify.FGHTask, idb: str):
    """⊕_z E(x,z)⊗X(z,y)  vs  ⊕_z X(x,z)⊗E(z,y), per binary bool EDB."""
    schema = task.schema
    if len(schema[idb].sorts) != 2:
        return
    s0, s1 = schema[idb].sorts
    for e in task.edbs:
        if schema[e].sorts == (s0, s1) and \
                schema[e].semiring == schema[idb].semiring:
            lhs = Term((RelAtom(e, ("x", "z")), RelAtom(idb, ("z", "y"))),
                       ("z",))
            rhs = Term((RelAtom(idb, ("x", "z")), RelAtom(e, ("z", "y"))),
                       ("z",))
            yield Invariant(lhs, rhs, ("x", "y"))


def infer_invariants(task: verify.FGHTask, *, steps: int = 5,
                     rng: np.random.Generator | None = None,
                     n_confirm_dbs: int = 6) -> tuple[list[Invariant], dict]:
    rng = rng or np.random.default_rng(1)
    t0 = time.perf_counter()
    try:
        orbits = symbolic_orbit(task, steps)
    except ir.NonIdempotentCast:
        return [], {"time_s": time.perf_counter() - t0, "candidates": 0}

    found: list[Invariant] = []
    n_cand = 0
    for idb in task.stratum.rules:
        for inv in _commutation_probes(task, idb):
            n_cand += 1
            symbolic_ok = True
            for xt in orbits[idb][1:]:
                l = ir.substitute_defs(
                    ir.SSP(inv.head, (inv.lhs,), xt.semiring), {idb: xt})
                r = ir.substitute_defs(
                    ir.SSP(inv.head, (inv.rhs,), xt.semiring), {idb: xt})
                if not ir.isomorphic(l, r):
                    symbolic_ok = False
                    break
            # symbolic isomorphism is a fast certificate; when it fails
            # (e.g. V-guards make the forms differ off-support) we still
            # accept numerically-confirmed candidates — the synthesized H
            # is independently verified afterwards, so a spurious rewrite
            # rule can enlarge the search space but not unsoundify it.
            n_dbs = n_confirm_dbs if symbolic_ok else 2 * n_confirm_dbs
            if _confirm_numeric(task, idb, inv, rng, n_dbs):
                found.append(inv)
    return found, {"time_s": time.perf_counter() - t0, "candidates": n_cand}


def _confirm_numeric(task: verify.FGHTask, idb: str, inv: Invariant,
                     rng: np.random.Generator, n_dbs: int) -> bool:
    from repro.core import engine
    from repro.core.program import make_ico, zero_state

    sr_name = task.schema[idb].semiring
    for db in verify.sample_dbs(task, rng, n_dbs):
        ico = make_ico(task.stratum, db, task.sort_hints, backend="np")
        x = zero_state(task.stratum, db, backend="np")
        for _ in range(6):
            cur = db.with_relations(x)
            l = engine.eval_ssp(ir.SSP(inv.head, (inv.lhs,), sr_name), cur,
                                task.sort_hints, backend="np")
            r = engine.eval_ssp(ir.SSP(inv.head, (inv.rhs,), sr_name), cur,
                                task.sort_hints, backend="np")
            if not verify.values_equal(np.asarray(l), np.asarray(r)):
                return False
            nx = ico(x)
            if all(bool((nx[k] == x[k]).all()) for k in nx):
                break
            x = nx
    return True
