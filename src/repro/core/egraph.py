"""Equality saturation (paper Sec. 7): a compact egg-style e-graph.

E-nodes are (op, child-eclass-ids) with leaves (vars/consts/symbols);
e-classes live in a union-find with hashcons-based congruence closure.
Rewrite rules are pattern pairs; saturation applies all matches until a
fixpoint or a node budget.  Used for the paper's three EQSAT roles:

* **equivalence under constraints** — a constraint Δ ⇒ Θ is inserted as
  the equation Δ∧Θ = Δ (Sec. 7), then equivalence is an e-class check;
* **denormalization** (query rewriting using views, Sec. 6.1) — insert the
  normalized body and the view V = G(X), merge V's e-class with a fresh
  symbol Y, extract the smallest expression containing no X;
* **invariant mining support** — identities over symbolic iterates.

Terms here are generic s-expressions ``("op", child, child, ...)`` with
string leaves; the Datalog°-specific bridge lives in the callers (the SSP
IR canonicalizes AC operators itself, so the e-graph handles the
*structural* rules: distributivity, factoring, cast algebra, constraint
equations).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable

Term = "tuple | str"


@dataclasses.dataclass(frozen=True)
class ENode:
    op: str
    children: tuple[int, ...]


class EGraph:
    def __init__(self):
        self.parent: list[int] = []
        self.classes: dict[int, set[ENode]] = {}
        self.hashcons: dict[ENode, int] = {}
        self.worklist: list[int] = []

    # -- union-find --------------------------------------------------------
    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def _new_class(self, node: ENode) -> int:
        cid = len(self.parent)
        self.parent.append(cid)
        self.classes[cid] = {node}
        self.hashcons[node] = cid
        return cid

    def canonicalize(self, node: ENode) -> ENode:
        return ENode(node.op, tuple(self.find(c) for c in node.children))

    def add_node(self, node: ENode) -> int:
        node = self.canonicalize(node)
        if node in self.hashcons:
            return self.find(self.hashcons[node])
        return self._new_class(node)

    def add_term(self, t: Term) -> int:
        if isinstance(t, str):
            return self.add_node(ENode(t, ()))
        op, *children = t
        return self.add_node(ENode(op, tuple(self.add_term(c)
                                             for c in children)))

    def merge(self, a: int, b: int) -> int:
        a, b = self.find(a), self.find(b)
        if a == b:
            return a
        if len(self.classes[a]) < len(self.classes[b]):
            a, b = b, a
        self.parent[b] = a
        self.classes[a] |= self.classes.pop(b)
        self.worklist.append(a)
        return a

    def rebuild(self):
        """Restore congruence closure after merges."""
        while self.worklist:
            todo, self.worklist = self.worklist, []
            seen: dict[ENode, int] = {}
            for cid in list(self.classes):
                if cid not in self.classes:
                    continue
                for node in list(self.classes[cid]):
                    if cid not in self.classes:
                        break  # a merge below absorbed cid into another class
                    canon = self.canonicalize(node)
                    self.classes[cid].discard(node)
                    self.classes[cid].add(canon)
                    self.hashcons[canon] = cid
                    if canon in seen and self.find(seen[canon]) != \
                            self.find(cid):
                        self.merge(seen[canon], cid)
                    seen[canon] = self.find(cid)
            del todo

    def eq(self, a: int, b: int) -> bool:
        return self.find(a) == self.find(b)

    # -- e-matching ----------------------------------------------------------
    def ematch(self, pattern: Term) -> Iterable[tuple[int, dict]]:
        """Yield (eclass, substitution) for every match of ``pattern``.
        Pattern variables are strings starting with '?'."""
        for cid in list(self.classes):
            yield from ((cid, s) for s in self._match_class(pattern, cid, {}))

    def _match_class(self, pattern, cid, subst):
        cid = self.find(cid)
        if isinstance(pattern, str):
            if pattern.startswith("?"):
                if pattern in subst:
                    if self.find(subst[pattern]) == cid:
                        yield subst
                    return
                s2 = dict(subst)
                s2[pattern] = cid
                yield s2
                return
            if ENode(pattern, ()) in self.hashcons and \
                    self.find(self.hashcons[ENode(pattern, ())]) == cid:
                yield subst
            return
        op, *children = pattern
        for node in list(self.classes.get(cid, ())):
            if node.op != op or len(node.children) != len(children):
                continue
            substs = [subst]
            for pat_c, node_c in zip(children, node.children):
                substs = [s2 for s in substs
                          for s2 in self._match_class(pat_c, node_c, s)]
                if not substs:
                    break
            yield from substs

    def instantiate(self, pattern: Term, subst: dict) -> int:
        if isinstance(pattern, str):
            if pattern.startswith("?"):
                return subst[pattern]
            return self.add_node(ENode(pattern, ()))
        op, *children = pattern
        return self.add_node(ENode(op, tuple(
            self.instantiate(c, subst) for c in children)))

    # -- saturation -----------------------------------------------------------
    def run_rules(self, rules: list[tuple[Term, Term]], *, iters: int = 8,
                  node_limit: int = 20_000) -> int:
        applied = 0
        for _ in range(iters):
            matches = []
            for lhs, rhs in rules:
                for cid, subst in self.ematch(lhs):
                    matches.append((cid, rhs, subst))
            changed = False
            for cid, rhs, subst in matches:
                new_id = self.instantiate(rhs, subst)
                if self.find(new_id) != self.find(cid):
                    self.merge(cid, new_id)
                    changed = True
                    applied += 1
            self.rebuild()
            if not changed or len(self.parent) > node_limit:
                break
        return applied

    # -- extraction -----------------------------------------------------------
    def extract(self, cid: int, *, forbid_ops: set[str] = frozenset(),
                max_iters: int = 50) -> Term | None:
        """Smallest term for e-class ``cid`` avoiding ``forbid_ops``."""
        INF = float("inf")
        cost: dict[int, float] = {}
        best: dict[int, ENode] = {}
        for _ in range(max_iters):
            changed = False
            for c, nodes in self.classes.items():
                for n in nodes:
                    if n.op in forbid_ops:
                        continue
                    child_cost = 0.0
                    ok = True
                    for ch in n.children:
                        ch = self.find(ch)
                        if ch not in cost:
                            ok = False
                            break
                        child_cost += cost[ch]
                    if not ok:
                        continue
                    total = 1.0 + child_cost
                    c_root = self.find(c)
                    if total < cost.get(c_root, INF):
                        cost[c_root] = total
                        best[c_root] = n
                        changed = True
            if not changed:
                break
        root = self.find(cid)
        if root not in best:
            return None

        def build(c: int) -> Term:
            n = best[self.find(c)]
            if not n.children:
                return n.op
            return (n.op,) + tuple(build(ch) for ch in n.children)

        return build(root)


# -- convenience -------------------------------------------------------------


def equivalent_under(rules: list[tuple[Term, Term]], a: Term, b: Term,
                     constraints: list[tuple[Term, Term]] = (),
                     iters: int = 8) -> bool:
    """Check a ≡ b under rewrite rules + constraint equations (Δ∧Θ = Δ)."""
    g = EGraph()
    ia, ib = g.add_term(a), g.add_term(b)
    for lhs, rhs in constraints:
        g.merge(g.add_term(lhs), g.add_term(rhs))
    g.rebuild()
    g.run_rules(list(rules), iters=iters)
    return g.eq(ia, ib)


#: structural semiring rules (AC is canonicalized by the SSP IR; these are
#: the directional rules the paper's Sec. 5.1/7 uses the e-graph for)
SEMIRING_RULES: list[tuple[Term, Term]] = [
    (("mul", "?a", ("add", "?b", "?c")),
     ("add", ("mul", "?a", "?b"), ("mul", "?a", "?c"))),   # distribute
    (("add", ("mul", "?a", "?b"), ("mul", "?a", "?c")),
     ("mul", "?a", ("add", "?b", "?c"))),                   # factor
    (("mul", "?a", "one"), "?a"),
    (("mul", "?a", "zero"), "zero"),
    (("add", "?a", "zero"), "?a"),
    (("mul", "?a", "?b"), ("mul", "?b", "?a")),
    (("add", "?a", "?b"), ("add", "?b", "?a")),
    (("mul", ("mul", "?a", "?b"), "?c"), ("mul", "?a", ("mul", "?b", "?c"))),
    (("add", ("add", "?a", "?b"), "?c"), ("add", "?a", ("add", "?b", "?c"))),
    # cast algebra: [P]⊗[P] = [P]
    (("mul", ("cast", "?p"), ("cast", "?p")), ("cast", "?p")),
]


#: structural rules over maintenance-rule terms (DESIGN.md §11).  A
#: candidate is an s-expression ``("recount", cone(seed("delta")))``;
#: these rewrites canonicalize it — closure operators are idempotent,
#: the forward closure absorbs the tight closure it contains, a
#: seed-only "cone" is the identity on its seed set, and the full cone
#: is the whole vertex universe no matter what seeded it, at which point
#: the recount *is* a cold fixpoint.  The synthesizer uses the last fact
#: to reject the degenerate candidate by proof instead of by pricing.
MAINTENANCE_RULES: list[tuple[Term, Term]] = [
    (("cone_tight", ("cone_tight", "?x")), ("cone_tight", "?x")),
    (("cone_forward", ("cone_forward", "?x")), ("cone_forward", "?x")),
    (("cone_forward", ("cone_tight", "?x")), ("cone_forward", "?x")),
    (("cone_one_hop", ("cone_seeds", "?x")), ("cone_one_hop", "?x")),
    (("cone_seeds", "?x"), "?x"),
    (("cone_all", "?x"), "univ"),
    (("cone_tight", "univ"), "univ"),
    (("cone_forward", "univ"), "univ"),
    (("recount", "univ"), "cold_fixpoint"),
]


def normalize(term: Term, rules: list[tuple[Term, Term]] | None = None,
              *, iters: int = 8) -> Term:
    """Saturate ``term`` under ``rules`` (default
    :data:`MAINTENANCE_RULES`) and extract the smallest equivalent —
    the canonical form cached and surfaced by ``explain()``."""
    g = EGraph()
    cid = g.add_term(term)
    g.run_rules(list(rules if rules is not None else MAINTENANCE_RULES),
                iters=iters)
    out = g.extract(cid)
    return term if out is None else out
