"""Verification of the FGH identity G(F(X)) = H(G(X))  (paper Sec. 5).

The paper verifies with z3 over normalized expressions; offline we use a
*bounded-model / orbit* check (DESIGN.md §4):

* sample small databases D (Γ-constrained when the task has a constraint);
* walk the F-orbit X₀, X₁ = F(X₀), … (⊆ 8 steps) — every loop invariant Φ
  of F holds on the orbit *by construction*, so checking the commutation on
  orbit states is exactly the premise of Theorem 3.1's diagram (invariants
  are a proof device; the diagram only ever visits orbit states);
* at each state, compare G(F(Xₜ)) with H(G(Xₜ)) numerically.

Refutation is sound (a mismatch is a real counterexample — returned to the
synthesizer as CEGIS feedback).  Acceptance is exhaustive over tiny boolean
domains plus randomized over larger ones; the final program additionally
passes a full Π₁-vs-Π₂ answer comparison.

Also here: :class:`UpdateProbe` / :func:`sample_update_probes`, the probe
generator for the *maintenance*-rule CEGIS loop (DESIGN.md §11) — small
adversarial graphs (chains, diamonds, slack paths, cycles feeding tails)
plus randomized digraphs, each with a deletion/increase batch, on which
``maintain(y*, ΔE) ≡ fixpoint(E ⊖ ΔE)`` is checked numerically.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import constraints as gamma
from repro.core import engine, ir
from repro.core import semiring as sr_mod
from repro.core.program import Program, Rule, Stratum, make_ico, zero_state


@dataclasses.dataclass
class FGHTask:
    """One stratum Π₁ = (F, G) to optimize, plus its verification context."""

    name: str
    schema: ir.Schema
    stratum: Stratum                 # F: the recursive IDBs X
    outputs: list[Rule]              # G chain; last head is the answer Y
    edbs: list[str]
    constraint: str | None = None
    small_domains: dict[str, int] = dataclasses.field(default_factory=dict)
    sampler: Callable | None = None  # custom Γ/shape-aware DB sampler
    sort_hints: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def y_name(self) -> str:
        return self.outputs[-1].head

    def y_semiring(self) -> sr_mod.Semiring:
        return sr_mod.get(self.schema[self.y_name].semiring)


_DEFAULT_SORT_SIZES = {"id": 3, "w": 3, "d": 12, "pos": 5, "cnt": 6}


def task_from_program(prog: Program, edbs: list[str],
                      constraint: str | None = None,
                      small_domains: dict[str, int] | None = None,
                      sampler: Callable | None = None) -> FGHTask:
    assert len(prog.strata) == 1, "FGH optimizes one stratum at a time"
    sorts: set[str] = set()
    for rs in prog.schema.values():
        sorts.update(rs.sorts)
    doms = {s: _DEFAULT_SORT_SIZES.get(s, 4) for s in sorts}
    doms.update(small_domains or {})
    return FGHTask(prog.name, prog.schema, prog.strata[0], prog.outputs,
                   edbs, constraint, doms, sampler, prog.sort_hints)


@dataclasses.dataclass
class OrbitPoint:
    """One CEGIS counterexample: Y_in = G(Xₜ) and target = G(F(Xₜ))."""

    db: engine.Database
    y_in: jnp.ndarray
    target: np.ndarray


def eval_g(task: FGHTask, db: engine.Database,
           state: dict[str, jnp.ndarray]) -> jnp.ndarray:
    cur = db.with_relations(state)
    out = None
    for rule in task.outputs:
        out = engine.eval_ssp(rule.body, cur, task.sort_hints, backend="np")
        cur = cur.with_relations({rule.head: out})
    return out


def orbit_points(task: FGHTask, db: engine.Database, *,
                 max_steps: int = 8) -> list[OrbitPoint]:
    """G-images and G∘F-targets along the F-orbit from X₀ = 0̄."""
    ico = make_ico(task.stratum, db, task.sort_hints, backend="np")
    x = zero_state(task.stratum, db, backend="np")
    pts = []
    for _ in range(max_steps):
        nx = ico(x)
        pts.append(OrbitPoint(db, eval_g(task, db, x),
                              np.asarray(eval_g(task, db, nx))))
        if all(bool(np.all(nx[k] == x[k])) for k in nx):
            break
        x = nx
    return pts


def eval_h(task: FGHTask, h_body: ir.SSP, pt: OrbitPoint) -> np.ndarray:
    db = pt.db.with_relations({task.y_name: pt.y_in})
    return np.asarray(engine.eval_ssp(h_body, db, task.sort_hints,
                                      backend="np"))


def values_equal(a: np.ndarray, b: np.ndarray, atol: float = 1e-4) -> bool:
    if a.dtype == bool:
        return bool((a == b).all())
    return bool(np.allclose(a, b, atol=atol, rtol=1e-4, equal_nan=True))


def constant_floors(task: FGHTask) -> dict[str, int]:
    """Smallest domain size per sort that contains every constant the
    program mentions — a query-source constant C(a) in an id position
    forces id ≥ a + 1, or the probe databases cannot even index it (the
    serve loop optimizes source-parameterized programs at arbitrary
    vertices, not just 0)."""
    floors: dict[str, int] = {}

    def bump(sort: str, value: int) -> None:
        floors[sort] = max(floors.get(sort, 0), int(value) + 1)

    def visit(e: ir.SSP) -> None:
        sorts = engine.infer_var_sorts(e, task.schema, task.sort_hints)
        for t in e.terms:
            for a in t.atoms:
                if isinstance(a, ir.RelAtom):
                    for arg, s in zip(a.args, task.schema[a.name].sorts):
                        if isinstance(arg, ir.C):
                            bump(s, arg.value)
                elif isinstance(a, (ir.PredAtom, ir.ValFnAtom)):
                    var_sorts = [sorts[x] for x in a.args
                                 if not isinstance(x, ir.C) and x in sorts]
                    for arg in a.args:
                        if isinstance(arg, ir.C):
                            for s in var_sorts:
                                bump(s, arg.value)

    for rule in list(task.stratum.rules.values()) + list(task.outputs):
        visit(rule.body)
    if task.stratum.init:
        for e in task.stratum.init.values():
            visit(e)
    return floors


#: largest probe-domain size the bounded-model check will materialize —
#: dense probe relations are O(size²); beyond this a program constant
#: (e.g. a 50k-vertex query source) must be substituted into an already
#: verified template instead of re-verified from scratch
_MAX_PROBE_DOMAIN = 512


def sample_dbs(task: FGHTask, rng: np.random.Generator, count: int,
               ) -> list[engine.Database]:
    floors = constant_floors(task)
    too_big = {s: v for s, v in floors.items() if v > _MAX_PROBE_DOMAIN}
    if too_big:
        raise ValueError(
            f"{task.name}: constants force probe domains {too_big} past "
            f"the bounded-model capacity ({_MAX_PROBE_DOMAIN}); verify a "
            f"small-constant template and substitute instead")

    def floored(d: dict) -> dict:
        out = {s: max(v, floors.get(s, 0)) for s, v in d.items()}
        for s, v in floors.items():
            out.setdefault(s, v)
        return out

    doms = floored({"id": 3, **task.small_domains})
    dbs: list[engine.Database] = []
    if task.sampler is not None:
        for _ in range(count):
            dbs.append(task.sampler(rng, doms))
        return dbs
    # a slice of the exhaustive n=2 space plus random n∈{3,4} instances.
    # Γ-constrained tasks skip the exhaustive slice: its instances ignore
    # the V-covers-all-nodes aspect of the tree/dag constraints.
    if task.constraint is None:
        doms2 = floored({**doms, "id": 2})
        dbs.extend(gamma.exhaustive_databases(
            task.schema, task.edbs, doms2, constraint=task.constraint,
            limit=8))
    for i in range(count):
        d = dict(doms)
        d["id"] = max(3 + (i % 2), floors.get("id", 0))
        dbs.append(gamma.sample_database(task.schema, task.edbs, d, rng,
                                         constraint=task.constraint))
    return dbs


@dataclasses.dataclass
class VerifyResult:
    ok: bool
    counterexample: OrbitPoint | None = None
    points_checked: int = 0


def verify_h(task: FGHTask, h_body: ir.SSP, *, rng: np.random.Generator,
             n_dbs: int = 10, max_steps: int = 8) -> VerifyResult:
    """Check G(F(X)) = H(G(X)) on sampled orbits; CEGIS's verifier."""
    checked = 0
    for db in sample_dbs(task, rng, n_dbs):
        for pt in orbit_points(task, db, max_steps=max_steps):
            checked += 1
            got = eval_h(task, h_body, pt)
            if not values_equal(got, pt.target):
                return VerifyResult(False, pt, checked)
    return VerifyResult(True, None, checked)


# --------------------------------------------------------------------------
# Update-maintenance probes (DESIGN.md §11)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class UpdateProbe:
    """One bounded-model instance for maintenance-rule verification: a
    small vector fixpoint ``x = init ⊕ x ⊗ E`` plus a non-monotone
    update against ``E``.  The CEGIS loop in
    :mod:`repro.incremental.maintenance` replays each candidate rule on
    these and compares against a from-scratch solve — the maintenance
    analogue of :func:`sample_dbs` + :func:`orbit_points`."""

    name: str
    edges: object          # SparseRelation over the probe semiring
    init: np.ndarray       # (n,) init vector (a query source)
    coords: np.ndarray     # (k, 2) updated edge keys
    new_values: np.ndarray | None = None  # increase op: the heavier values


def _probe_rel(coords, values, n, semiring):
    from repro.sparse.coo import SparseRelation
    return SparseRelation.from_coo(coords, values, (n, n), semiring,
                                   capacity=max(1, 2 * len(coords)),
                                   lib="np")


def sample_update_probes(semiring: str, rng: np.random.Generator,
                         count: int = 8, *, op: str = "delete"
                         ) -> list[UpdateProbe]:
    """Adversarial + randomized probes for non-monotone maintenance.

    The deterministic set is chosen to *refute* every unsound candidate
    in the rule grammar (DESIGN.md §11): chains kill no-closure and
    one-hop cones, cyclic support kills DRed-style support counting
    (a cycle keeps itself "supported" after its external feed is
    deleted).  ``maxplus`` probes are DAGs only — a positive cycle has
    no finite longest path, so cyclic instances would not even have a
    from-scratch ground truth to compare against.
    """
    sr = sr_mod.get(semiring, lib="np")
    cyclic_ok = semiring != "maxplus"

    def mk(name, coords, dels, *, n=None, w=None, inc=None):
        coords = np.asarray(coords, np.int64)
        n = n or int(coords.max()) + 1
        if semiring == "bool":
            vals = np.ones(len(coords), bool)
        else:
            vals = np.asarray(w if w is not None
                              else np.ones(len(coords)), sr.dtype)
        init = np.full(n, sr.zero, sr.dtype)
        init[0] = sr.one
        return UpdateProbe(name, _probe_rel(coords, vals, n, semiring),
                           init, np.asarray(dels, np.int64),
                           None if inc is None
                           else np.asarray(inc, sr.dtype))

    probes = [
        # chain: effects propagate ≥ 3 hops past the deleted edge
        mk("chain", [(0, 1), (1, 2), (2, 3), (3, 4)], [(0, 1)]),
        # diamond: surviving alternate support must be kept, not dropped
        mk("diamond", [(0, 1), (0, 2), (1, 3), (2, 3)], [(0, 1)],
           w=[1, 5, 1, 1]),
        # slack: deleting a non-tight edge must be a no-op
        mk("slack", [(0, 1), (1, 2), (0, 2)], [(0, 2)], w=[1, 1, 9]),
        # batch: two deletes in one update
        mk("batch", [(0, 1), (1, 2), (2, 3), (3, 4)],
           [(0, 1), (2, 3)]),
    ]
    if cyclic_ok:
        probes += [
            # cyclic support: 1⇄2 keep each other "supported" after the
            # external feed (0,1) is deleted — the DRed counterexample
            mk("cycle-feed", [(0, 1), (1, 2), (2, 1)], [(0, 1)]),
            # self-loop support (the 1-cycle variant)
            mk("self-loop", [(0, 1), (1, 1)], [(0, 1)],
               w=[1, 0] if semiring != "bool" else None),
            # a cycle with a tail hanging off it
            mk("cycle-tail", [(0, 1), (1, 2), (2, 3), (3, 1), (1, 4)],
               [(0, 1)]),
        ]
    for i in range(count):
        n = int(rng.integers(6, 10))
        mask = rng.random((n, n)) < 0.3
        np.fill_diagonal(mask, False)
        if not cyclic_ok:
            mask = np.triu(mask)  # DAG
        coords = np.argwhere(mask)
        if len(coords) == 0:
            coords = np.asarray([(0, 1)])
        w = rng.integers(1, 6, len(coords))
        k = int(rng.integers(1, min(4, len(coords)) + 1))
        dels = coords[rng.choice(len(coords), size=k, replace=False)]
        probes.append(mk(f"rand{i}", coords, dels, n=n, w=w))
    if op == "increase":
        for p in probes:
            k = len(p.coords)
            bump = rng.integers(1, 5, k)
            if semiring == "bool":
                p.new_values = np.ones(k, bool)
            else:
                p.new_values = np.asarray(bump * 3 + 1, sr.dtype)
    return probes


def verify_programs_equal(p1: Program, p2: Program, dbs, *,
                          atol: float = 1e-4) -> bool:
    """End-to-end Π₁ ≡ Π₂ answer check on concrete databases."""
    from repro.core.program import run_program
    for db in dbs:
        # ground-truth naive evaluation: CEGIS candidates may be
        # non-monotone mid-search, where fancier runners can diverge
        a, _ = run_program(p1, db, mode="naive")
        b, _ = run_program(p2, db, mode="naive")
        if not values_equal(np.asarray(a), np.asarray(b), atol):
            return False
    return True
