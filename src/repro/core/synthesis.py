"""CEGIS query synthesis: find H with G(F(X)) = H(G(X))  (paper Sec. 6).

Grammar Σ (paper Fig. 8, k_max = 1 — linear programs): candidates are
normalized SSPs ``H = H⁰ ⊕ H¹(Y)`` where H⁰-terms use only EDB atoms and
each H¹-term contains exactly one Y atom.  As in the paper's refinements
(Appendix A) the atom vocabulary is mined from the original program: EDB
atom patterns, interpreted predicates, value atoms and constants appearing
in F and G, instantiated over a typed variable pool (head vars + per-sort
fresh bound vars).

The CEGIS loop (paper Sec. 6.2.1), adapted to the ⊕-of-terms structure:

* generator — enumerate candidate *terms*, keep those *admissible* on all
  counterexamples so far (a term t is admissible iff target ⊕ t = target
  pointwise for idempotent ⊕, iff t ≤ target for (+)-semirings with
  non-negative values: adding terms can then only overshoot);
* search ⊕-combinations of admissible terms (DFS, ≤ max_terms) whose ⊕
  matches the target exactly on every counterexample — term evaluations are
  cached per counterexample so a combination test is a couple of numpy
  reductions;
* verifier — the orbit/bounded-model check (verify.py); failures return a
  fresh counterexample database and the loop repeats.

This mirrors Rosette's generate/verify duel; we replace the SMT-encoded
choice variables with the admissibility filter + cached-evaluation DFS
(DESIGN.md §4), which keeps the explored space in the paper's 10–150 range.

The same sketch/verify/refine shape is reused a second time by
:mod:`repro.incremental.maintenance` (DESIGN.md §11), where the grammar
ranges over ⊖/recount *maintenance* rules instead of query rewrites and
the counterexamples are update probes (:func:`repro.core.verify.
sample_update_probes`) rather than orbit databases.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Sequence

import numpy as np

from repro.core import ir, verify
from repro.core import semiring as sr_mod
from repro.core.ir import (C, ConstAtom, PredAtom, RelAtom, Term, ValAtom,
                           canonical_term)


@dataclasses.dataclass
class SynthesisResult:
    ok: bool
    h_body: ir.SSP | None
    stats: dict


# --------------------------------------------------------------------------
# Vocabulary mining (paper Appendix A: types + program subexpressions)
# --------------------------------------------------------------------------


def _program_atoms(task: verify.FGHTask):
    for rule in list(task.stratum.rules.values()) + list(task.outputs):
        for t in rule.body.terms:
            yield from t.atoms


def _collect_consts(task: verify.FGHTask) -> tuple[list[C], list[float]]:
    key_consts: dict[tuple, C] = {}
    val_consts: set[float] = set()
    uses_succ = False
    for a in _program_atoms(task):
        if isinstance(a, (RelAtom, PredAtom)):
            for arg in a.args:
                if isinstance(arg, C):
                    key_consts.setdefault(("c", arg.value), arg)
            if isinstance(a, PredAtom) and a.pred in ("succ", "sum3"):
                uses_succ = True
        elif isinstance(a, ConstAtom):
            val_consts.add(a.value)
    sr = task.y_semiring()
    if uses_succ and sr.name in ("trop", "maxplus"):
        val_consts.add(1.0)  # x = y+1 in a (min/max,+) ring ⇒ the const 1̄⊗1
    return list(key_consts.values()), sorted(val_consts)


def build_term_pool(task: verify.FGHTask, *, max_atoms: int = 3,
                    max_bound: int = 2) -> list[Term]:
    """Instantiate the grammar's sum-product terms (one pool for H⁰ ∪ H¹)."""
    schema = task.schema
    sr = task.y_semiring()
    y = task.y_name
    y_sorts = schema[y].sorts
    head = task.outputs[-1].body.head  # answer head vars

    # typed variable pool: head vars + per-sort bound variables
    var_sort: dict[str, str] = dict(zip(head, y_sorts))
    bound_pool: dict[str, list[str]] = {}
    sorts_in_play = set(y_sorts)
    # H's vocabulary: the EDBs plus the view Y — never the IDBs X (total
    # rewrite) nor G-chain intermediates (they exist only inside G).
    rel_names = {a.name for a in _program_atoms(task) if isinstance(a, RelAtom)}
    rel_names &= set(task.edbs)
    rel_names |= {y}
    for rn in rel_names:
        sorts_in_play.update(schema[rn].sorts)
    for s in sorts_in_play:
        bound_pool[s] = [f"{s}$1", f"{s}$2"][:max_bound]
        for v in bound_pool[s]:
            var_sort[v] = s

    key_consts, val_consts = _collect_consts(task)

    def args_for(sorts: Sequence[str]):
        pools = []
        for s in sorts:
            p = [v for v in head if var_sort[v] == s] + bound_pool.get(s, [])
            p = p + [c for c in key_consts]
            pools.append(p)
        return itertools.product(*pools)

    # key-level arithmetic predicates (sum3/winlt) encode what value atoms
    # already express under (min/max,+)/(+,×) — dropping them from Σ keeps
    # the space in the paper's range without losing the published rewrites.
    preds_used = {a.pred for a in _program_atoms(task)
                  if isinstance(a, PredAtom)} - {"sum3", "winlt"}

    atoms: list = []
    for rn in sorted(rel_names):
        rs = schema[rn]
        need_cast = rs.semiring != sr.name and rs.semiring == "bool"
        for args in args_for(rs.sorts):
            vs_only = [a2 for a2 in args if not isinstance(a2, C)]
            if len(set(vs_only)) != len(vs_only):
                continue  # repeated-variable (diagonal) atoms: not in Σ
            atoms.append(RelAtom(rn, tuple(args), cast=need_cast))
    for pred in sorted(preds_used):
        arity = ir.PREDICATES[pred]
        # predicates on any same-sort variable pairs/triples
        for s in sorted(sorts_in_play):
            vs = [v for v in head if var_sort[v] == s] + bound_pool.get(s, [])
            vs = vs + [c for c in key_consts]
            for args in itertools.product(vs, repeat=arity):
                if all(isinstance(a2, C) for a2 in args):
                    continue
                atoms.append(PredAtom(pred, tuple(args)))
    if sr.name != "bool":
        for v in list(var_sort):
            atoms.append(ValAtom(v))
        for c in val_consts:
            atoms.append(ConstAtom(c))

    # assemble connected terms with ≤ max_atoms atoms and ≤ 1 Y-occurrence
    head_set = set(head)
    pool: dict[tuple, Term] = {}

    def add_term(selected: tuple):
        n_y = sum(1 for a in selected
                  if isinstance(a, RelAtom) and a.name == y)
        if n_y > 1:
            return
        vs: set[str] = set()
        for a in selected:
            vs.update(ir.atom_vars(a))
        bound = tuple(sorted(vs - head_set))
        if len(bound) > max_bound:
            return
        # connectivity: bound vars must link to the head/other atoms
        if len(selected) > 1:
            # every atom shares a variable with some other atom, or uses a
            # head var (keeps products from being arbitrary cartesians)
            for a in selected:
                av = set(ir.atom_vars(a))
                if not av:
                    continue
                if av & head_set:
                    continue
                others = set()
                for b in selected:
                    if b is not a:
                        others.update(ir.atom_vars(b))
                if not av & others:
                    return
        # every bound var must appear in a relational/value atom (safety-ish)
        try:
            t = ir.normalize_term(Term(tuple(selected), bound), sr.name)
        except ValueError:  # dangling bound var under a non-idempotent ⊕
            return
        if t is None:
            return
        key = canonical_term(t, tuple(head))
        pool.setdefault(key, t)

    for k in range(1, max_atoms + 1):
        for combo in itertools.combinations(range(len(atoms)), k):
            add_term(tuple(atoms[i] for i in combo))
    return list(pool.values())


# --------------------------------------------------------------------------
# The CEGIS loop
# --------------------------------------------------------------------------


def _admissible(sr: sr_mod.Semiring, tv: np.ndarray, target: np.ndarray,
                atol: float = 1e-4) -> bool:
    if sr.idempotent:
        joined = np.asarray(sr.add(tv, target))
        return verify.values_equal(joined, target, atol)
    return bool(np.all(tv <= target + atol))


def synthesize(task: verify.FGHTask, *, rng: np.random.Generator | None = None,
               max_terms: int = 3, max_atoms: int = 3,
               max_rounds: int = 12, n_verify_dbs: int = 10,
               require_recursive: bool = True) -> SynthesisResult:
    rng = rng or np.random.default_rng(0)
    t0 = time.perf_counter()
    sr = task.y_semiring()
    head = task.outputs[-1].body.head
    # the answer head vars are sort-hinted so pure-predicate terms evaluate
    # at the right domain shapes
    hints = dict(task.sort_hints)
    hints.update(zip(head, task.schema[task.y_name].sorts))
    task = dataclasses.replace(task, sort_hints=hints)
    pool = build_term_pool(task, max_atoms=max_atoms)

    # initial counterexamples: random orbits (exhaustive tiny instances are
    # left to the verifier — as CEGIS seeds they are too degenerate and
    # collapse the signature space)
    from repro.core import constraints as gamma

    def fresh_ces(n_id: int) -> list[verify.OrbitPoint]:
        doms = dict(task.small_domains)
        doms["id"] = n_id
        if task.sampler is not None:
            db = task.sampler(rng, doms)
        else:
            db = gamma.sample_database(task.schema, task.edbs, doms, rng,
                                       constraint=task.constraint)
        return verify.orbit_points(task, db)[:5]

    ces: list[verify.OrbitPoint] = fresh_ces(3) + fresh_ces(4)

    term_cache: list[dict[int, np.ndarray]] = []  # per-ce: idx -> eval

    def ce_evals(ce_idx: int) -> dict[int, np.ndarray]:
        while len(term_cache) <= ce_idx:
            term_cache.append({})
        return term_cache[ce_idx]

    def eval_term_on(ti: int, ce_idx: int) -> np.ndarray:
        cache = ce_evals(ce_idx)
        if ti not in cache:
            body = ir.SSP(tuple(head), (pool[ti],), sr.name)
            cache[ti] = verify.eval_h(task, body, ces[ce_idx])
        return cache[ti]

    tested = 0
    rounds = 0
    y = task.y_name

    def is_recursive(idxs) -> bool:
        return any(any(isinstance(a, RelAtom) and a.name == y
                       for a in pool[i].atoms) for i in idxs)

    while rounds < max_rounds:
        rounds += 1
        # 1. admissibility filter against all current counterexamples
        admissible = []
        for ti in range(len(pool)):
            ok = True
            for ci in range(len(ces)):
                if not _admissible(sr, eval_term_on(ti, ci), ces[ci].target):
                    ok = False
                    break
            if ok:
                admissible.append(ti)

        # 1b. usefulness: a term that never *attains* the target anywhere
        # (idempotent ⊕) / is identically 0̄ (additive ⊕) cannot matter.
        def useful(ti: int) -> bool:
            for ci in range(len(ces)):
                tv = eval_term_on(ti, ci)
                tgt = ces[ci].target
                if sr.idempotent:
                    hit = (tv == tgt) & (tgt != np.asarray(sr.zero))
                    if tgt.dtype == bool:
                        hit = tv & tgt
                    if np.any(hit):
                        return True
                elif np.any(tv != np.asarray(sr.zero)):
                    return True
            return False

        admissible = [ti for ti in admissible if useful(ti)]

        # 1c. dedup by evaluation signature across counterexamples — terms
        # indistinguishable on every counterexample collapse to the
        # syntactically smallest representative (Rosette's symbolic choice
        # variables play this role in the paper).
        admissible.sort(key=lambda ti: (len(pool[ti].atoms),
                                        len(pool[ti].bound)))
        sig_seen: dict[bytes, int] = {}
        deduped = []
        for ti in admissible:
            sig = b"".join(np.ascontiguousarray(eval_term_on(ti, ci)).tobytes()
                           for ci in range(len(ces)))
            if sig not in sig_seen:
                sig_seen[sig] = ti
                deduped.append(ti)
        admissible = deduped
        if len(admissible) > 64:
            admissible = admissible[:64]

        # 2. DFS over ⊕-combinations (smallest first)
        candidate = None
        for k in range(1, max_terms + 1):
            for combo in itertools.combinations(admissible, k):
                if require_recursive and not is_recursive(combo):
                    continue
                tested += 1
                ok = True
                for ci in range(len(ces)):
                    acc = None
                    for ti in combo:
                        tv = eval_term_on(ti, ci)
                        acc = tv if acc is None else np.asarray(sr.add(acc, tv))
                    if not verify.values_equal(acc, ces[ci].target):
                        ok = False
                        break
                if ok:
                    candidate = combo
                    break
            if candidate:
                break
        if candidate is None:
            # no exact ⊕-combination on the current counterexample set:
            # richer instances may separate collapsed signatures — widen
            # the set before giving up
            if rounds < max_rounds:
                ces.extend(fresh_ces(3 + rounds % 3))
                continue
            return SynthesisResult(False, None, _stats(t0, pool, tested,
                                                       rounds, len(ces)))

        h_body = ir.normalize(ir.SSP(tuple(head),
                                     tuple(pool[i] for i in candidate),
                                     sr.name))
        res = verify.verify_h(task, h_body, rng=rng, n_dbs=n_verify_dbs)
        if res.ok:
            stats = _stats(t0, pool, tested, rounds, len(ces))
            stats["points_checked"] = res.points_checked
            return SynthesisResult(True, h_body, stats)
        ces.append(res.counterexample)

    return SynthesisResult(False, None, _stats(t0, pool, tested, rounds,
                                               len(ces)))


def _stats(t0, pool, tested, rounds, n_ces) -> dict:
    return {
        "time_s": time.perf_counter() - t0,
        "pool_terms": len(pool),
        "candidates_tested": tested,
        "cegis_rounds": rounds,
        "counterexamples": n_ces,
    }
