"""Datalog° programs: rules, strata, ICOs, and end-to-end execution.

A :class:`Program` is a list of strata executed in order (paper Sec. 2:
interpreted functions/casts may only apply to EDBs or IDBs of earlier
strata, so each stratum's ICO is monotone and has a least fixpoint).  Each
stratum holds one merged rule per IDB (multiple rules with the same head are
OR-ed into one SSP, the paper's convention) plus an optional non-0̄ initial
state (the GH-program's ``Y ← G(X₀)``).

Which physical runner executes each stratum is decided by the cost-based
planner — see :mod:`repro.core.planner` and DESIGN.md §4;
:func:`run_program` is a thin plan-then-execute shell.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax.numpy as jnp

from repro.core import engine, fixpoint, ir
from repro.core import semiring as sr_mod


@dataclasses.dataclass(frozen=True)
class Rule:
    head: str
    body: ir.SSP  # body.head are the rule's head variables

    def __post_init__(self):
        assert isinstance(self.body, ir.SSP)


@dataclasses.dataclass
class Stratum:
    """One fixpoint block: mutually recursive IDBs and their merged rules."""

    rules: dict[str, Rule]
    init: dict[str, ir.SSP] | None = None  # optional Y₀ expressions

    @property
    def idbs(self) -> tuple[str, ...]:
        return tuple(self.rules)

    def is_linear(self) -> bool:
        for r in self.rules.values():
            for t in r.body.terms:
                n = sum(1 for a in t.atoms
                        if isinstance(a, ir.RelAtom) and a.name in self.rules)
                if n > 1:
                    return False
        return True


@dataclasses.dataclass
class Program:
    """``strata`` run in order; then the ``outputs`` chain G = G_k∘…∘G_1 is
    evaluated (each intermediate head registered as a relation — the paper's
    single-relation G generalized to helper-function chains, Appendix A);
    ``post`` is an optional host-side epilogue (e.g. WS's P[t]−P[t−10],
    which uses a non-semiring minus)."""

    name: str
    schema: ir.Schema
    strata: list[Stratum]
    outputs: list[Rule]
    post: object | None = None  # Callable[[jnp.ndarray, engine.Database], jnp.ndarray]
    sort_hints: dict[str, str] = dataclasses.field(default_factory=dict)

    def idb_semiring(self, name: str) -> sr_mod.Semiring:
        return sr_mod.get(self.schema[name].semiring)

    @property
    def answer(self) -> str:
        return self.outputs[-1].head


# --------------------------------------------------------------------------
# ICO construction
# --------------------------------------------------------------------------


def zero_state(stratum: Stratum, db: engine.Database,
               backend: str = "jnp") -> fixpoint.State:
    out = {}
    for name in stratum.idbs:
        rs = db.schema[name]
        sr = sr_mod.get(rs.semiring, lib=backend)
        shape = tuple(db.dom(s) for s in rs.sorts)
        out[name] = sr.zeros(shape)
    return out


def init_state(stratum: Stratum, db: engine.Database,
               hints: Mapping[str, str],
               backend: str = "jnp") -> fixpoint.State:
    state = zero_state(stratum, db, backend)
    if stratum.init:
        for name, expr in stratum.init.items():
            state[name] = engine.eval_ssp(expr, db, hints, backend=backend)
    return state


def make_ico(stratum: Stratum, db: engine.Database,
             hints: Mapping[str, str], backend: str = "jnp"):
    def ico(state: fixpoint.State) -> fixpoint.State:
        cur = db.with_relations(state)
        return {name: engine.eval_ssp(rule.body, cur, hints, backend=backend)
                for name, rule in stratum.rules.items()}
    return ico


def make_delta_ico(stratum: Stratum, db: engine.Database,
                   hints: Mapping[str, str]):
    """δF for linear strata: keep only terms containing an IDB atom and
    evaluate them against the Δ state (DESIGN of fixpoint.py)."""
    assert stratum.is_linear(), "GSN differential needs a linear program"
    delta_rules = {}
    for name, rule in stratum.rules.items():
        lin_terms = tuple(
            t for t in rule.body.terms
            if any(isinstance(a, ir.RelAtom) and a.name in stratum.rules
                   for a in t.atoms))
        delta_rules[name] = Rule(name, ir.SSP(rule.body.head, lin_terms,
                                              rule.body.semiring))

    def dico(delta: fixpoint.State) -> fixpoint.State:
        cur = db.with_relations(delta)
        out = {}
        for name, rule in delta_rules.items():
            if rule.body.terms:
                out[name] = engine.eval_ssp(rule.body, cur, hints)
            else:
                sr = sr_mod.get(db.schema[name].semiring)
                shape = tuple(db.dom(s) for s in db.schema[name].sorts)
                out[name] = sr.zeros(shape)
        return out

    return dico


# --------------------------------------------------------------------------
# Execution
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RunStats:
    iterations: list[int]
    mode: str
    plan: object | None = None  # the ExecutionPlan that was executed


def run_program(prog: Program, db: engine.Database, *, mode: str = "auto",
                max_iters: int = 10_000,
                plan=None) -> tuple[jnp.ndarray, RunStats]:
    """Run all strata to fixpoint, then evaluate the output rule G.

    A thin shell over the cost-based planner (DESIGN.md §4):
    ``mode="auto"`` (the default) lets :func:`repro.core.planner.
    plan_program` pick a physical runner and per-relation storage per
    stratum; the legacy mode strings compile to forced plans with the
    historical semantics ("naive" → dense naive, "seminaive" → dense
    GSN, anything else → the host loop), leaving storage untouched.
    Pass a pre-built ``plan`` (e.g. one carrying an ``edges`` override)
    to skip planning.  Staged fixpoints, initial states, and storage
    conversions are cached on ``prog`` keyed by stable database
    fingerprints (weakref tokens, not recyclable ``id()``s).
    """
    from repro.core import planner
    if plan is None:
        plan = planner.plan_for(prog, db, mode=mode, max_iters=max_iters)
    return planner.execute_plan(plan, prog, db, max_iters=max_iters)


def declare_idbs(prog: Program) -> None:
    """Sanity: every IDB referenced by rules must be in the schema."""
    for stratum in prog.strata:
        for name in stratum.idbs:
            assert name in prog.schema, f"IDB {name} missing from schema"
