"""Ordered (pre-)semirings as JAX-compatible value spaces.

The paper (Sec. 2) generalizes Datalog to Datalog° over ordered
(pre-)semirings ``(S, ⊕, ⊗, 0̄, 1̄, ≤)``.  Each semiring here carries:

* elementwise ``add``/``mul`` (⊕/⊗) and a reduction ``add_reduce`` (⊕ over an
  axis) implemented with jnp ops, so S-relations are dense jnp arrays;
* the lattice order ``leq`` used for monotone-convergence reasoning;
* ``minus`` (⊖, Sec. 3.1: ``b ⊖ a = ⋀{c | b ≤ a ⊕ c}``) for generalized
  semi-naive evaluation — defined only for idempotent complete lattices;
* ``from_bool`` — the cast operator ``[-]₀̄¹̄ : 𝔹 → S`` (Sec. 2, Datalog°).

Concrete semirings (paper Sec. 2): 𝔹, Trop (min,+), Tropʳ (max,+), ℕ∞ (+,×)
and the lifted reals ℝ (+,×).  Values use float32 tensors except 𝔹 (bool):
``inf`` encodes both ℕ∞'s ∞ and Trop's 0̄.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A commutative ordered (pre-)semiring over a jnp/numpy dtype.

    ``lib`` selects the array library: "jnp" for staged/distributed
    execution, "np" for the synthesizer/verifier's eager tiny-database
    evaluations (numpy avoids per-op dispatch overhead — the CEGIS inner
    loop evaluates thousands of micro-expressions).
    """

    name: str
    dtype: object
    zero: float
    one: float
    add: Callable[[Array, Array], Array]
    mul: Callable[[Array, Array], Array]
    add_reduce: Callable[..., Array]  # (x, axis=...) -> x reduced with ⊕
    leq: Callable[[Array, Array], Array]  # the semiring's partial order
    idempotent: bool  # ⊕ idempotent (⇒ GSN applies, Sec. 3.1)
    minus: Callable[[Array, Array], Array] | None = None  # b ⊖ a
    # ``total`` orders admit argmin-style extraction; informational.
    naturally_ordered: bool = True
    lib: str = "jnp"

    @property
    def xp(self):
        return np if self.lib == "np" else jnp

    # -- casts ---------------------------------------------------------------
    def from_bool(self, b: Array) -> Array:
        """The cast operator [-] : 𝔹 → S mapping 0 ↦ 0̄ and 1 ↦ 1̄."""
        if self.name == "bool":
            return b
        xp = self.xp
        return xp.where(b, xp.asarray(self.one, self.dtype),
                        xp.asarray(self.zero, self.dtype))

    def lift_value(self, v: Array) -> Array:
        """Interpret a numeric key value as an element of S (ValAtom)."""
        if self.name == "bool":
            raise TypeError("𝔹 has no numeric value atoms")
        return v.astype(self.dtype)

    def const(self, c: float) -> Array:
        return self.xp.asarray(c, self.dtype)

    def zeros(self, shape) -> Array:
        return self.xp.full(shape, self.zero, self.dtype)

    def ones(self, shape) -> Array:
        return self.xp.full(shape, self.one, self.dtype)

    def equal(self, a: Array, b: Array) -> Array:
        """Elementwise equality (used for fixpoint detection)."""
        return a == b

    def __repr__(self) -> str:  # keep reprs small in test output
        return f"Semiring({self.name}/{self.lib})"


def _min_reduce(x, axis=None, keepdims=False):
    return jnp.min(x, axis=axis, keepdims=keepdims)


def _max_reduce(x, axis=None, keepdims=False):
    return jnp.max(x, axis=axis, keepdims=keepdims)


def _sum_reduce(x, axis=None, keepdims=False):
    return jnp.sum(x, axis=axis, keepdims=keepdims)


def _any_reduce(x, axis=None, keepdims=False):
    return jnp.any(x, axis=axis, keepdims=keepdims)


INF = float("inf")

#: Booleans 𝔹 = ({0,1}, ∨, ∧, 0, 1); the classic Datalog semiring.
BOOL = Semiring(
    name="bool",
    dtype=jnp.bool_,
    zero=False,
    one=True,
    add=jnp.logical_or,
    mul=jnp.logical_and,
    add_reduce=_any_reduce,
    leq=lambda a, b: jnp.logical_or(jnp.logical_not(a), b),  # a ⇒ b
    idempotent=True,
    minus=lambda b, a: jnp.logical_and(b, jnp.logical_not(a)),
)

#: Tropical semiring Trop = (ℕ∪{∞}, min, +, ∞, 0).  NOTE (paper Sec. 2): the
#: order is *reversed*: ∞ is the smallest element, so "a ≤ b" is "a ≥ b" on ℝ.
TROP = Semiring(
    name="trop",
    dtype=jnp.float32,
    zero=INF,
    one=0.0,
    add=jnp.minimum,
    mul=lambda a, b: a + b,
    add_reduce=_min_reduce,
    leq=lambda a, b: a >= b,  # natural order of Trop is reversed
    idempotent=True,
    # b ⊖ a keeps b only where it strictly improves on a (min-lattice delta).
    minus=lambda b, a: jnp.where(b < a, b, jnp.asarray(INF, jnp.float32)),
)

#: Reversed tropical Tropʳ = (ℕ, max, +, 0, 0) — a pre-semiring (no
#: annihilation); used e.g. for the Graph Radius outer aggregate.
MAXPLUS = Semiring(
    name="maxplus",
    dtype=jnp.float32,
    zero=-INF,  # we lift to ℝ∪{-∞} so ⊕ has a true identity on tensors
    one=0.0,
    add=jnp.maximum,
    mul=lambda a, b: a + b,
    add_reduce=_max_reduce,
    leq=lambda a, b: a <= b,
    idempotent=True,
    minus=lambda b, a: jnp.where(b > a, b, jnp.asarray(-INF, jnp.float32)),
)

#: Closed naturals ℕ∞ = (ℕ∪{∞}, +, ×, 0, 1) — bag semantics / counting.
NAT = Semiring(
    name="nat",
    dtype=jnp.float32,
    zero=0.0,
    one=1.0,
    add=lambda a, b: a + b,
    mul=lambda a, b: a * b,
    add_reduce=_sum_reduce,
    leq=lambda a, b: a <= b,
    idempotent=False,
    minus=None,
)

#: Lifted reals ℝ⊥ = (ℝ∪{⊥}, +, ×, 0, 1) — tensors.  ⊥ is not materialized by
#: the engine (the paper uses it only for undefined entries).
REAL = Semiring(
    name="real",
    dtype=jnp.float32,
    zero=0.0,
    one=1.0,
    add=lambda a, b: a + b,
    mul=lambda a, b: a * b,
    add_reduce=_sum_reduce,
    leq=lambda a, b: a <= b,
    idempotent=False,
    minus=None,
    naturally_ordered=False,
)

SEMIRINGS: dict[str, Semiring] = {
    s.name: s for s in (BOOL, TROP, MAXPLUS, NAT, REAL)
}


def _np_reduce(fn):
    def red(x, axis=None, keepdims=False):
        return fn(x, axis=axis, keepdims=keepdims)
    return red


def _numpy_twin(sr: Semiring) -> Semiring:
    table = {
        "bool": dict(add=np.logical_or, mul=np.logical_and,
                     add_reduce=_np_reduce(np.any),
                     leq=lambda a, b: np.logical_or(~np.asarray(a), b),
                     minus=lambda b, a: np.logical_and(b, ~np.asarray(a)),
                     dtype=np.bool_),
        "trop": dict(add=np.minimum, mul=lambda a, b: a + b,
                     add_reduce=_np_reduce(np.min),
                     leq=lambda a, b: a >= b,
                     minus=lambda b, a: np.where(b < a, b,
                                                 np.float32(INF)),
                     dtype=np.float32),
        "maxplus": dict(add=np.maximum, mul=lambda a, b: a + b,
                        add_reduce=_np_reduce(np.max),
                        leq=lambda a, b: a <= b,
                        minus=lambda b, a: np.where(b > a, b,
                                                    np.float32(-INF)),
                        dtype=np.float32),
        "nat": dict(add=lambda a, b: a + b, mul=lambda a, b: a * b,
                    add_reduce=_np_reduce(np.sum),
                    leq=lambda a, b: a <= b, minus=None, dtype=np.float32),
        "real": dict(add=lambda a, b: a + b, mul=lambda a, b: a * b,
                     add_reduce=_np_reduce(np.sum),
                     leq=lambda a, b: a <= b, minus=None, dtype=np.float32),
    }
    t = table[sr.name]
    return dataclasses.replace(sr, lib="np", **t)


_NP_SEMIRINGS: dict[str, Semiring] = {
    name: _numpy_twin(s) for name, s in SEMIRINGS.items()
}


def get(name: str | Semiring, lib: str = "jnp") -> Semiring:
    if isinstance(name, Semiring):
        if name.lib == lib:
            return name
        name = name.name
    try:
        return _NP_SEMIRINGS[name] if lib == "np" else SEMIRINGS[name]
    except KeyError:
        raise KeyError(f"unknown semiring {name!r}; have {sorted(SEMIRINGS)}")


def scatter_op(sr_name: str, at):
    """The ⊕-combining scatter for a jnp ``x.at[idx]`` handle — the one
    table shared by sparse materialization, contraction, and the kernel
    oracle (⊕ = max/min/add per semiring)."""
    return {"bool": at.max, "trop": at.min, "maxplus": at.max,
            "nat": at.add, "real": at.add}[sr_name]


#: numpy ufuncs whose ``.at`` performs the same ⊕-combining scatter
NP_COMBINE = {
    "bool": np.logical_or,
    "trop": np.minimum,
    "maxplus": np.maximum,
    "nat": np.add,
    "real": np.add,
}


def np_value_pool(sr: Semiring, *, small: bool = True) -> np.ndarray:
    """A small pool of semiring values for bounded-model verification."""
    if sr.name == "bool":
        return np.array([False, True])
    if sr.name == "trop":
        return np.array([0.0, 1.0, 2.0, INF], np.float32)
    if sr.name == "maxplus":
        return np.array([-INF, 0.0, 1.0, 2.0], np.float32)
    # nat / real: keep tiny so products stay distinguishable
    return np.array([0.0, 1.0, 2.0, 3.0], np.float32)
