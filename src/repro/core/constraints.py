"""Global constraints Γ and Γ-constrained database sampling (Sec. 3.3).

The paper checks the FGH identity only over databases satisfying Γ (e.g.
"the graph is a tree").  Offline (no SMT solver), our verifier evaluates
both sides on *sampled* databases; Γ therefore becomes a constrained
generator: ``tree`` yields random parent trees, ``dag`` topologically
ordered DAGs, ``none`` unconstrained relations.  Samplers mask binary
relations to V×V so instances are well-formed.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

from repro.core import engine, ir


def sample_database(schema: ir.Schema, edbs: list[str],
                    domains: Mapping[str, int], rng: np.random.Generator, *,
                    constraint: str | None = None,
                    density: float = 0.4) -> engine.Database:
    rels: dict[str, np.ndarray] = {}
    n = domains.get("id", 3)

    v = None
    if "V" in edbs:
        v = rng.random(n) < 0.8
        if not v.any():
            v[rng.integers(0, n)] = True
        rels["V"] = v

    for name in edbs:
        if name == "V" or name in rels:
            continue
        rs = schema[name]
        shape = tuple(domains[s] for s in rs.sorts)
        if name == "E" and constraint == "tree":
            e = _random_tree(n, rng)
            if v is not None:
                rels["V"] = np.ones(n, bool)  # tree constraint: all nodes
                v = np.ones(n, bool)
            rels[name] = e
            continue
        if name == "E" and constraint == "dag":
            e = np.triu(rng.random((n, n)) < density, 1)
            rels[name] = _mask_v(e, v)
            continue
        if rs.semiring == "bool":
            t = rng.random(shape) < density
            if rs.sorts[:2] == ("id", "id"):
                t = _mask_v(t, v)
                if len(shape) == 2:
                    np.fill_diagonal(t, False)
            rels[name] = t
        elif rs.semiring == "trop":
            t = rng.integers(0, 3, shape).astype(np.float32)
            t[rng.random(shape) > density] = np.inf
            rels[name] = t
        elif rs.semiring == "maxplus":
            t = rng.integers(0, 3, shape).astype(np.float32)
            t[rng.random(shape) > density] = -np.inf
            rels[name] = t
        else:  # nat / real: small non-negative values
            t = rng.integers(0, 3, shape).astype(np.float32)
            rels[name] = t
    return engine.Database(schema, dict(domains), rels)


def _mask_v(e: np.ndarray, v: np.ndarray | None) -> np.ndarray:
    if v is None:
        return e
    m = e.copy()
    m[~v, ...] = False
    if m.ndim >= 2:
        m[:, ~v, ...] = False
    return m


def _random_tree(n: int, rng: np.random.Generator) -> np.ndarray:
    e = np.zeros((n, n), bool)
    for i in range(1, n):
        e[rng.integers(0, i), i] = True  # parent -> child
    return e


def exhaustive_databases(schema: ir.Schema, edbs: list[str],
                         domains: Mapping[str, int], *,
                         constraint: str | None = None, limit: int = 64):
    """Exhaust tiny boolean EDB spaces (n=2) for the bounded-model check.

    Only enumerates when the total boolean EDB bit-count is small; yields
    at most ``limit`` databases (all of them when the space is ≤ limit).
    """
    import itertools

    bool_edbs = [e for e in edbs if schema[e].semiring == "bool"]
    if len(bool_edbs) != len(edbs):
        return  # mixed-semiring EDBs: sampling only
    shapes = {e: tuple(domains[s] for s in schema[e].sorts) for e in bool_edbs}
    bits = sum(int(np.prod(shapes[e])) for e in bool_edbs)
    if bits > 16:
        return
    total = 1 << bits
    step = max(1, total // limit)
    for idx in range(0, total, step):
        rels = {}
        rest = idx
        ok = True
        for e in bool_edbs:
            size = int(np.prod(shapes[e]))
            val = rest & ((1 << size) - 1)
            rest >>= size
            arr = np.array([(val >> i) & 1 for i in range(size)],
                           bool).reshape(shapes[e])
            if e == "E" and constraint == "tree" and not _is_forest(arr):
                ok = False
                break
            rels[e] = arr
        if ok:
            yield engine.Database(schema, dict(domains), rels)


def _is_forest(e: np.ndarray) -> bool:
    n = e.shape[0]
    if e.ndim != 2:
        return True
    indeg = e.sum(axis=0)
    if (indeg > 1).any() or np.trace(e) > 0:
        return False
    # acyclic check via repeated leaf removal
    e = e.copy()
    alive = np.ones(n, bool)
    for _ in range(n):
        leaves = alive & (e.sum(axis=1) == 0)
        if not leaves.any():
            break
        e[:, leaves] = False
        alive &= ~leaves
    return not alive.any() or e[alive][:, alive].sum() == 0
