"""Registered Runner protocol + the adaptive re-planning executor
(DESIGN.md §10).

The planner (DESIGN.md §4) picks a physical runner per stratum; until
this module, executing that choice was a string-keyed if/elif ladder in
``planner._run_stratum`` and ``planner.compile_batched``.  Now every
physical runner is a registered :class:`Runner`:

* ``full_fn(ctx)`` — the static path: a ``fn(edges, init)`` closure with
  exactly the wrapping the old ladder used (outer ``jax.jit`` for the
  staged runners, un-jitted for the host worklist and the fused backend
  whose geometry planning needs concrete buffers);
* ``run_chunk(ctx, state, budget) → (state, stats)`` — advance a
  :class:`~repro.sparse.fixpoint.FixpointState` by at most ``budget``
  GSN rounds and report the chunk-boundary
  :class:`~repro.sparse.fixpoint.FrontierStats`;
* ``estimate(ctx, state) → CostEstimate`` — re-price the runner's *next
  round* from the observed frontier
  (:data:`repro.sparse.adaptive.ADAPTIVE_COST`);
* ``finalize(ctx, state)`` — extract ``(x*, iters)`` from the carry.

Because every runner shares the GSN round body (DESIGN.md §2/§6/§9),
the carry is a common currency: :func:`adaptive_fixpoint` executes in
bounded chunks and — under a :class:`~repro.sparse.adaptive.
ReplanPolicy` — hands the state to whichever runner prices cheapest for
the *remaining* fixpoint, bit-exact with any static plan.  That is the
mid-fixpoint adaptive re-planning of Herlihy et al. (PAPERS.md): the
frontier worklist wins while Δ is a handful of vertices, the staged
O(nnz) runners win when it explodes, and real workloads cross that
boundary mid-run.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import semiring as sr_mod
from repro.sparse import adaptive
from repro.sparse import fixpoint as fx
from repro.sparse.coo import SparseRelation


@dataclasses.dataclass
class RunnerContext:
    """Everything a runner needs to execute one vector-form stratum:
    the materialized linear operator, the init vector, and the memo dict
    (``extras``) where runners stash prepared operands and compiled
    chunk closures — cached alongside the plan so repeat executions
    re-enter compiled code."""

    edges: object            # SparseRelation (jnp COO) or dense matrix
    init: object             # (n,) or (B, n)
    semiring: str
    max_iters: int
    n: int
    e_nnz: int
    mesh: object = None      # concrete graph Mesh (sharded candidate)
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def batch(self) -> int:
        return int(np.shape(self.init)[0]) if np.ndim(self.init) == 2 \
            else 1


def make_context(edges, init, semiring: str, max_iters: int, *,
                 mesh=None) -> RunnerContext:
    if isinstance(edges, SparseRelation):
        n, e_nnz = int(edges.shape[1]), int(edges.nnz)
    else:
        srn = sr_mod.get(semiring, lib="np")
        arr = np.asarray(edges)
        n, e_nnz = int(arr.shape[1]), int((arr != srn.zero).sum())
    return RunnerContext(edges, init, semiring, max_iters, n, e_nnz,
                         mesh=mesh)


class Runner:
    """One physical fixpoint runner (registered; see module docstring).

    ``vector`` runners execute the vector equation ``x = init ⊕ x ⊗ E``
    from a :class:`RunnerContext`; non-vector (dense engine) runners
    execute a whole stratum via ``stratum_fn``.  ``chunkable`` runners
    additionally support the bounded-chunk protocol and are adaptive-
    executor candidates.
    """

    name: str = ""
    vector: bool = True
    chunkable: bool = False

    def feasible(self, ctx: RunnerContext) -> bool:
        return True

    def operand(self, ctx: RunnerContext):
        """The runner-specific form of the linear operator (sharded,
        densified, ...), memoized on ``ctx.extras``."""
        return ctx.edges

    def full_fn(self, ctx: RunnerContext):
        """The static path: ``fn(operand, init) → (x*, iters)``."""
        raise NotImplementedError(self.name)

    def run_chunk(self, ctx: RunnerContext, state: fx.FixpointState,
                  budget: int):
        raise NotImplementedError(f"runner {self.name} is not chunkable")

    def estimate(self, ctx: RunnerContext,
                 state: fx.FixpointState):
        """Price this runner's next GSN round from the chunk-boundary
        frontier observation (ns; trips cancel across candidates)."""
        from repro.core import planner
        ns = adaptive.ADAPTIVE_COST.round_ns(
            self.name, n=ctx.n, e_nnz=ctx.e_nnz, batch=state.batch,
            frontier_nnz=state.frontier_nnz(),
            live_rows=state.live_rows(), semiring=ctx.semiring,
            fused_speedup=planner.SPMM_COST.speedup(
                ctx.semiring, jax.default_backend()),
            mesh_d=_mesh_d(ctx.mesh))
        return planner.CostEstimate(ns, 0.0, 1, "adaptive")

    def finalize(self, ctx: RunnerContext, state: fx.FixpointState):
        return state.solution()

    def stratum_fn(self, stratum, cur_db, hints, max_iters: int):
        """Non-vector runners: ``(fn, x0)`` executing a whole stratum."""
        raise NotImplementedError(self.name)

    def batched_fn(self, plan, max_iters: int):
        """The :func:`repro.core.planner.compile_batched` body:
        ``run(edges, init)`` over a ``(B, n)`` init pack — jitted here
        unless the runner manages its own compiled closures."""
        raise NotImplementedError(self.name)

    def serve_chunk_fn(self, chunk_iters: int):
        """The serve scheduler's compiled unit: ``(e, y, d, it) →
        (y, d, it)`` advancing the slot-pool carry by ``chunk_iters``
        rounds (:mod:`repro.serve.slots`)."""
        return jax.jit(lambda e, y, d, it: fx._resume_chunk(
            e, y, d, it, max_iters=chunk_iters))


def _mesh_d(mesh) -> int:
    if mesh is None:
        return 1
    from repro.distributed.datalog import mesh_size
    return mesh_size(mesh)


RUNNER_REGISTRY: dict[str, Runner] = {}


def register(runner_cls):
    r = runner_cls()
    RUNNER_REGISTRY[r.name] = r
    return runner_cls


def get(name: str) -> Runner:
    r = RUNNER_REGISTRY.get(name)
    if r is None:
        raise KeyError(f"no registered runner {name!r}; have "
                       f"{sorted(RUNNER_REGISTRY)}")
    return r


# --------------------------------------------------------------------------
# Vector-equation runners
# --------------------------------------------------------------------------


class _SparseRunner(Runner):
    def feasible(self, ctx: RunnerContext) -> bool:
        return isinstance(ctx.edges, SparseRelation)

    def batched_fn(self, plan, max_iters):
        # the batched serve form of both the staged and the frontier
        # runner is the staged loop (one SpMM per round); the frontier
        # representation is per-source and cannot batch
        return jax.jit(lambda e, i: fx.fixpoint(e, i, mode="jit",
                                                max_iters=max_iters))


@register
class FrontierRunner(_SparseRunner):
    """Host worklist rounds: per-round work tracks the live frontier."""

    name = "sparse_frontier"
    chunkable = True

    def full_fn(self, ctx):
        mi = ctx.max_iters
        return lambda e, i: fx.fixpoint(e, i, mode="frontier",
                                        max_iters=mi)

    def run_chunk(self, ctx, state, budget):
        st = fx.fixpoint(ctx.edges, state=state, budget=budget,
                         mode="frontier")
        return st, st.stats()


@register
class JitRunner(_SparseRunner):
    """Staged ``lax.while_loop``: O(nnz(E)) per round, density-blind."""

    name = "sparse_jit"
    chunkable = True
    backend = "jnp"

    def full_fn(self, ctx):
        mi = ctx.max_iters
        return jax.jit(lambda e, i: fx.fixpoint(e, i, mode="jit",
                                                max_iters=mi))

    def run_chunk(self, ctx, state, budget):
        # memoize a jitted chunk per budget so repeat chunks (and the
        # serve loop) re-enter compiled code instead of re-tracing the
        # while_loop; the pallas/fused backends memoize on the SpMM plan
        key = ("chunk", self.name, budget)
        fn = ctx.extras.get(key)
        if fn is None:
            sr = sr_mod.get(ctx.semiring)
            ej = ctx.edges.as_jnp()
            fn = ctx.extras[key] = jax.jit(
                lambda y, d, it: fx._chunk_loop(ej, y, d, it, sr, budget))
        y, d, it = fn(np.asarray(state.y), np.asarray(state.delta),
                      np.asarray(state.iters, np.int32))
        st = fx.FixpointState(y, d, it, state.semiring, state.batched)
        return st, st.stats()


@register
class PallasRunner(_SparseRunner):
    """The staged loop with the fused SpMM advance (DESIGN.md §9):
    Pallas kernel on TPU, bit-packed host rounds for 𝔹 on CPU."""

    name = "sparse_frontier_pallas"
    chunkable = True

    def _backend(self) -> str:
        from repro.core import planner
        return planner.spmm_exec_backend(self.name)

    def full_fn(self, ctx):
        # no outer jax.jit: the fused backend plans its edge-tile
        # geometry on the host (needs concrete buffers) and memoizes its
        # own compiled closures per operator
        mi, be = ctx.max_iters, self._backend()
        return lambda e, i: fx.fixpoint(e, i, mode="jit", backend=be,
                                        max_iters=mi)

    def run_chunk(self, ctx, state, budget):
        st = fx.fixpoint(ctx.edges, state=state, budget=budget,
                         backend=self._backend())
        return st, st.stats()

    def batched_fn(self, plan, max_iters):
        # returned un-jitted: the fused backend needs concrete edge
        # buffers for host geometry planning and carries its own
        # per-operator compiled closures (plan.jit_cache), so the serve
        # loop still re-enters compiled code on every call
        be = self._backend()
        return lambda e, i: fx.fixpoint(e, i, mode="jit", backend=be,
                                        max_iters=max_iters)

    def serve_chunk_fn(self, chunk_iters):
        be = self._backend()
        return lambda e, y, d, it: fx._resume_chunk(
            e, y, d, it, max_iters=chunk_iters, backend=be)


@register
class DenseVectorRunner(Runner):
    """Dense semiring matmul rounds — wins when E itself is dense."""

    name = "vector_dense"
    chunkable = True

    def operand(self, ctx):
        if not isinstance(ctx.edges, SparseRelation):
            return ctx.edges
        dense = ctx.extras.get("dense_edges")
        if dense is None:
            dense = ctx.extras["dense_edges"] = ctx.edges.to_dense()
        return dense

    def full_fn(self, ctx):
        sr, mi = sr_mod.get(ctx.semiring), ctx.max_iters
        return jax.jit(lambda e, i: _dense_vector_fixpoint(e, i, sr, mi))

    def batched_fn(self, plan, max_iters):
        sr = sr_mod.get(plan.strata[0].vf.semiring)
        return jax.jit(lambda e, i: _batched_dense_vector_fixpoint(
            e, i, sr, max_iters))

    def run_chunk(self, ctx, state, budget):
        edge = self.operand(ctx)
        key = ("chunk", self.name, budget)
        fn = ctx.extras.get(key)
        if fn is None:
            from repro.kernels import ops as kops
            sr = sr_mod.get(ctx.semiring)

            def adv(d):
                # carry is (n, B); the dense advance is the same ⊗/⊕
                # contraction as SpMM over the 0̄-filled matrix, so the
                # hand-off stays bit-exact (⊕ with 0̄ is identity)
                return kops.semiring_matmul(sr, d.T, edge).T

            fn = ctx.extras[key] = jax.jit(
                lambda y, d, it: fx._chunk_loop(None, y, d, it, sr,
                                                budget, advance=adv))
        y, d, it = fn(np.asarray(state.y), np.asarray(state.delta),
                      np.asarray(state.iters, np.int32))
        st = fx.FixpointState(y, d, it, state.semiring, state.batched)
        return st, st.stats()


@register
class ShardedRunner(_SparseRunner):
    """Graph-axis row-partitioned SpMM loop (DESIGN.md §6)."""

    name = "sparse_sharded"
    chunkable = True

    def feasible(self, ctx):
        return ctx.mesh is not None and super().feasible(ctx)

    def operand(self, ctx):
        es = ctx.extras.get("sharded_edges")
        if es is None:
            from repro.distributed.datalog import shard_relation
            es = ctx.extras["sharded_edges"] = shard_relation(ctx.edges,
                                                              ctx.mesh)
        return es

    def full_fn(self, ctx):
        from repro.distributed.datalog import sharded_seminaive_fixpoint
        m, mi = ctx.mesh, ctx.max_iters
        return jax.jit(lambda e, i: sharded_seminaive_fixpoint(
            e, i, mesh=m, max_iters=mi))

    def batched_fn(self, plan, max_iters):
        from repro.core import planner
        from repro.distributed.datalog import sharded_seminaive_fixpoint
        mesh = planner.exec_mesh(plan)
        return jax.jit(lambda e, i: sharded_seminaive_fixpoint(
            e, i, mesh=mesh, max_iters=max_iters))

    def run_chunk(self, ctx, state, budget):
        es = self.operand(ctx)
        key = ("chunk", self.name, budget)
        fn = ctx.extras.get(key)
        if fn is None:
            from repro.distributed.datalog import sharded_resume_chunk
            m = ctx.mesh
            fn = ctx.extras[key] = jax.jit(
                lambda y, d, it: sharded_resume_chunk(
                    es, y, d, it, mesh=m, max_iters=budget))
        y, d, it = fn(np.asarray(state.y), np.asarray(state.delta),
                      np.asarray(state.iters, np.int32))
        st = fx.FixpointState(y, d, it, state.semiring, state.batched)
        return st, st.stats()


def _batched_dense_vector_fixpoint(edge, init, sr, max_iters):
    """The vectorized ``x = init ⊕ x ⊗ E`` GSN step over a dense E for a
    ``(B, n)`` init pack — the one dense vector runner shared by
    :func:`repro.core.planner.execute_plan` (B = 1) and
    :func:`repro.core.planner.compile_batched`."""
    from repro.core import fixpoint
    from repro.kernels import ops as kops

    def ico(s):
        return {"x": sr.add(init, kops.semiring_matmul(sr, s["x"], edge))}

    def dico(s):
        return {"x": kops.semiring_matmul(sr, s["x"], edge)}

    x0 = {"x": sr.zeros(init.shape)}
    y, iters = fixpoint.batched_seminaive_fixpoint(
        ico, dico, x0, {"x": sr}, max_iters=max_iters)
    return y["x"], iters


def _dense_vector_fixpoint(edge, init, sr, max_iters):
    y, iters = _batched_dense_vector_fixpoint(edge, init.reshape(1, -1),
                                              sr, max_iters)
    return y[0], iters[0]


# --------------------------------------------------------------------------
# Dense engine runners (whole-stratum; not chunkable)
# --------------------------------------------------------------------------


class _IcoRunner(Runner):
    vector = False

    def _prep(self, stratum, cur_db, hints):
        from repro.core import program as prog_mod
        ico = prog_mod.make_ico(stratum, cur_db, hints)
        x0 = prog_mod.init_state(stratum, cur_db, hints)
        return ico, x0


@register
class DenseGsnRunner(_IcoRunner):
    name = "dense_gsn"

    def stratum_fn(self, stratum, cur_db, hints, max_iters):
        from repro.core import fixpoint
        from repro.core import program as prog_mod
        ico, x0 = self._prep(stratum, cur_db, hints)
        srs = {n: sr_mod.get(cur_db.schema[n].semiring)
               for n in stratum.idbs}
        dico = prog_mod.make_delta_ico(stratum, cur_db, hints)
        fn = jax.jit(lambda x0: fixpoint.seminaive_fixpoint(
            ico, dico, x0, srs, max_iters=max_iters))
        return fn, x0


@register
class DenseNaiveRunner(_IcoRunner):
    name = "dense_naive"

    def stratum_fn(self, stratum, cur_db, hints, max_iters):
        from repro.core import fixpoint
        ico, x0 = self._prep(stratum, cur_db, hints)
        fn = jax.jit(lambda x0: fixpoint.naive_fixpoint(
            ico, x0, max_iters=max_iters))
        return fn, x0


@register
class DenseHostRunner(_IcoRunner):
    name = "dense_host"

    def stratum_fn(self, stratum, cur_db, hints, max_iters):
        from repro.core import fixpoint
        ico, x0 = self._prep(stratum, cur_db, hints)

        def fn(x0, ico=ico):  # python loop, per-iteration visibility
            return fixpoint.host_fixpoint(ico, x0, max_iters=max_iters)

        return fn, x0


# --------------------------------------------------------------------------
# The adaptive executor
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ReplanEvent:
    """One mid-fixpoint runner switch, as logged in ``explain(plan)``."""

    chunk: int           # 0-based index of the chunk just finished
    iteration: int       # global iteration at the switch boundary
    frontier_nnz: int
    density: float
    from_runner: str
    to_runner: str
    est_from: float      # incumbent's priced next round (ns)
    est_to: float        # challenger's priced next round (ns)


@dataclasses.dataclass
class AdaptiveRun:
    """Execution trace of one adaptive fixpoint: per-chunk frontier
    observations plus the switch history (rendered by ``explain``)."""

    start_runner: str
    final_runner: str
    chunks: list
    switches: list
    policy: adaptive.ReplanPolicy


def adaptive_fixpoint(ctx: RunnerContext, *, start: str,
                      candidates=(), policy=None, observer=None):
    """Execute the fixpoint in bounded chunks, re-pricing the remaining
    work at every chunk boundary and switching runners via warm hand-off
    when the :class:`~repro.sparse.adaptive.ReplanPolicy` allows.

    Returns ``(x*, iters, AdaptiveRun)``; the answer and per-row
    iteration counts are bit-exact with any static chunkable runner
    (shared GSN round body + exact carry hand-off).  ``observer``, if
    given, receives each chunk's :class:`~repro.sparse.fixpoint.
    FrontierStats` as it lands (the serve-metrics hook).
    """
    policy = policy if policy is not None else adaptive.ReplanPolicy()
    cands = [start] + [c for c in candidates if c != start]
    cands = [c for c in cands
             if c in RUNNER_REGISTRY and get(c).chunkable
             and get(c).feasible(ctx)]
    if start not in cands:
        raise ValueError(f"start runner {start!r} is not a feasible "
                         f"chunkable runner here")
    state = fx.FixpointState.cold(ctx.edges, ctx.init)
    current = start
    trace = AdaptiveRun(start, start, [], [], policy)
    rounds_done = 0
    while not state.converged and rounds_done < ctx.max_iters:
        budget = int(min(policy.chunk_iters, ctx.max_iters - rounds_done))
        state, stats = get(current).run_chunk(ctx, state, budget)
        # a chunk only stops early on global convergence, so a
        # non-converged chunk ran exactly `budget` global rounds
        rounds_done += budget
        trace.chunks.append(stats)
        if observer is not None:
            observer(stats)
        if state.converged or rounds_done >= ctx.max_iters:
            break
        if len(cands) < 2:
            continue  # nothing to re-plan against; keep chunking
        ests = {c: get(c).estimate(ctx, state) for c in cands}
        best = min(ests, key=lambda c: (ests[c].total, c != current, c))
        chunk_index = len(trace.chunks) - 1
        since = chunk_index - trace.switches[-1].chunk \
            if trace.switches else chunk_index + 1
        if best != current and policy.should_switch(
                ests[current].total, ests[best].total,
                chunk_index=chunk_index, chunks_since_switch=since,
                switches=len(trace.switches)):
            trace.switches.append(ReplanEvent(
                chunk=chunk_index, iteration=stats.iteration,
                frontier_nnz=stats.nnz, density=stats.density,
                from_runner=current, to_runner=best,
                est_from=ests[current].total, est_to=ests[best].total))
            current = best
    trace.final_runner = current
    y, iters = get(current).finalize(ctx, state)
    return y, iters, trace
