"""The continuous-batching scheduler (repro.serve, DESIGN.md §7):
slot admission/eviction exactness on every stepper, update fencing,
FIFO-per-family delivery, weighted fairness, backpressure, the bounded
caches, the latency histograms, and the B=1 latency-route regression."""

import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.launch.datalog_serve import DatalogServer
from repro.serve import (BackpressureError, ContinuousServer, LRUCache,
                         LatencyHistogram)
from repro.serve.slots import LevelSyncTropStepper
from repro.sparse import SparseRelation, sparse_seminaive_fixpoint


def _bm_db(n=120, seed=2, sparse=True):
    g = datasets.erdos_renyi(n, 3.0, seed=seed)
    schema = programs.bm(a=0).original.schema
    e = g.sparse_adjacency() if sparse else g.adjacency()
    return g, engine.Database(schema, {"id": n},
                              {"E": e, "V": jnp.ones((n,), bool)})


def _expected_bm(db, source):
    dense_db = db.with_storage("E", "dense")
    ans, _ = run_program(programs.bm(a=source).optimized, dense_db,
                         mode="seminaive")
    return np.asarray(ans)


def _sssp_setup(n=90, wmax=4, seed=3):
    g = datasets.erdos_renyi(n, 3.0, seed=seed, weighted=True, wmax=wmax)
    b = programs.sssp(a=0, wmax=wmax, dmax=12 * wmax)
    return g, b.make_db(g), (
        lambda a: programs.sssp(a=a, wmax=wmax, dmax=12 * wmax).optimized)


def _expected_sssp(db, mk, source):
    ans, _ = run_program(mk(source), db, mode="seminaive")
    return np.asarray(ans)


# --------------------------------------------------------------------------
# bounded caches & histograms
# --------------------------------------------------------------------------


def test_lru_cache_eviction_and_counters():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1           # refreshes a's recency
    c.put("c", 3)                    # evicts b, the least recent
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert (c.hits, c.misses, c.evictions) == (3, 1, 1)
    assert c.peek("a") == 1 and c.hits == 3  # peek: uncounted
    assert c.clear() == 2 and len(c) == 0


def test_lru_cache_zero_capacity_drops():
    c = LRUCache(0)
    c.put("a", 1)
    assert c.get("a") is None and len(c) == 0


def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    for ms in range(1, 101):         # 1ms … 100ms uniformly
        h.record(ms * 1e-3)
    s = h.summary()
    assert s["count"] == 100
    # log-bucketed: ~4.4% resolution per bucket
    assert s["p50_ms"] == pytest.approx(50, rel=0.15)
    assert s["p95_ms"] == pytest.approx(95, rel=0.15)
    assert s["p99_ms"] == pytest.approx(99, rel=0.15)
    assert s["max_ms"] >= s["p99_ms"]


# --------------------------------------------------------------------------
# exactness: every stepper matches the single-source engine
# --------------------------------------------------------------------------


def test_continuous_bool_bitset_exact():
    """CPU boolean families ride the lane-bitset stepper; every answer
    must equal the engine's single-source run."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=8, chunk_iters=3, warm_answers=0)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    rng = np.random.default_rng(0)
    reqs = [cs.submit("reach", int(s)) for s in rng.integers(0, 120, 20)]
    assert cs.run_until_idle() == 20
    for r in reqs:
        assert r.error is None, r.error
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source)), r.source
    st = cs.stats()
    assert st["evicted"] + st["latency_routed"] == 20
    assert st["packed_fallback"] == 0


def test_continuous_trop_level_sync_exact():
    """Integer-weighted SSSP rides the level-synchronous BFS stepper."""
    g, db, mk = _sssp_setup()
    cs = ContinuousServer(max_batch=8, chunk_iters=3, warm_answers=0)
    cs.register("sssp", mk, db, edges=g.sparse_adjacency(semiring="trop"))
    rng = np.random.default_rng(1)
    reqs = [cs.submit("sssp", int(s)) for s in rng.integers(0, g.n, 16)]
    cs.run_until_idle()
    for r in reqs:
        assert r.error is None, r.error
        assert np.array_equal(np.asarray(r.result),
                              _expected_sssp(db, mk, r.source)), r.source


def test_continuous_jax_chunk_stepper_exact():
    """host_kernels=False forces the jitted chunked-while-loop stepper;
    it must agree bit-for-bit with the host kernels' answers."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=8, chunk_iters=2, warm_answers=0,
                          host_kernels=False)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    rng = np.random.default_rng(2)
    reqs = [cs.submit("reach", int(s)) for s in rng.integers(0, 120, 12)]
    cs.run_until_idle()
    for r in reqs:
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source)), r.source
    assert cs.stats()["compile_cache"]["misses"] >= 1


def test_continuous_dense_packed_fallback():
    """A dense-operator family has no columnwise splice: the scheduler
    serves it through the packed whole-run fallback, still exactly."""
    _, db = _bm_db(sparse=False)
    cs = ContinuousServer(max_batch=4, warm_answers=0)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    reqs = [cs.submit("reach", s) for s in (3, 14, 15, 92, 65)]
    cs.run_until_idle()
    for r in reqs:
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source)), r.source
    assert cs.stats()["packed_fallback"] >= 1


def test_trop_stepper_refuses_finite_nonzero_init():
    """Only {0, ∞} init vectors encode as a level-0 BFS frontier; any
    other init must be refused at admission (scheduler then serves it
    solo) — never silently mis-encoded."""
    g, _, _ = _sssp_setup()
    st = LevelSyncTropStepper(
        g.sparse_adjacency(semiring="trop").as_jnp(), g.n, 4)
    bad = np.full(g.n, np.inf, np.float32)
    bad[3] = 2.0                     # finite but not the semiring one
    assert st.admit(0, bad) is False
    ok = np.full(g.n, np.inf, np.float32)
    ok[3] = 0.0
    assert st.admit(0, ok) is True


def test_trop_stepper_rejects_fractional_weights():
    g0 = datasets.erdos_renyi(40, 3.0, seed=5)
    w = np.full(len(g0.edges), 1.5, np.float32)
    rel = SparseRelation.from_coo(g0.edges, w, (40, 40), "trop")
    with pytest.raises(ValueError):
        LevelSyncTropStepper(rel, 40, 4)


def test_multi_chunk_long_chain_no_early_harvest():
    """A path graph needs ~n GSN rounds: with a tiny chunk the row must
    survive many chunk boundaries before its mask fires, and the answer
    must be the full chain (an early harvest would truncate it)."""
    n = 64
    g = datasets.path_graph(n)
    schema = programs.bm(a=0).original.schema
    db = engine.Database(schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    for hk in (True, False):
        cs = ContinuousServer(max_batch=4, chunk_iters=2,
                              warm_answers=0, host_kernels=hk)
        cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
        r0 = cs.submit("reach", 0)   # reaches all n vertices
        r1 = cs.submit("reach", n - 2)  # reaches one
        cs.run_until_idle()
        assert np.asarray(r0.result).sum() == n
        assert np.asarray(r1.result).sum() == 2
        assert r0.iters >= n - 2     # many chunks, counted exactly
        assert cs.stats()["chunks"] >= (n - 2) // 2


# --------------------------------------------------------------------------
# scheduling semantics
# --------------------------------------------------------------------------


def test_slots_reused_across_stream():
    """More requests than slots: the pool must recycle freed rows (one
    pool, many admissions) instead of growing or re-pooling."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=4, chunk_iters=2, warm_answers=0)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    rng = np.random.default_rng(3)
    reqs = [cs.submit("reach", int(s)) for s in rng.integers(0, 120, 20)]
    cs.run_until_idle()
    st = cs.stats()
    assert st["admitted"] == 20 and st["evicted"] == 20
    assert st["families"]["reach"]["pool_b"] == 4
    for r in reqs:
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source))


def test_fifo_delivery_per_family():
    """Rows converge out of order; answers still publish in submission
    order within a family."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=8, chunk_iters=1, warm_answers=0)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    reqs = [cs.submit("reach", int(s)) for s in
            np.random.default_rng(4).integers(0, 120, 12)]
    delivered = []
    while cs.pending():
        delivered.extend(cs.step())
    assert delivered == reqs
    dones = [r.done_s for r in reqs]
    assert dones == sorted(dones)


def test_update_fence_orders_answers():
    """A query submitted before an edge merge answers from the old
    graph; one submitted after answers from the new graph — even though
    both may sit queued at the same time."""
    n = 16
    edges = np.array([[i, i + 1] for i in range(6)])  # 0→…→6, 7+ isolated
    rel = SparseRelation.from_coo(
        edges, np.ones(len(edges), bool), (n, n), "bool")
    schema = programs.bm(a=0).original.schema
    db = engine.Database(schema, {"id": n},
                         {"E": rel, "V": jnp.ones((n,), bool)})
    cs = ContinuousServer(max_batch=4, chunk_iters=1, warm_answers=0)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    q_before = cs.submit("reach", 0)
    u = cs.submit_update("reach", [[6, 9]])   # bridge to vertex 9
    q_after = cs.submit("reach", 0)
    cs.run_until_idle()
    assert u.applied
    before, after = np.asarray(q_before.result), np.asarray(q_after.result)
    assert not before[9] and before.sum() == 7
    assert after[9] and after.sum() == 8


def test_update_delete_repairs_warm_answers():
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=4)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    cs.submit("reach", 5)
    cs.run_until_idle()
    r_warm = cs.submit("reach", 5)
    cs.run_until_idle()
    assert cs.stats()["warm_hits"] == 1 and r_warm.iters == 0
    eh = db.relations["E"].as_np()
    e0 = np.asarray(eh.coords[:1])
    u = cs.submit_update("reach", e0, op="delete")
    r_next = cs.submit("reach", 5)
    cs.run_until_idle()
    # the synthesized maintenance rule (DESIGN.md §11) repairs the
    # cached answer in place instead of dropping it
    assert u.applied and cs.stats()["answers_dropped"] == 0
    assert cs.stats()["answers_repaired"] >= 1
    assert cs.stats()["warm_hits"] == 2, \
        "the post-delete query should warm-hit the repaired answer"
    db2 = engine.Database(db.schema, db.domains,
                          {"E": db.relations["E"].delete_keys(e0),
                           "V": db.relations["V"]})
    assert np.array_equal(np.asarray(r_next.result), _expected_bm(db2, 5))


def test_backpressure_sheds_at_queue_limit():
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=4, queue_limit=3)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    ok, shed = 0, 0
    for s in range(8):
        try:
            cs.submit("reach", s)
            ok += 1
        except BackpressureError as e:
            assert e.family == "reach" and e.limit == 3
            shed += 1
    assert (ok, shed) == (3, 5) and cs.stats()["shed"] == 5
    cs.run_until_idle()
    assert cs.stats()["served"] == 3
    # updates are never shed, even at the bound
    eh = db.relations["E"].as_np()
    cs.submit("reach", 9)            # refill to the limit... almost
    cs.submit_update("reach", np.asarray(eh.coords[:1]), op="delete")
    cs.run_until_idle()
    assert cs.stats()["updates"] == 1


def test_weighted_fairness_no_starvation():
    """A deep queue on one family cannot starve another: each family
    advances every scheduling round, so the light family finishes while
    the heavy backlog is still draining."""
    _, db = _bm_db()
    g2, db2, mk2 = _sssp_setup()
    cs = ContinuousServer(max_batch=4, chunk_iters=1, warm_answers=0)
    cs.register("heavy", lambda a: programs.bm(a=a).optimized, db)
    cs.register("light", mk2, db2,
                edges=g2.sparse_adjacency(semiring="trop"))
    rng = np.random.default_rng(6)
    heavy = [cs.submit("heavy", int(s)) for s in rng.integers(0, 120, 40)]
    light = [cs.submit("light", int(s)) for s in rng.integers(0, g2.n, 3)]
    while any(r.done_s == 0.0 for r in light):
        assert cs.step() is not None
    assert sum(r.done_s > 0.0 for r in heavy) < len(heavy)
    cs.run_until_idle()
    for r in light:
        assert np.array_equal(np.asarray(r.result),
                              _expected_sssp(db2, mk2, r.source))
    for r in heavy:
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source))


def test_register_weight_validation():
    _, db = _bm_db()
    cs = ContinuousServer()
    with pytest.raises(ValueError):
        cs.register("reach", lambda a: programs.bm(a=a).optimized, db,
                    weight=0)


def test_bad_source_fails_without_stranding():
    """A source whose program changes the linear operator fails its own
    request only."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=4, warm_answers=0)

    def mk(a):
        if a == 999:                 # different operator shape
            return programs.sssp(a=0, wmax=4, dmax=16).optimized
        return programs.bm(a=a).optimized

    cs.register("reach", mk, db)
    good = [cs.submit("reach", s) for s in (1, 2)]
    bad = cs.submit("reach", 999)
    more = cs.submit("reach", 3)
    cs.run_until_idle()
    assert bad.result is None and bad.error
    assert cs.stats()["failed"] == 1
    for r in (*good, more):
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source))


def test_fast_init_matches_eval_and_rejects_operator_swap():
    """The probed one-hot init fast path must produce exactly the
    evaluated init, and fall back (to the erroring slow path) for a
    source whose program is not the template with the source constant
    substituted."""
    from repro.core import planner
    from repro.serve import family as fam_mod

    _, db = _bm_db()
    g, ss_db, mk_ss = _sssp_setup()

    def mk_bm(a):
        if a == 7:                    # operator swap at an in-range source
            return programs.cc().optimized
        return programs.bm(a=a).optimized

    for mk, d in ((mk_bm, db), (mk_ss, ss_db)):
        fam = fam_mod.build_family("f", mk, d)
        assert fam.fast_init is not None
        for s in (0, 1, 5, fam.n - 1):
            prog = mk(s)
            expect = planner.source_init(fam.plan, prog, fam.host_db,
                                         hints=dict(prog.sort_hints),
                                         backend="np")
            got = fam_mod.family_init(fam, s)
            assert got.dtype == np.asarray(expect).dtype
            assert np.array_equal(got, expect), s

    fam = fam_mod.build_family("reach", mk_bm, db)
    with pytest.raises(Exception, match="linear operator"):
        fam_mod.family_init(fam, 7)   # structural check must not pass it


# --------------------------------------------------------------------------
# bounded compile cache
# --------------------------------------------------------------------------


def test_compile_cache_lru_bound_continuous():
    """compiled_cache=1 with two bucket sizes forces evictions; results
    stay exact (an evicted runner just recompiles)."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=8, warm_answers=0, compiled_cache=1,
                          host_kernels=False)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    # pools grow only, so drive demand upward: bucket 2 → 4 → 8
    reqs = [cs.submit("reach", s) for s in (1, 2)]
    cs.run_until_idle()
    reqs += [cs.submit("reach", s) for s in (3, 4, 5)]
    cs.run_until_idle()
    reqs += [cs.submit("reach", s) for s in range(8)]
    cs.run_until_idle()
    cc = cs.stats()["compile_cache"]
    assert cc["size"] == 1 and cc["evictions"] >= 2
    for r in reqs:
        assert np.array_equal(np.asarray(r.result),
                              _expected_bm(db, r.source))


def test_compile_cache_lru_bound_shim():
    """The packed shim's compile cache honors the same bound and
    surfaces evictions in its stats dict."""
    _, db = _bm_db()
    server = DatalogServer(max_batch=8, warm_answers=0, compiled_cache=1)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    for batch in ((1, 2), tuple(range(8)), (11, 12)):
        for s in batch:
            server.submit("reach", s)
        server.run_until_idle()
    assert server.stats["cache_evictions"] >= 2
    assert server.stats["cache_misses"] >= 3


def test_warm_answer_lru_bound():
    """The warm-answer store is capacity-bounded: old entries evict and
    re-serve cold (counted), instead of growing without bound."""
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=4, warm_answers=2)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    for s in (1, 2, 3):              # 3 distinct answers, capacity 2
        cs.submit("reach", s)
    cs.run_until_idle()
    fam_stats = cs.stats()["families"]["reach"]
    assert fam_stats["warm_answers"] == 2
    assert fam_stats["warm_evictions"] >= 1
    r = cs.submit("reach", 1)        # evicted → cold, still exact
    cs.run_until_idle()
    assert cs.stats()["warm_hits"] == 0 and r.iters >= 1
    assert np.array_equal(np.asarray(r.result), _expected_bm(db, 1))


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------


def test_stats_latency_and_gauges():
    _, db = _bm_db()
    cs = ContinuousServer(max_batch=4, warm_answers=0)
    cs.register("reach", lambda a: programs.bm(a=a).optimized, db)
    for s in range(6):
        cs.submit("reach", s)
    cs.run_until_idle()
    st = cs.stats()
    lat = st["latency"]["total"]
    assert lat["count"] == 6
    assert 0 < lat["p50_ms"] <= lat["p95_ms"] <= lat["p99_ms"]
    assert st["families"]["reach"]["queue_depth"] == 0
    assert st["families"]["reach"]["in_flight"] == 0
    assert st["families"]["reach"]["served"] == 6


# --------------------------------------------------------------------------
# B=1 regression: the latency route must beat the (1, n) batched loop
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_single_request_latency_route_beats_loop():
    """ISSUE 6 satellite: serving B=1 requests must be at least as fast
    as the naive per-source jitted loop (it was 0.81× before the
    frontier routing).  Generous margin — the frontier path measures
    ~5-7× the loop on this shape."""
    if jax.default_backend() != "cpu":
        pytest.skip("latency routing is the CPU frontier path")
    n = 5000
    g = datasets.powerlaw(n, 4, seed=1)
    rel = g.sparse_adjacency().as_jnp()
    schema = programs.bm(a=0).original.schema
    db = engine.Database(schema, {"id": n},
                         {"E": rel, "V": jnp.ones((n,), bool)})
    server = DatalogServer(max_batch=64, warm_answers=0)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)

    single = jax.jit(lambda e, i: sparse_seminaive_fixpoint(
        e, i, mode="jit"))

    def one_hot(s):
        v = np.zeros(n, bool)
        v[s] = True
        return jnp.asarray(v)

    jax.block_until_ready(single(rel, one_hot(0))[0])   # warm the jit
    q = server.submit("reach", 0)
    server.run_until_idle()                              # warm the route

    sources = [7, 501, 2003, 3999, 4444]
    t0 = time.perf_counter()
    loop_out = [np.asarray(single(rel, one_hot(s))[0]) for s in sources]
    t_loop = time.perf_counter() - t0

    reqs = []
    t0 = time.perf_counter()
    for s in sources:                # one at a time: every serve is B=1
        reqs.append(server.submit("reach", s))
        server.run_until_idle()
    t_serve = time.perf_counter() - t0

    assert server.stats["latency_routed"] == len(sources) + 1
    for r, y in zip(reqs, loop_out):
        assert np.array_equal(np.asarray(r.result), y)
    assert t_serve <= t_loop * 1.2, \
        f"B=1 serve {t_serve:.3f}s slower than loop {t_loop:.3f}s"
