"""Sparse S-relation subsystem: COO round-trips, semiring contraction vs
dense oracles, the Pallas segment-reduce kernel, the adaptive density
switch, and engine routing of sparse relations."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine, semiring as sr_mod
from repro.datalog import datasets, programs
from repro.core.program import run_program
from repro.kernels import ref
from repro.kernels.coo_segment import segment_reduce_pallas
from repro.sparse import (SparseRelation, adapt_value, density, spmm,
                          spmspm, spmv, vspm)

SEMIRINGS = ["bool", "trop", "maxplus", "nat", "real"]


def _random_dense(rng, shape, sr_name):
    sr = sr_mod.get(sr_name, lib="np")
    if sr_name == "bool":
        return rng.random(shape) < 0.35
    a = rng.integers(0, 4, shape).astype(np.float32)
    a[rng.random(shape) < 0.4] = sr.zero
    return a


@pytest.mark.parametrize("sr_name", SEMIRINGS)
def test_dense_roundtrip_and_coalesce(sr_name):
    rng = np.random.default_rng(0)
    a = _random_dense(rng, (9, 6), sr_name)
    rel = SparseRelation.from_dense(a, sr_name, capacity=9 * 6)
    assert np.array_equal(np.asarray(rel.to_dense()), a)
    assert rel.density() == pytest.approx(density(a, sr_name))
    # duplicate coordinates must ⊕-coalesce
    sr = sr_mod.get(sr_name, lib="np")
    coords = [[1, 2], [1, 2], [0, 0]]
    vals = np.asarray([sr.one, sr.one, sr.one], sr.dtype)
    rel2 = SparseRelation.from_coo(coords, vals, (3, 3), sr_name)
    dense = np.asarray(rel2.to_dense())
    assert dense[1, 2] == sr.add(np.asarray(sr.one, sr.dtype),
                                 np.asarray(sr.one, sr.dtype))
    # overfull buffers are rejected, not silently truncated
    with pytest.raises(ValueError, match="capacity"):
        SparseRelation.from_coo([[0, 0], [1, 1]],
                                np.asarray([sr.one, sr.one], sr.dtype),
                                (3, 3), sr_name, capacity=1)


@pytest.mark.parametrize("sr_name", SEMIRINGS)
def test_union_matches_dense_add(sr_name):
    rng = np.random.default_rng(9)
    sr = sr_mod.get(sr_name, lib="np")
    a = _random_dense(rng, (7, 7), sr_name)
    b = _random_dense(rng, (7, 7), sr_name)
    ra = SparseRelation.from_dense(a, sr_name)
    rb = SparseRelation.from_dense(b, sr_name)
    got = ra.union(rb, capacity=7 * 7)
    assert got.capacity == 7 * 7  # requested headroom is honored
    np.testing.assert_allclose(
        np.asarray(got.to_dense()).astype(np.float32),
        np.asarray(sr.add(a, b), np.float32))


@pytest.mark.parametrize("sr_name", SEMIRINGS)
def test_spmv_vspm_spmm_match_dense(sr_name):
    rng = np.random.default_rng(1)
    sr = sr_mod.get(sr_name, lib="np")
    a = _random_dense(rng, (8, 5), sr_name)
    rel = SparseRelation.from_dense(a, sr_name, capacity=8 * 5)
    x = _random_dense(rng, (5,), sr_name)
    y = _random_dense(rng, (8,), sr_name)
    b = _random_dense(rng, (5, 3), sr_name)

    want = sr.add_reduce(sr.mul(a, x[None, :]), axis=1)
    got = np.asarray(spmv(rel, jnp.asarray(x)))
    np.testing.assert_allclose(got.astype(np.float32),
                               np.asarray(want, np.float32))

    wantv = sr.add_reduce(sr.mul(a, y[:, None]), axis=0)
    gotv = np.asarray(vspm(jnp.asarray(y), rel))
    np.testing.assert_allclose(gotv.astype(np.float32),
                               np.asarray(wantv, np.float32))

    wantm = np.stack([sr.add_reduce(sr.mul(a, b[:, j][None, :]), axis=1)
                      for j in range(3)], axis=1)
    gotm = np.asarray(spmm(rel, jnp.asarray(b)))
    np.testing.assert_allclose(gotm.astype(np.float32),
                               wantm.astype(np.float32))


@pytest.mark.parametrize("sr_name", SEMIRINGS)
def test_spmspm_matches_dense_matmul(sr_name):
    rng = np.random.default_rng(2)
    sr = sr_mod.get(sr_name, lib="np")
    a = _random_dense(rng, (6, 5), sr_name)
    b = _random_dense(rng, (5, 7), sr_name)
    ra = SparseRelation.from_dense(a, sr_name, lib="np")
    rb = SparseRelation.from_dense(b, sr_name, lib="np")
    c = spmspm(ra, rb)
    want = np.stack([sr.add_reduce(sr.mul(a, b[:, j][None, :]), axis=1)
                     for j in range(7)], axis=1)
    np.testing.assert_allclose(
        np.asarray(c.to_dense()).astype(np.float32),
        want.astype(np.float32))


@pytest.mark.parametrize("sr_name", SEMIRINGS)
@pytest.mark.parametrize("m,n", [(0, 5), (37, 10), (64, 257)])
def test_segment_reduce_kernel_vs_ref(sr_name, m, n):
    """Pallas kernel (interpret mode) against the jnp scatter oracle,
    including out-of-range padding sentinels."""
    rng = np.random.default_rng(hash((sr_name, m, n)) % 2**31)
    sr = sr_mod.get(sr_name)
    ids = rng.integers(0, n + 3, m)  # n..n+2 emulate COO padding
    if sr_name == "bool":
        vals = rng.random(m) < 0.5
    else:
        vals = rng.integers(0, 5, m).astype(np.float32)
        vals[rng.random(m) < 0.3] = sr.zero
    want = ref.segment_reduce_ref(sr, jnp.asarray(vals),
                                  jnp.asarray(ids), n)
    got = segment_reduce_pallas(jnp.asarray(vals), jnp.asarray(ids), n,
                                sr_name=sr_name, bk=16, bn=8,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32))


def test_adaptive_density_switch():
    rng = np.random.default_rng(3)
    sparse_arr = _random_dense(rng, (40, 40), "bool") & \
        (rng.random((40, 40)) < 0.05)
    out = adapt_value(sparse_arr, "bool")
    assert isinstance(out, SparseRelation)
    # densify when a sparse relation saturates
    dense_arr = rng.random((20, 20)) < 0.9
    rel = SparseRelation.from_dense(dense_arr, "bool")
    back = adapt_value(rel, "bool")
    assert isinstance(back, jnp.ndarray) or isinstance(back, np.ndarray)
    assert np.array_equal(np.asarray(back), dense_arr)
    # hysteresis: mid-density keeps current representation
    mid = rng.random((20, 20)) < 0.15
    assert isinstance(adapt_value(mid, "bool"), (jnp.ndarray, np.ndarray))
    assert isinstance(
        adapt_value(SparseRelation.from_dense(mid, "bool"), "bool"),
        SparseRelation)


def test_database_storage_routing():
    """run_program must give identical answers with E stored sparse."""
    g = datasets.erdos_renyi(120, 3.0, seed=4)
    b = programs.bm(a=0)
    db = b.make_db(g)
    want, _ = run_program(b.optimized, db, mode="seminaive")
    db_sp = db.with_storage("E", "sparse")
    assert db_sp.storage_of("E") == "sparse"
    got, _ = run_program(b.optimized, db_sp, mode="seminaive")
    assert np.array_equal(np.asarray(want), np.asarray(got))
    # adapt() sparsifies the low-density adjacency and stays correct
    db_ad = db.adapt()
    assert db_ad.storage_of("E") == "sparse"
    got2, _ = run_program(b.optimized, db_ad, mode="naive")
    assert np.array_equal(np.asarray(want), np.asarray(got2))
    # and converting back is lossless
    assert np.array_equal(
        np.asarray(db_sp.with_storage("E", "dense").relations["E"]),
        np.asarray(db.relations["E"]))


def test_engine_eval_ssp_with_sparse_factor():
    """eval_ssp on a term mixing a sparse E with dense factors."""
    from repro.core import ir
    from repro.core.ir import RelAtom, Term
    g = datasets.erdos_renyi(60, 3.0, seed=5)
    b = programs.bm(a=0)
    db = b.make_db(g)
    q = np.asarray(np.random.default_rng(6).random(60) < 0.3)
    ssp = ir.normalize(ir.SSP(("y",), (
        Term((RelAtom("Q", ("z",)), RelAtom("E", ("z", "y"))), ("z",)),
    ), "bool"))
    schema = db.schema
    schema.declare("Q", ("id",), "bool")
    db = db.with_relations({"Q": jnp.asarray(q)})
    want = engine.eval_ssp(ssp, db)
    db_sp = db.with_storage("E", "sparse")
    got = engine.eval_ssp(ssp, db_sp)
    assert np.array_equal(np.asarray(want), np.asarray(got))


def test_sparse_builders_scale_without_dense_alloc():
    """50k-vertex graphs build as COO without touching n² memory."""
    g = datasets.powerlaw(50_000, 4, seed=1)
    rel = g.sparse_adjacency()
    assert rel.shape == (50_000, 50_000)
    assert rel.capacity == len(g.edges)
    g2 = datasets.erdos_renyi_sparse(50_000, 4.0, seed=1)
    assert abs(len(g2.edges) / 50_000 - 4.0) < 0.5
    wrel = datasets.erdos_renyi_sparse(1000, 3.0, seed=2, weighted=True) \
        .sparse_adjacency(semiring="trop")
    assert wrel.semiring == "trop"
