"""Fixpoint-runner agreement: naive, (dense) semi-naive, and the sparse
frontier runner must compute identical least fixpoints — and identical
truncated states under ``max_iters`` — on random BM/TC, CC, and SSSP
instances over the 𝔹 and Trop semirings."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fixpoint as fx
from repro.core import semiring as sr_mod
from repro.datalog import datasets
from repro.sparse import SparseRelation
from repro.sparse.fixpoint import sparse_seminaive_fixpoint_stats


def _instance(kind: str, seed: int):
    """Returns (edges: SparseRelation, adj dense, init, semiring name)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 40))
    g = datasets.erdos_renyi(n, float(rng.uniform(1.0, 3.5)), seed=seed,
                             weighted=True)
    if kind == "bm":      # single-source reachability (TC section)
        adj = np.asarray(g.adjacency())
        init = np.zeros(n, bool)
        init[int(rng.integers(0, n))] = True
        return g.sparse_adjacency(), adj, init, "bool"
    if kind == "cc":      # connected components: min label propagation
        adj = np.asarray(g.adjacency(symmetric=True))
        w = np.where(adj, 0.0, np.inf).astype(np.float32)
        init = np.arange(n, dtype=np.float32)
        rel = g.sparse_adjacency(symmetric=True, semiring="trop")
        rel = SparseRelation(rel.coords, jnp.zeros_like(rel.values),
                             rel.nnz, rel.shape, rel.semiring)
        return rel, w, init, "trop"
    # sssp
    adj = np.asarray(g.adjacency())
    w = np.where(adj, 1.0, np.inf).astype(np.float32)
    w[g.edges[:, 0], g.edges[:, 1]] = g.weights
    init = np.full(n, np.inf, np.float32)
    init[int(rng.integers(0, n))] = 0.0
    return g.sparse_adjacency(semiring="trop"), w, init, "trop"


def _dense_runners(w, init, sr_name):
    sr = sr_mod.get(sr_name)
    wj, ij = jnp.asarray(w), jnp.asarray(init)

    def a_of(x):  # the linear part: ⊕_z x[z] ⊗ E[z, y]
        if sr_name == "bool":
            return jnp.any(x[:, None] & wj, axis=0)
        return jnp.min(x[:, None] + wj, axis=0)

    def ico(s):
        return {"X": sr.add(ij, a_of(s["X"]))}

    def dico(s):
        return {"X": a_of(s["X"])}

    x0 = {"X": jnp.full(init.shape, sr.zero, sr.dtype)}
    return sr, ico, dico, x0


KINDS = ["bm", "cc", "sssp"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_runners_agree_at_fixpoint(kind, seed):
    rel, w, init, sr_name = _instance(kind, seed)
    sr, ico, dico, x0 = _dense_runners(w, init, sr_name)
    yn, itn = fx.naive_fixpoint(ico, x0)
    ys, its = fx.seminaive_fixpoint(ico, dico, x0, {"X": sr})
    yj, itj = fx.sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                           mode="jit")
    yf, itf, stats = sparse_seminaive_fixpoint_stats(rel, init,
                                                     mode="frontier")
    assert np.array_equal(np.asarray(yn["X"]), np.asarray(ys["X"]))
    assert np.array_equal(np.asarray(ys["X"]), np.asarray(yj))
    assert np.array_equal(np.asarray(ys["X"]), np.asarray(yf))
    # GSN runners execute the same number of rounds
    assert int(its) == int(itj) == itf
    # the frontier is a worklist: it never expands more than nnz·rounds
    k = int(np.asarray(rel.nnz))
    assert stats.total_edges <= k * max(1, itf)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("max_iters", [1, 2, 4])
def test_max_iters_truncation_parity(kind, max_iters):
    """Early exit must leave every GSN runner in the same partial state."""
    rel, w, init, sr_name = _instance(kind, seed=7)
    sr, ico, dico, x0 = _dense_runners(w, init, sr_name)
    ys, its = fx.seminaive_fixpoint(ico, dico, x0, {"X": sr},
                                    max_iters=max_iters)
    yj, itj = fx.sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                           mode="jit",
                                           max_iters=max_iters)
    yf, itf, _ = sparse_seminaive_fixpoint_stats(rel, init,
                                                 mode="frontier",
                                                 max_iters=max_iters)
    assert np.array_equal(np.asarray(ys["X"]), np.asarray(yj))
    assert np.array_equal(np.asarray(ys["X"]), np.asarray(yf))
    assert int(its) == int(itj) == itf <= max_iters


def test_non_lattice_semiring_rejected():
    rel = SparseRelation.from_coo([[0, 1]], [1.0], (2, 2), "nat")
    with pytest.raises(ValueError, match="lacks"):
        fx.sparse_seminaive_fixpoint(rel, jnp.zeros(2))


def test_non_square_edges_rejected():
    """x = init ⊕ x⊗E is only well-formed for square E; both modes must
    reject rectangular relations identically instead of diverging."""
    rel = SparseRelation.from_coo([[0, 2], [1, 3]], [True, True], (2, 4),
                                  "bool")
    for mode in ("jit", "frontier"):
        with pytest.raises(ValueError, match="square"):
            fx.sparse_seminaive_fixpoint(rel, jnp.zeros(4, bool),
                                         mode=mode)
