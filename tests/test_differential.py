"""Differential testing of the FGH optimizer: on randomized programs and
graphs, the optimized Π₂ must return exactly the answers of the original
Π₁ — across the boolean (reachability), tropical (shortest path /
min-label) and counting (ℕ, bag semantics) semirings.

The rule-based families (BM, SM, CC, SSSP) re-derive Π₂ with
``fgh.optimize`` once per family (module-scoped cache — synthesis is
deterministic) and then sweep randomized instances; the counting family
(MLM, whose Π₂ the paper derives by CEGIS under a tree constraint Γ) uses
the published rewrite and randomized trees, since the Γ-constrained
rewrite is only valid on trees.
"""

import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from helpers import given, settings, strategies as st

from helpers import values_close
from repro.core import fgh, verify
from repro.core.program import run_program
from repro.datalog import datasets, programs

#: family -> (bench builder(source), EDBs, semiring under test)
RULE_FAMILIES = {
    "BM": (lambda a: programs.bm(a=a), ["E", "V"], "bool"),
    "SM": (lambda a: programs.simple_magic(a=a), ["E", "V"], "bool"),
    "CC": (lambda a: programs.cc(), ["E", "V"], "trop"),
    "SSSP": (lambda a: programs.sssp(a=a, wmax=4, dmax=40), ["E3"],
             "trop"),
}


@functools.lru_cache(maxsize=None)
def _optimized(family: str, source: int):
    mk, edbs, _ = RULE_FAMILIES[family]
    b = mk(source)
    task = verify.task_from_program(b.original, edbs,
                                    constraint=b.constraint)
    rep = fgh.optimize(task, rng=np.random.default_rng(0))
    assert rep.ok, (family, rep.stats)
    if b.original.post is not None:
        rep.program.post = b.original.post
    return b, rep.program


def _graph(family: str, n: int, avg_deg: float, seed: int):
    if family == "SSSP":
        return datasets.erdos_renyi(n, avg_deg, seed=seed, weighted=True,
                                    wmax=4)
    return datasets.erdos_renyi(n, avg_deg, seed=seed)


@pytest.mark.parametrize("family", list(RULE_FAMILIES))
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_fgh_differential_random_graphs(family, data):
    n = data.draw(st.integers(8, 20))
    avg_deg = data.draw(st.integers(1, 3))
    seed = data.draw(st.integers(0, 10_000))
    source = data.draw(st.integers(0, n - 1))
    b, prog2 = _optimized(family, source)
    g = _graph(family, n, float(avg_deg), seed)
    db = b.make_db(g)
    a1, _ = run_program(b.original, db)
    a2, _ = run_program(prog2, db)
    assert values_close(np.asarray(a1), np.asarray(a2)), \
        (family, n, seed, source)
    # and the optimized program runs under GSN when its semiring is a
    # lattice (Sec. 3.1) — same answers again
    _, _, sr_name = RULE_FAMILIES[family]
    if sr_name in ("bool", "trop"):
        a3, _ = run_program(prog2, db, mode="seminaive")
        assert values_close(np.asarray(a2), np.asarray(a3)), \
            (family, n, seed, source)


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_counting_differential_random_trees(data):
    """ℕ (counting) semiring: MLM's published Γ-constrained rewrite vs
    the original bag-semantics program on randomized trees — both tree
    families the paper benchmarks (log-depth and linear-depth)."""
    n = data.draw(st.integers(6, 18))
    seed = data.draw(st.integers(0, 10_000))
    deep = data.draw(st.booleans())
    b = programs.mlm()
    g = (datasets.decay_tree(n, seed=seed) if deep
         else datasets.random_recursive_tree(n, seed=seed))
    db = b.make_db(g)
    a1, _ = run_program(b.original, db)
    a2, _ = run_program(b.optimized, db)
    assert values_close(np.asarray(a1), np.asarray(a2)), (n, seed, deep)


@settings(max_examples=4, deadline=None)
@given(data=st.data())
def test_maxplus_differential_random_trees(data):
    """Graph Radius: max-plus outer aggregate over a tropical inner
    distance — the published Γ-constrained rewrite on random trees."""
    n = data.draw(st.integers(6, 14))
    seed = data.draw(st.integers(0, 10_000))
    b = programs.radius(dmax=24)
    g = datasets.random_recursive_tree(n, seed=seed)
    db = b.make_db(g)
    a1, _ = run_program(b.original, db)
    a2, _ = run_program(b.optimized, db)
    assert values_close(np.asarray(a1), np.asarray(a2)), (n, seed)
