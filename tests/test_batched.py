"""Batch-parity: the (B, n) multi-source fixpoints must equal a Python
loop of B single-source runs — iteration-for-iteration on a fixed seed —
for dense vs sparse backends and jit vs frontier modes (DESIGN.md §3)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fixpoint as fx
from repro.core import semiring as sr_mod
from repro.datalog import datasets
from repro.sparse import SparseRelation, mspm, vspm
from repro.sparse.fixpoint import (sparse_seminaive_fixpoint,
                                   sparse_seminaive_fixpoint_stats)


def _instance(kind: str, seed: int, b: int = 5):
    """(edges SparseRelation, dense weights, (B, n) init, semiring)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(15, 40))
    g = datasets.erdos_renyi(n, float(rng.uniform(1.5, 3.5)), seed=seed,
                             weighted=True)
    sources = rng.integers(0, n, b)
    if kind == "bm":
        adj = np.asarray(g.adjacency())
        init = np.zeros((b, n), bool)
        init[np.arange(b), sources] = True
        return g.sparse_adjacency(), adj, init, "bool"
    # sssp
    adj = np.asarray(g.adjacency())
    w = np.where(adj, 1.0, np.inf).astype(np.float32)
    w[g.edges[:, 0], g.edges[:, 1]] = g.weights
    init = np.full((b, n), np.inf, np.float32)
    init[np.arange(b), sources] = 0.0
    return g.sparse_adjacency(semiring="trop"), w, init, "trop"


KINDS = ["bm", "sssp"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_jit_batched_equals_loop(kind, seed):
    rel, _, init, _ = _instance(kind, seed)
    yb, itb = sparse_seminaive_fixpoint(rel, jnp.asarray(init), mode="jit")
    assert yb.shape == init.shape and itb.shape == (init.shape[0],)
    for i, row in enumerate(init):
        ys, its = sparse_seminaive_fixpoint(rel, jnp.asarray(row),
                                            mode="jit")
        assert np.array_equal(np.asarray(yb[i]), np.asarray(ys))
        assert int(itb[i]) == int(its)


@pytest.mark.parametrize("kind", KINDS)
def test_sparse_frontier_batched_equals_loop(kind):
    rel, _, init, _ = _instance(kind, seed=3)
    yb, itb, stats = sparse_seminaive_fixpoint_stats(rel, init,
                                                     mode="frontier")
    assert len(stats) == init.shape[0]
    for i, row in enumerate(init):
        ys, its, _ = sparse_seminaive_fixpoint_stats(rel, row,
                                                     mode="frontier")
        assert np.array_equal(np.asarray(yb[i]), np.asarray(ys))
        assert int(itb[i]) == int(its)


@pytest.mark.parametrize("kind", KINDS)
def test_jit_and_frontier_batched_agree(kind):
    rel, _, init, _ = _instance(kind, seed=4)
    yj, itj = sparse_seminaive_fixpoint(rel, jnp.asarray(init), mode="jit")
    yf, itf, _ = sparse_seminaive_fixpoint_stats(rel, init,
                                                 mode="frontier")
    assert np.array_equal(np.asarray(yj), np.asarray(yf))
    assert np.array_equal(np.asarray(itj), np.asarray(itf))


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("max_iters", [1, 2, 4])
def test_batched_truncation_parity(kind, max_iters):
    """max_iters truncation must leave each batched row in exactly the
    state its single-source run reaches at the same cutoff."""
    rel, _, init, _ = _instance(kind, seed=5)
    yb, itb = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                        mode="jit", max_iters=max_iters)
    for i, row in enumerate(init):
        ys, its = sparse_seminaive_fixpoint(rel, jnp.asarray(row),
                                            mode="jit",
                                            max_iters=max_iters)
        assert np.array_equal(np.asarray(yb[i]), np.asarray(ys))
        assert int(itb[i]) == int(its) <= max_iters


def _dense_batched_runners(w, init, sr_name):
    sr = sr_mod.get(sr_name)
    wj, ij = jnp.asarray(w), jnp.asarray(init)

    def a_of(x):  # batched linear part: x (B, n) → (B, n)
        if sr_name == "bool":
            return jnp.any(x[:, :, None] & wj[None], axis=1)
        return jnp.min(x[:, :, None] + wj[None], axis=1)

    ico = lambda s: {"X": sr.add(ij, a_of(s["X"]))}
    dico = lambda s: {"X": a_of(s["X"])}
    x0 = {"X": jnp.full(init.shape, sr.zero, sr.dtype)}
    return sr, ico, dico, x0


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 6])
def test_dense_batched_gsn_equals_loop_and_sparse(kind, seed):
    """The dense mirror (core.fixpoint.batched_seminaive_fixpoint) must
    match both a loop of dense single GSN runs and the sparse batched
    runner, with identical per-row iteration counts."""
    rel, w, init, sr_name = _instance(kind, seed)
    sr, ico, dico, x0 = _dense_batched_runners(w, init, sr_name)
    yd, itd = fx.batched_seminaive_fixpoint(ico, dico, x0, {"X": sr})
    ys, its = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                        mode="jit")
    assert np.array_equal(np.asarray(yd["X"]), np.asarray(ys))
    assert np.array_equal(np.asarray(itd), np.asarray(its))
    for i, row in enumerate(init):
        w1 = jnp.asarray(w)

        def a1(x):
            if sr_name == "bool":
                return jnp.any(x[:, None] & w1, axis=0)
            return jnp.min(x[:, None] + w1, axis=0)

        r = jnp.asarray(row)
        y1, it1 = fx.seminaive_fixpoint(
            lambda s: {"X": sr.add(r, a1(s["X"]))},
            lambda s: {"X": a1(s["X"])},
            {"X": jnp.full(row.shape, sr.zero, sr.dtype)}, {"X": sr})
        assert np.array_equal(np.asarray(yd["X"][i]), np.asarray(y1["X"]))
        assert int(itd[i]) == int(it1)


def test_batched_gsn_rejects_non_lattice():
    sr = sr_mod.get("nat")
    x0 = {"X": jnp.zeros((2, 3), jnp.float32)}
    with pytest.raises(ValueError, match="lacks"):
        fx.batched_seminaive_fixpoint(lambda s: s, lambda s: s, x0,
                                      {"X": sr})


def test_zero_init_rows_are_inert_padding():
    """All-0̄ init rows (the serve loop's batch padding) converge in one
    round and never disturb live rows."""
    rel, _, init, _ = _instance("bm", seed=7, b=3)
    padded = np.zeros((5, init.shape[1]), init.dtype)
    padded[:3] = init
    yp, itp = sparse_seminaive_fixpoint(rel, jnp.asarray(padded),
                                        mode="jit")
    yb, itb = sparse_seminaive_fixpoint(rel, jnp.asarray(init),
                                        mode="jit")
    assert np.array_equal(np.asarray(yp[:3]), np.asarray(yb))
    assert not np.asarray(yp[3:]).any()
    assert np.asarray(itp[3:]).max() <= 1


def test_mspm_equals_vspm_loop():
    rel, _, _, _ = _instance("sssp", seed=8)
    rng = np.random.default_rng(0)
    x = rng.uniform(0.0, 4.0, (6, rel.shape[0])).astype(np.float32)
    out = mspm(jnp.asarray(x), rel.as_jnp())
    for i in range(x.shape[0]):
        row = vspm(jnp.asarray(x[i]), rel.as_jnp())
        assert np.allclose(np.asarray(out[i]), np.asarray(row))


def test_non_square_batched_rejected():
    rel = SparseRelation.from_coo([[0, 2]], [True], (2, 4), "bool")
    with pytest.raises(ValueError, match="square"):
        sparse_seminaive_fixpoint(rel, jnp.zeros((3, 4), bool), mode="jit")
