"""Pallas kernels vs jnp oracles — shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic shim (see helpers.py)
    from helpers import given, settings, strategies as st

from repro.core import semiring as sr_mod
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.semiring_matmul import semiring_matmul_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

SHAPES = [(8, 16, 8), (32, 64, 16), (128, 128, 128), (130, 70, 60)]


@pytest.mark.parametrize("sr_name", ["bool", "trop", "maxplus", "nat",
                                     "real"])
@pytest.mark.parametrize("shape", SHAPES)
def test_semiring_matmul_kernel(sr_name, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((sr_name, shape)) % 2**31)
    sr = sr_mod.get(sr_name)
    if sr_name == "bool":
        a = rng.random((m, k)) < 0.3
        b = rng.random((k, n)) < 0.3
    else:
        a = rng.integers(0, 5, (m, k)).astype(np.float32)
        b = rng.integers(0, 5, (k, n)).astype(np.float32)
        if sr_name in ("trop", "maxplus"):
            a[rng.random((m, k)) < 0.2] = sr.zero
            b[rng.random((k, n)) < 0.2] = sr.zero
    got = semiring_matmul_pallas(jnp.asarray(a), jnp.asarray(b),
                                 sr_name=sr_name, interpret=True)
    want = ref.semiring_matmul_ref(sr, jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tq,tk,hq,hkv,d", [
    (64, 64, 4, 4, 32),     # MHA
    (64, 64, 8, 2, 32),     # GQA
    (128, 128, 4, 1, 64),   # MQA
])
@pytest.mark.parametrize("variant", ["causal", "window", "chunk", "full"])
def test_flash_attention_kernel(tq, tk, hq, hkv, d, variant):
    rng = np.random.default_rng(0)
    q = rng.standard_normal((2, tq, hq, d)).astype(np.float32)
    k = rng.standard_normal((2, tk, hkv, d)).astype(np.float32)
    v = rng.standard_normal((2, tk, hkv, d)).astype(np.float32)
    kw = dict(causal=variant != "full",
              window=32 if variant == "window" else None,
              chunk=32 if variant == "chunk" else None)
    got = flash_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), bq=32, bkv=32,
                                 interpret=True, **kw)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_decode_offset():
    rng = np.random.default_rng(1)
    q = rng.standard_normal((1, 1, 4, 32)).astype(np.float32)
    k = rng.standard_normal((1, 64, 4, 32)).astype(np.float32)
    v = rng.standard_normal((1, 64, 4, 32)).astype(np.float32)
    got = flash_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), q_offset=63, bq=1, bkv=32,
                                 interpret=True)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             q_offset=63)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), t=st.sampled_from([8, 32, 64, 256]),
       d=st.sampled_from([4, 16]), seed=st.integers(0, 100))
def test_ssm_scan_kernel(b, t, d, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32)
    x = rng.standard_normal((b, t, d)).astype(np.float32)
    got = ssm_scan_pallas(jnp.asarray(a), jnp.asarray(x),
                          bt=min(32, t), interpret=True)
    want = ref.ssm_scan_ref(jnp.asarray(a), jnp.asarray(x))
    seq = ref.ssm_scan_sequential(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(want), np.asarray(seq),
                               rtol=2e-4, atol=2e-4)


def test_scan_is_fgh_rewrite_of_sequential_loop():
    """The associative scan (GH-form) equals the token loop (FG-form):
    the DESIGN.md §Arch-applicability claim, checked numerically."""
    rng = np.random.default_rng(3)
    a = rng.uniform(0.0, 1.0, (2, 128, 8)).astype(np.float32)
    x = rng.standard_normal((2, 128, 8)).astype(np.float32)
    fg = ref.ssm_scan_sequential(jnp.asarray(a), jnp.asarray(x))
    gh = ref.ssm_scan_ref(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(fg), np.asarray(gh), rtol=1e-4,
                               atol=1e-4)


def test_online_attention_matches_sdpa():
    """§Perf 'online' XLA attention ≡ plain SDPA (all mask variants)."""
    import numpy as np
    from repro.models import attention as A
    rng = np.random.default_rng(0)
    b, tq, tk, hq, hkv, hd = 2, 64, 64, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((b, tq, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, tk, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, tk, hkv, hd)), jnp.float32)
    qpos, kpos = jnp.arange(tq), jnp.arange(tk)
    for kw in [dict(causal=True, window=None, chunk=None, is_global=False),
               dict(causal=True, window=16, chunk=None, is_global=False),
               dict(causal=True, window=None, chunk=16, is_global=False)]:
        a1 = A._sdpa(q, k, v, qpos, kpos, **kw)
        a2 = A._sdpa_online(q, k, v, qpos, kpos, **kw)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                                   atol=2e-4, rtol=2e-4)


def test_chunked_scan_matches_ref():
    import numpy as np
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (2, 512, 8)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 512, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.ssm_scan_ref(a, x)),
        np.asarray(ref.ssm_scan_chunked(a, x, chunk=128)),
        atol=2e-4, rtol=2e-4)
