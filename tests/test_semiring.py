"""Property tests: semiring axioms (paper Sec. 2) on every value space."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic shim (see helpers.py)
    from helpers import given, settings, strategies as st

from repro.core import semiring as sr_mod

SEMIRINGS = ["bool", "trop", "maxplus", "nat", "real"]


def _values(sr_name):
    pool = sr_mod.np_value_pool(sr_mod.get(sr_name, lib="np"))
    return st.sampled_from(list(pool))


@pytest.mark.parametrize("name", SEMIRINGS)
@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_semiring_axioms(name, data):
    sr = sr_mod.get(name, lib="np")
    a = data.draw(_values(name))
    b = data.draw(_values(name))
    c = data.draw(_values(name))
    # ⊕ commutative + associative, identity 0̄
    assert _eq(sr.add(a, b), sr.add(b, a))
    assert _eq(sr.add(sr.add(a, b), c), sr.add(a, sr.add(b, c)))
    assert _eq(sr.add(a, np.asarray(sr.zero, sr.dtype)), a)
    # ⊗ commutative + associative, identity 1̄
    assert _eq(sr.mul(a, b), sr.mul(b, a))
    assert _eq(sr.mul(sr.mul(a, b), c), sr.mul(a, sr.mul(b, c)))
    assert _eq(sr.mul(a, np.asarray(sr.one, sr.dtype)), a)
    # distributivity  a⊗(b⊕c) = a⊗b ⊕ a⊗c
    assert _eq(sr.mul(a, sr.add(b, c)), sr.add(sr.mul(a, b), sr.mul(a, c)))
    if name in ("bool", "trop", "nat"):  # true semirings annihilate
        assert _eq(sr.mul(a, np.asarray(sr.zero, sr.dtype)),
                   np.asarray(sr.zero, sr.dtype))
    if sr.idempotent:
        assert _eq(sr.add(a, a), a)


@pytest.mark.parametrize("name", ["bool", "trop", "maxplus"])
@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_minus_is_lattice_difference(name, data):
    """b ⊖ a is the least c with b ≤ a ⊕ c  (Sec. 3.1 GSN)."""
    sr = sr_mod.get(name, lib="np")
    a = data.draw(_values(name))
    b = data.draw(_values(name))
    d = sr.minus(b, a)
    # a ⊕ (b ⊖ a) = a ⊕ b   (recovers the join)
    assert _eq(sr.add(a, d), sr.add(a, b))


@pytest.mark.parametrize("name", SEMIRINGS)
def test_cast_operator(name):
    sr = sr_mod.get(name, lib="np")
    if name == "bool":
        return
    out = sr.from_bool(np.array([True, False]))
    assert out[0] == np.asarray(sr.one, sr.dtype)
    assert _eq(out[1], np.asarray(sr.zero, sr.dtype))


def test_jnp_and_np_twins_agree():
    import jax.numpy as jnp
    for name in SEMIRINGS:
        j = sr_mod.get(name, lib="jnp")
        n = sr_mod.get(name, lib="np")
        pool = sr_mod.np_value_pool(n)
        a, b = pool[:2], pool[1:3]
        assert values_equalish(np.asarray(j.add(jnp.asarray(a), jnp.asarray(b))),
                               n.add(a, b))
        assert values_equalish(np.asarray(j.mul(jnp.asarray(a), jnp.asarray(b))),
                               n.mul(a, b))


def values_equalish(x, y):
    x, y = np.asarray(x), np.asarray(y)
    return bool(np.all((x == y) | (np.isnan(x.astype(float)) &
                                   np.isnan(y.astype(float)))))


def _eq(x, y):
    x, y = np.asarray(x), np.asarray(y)
    return bool(np.all((x == y)))
