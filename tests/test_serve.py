"""The batched Datalog serve loop: vector-form routing, request packing,
compile-cache reuse, inert padding, FGH Π₂ routing, and the sharded
(mesh-attached) path must all return exactly the single-source engine
answers."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine, ir, vectorize
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.launch.datalog_serve import (DatalogServer, fgh_make_program,
                                        _bucket)
from repro.launch.mesh import make_datalog_mesh
from repro.sparse import SparseRelation


def _bm_db(n=120, seed=2, sparse=True):
    g = datasets.erdos_renyi(n, 3.0, seed=seed)
    schema = programs.bm(a=0).original.schema
    e = g.sparse_adjacency() if sparse else g.adjacency()
    return g, engine.Database(schema, {"id": n},
                              {"E": e, "V": jnp.ones((n,), bool)})


def _expected_bm(db, source):
    dense_db = db.with_storage("E", "dense")
    ans, _ = run_program(programs.bm(a=source).optimized, dense_db,
                         mode="seminaive")
    return np.asarray(ans)


def test_bucket():
    assert [_bucket(b, 64) for b in (1, 2, 3, 5, 8, 33, 64, 200)] == \
        [1, 2, 4, 8, 8, 64, 64, 64]


@pytest.mark.parametrize("sparse", [True, False])
def test_served_answers_match_engine(sparse):
    """Published Π₂, sparse and dense backends: every served answer is
    the single-source engine answer."""
    _, db = _bm_db(sparse=sparse)
    server = DatalogServer(max_batch=8)
    fam = server.register("reach", lambda a: programs.bm(a=a).optimized,
                          db)
    assert fam.backend == ("sparse" if sparse else "dense")
    sources = [0, 7, 31, 99, 5, 5]
    reqs = [server.submit("reach", s) for s in sources]
    served = server.run_until_idle()
    assert served == len(sources)
    for req in reqs:
        assert req.iters >= 1
        assert np.array_equal(req.result, _expected_bm(db, req.source)), \
            req.source


def test_compile_cache_reuse_and_buckets():
    """Same B-bucket → cache hit; new bucket → exactly one new entry.

    The warm answer cache is disabled: this test re-serves the same
    sources to count *compile*-cache traffic, which warm hits would
    short-circuit before the compiled runner is even looked up.
    """
    _, db = _bm_db()
    server = DatalogServer(max_batch=8, warm_answers=0)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    for s in range(8):
        server.submit("reach", s)
    server.run_until_idle()          # one batch of 8 → bucket 8
    assert server.stats == {**server.stats, "cache_misses": 1,
                            "cache_hits": 0}
    for s in range(16):
        server.submit("reach", s)
    server.run_until_idle()          # two more bucket-8 batches
    assert server.stats["cache_misses"] == 1
    assert server.stats["cache_hits"] == 2
    server.submit("reach", 3)
    server.run_until_idle()          # lone query → per-source latency
    assert server.stats["latency_routed"] == 1  # path, no batched compile
    assert server.stats["cache_misses"] == 1
    server.submit("reach", 3)
    server.submit("reach", 5)
    server.run_until_idle()          # bucket 2 → second compile
    assert server.stats["cache_misses"] == 2


def test_padding_rows_do_not_leak():
    """A short batch is padded to its power-of-two bucket with inert 0̄
    rows; answers must be identical to unpadded serving."""
    _, db = _bm_db()
    server = DatalogServer(max_batch=8)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    reqs = [server.submit("reach", s) for s in (11, 22, 33)]
    server.run_until_idle()
    assert server.stats["padded_rows"] == 1  # bucket 4, three live rows
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db, req.source))


def test_mixed_families_interleaved():
    """Two families interleaved in the queue: the packer groups per
    family while preserving arrival order of the rest."""
    g, db = _bm_db()
    b = programs.sssp(a=0, wmax=4, dmax=40)
    g2 = datasets.erdos_renyi(60, 2.5, seed=4, weighted=True, wmax=4)
    db2 = b.make_db(g2)
    server = DatalogServer(max_batch=4)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    server.register("sssp",
                    lambda a: programs.sssp(a=a, wmax=4, dmax=40).optimized,
                    db2)
    reqs = []
    for i in range(6):
        reqs.append(server.submit("reach", 2 * i))
        reqs.append(server.submit("sssp", 3 * i))
    server.run_until_idle()
    for req in reqs:
        if req.family == "reach":
            assert np.array_equal(req.result, _expected_bm(db, req.source))
        else:
            ans, _ = run_program(
                programs.sssp(a=req.source, wmax=4, dmax=40).optimized,
                db2, mode="seminaive")
            assert np.array_equal(req.result, np.asarray(ans)), req.source


def test_sparse_edges_override():
    """SSSP at scale: the schema-level E3 is a dense (n, n, w) tensor,
    but serving can route a weighted COO adjacency straight into the
    batched runner via the ``edges=`` override."""
    b = programs.sssp(a=0, wmax=6, dmax=48)
    g = datasets.erdos_renyi(80, 2.5, seed=5, weighted=True, wmax=6)
    db = b.make_db(g)
    rel = g.sparse_adjacency(semiring="trop")
    server = DatalogServer(max_batch=4)
    fam = server.register(
        "sssp", lambda a: programs.sssp(a=a, wmax=6, dmax=48).optimized,
        db, edges=rel)
    assert fam.backend == "sparse"
    reqs = [server.submit("sssp", s) for s in (0, 13, 42)]
    server.run_until_idle()
    for req in reqs:
        ans, _ = run_program(
            programs.sssp(a=req.source, wmax=6, dmax=48).optimized, db,
            mode="seminaive")
        assert np.array_equal(req.result, np.asarray(ans)), req.source


def test_fgh_route_serves_every_source():
    """Π₂ synthesized by core.fgh at two placeholder sources serves
    arbitrary sources through constant substitution."""
    _, db = _bm_db(n=60)
    make_program = fgh_make_program(lambda a: programs.bm(a=a),
                                    ["E", "V"])
    # the substituted program is a faithful Π₂ for an unseen source
    p7 = make_program(7)
    dense_db = db.with_storage("E", "dense")
    a_pub, _ = run_program(programs.bm(a=7).optimized, dense_db,
                           mode="seminaive")
    a_fgh, _ = run_program(p7, dense_db)
    assert np.array_equal(np.asarray(a_pub), np.asarray(a_fgh))

    # the second placeholder (1) must serve through substitution too —
    # its own derivation run has drifted fresh-variable names
    server = DatalogServer(max_batch=4)
    server.register("reach", make_program, db)
    reqs = [server.submit("reach", s) for s in (0, 1, 7, 29, 53)]
    server.run_until_idle()
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db, req.source)), \
            req.source


def test_linear_signature_is_name_drift_invariant():
    """Two independent fgh derivations (fresh-counter variable names
    drift between runs) and the published rewrite must all hash to the
    same linear signature — the compile-cache / init-routing key."""
    from repro.core import fgh, verify

    sigs = []
    for p in (0, 1):
        b = programs.bm(a=p)
        task = verify.task_from_program(b.original, ["E", "V"],
                                        constraint=b.constraint)
        rep = fgh.optimize(task, rng=np.random.default_rng(0))
        assert rep.ok
        sigs.append(vectorize.vector_form(rep.program).signature)
    published = vectorize.vector_form(programs.bm(a=9).optimized).signature
    assert sigs[0] == sigs[1] == published


def test_mesh_attached_serving():
    """With a (single-device here) datalog mesh attached, the sharded
    path — device_put of the packed batch + in-loop constraints — still
    returns exact answers."""
    _, db = _bm_db(n=64)
    server = DatalogServer(max_batch=4, mesh=make_datalog_mesh(1))
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    reqs = [server.submit("reach", s) for s in (1, 2, 3, 4, 5)]
    server.run_until_idle()
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db, req.source))


def test_bad_source_fails_alone():
    """A request whose program changed the family's linear operator is
    marked failed; the rest of its batch is still served."""
    _, db = _bm_db(n=60)
    g2 = datasets.erdos_renyi(60, 2.0, seed=9)
    db2 = engine.Database(programs.cc().original.schema, {"id": 60},
                          {"E": g2.sparse_adjacency(symmetric=True),
                           "V": jnp.ones((60,), bool)})

    def make_program(a):
        if a == 13:  # different linear operator → signature mismatch
            return programs.cc().optimized
        return programs.bm(a=a).optimized

    server = DatalogServer(max_batch=8)
    server.register("reach", make_program, db)
    reqs = [server.submit("reach", s) for s in (2, 13, 41)]
    server.run_until_idle()
    bad = reqs[1]
    assert bad.result is None and "linear operator" in bad.error
    assert server.stats["failed"] == 1 and server.stats["served"] == 2
    for req in (reqs[0], reqs[2]):
        assert req.error is None
        assert np.array_equal(req.result, _expected_bm(db, req.source))


def test_vector_form_rejects_post_and_non_identity_outputs():
    """Programs whose answer is not the raw fixpoint x* must be refused:
    a host post-epilogue or a non-identity output chain."""
    ws = programs.ws()
    with pytest.raises(ValueError, match="post-epilogue"):
        vectorize.vector_form(ws.optimized)
    b = programs.bm(a=0).optimized
    from repro.core import ir
    from repro.core.program import Program, Rule
    twisted = Program(
        b.name, b.schema, b.strata,
        [Rule("Qans", ir.SSP(("y",), (ir.Term(
            (ir.RelAtom("Q", ("y",)), ir.RelAtom("V", ("y",))), ()),),
            "bool"))],
        sort_hints=dict(b.sort_hints))
    with pytest.raises(ValueError, match="not the identity"):
        vectorize.vector_form(twisted)


def test_non_lattice_family_rejected():
    """MLM's counting semiring has no ⊖ — registration must refuse."""
    b = programs.mlm()
    g = datasets.random_recursive_tree(20, seed=1)
    db = b.make_db(g)
    server = DatalogServer()
    with pytest.raises(ValueError, match="lacks"):
        server.register("mlm", lambda a: b.optimized, db)


def test_unknown_family_rejected():
    server = DatalogServer()
    with pytest.raises(KeyError, match="unknown family"):
        server.submit("nope", 0)


def test_vector_form_rejects_non_vector_programs():
    b = programs.bm(a=0)
    # binary TC IDB behind a real (non-identity) G-map: refused
    with pytest.raises(ValueError, match="not the identity|unary IDB"):
        vectorize.vector_form(b.original)
    ws = programs.ws()
    with pytest.raises(ValueError):
        vectorize.vector_form(ws.original)


def _bridge_db(n=80):
    """Two disjoint path components 0..n/2-1 and n/2..n-1 — updates that
    bridge them make answers change visibly."""
    h = n // 2
    edges = np.concatenate(
        [np.stack([np.arange(0, h - 1), np.arange(1, h)], 1),
         np.stack([np.arange(h, n - 1), np.arange(h + 1, n)], 1)])
    g = datasets.Graph(n, edges)
    db = engine.Database(programs.bm(a=0).original.schema, {"id": n},
                         {"E": g.sparse_adjacency(),
                          "V": jnp.ones((n,), bool)})
    return db, h


def test_update_acknowledged_before_later_queries():
    """FIFO through the shared queue: a query submitted after an update
    must never be served from the pre-update graph — even when it could
    have been packed into the same batch as a pre-update query, and even
    when the answer comes from the warm cache (which the update must
    repair, not leak stale)."""
    db, h = _bridge_db()
    server = DatalogServer(max_batch=8)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    q1 = server.submit("reach", 0)
    u = server.submit_update("reach", [[10, h]])
    q2 = server.submit("reach", 0)
    server.run_until_idle()
    assert not q1.result[h:].any(), "q1 predates the update"
    assert u.applied and u.latency_s >= 0
    assert q2.result[h:].all(), "q2 was served a pre-update answer"

    db2 = db.with_relations(
        {"E": db.relations["E"].apply_delta([[10, h]])})
    assert np.array_equal(q2.result, _expected_bm(db2, 0))
    # q1 was cached cold, the update repaired it, q2 warm-hit the repair
    assert server.stats["warm_hits"] == 1
    assert server.stats["answers_repaired"] == 1


def test_update_compile_cache_survives_mutations():
    """Mutations must not re-plan or re-lower: the compiled-runner cache
    sees zero new misses across updates — including one that overflows
    the COO capacity and re-pads at doubled capacity."""
    db, h = _bridge_db()
    server = DatalogServer(max_batch=4, warm_answers=0)
    fam = server.register("reach", lambda a: programs.bm(a=a).optimized,
                          db)
    sig0 = fam.plan.signature
    for s in (0, 1, 2, 3):
        server.submit("reach", s)
    server.run_until_idle()
    misses0 = server.stats["cache_misses"]

    cap = fam.edges.capacity
    server.submit_update("reach", [[10, h]])
    server.run_until_idle()
    rng = np.random.default_rng(0)
    big = np.stack([rng.integers(0, 80, cap + 8),
                    rng.integers(0, 80, cap + 8)], 1)
    server.submit_update("reach", big)         # forces capacity doubling
    for s in (0, 1, 2, 3):
        server.submit("reach", s)
    server.run_until_idle()
    assert fam.edges.capacity > cap
    assert fam.plan.signature == sig0
    assert server.stats["cache_misses"] == misses0, \
        "an update re-lowered the staged fixpoint"
    assert server.stats["updates"] == 2

    db2 = db.with_relations({"E": db.relations["E"]
                             .apply_delta([[10, h]]).apply_delta(big)})
    q = server.submit("reach", 0)
    server.run_until_idle()
    assert np.array_equal(q.result, _expected_bm(db2, 0))


def test_warm_answers_repaired_in_one_pass():
    """Several cached sources; one update repairs them all in a single
    batched delta-restart; every repaired answer is exact."""
    db, h = _bridge_db()
    server = DatalogServer(max_batch=8)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    sources = (0, 3, 9, 11)
    for s in sources:
        server.submit("reach", s)
    server.run_until_idle()
    server.submit_update("reach", [[10, h], [h + 3, 2]])
    server.run_until_idle()
    assert server.stats["answers_repaired"] == len(sources)

    db2 = db.with_relations(
        {"E": db.relations["E"].apply_delta([[10, h], [h + 3, 2]])})
    reqs = [server.submit("reach", s) for s in sources]
    hits0 = server.stats["warm_hits"]
    server.run_until_idle()
    assert server.stats["warm_hits"] == hits0 + len(sources)
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db2, req.source)), \
            req.source


def test_delete_update_repairs_warm_answers_and_serves_fresh():
    """A delete no longer drops the warm cache: the synthesized
    ⊖/recount maintenance rule (DESIGN.md §11) repairs the cached
    answer in place, and a post-delete query warm-hits the repair."""
    db, h = _bridge_db()
    server = DatalogServer(max_batch=4)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    server.submit("reach", 0)
    server.submit_update("reach", [[10, h]])
    server.run_until_idle()
    repaired0 = server.stats["answers_repaired"]
    hits0 = server.stats["warm_hits"]
    u = server.submit_update("reach", [[10, h]], op="delete")
    q = server.submit("reach", 0)
    server.run_until_idle()
    assert u.applied
    assert server.stats["answers_dropped"] == 0
    assert server.stats["answers_repaired"] == repaired0 + 1
    assert server.stats["warm_hits"] == hits0 + 1, \
        "the post-delete query should be a warm hit on the repair"
    assert not q.result[h:].any()
    assert np.array_equal(q.result, _expected_bm(db, 0))


def test_update_weighted_override_family():
    """Updates against an edges=-override family (weighted SSSP COO):
    a monotone weight decrease repairs the warm distances exactly."""
    b = programs.sssp(a=0, wmax=6, dmax=48)
    g = datasets.erdos_renyi(60, 2.5, seed=11, weighted=True, wmax=6)
    db = b.make_db(g)
    rel = g.sparse_adjacency(semiring="trop")
    server = DatalogServer(max_batch=4)
    server.register("sssp",
                    lambda a: programs.sssp(a=a, wmax=6, dmax=48).optimized,
                    db, edges=rel)
    q0 = server.submit("sssp", 0)
    server.run_until_idle()
    u = server.submit_update("sssp", [[0, 42]], [1.0])
    q1 = server.submit("sssp", 0)
    server.run_until_idle()
    assert u.applied and server.stats["answers_repaired"] == 1
    assert q1.result[42] == 1.0
    # reference: single-source run over the updated override operator
    from repro.sparse import sparse_seminaive_fixpoint
    init = np.full(60, np.inf, np.float32)
    init[0] = 0.0
    y_ref, _ = sparse_seminaive_fixpoint(rel.apply_delta([[0, 42]], [1.0]),
                                         init, mode="frontier")
    assert np.array_equal(q1.result, np.asarray(y_ref))
    assert (q0.result[42] >= q1.result[42]).all()


def test_update_edge_fed_init_family_recomputes_cold():
    """A family whose init term reads the edge relation cannot have its
    warm answers repaired (the Δ-seed misses the init change) nor its
    memoized init vectors kept — updates must drop both and later
    queries recompute cold, exactly."""
    from repro.core.program import Program, Rule, Stratum

    n = 6
    schema = programs.bm(a=0).original.schema

    def make_program(a):
        body = ir.SSP(("y",), (
            ir.Term((ir.RelAtom("E", (ir.C(a), "y")),), ()),
            ir.Term((ir.RelAtom("Q", ("z",)), ir.RelAtom("E", ("z", "y"))),
                    ("z",))), "bool")
        return Program("edge_init", schema,
                       [Stratum({"Q": Rule("Q", body)})],
                       [Rule("Qans", ir.SSP(("y",), (ir.Term(
                           (ir.RelAtom("Q", ("y",)),), ()),), "bool"))])

    db = engine.Database(schema, {"id": n},
                         {"E": SparseRelation.from_coo(
                             [[1, 2]], [True], (n, n), "bool", capacity=8),
                          "V": jnp.ones((n,), bool)})
    server = DatalogServer(max_batch=4)
    fam = server.register("ei", make_program, db)
    assert fam.init_reads_edges
    q0 = server.submit("ei", 0)
    server.run_until_idle()
    assert not q0.result.any()          # nothing reachable from 0 yet
    server.submit_update("ei", [[0, 1]])
    q1 = server.submit("ei", 0)
    server.run_until_idle()
    assert server.stats["answers_repaired"] == 0
    assert server.stats["answers_dropped"] == 1
    db2 = db.with_relations({"E": db.relations["E"]
                             .apply_delta([[0, 1]])})
    expect, _ = run_program(make_program(0), db2)
    assert np.asarray(expect).any()
    assert np.array_equal(q1.result, np.asarray(expect))


def test_update_unknown_family_or_op_rejected():
    server = DatalogServer()
    with pytest.raises(KeyError, match="unknown family"):
        server.submit_update("nope", [[0, 1]])
    db, _ = _bridge_db()
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    with pytest.raises(ValueError, match="unknown update op"):
        server.submit_update("reach", [[0, 1]], op="upsert")


def test_edge_operator_sparse_fast_path_matches_dense():
    g, db = _bm_db(n=50, seed=7)
    vf = vectorize.vector_form(programs.bm(a=0).optimized)
    e_sparse = vectorize.edge_operator(vf, db)
    assert isinstance(e_sparse, SparseRelation)
    e_dense = vectorize.edge_operator(vf, db.with_storage("E", "dense"))
    assert np.array_equal(np.asarray(e_sparse.to_dense()),
                          np.asarray(e_dense))
