"""The batched Datalog serve loop: vector-form routing, request packing,
compile-cache reuse, inert padding, FGH Π₂ routing, and the sharded
(mesh-attached) path must all return exactly the single-source engine
answers."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine, vectorize
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.launch.datalog_serve import (DatalogServer, fgh_make_program,
                                        _bucket)
from repro.launch.mesh import make_datalog_mesh
from repro.sparse import SparseRelation


def _bm_db(n=120, seed=2, sparse=True):
    g = datasets.erdos_renyi(n, 3.0, seed=seed)
    schema = programs.bm(a=0).original.schema
    e = g.sparse_adjacency() if sparse else g.adjacency()
    return g, engine.Database(schema, {"id": n},
                              {"E": e, "V": jnp.ones((n,), bool)})


def _expected_bm(db, source):
    dense_db = db.with_storage("E", "dense")
    ans, _ = run_program(programs.bm(a=source).optimized, dense_db,
                         mode="seminaive")
    return np.asarray(ans)


def test_bucket():
    assert [_bucket(b, 64) for b in (1, 2, 3, 5, 8, 33, 64, 200)] == \
        [1, 2, 4, 8, 8, 64, 64, 64]


@pytest.mark.parametrize("sparse", [True, False])
def test_served_answers_match_engine(sparse):
    """Published Π₂, sparse and dense backends: every served answer is
    the single-source engine answer."""
    _, db = _bm_db(sparse=sparse)
    server = DatalogServer(max_batch=8)
    fam = server.register("reach", lambda a: programs.bm(a=a).optimized,
                          db)
    assert fam.backend == ("sparse" if sparse else "dense")
    sources = [0, 7, 31, 99, 5, 5]
    reqs = [server.submit("reach", s) for s in sources]
    served = server.run_until_idle()
    assert served == len(sources)
    for req in reqs:
        assert req.iters >= 1
        assert np.array_equal(req.result, _expected_bm(db, req.source)), \
            req.source


def test_compile_cache_reuse_and_buckets():
    """Same B-bucket → cache hit; new bucket → exactly one new entry."""
    _, db = _bm_db()
    server = DatalogServer(max_batch=8)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    for s in range(8):
        server.submit("reach", s)
    server.run_until_idle()          # one batch of 8 → bucket 8
    assert server.stats == {**server.stats, "cache_misses": 1,
                            "cache_hits": 0}
    for s in range(16):
        server.submit("reach", s)
    server.run_until_idle()          # two more bucket-8 batches
    assert server.stats["cache_misses"] == 1
    assert server.stats["cache_hits"] == 2
    server.submit("reach", 3)
    server.run_until_idle()          # bucket 1 → second compile
    assert server.stats["cache_misses"] == 2


def test_padding_rows_do_not_leak():
    """A short batch is padded to its power-of-two bucket with inert 0̄
    rows; answers must be identical to unpadded serving."""
    _, db = _bm_db()
    server = DatalogServer(max_batch=8)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    reqs = [server.submit("reach", s) for s in (11, 22, 33)]
    server.run_until_idle()
    assert server.stats["padded_rows"] == 1  # bucket 4, three live rows
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db, req.source))


def test_mixed_families_interleaved():
    """Two families interleaved in the queue: the packer groups per
    family while preserving arrival order of the rest."""
    g, db = _bm_db()
    b = programs.sssp(a=0, wmax=4, dmax=40)
    g2 = datasets.erdos_renyi(60, 2.5, seed=4, weighted=True, wmax=4)
    db2 = b.make_db(g2)
    server = DatalogServer(max_batch=4)
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    server.register("sssp",
                    lambda a: programs.sssp(a=a, wmax=4, dmax=40).optimized,
                    db2)
    reqs = []
    for i in range(6):
        reqs.append(server.submit("reach", 2 * i))
        reqs.append(server.submit("sssp", 3 * i))
    server.run_until_idle()
    for req in reqs:
        if req.family == "reach":
            assert np.array_equal(req.result, _expected_bm(db, req.source))
        else:
            ans, _ = run_program(
                programs.sssp(a=req.source, wmax=4, dmax=40).optimized,
                db2, mode="seminaive")
            assert np.array_equal(req.result, np.asarray(ans)), req.source


def test_sparse_edges_override():
    """SSSP at scale: the schema-level E3 is a dense (n, n, w) tensor,
    but serving can route a weighted COO adjacency straight into the
    batched runner via the ``edges=`` override."""
    b = programs.sssp(a=0, wmax=6, dmax=48)
    g = datasets.erdos_renyi(80, 2.5, seed=5, weighted=True, wmax=6)
    db = b.make_db(g)
    rel = g.sparse_adjacency(semiring="trop")
    server = DatalogServer(max_batch=4)
    fam = server.register(
        "sssp", lambda a: programs.sssp(a=a, wmax=6, dmax=48).optimized,
        db, edges=rel)
    assert fam.backend == "sparse"
    reqs = [server.submit("sssp", s) for s in (0, 13, 42)]
    server.run_until_idle()
    for req in reqs:
        ans, _ = run_program(
            programs.sssp(a=req.source, wmax=6, dmax=48).optimized, db,
            mode="seminaive")
        assert np.array_equal(req.result, np.asarray(ans)), req.source


def test_fgh_route_serves_every_source():
    """Π₂ synthesized by core.fgh at two placeholder sources serves
    arbitrary sources through constant substitution."""
    _, db = _bm_db(n=60)
    make_program = fgh_make_program(lambda a: programs.bm(a=a),
                                    ["E", "V"])
    # the substituted program is a faithful Π₂ for an unseen source
    p7 = make_program(7)
    dense_db = db.with_storage("E", "dense")
    a_pub, _ = run_program(programs.bm(a=7).optimized, dense_db,
                           mode="seminaive")
    a_fgh, _ = run_program(p7, dense_db)
    assert np.array_equal(np.asarray(a_pub), np.asarray(a_fgh))

    # the second placeholder (1) must serve through substitution too —
    # its own derivation run has drifted fresh-variable names
    server = DatalogServer(max_batch=4)
    server.register("reach", make_program, db)
    reqs = [server.submit("reach", s) for s in (0, 1, 7, 29, 53)]
    server.run_until_idle()
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db, req.source)), \
            req.source


def test_linear_signature_is_name_drift_invariant():
    """Two independent fgh derivations (fresh-counter variable names
    drift between runs) and the published rewrite must all hash to the
    same linear signature — the compile-cache / init-routing key."""
    from repro.core import fgh, verify

    sigs = []
    for p in (0, 1):
        b = programs.bm(a=p)
        task = verify.task_from_program(b.original, ["E", "V"],
                                        constraint=b.constraint)
        rep = fgh.optimize(task, rng=np.random.default_rng(0))
        assert rep.ok
        sigs.append(vectorize.vector_form(rep.program).signature)
    published = vectorize.vector_form(programs.bm(a=9).optimized).signature
    assert sigs[0] == sigs[1] == published


def test_mesh_attached_serving():
    """With a (single-device here) datalog mesh attached, the sharded
    path — device_put of the packed batch + in-loop constraints — still
    returns exact answers."""
    _, db = _bm_db(n=64)
    server = DatalogServer(max_batch=4, mesh=make_datalog_mesh(1))
    server.register("reach", lambda a: programs.bm(a=a).optimized, db)
    reqs = [server.submit("reach", s) for s in (1, 2, 3, 4, 5)]
    server.run_until_idle()
    for req in reqs:
        assert np.array_equal(req.result, _expected_bm(db, req.source))


def test_bad_source_fails_alone():
    """A request whose program changed the family's linear operator is
    marked failed; the rest of its batch is still served."""
    _, db = _bm_db(n=60)
    g2 = datasets.erdos_renyi(60, 2.0, seed=9)
    db2 = engine.Database(programs.cc().original.schema, {"id": 60},
                          {"E": g2.sparse_adjacency(symmetric=True),
                           "V": jnp.ones((60,), bool)})

    def make_program(a):
        if a == 13:  # different linear operator → signature mismatch
            return programs.cc().optimized
        return programs.bm(a=a).optimized

    server = DatalogServer(max_batch=8)
    server.register("reach", make_program, db)
    reqs = [server.submit("reach", s) for s in (2, 13, 41)]
    server.run_until_idle()
    bad = reqs[1]
    assert bad.result is None and "linear operator" in bad.error
    assert server.stats["failed"] == 1 and server.stats["served"] == 2
    for req in (reqs[0], reqs[2]):
        assert req.error is None
        assert np.array_equal(req.result, _expected_bm(db, req.source))


def test_vector_form_rejects_post_and_non_identity_outputs():
    """Programs whose answer is not the raw fixpoint x* must be refused:
    a host post-epilogue or a non-identity output chain."""
    ws = programs.ws()
    with pytest.raises(ValueError, match="post-epilogue"):
        vectorize.vector_form(ws.optimized)
    b = programs.bm(a=0).optimized
    from repro.core import ir
    from repro.core.program import Program, Rule
    twisted = Program(
        b.name, b.schema, b.strata,
        [Rule("Qans", ir.SSP(("y",), (ir.Term(
            (ir.RelAtom("Q", ("y",)), ir.RelAtom("V", ("y",))), ()),),
            "bool"))],
        sort_hints=dict(b.sort_hints))
    with pytest.raises(ValueError, match="not the identity"):
        vectorize.vector_form(twisted)


def test_non_lattice_family_rejected():
    """MLM's counting semiring has no ⊖ — registration must refuse."""
    b = programs.mlm()
    g = datasets.random_recursive_tree(20, seed=1)
    db = b.make_db(g)
    server = DatalogServer()
    with pytest.raises(ValueError, match="lacks"):
        server.register("mlm", lambda a: b.optimized, db)


def test_unknown_family_rejected():
    server = DatalogServer()
    with pytest.raises(KeyError, match="unknown family"):
        server.submit("nope", 0)


def test_vector_form_rejects_non_vector_programs():
    b = programs.bm(a=0)
    # binary TC IDB behind a real (non-identity) G-map: refused
    with pytest.raises(ValueError, match="not the identity|unary IDB"):
        vectorize.vector_form(b.original)
    ws = programs.ws()
    with pytest.raises(ValueError):
        vectorize.vector_form(ws.original)


def test_edge_operator_sparse_fast_path_matches_dense():
    g, db = _bm_db(n=50, seed=7)
    vf = vectorize.vector_form(programs.bm(a=0).optimized)
    e_sparse = vectorize.edge_operator(vf, db)
    assert isinstance(e_sparse, SparseRelation)
    e_dense = vectorize.edge_operator(vf, db.with_storage("E", "dense"))
    assert np.array_equal(np.asarray(e_sparse.to_dense()),
                          np.asarray(e_dense))
