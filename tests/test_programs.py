"""The 7 paper benchmarks: original ≡ published-optimized ≡ external oracle."""

import numpy as np
import networkx as nx
import pytest

from repro.core.program import run_program
from repro.datalog import datasets, programs
from helpers import values_close


def _nx_digraph(g):
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    G.add_edges_from(map(tuple, g.edges))
    return G


def test_cc_matches_union_find():
    g = datasets.erdos_renyi(24, 2.0, seed=1)
    b = programs.cc()
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    G = nx.Graph()
    G.add_nodes_from(range(g.n))
    G.add_edges_from(map(tuple, g.edges))
    want = np.zeros(g.n)
    for comp in nx.connected_components(G):
        m = min(comp)
        for v in comp:
            want[v] = m
    assert values_close(o, p)
    assert values_close(np.asarray(p), want)


def test_bm_matches_reachability():
    g = datasets.erdos_renyi(20, 1.5, seed=2)
    b = programs.bm(a=0)
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    want = np.zeros(g.n, bool)
    reach = nx.descendants(_nx_digraph(g), 0) | {0}
    want[list(reach)] = True
    assert values_close(o, p)
    assert (np.asarray(p) == want).all()


def test_sssp_matches_dijkstra():
    g = datasets.erdos_renyi(18, 2.5, seed=3, weighted=True, wmax=4)
    b = programs.sssp(a=0, wmax=4, dmax=48)
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    for (u, v), w in zip(g.edges, g.weights):
        if not G.has_edge(u, v) or G[u][v]["weight"] > w:
            G.add_edge(u, v, weight=int(w))
    want = np.full(g.n, np.inf)
    for k, v in nx.single_source_dijkstra_path_length(G, 0).items():
        want[k] = v
    assert values_close(o, p)
    assert values_close(np.asarray(p), want)


def test_ws_matches_numpy():
    vals = datasets.vector_data(30, seed=0, vmax=6)
    b = programs.ws(window=5, vmax=6)
    db = b.make_db(vals)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    pref = np.cumsum(vals)
    want = pref - np.concatenate([np.zeros(5), pref[:-5]])
    assert values_close(o, p)
    assert values_close(np.asarray(p), want)


def test_bc_matches_networkx():
    g = datasets.erdos_renyi(12, 2.0, seed=4)
    b = programs.bc(dmax=14)
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    ref = np.array([v for _, v in sorted(
        nx.betweenness_centrality(_nx_digraph(g),
                                  normalized=False).items())])
    assert values_close(o, ref)
    assert values_close(p, ref)


@pytest.mark.parametrize("deep", [False, True])
def test_mlm_matches_subtree_sums(deep):
    g = (datasets.decay_tree if deep else datasets.random_recursive_tree)(
        25, seed=5)
    b = programs.mlm()
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    # oracle: sum of ids in each subtree
    children = {i: [] for i in range(g.n)}
    for u, v in g.edges:
        children[u].append(v)

    def subtree(v):
        return v + sum(subtree(c) for c in children[v])

    want = np.array([subtree(v) for v in range(g.n)], np.float64)
    assert values_close(o, p)
    assert values_close(np.asarray(p, np.float64), want)


def test_radius_matches_heights():
    g = datasets.random_recursive_tree(20, seed=6)
    b = programs.radius(dmax=24)
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    children = {i: [] for i in range(g.n)}
    for u, v in g.edges:
        children[u].append(v)

    def height(v):
        return 0 if not children[v] else 1 + max(height(c)
                                                 for c in children[v])

    want = np.array([height(v) for v in range(g.n)], np.float32)
    assert values_close(o, p)
    assert values_close(np.asarray(p), want)


def test_apsp100_cap():
    g = datasets.erdos_renyi(14, 2.0, seed=7, weighted=True, wmax=4)
    b = programs.apsp100(cap=6.0)
    db = b.make_db(g)
    o, _ = run_program(b.original, db)
    p, _ = run_program(b.optimized, db)
    assert values_close(o, p)
    assert float(np.asarray(p)[np.isfinite(np.asarray(p))].max()) <= 6.0


def test_gsn_mode_matches_naive():
    g = datasets.erdos_renyi(16, 2.0, seed=8)
    for mk in (programs.cc, programs.bm):
        b = mk()
        db = b.make_db(g)
        nav, s1 = run_program(b.optimized, db, mode="naive")
        gsn, s2 = run_program(b.optimized, db, mode="seminaive")
        assert values_close(nav, gsn), b.name
