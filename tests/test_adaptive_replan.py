"""Mid-fixpoint adaptive re-planning (DESIGN.md §10): the unified
``fixpoint`` entrypoint, the Runner protocol's warm hand-offs, the
ReplanPolicy thrash guards, and the planner's adaptive execution path.

The load-bearing property is *bit-exactness*: every chunkable runner
shares the GSN round body, so a fixpoint chunked across any runner
sequence must return byte-identical values AND per-row iteration counts
to the static single-runner run.  Sharded hand-offs need ≥ 2 devices —
run via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core import runners as runners_mod
from repro.core import engine
from repro.core.program import run_program
from repro.datalog import datasets, programs
from repro.sparse import adaptive
from repro.sparse import fixpoint as fx
from repro.sparse.coo import SparseRelation

CPU = jax.default_backend() == "cpu"
NDEV = len(jax.devices())


def _chain_hub(n_chain=30, hub=12, seed=0):
    """A drifting-density graph: a long chain whose tail feeds a dense
    hub clique — the frontier collapses to one vertex along the chain,
    then re-explodes inside the hub."""
    rng = np.random.default_rng(seed)
    edges = [(i, i + 1) for i in range(n_chain - 1)]
    base = n_chain
    for i in range(hub):
        for j in range(hub):
            if i != j and rng.random() < 0.6:
                edges.append((base + i, base + j))
    edges.append((n_chain - 1, base))
    n = n_chain + hub
    coords = np.asarray(edges, np.int64)
    rel = SparseRelation.from_coo(coords, np.ones(len(coords), bool),
                                  (n, n), "bool")
    return rel.as_jnp(), n


def _one_hot(n, src=0):
    init = np.zeros(n, bool)
    init[src] = True
    return init


# --------------------------------------------------------------------------
# The unified fixpoint() entrypoint (satellite: API collapse)
# --------------------------------------------------------------------------


def test_fixpoint_requires_exactly_one_seed():
    edges, n = _chain_hub()
    with pytest.raises(ValueError, match="exactly one"):
        fx.fixpoint(edges)
    st = fx.FixpointState.cold(edges, _one_hot(n))
    with pytest.raises(ValueError, match="exactly one"):
        fx.fixpoint(edges, _one_hot(n), state=st)


def test_fixpoint_chunked_matches_static():
    """Chained budget= calls across alternating runners converge to the
    static answer with identical iteration counts."""
    edges, n = _chain_hub()
    init = _one_hot(n)
    y_ref, it_ref = fx.fixpoint(edges, init, mode="jit")
    st = fx.FixpointState.cold(edges, init)
    modes = ["jit", "frontier"]
    k = 0
    while not st.converged:
        st = fx.fixpoint(edges, state=st, budget=3,
                         mode=modes[k % 2])
        k += 1
    y, iters = st.solution()
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    assert int(iters) == int(it_ref)
    assert k > 3  # the chain actually needed several chunks


def test_fixpoint_resume_from_state():
    edges, n = _chain_hub()
    init = _one_hot(n)
    y_ref, it_ref = fx.fixpoint(edges, init, mode="jit")
    st = fx.fixpoint(edges, init=None if False else init, budget=4)
    y, iters = fx.fixpoint(edges, state=st)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    assert int(iters) == int(it_ref)


def test_deprecated_shims_warn_and_agree():
    edges, n = _chain_hub()
    init = _one_hot(n)
    y_ref, it_ref = fx.fixpoint(edges, init, mode="jit")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y1, it1 = fx.sparse_seminaive_fixpoint(edges, init, mode="jit")
        st = fx.FixpointState.cold(edges, init)
        y2, it2 = fx.resume_fixpoint(edges, st.y[0], st.delta[0],
                                     mode="jit")
        y3, d3, it3 = fx.resume_fixpoint_chunk(
            edges, st.y, st.delta, np.zeros(1, np.int32),
            max_iters=10_000)
    kinds = [x.category for x in w]
    assert kinds.count(DeprecationWarning) >= 3
    assert np.array_equal(np.asarray(y1), np.asarray(y_ref))
    assert int(it1) == int(it_ref)
    assert np.array_equal(np.asarray(y2), np.asarray(y_ref))
    assert np.array_equal(np.asarray(y3)[0], np.asarray(y_ref))


# --------------------------------------------------------------------------
# Runner-pair hand-off bit-exactness (the tentpole's differential test)
# --------------------------------------------------------------------------


class _Favor:
    """A cost model that makes one runner permanently cheapest, so the
    executor must switch to it at the first boundary the policy allows
    — every other runner prices 100× dearer."""

    def __init__(self, favorite):
        self.favorite = favorite

    def round_ns(self, runner, **kw):
        return 1.0 if runner == self.favorite else 100.0


def _adaptive_vs_static(start, target, monkeypatch, *, mesh=None,
                        policy=None):
    edges, n = _chain_hub()
    init = _one_hot(n)
    y_ref, it_ref = fx.fixpoint(edges, init, mode="jit")
    monkeypatch.setattr(adaptive, "ADAPTIVE_COST", _Favor(target))
    ctx = runners_mod.make_context(edges, init, "bool", 10_000,
                                   mesh=mesh)
    pol = policy or adaptive.ReplanPolicy(chunk_iters=3)
    y, iters, trace = runners_mod.adaptive_fixpoint(
        ctx, start=start, candidates=(start, target), policy=pol)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref)), \
        (start, target)
    assert int(np.asarray(iters)) == int(it_ref), (start, target)
    return trace


@pytest.mark.parametrize("start,target", [
    ("sparse_jit", "sparse_frontier"),
    ("sparse_frontier", "sparse_jit"),
    ("sparse_jit", "vector_dense"),
    ("vector_dense", "sparse_frontier"),
    ("sparse_jit", "sparse_frontier_pallas"),
    ("sparse_frontier_pallas", "sparse_frontier"),
])
def test_handoff_bit_exact(start, target, monkeypatch):
    trace = _adaptive_vs_static(start, target, monkeypatch)
    assert trace.final_runner == target
    assert len(trace.switches) == 1
    ev = trace.switches[0]
    assert (ev.from_runner, ev.to_runner) == (start, target)
    assert ev.est_to < ev.est_from


@pytest.mark.skipif(NDEV < 2, reason="sharded hand-off needs >= 2 "
                    "devices (XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=8)")
@pytest.mark.parametrize("start,target", [
    ("sparse_jit", "sparse_sharded"),
    ("sparse_sharded", "sparse_frontier"),
])
def test_sharded_handoff_bit_exact(start, target, monkeypatch):
    from repro.launch.mesh import make_graph_mesh
    mesh = make_graph_mesh(2)
    trace = _adaptive_vs_static(start, target, monkeypatch, mesh=mesh)
    assert trace.final_runner == target


def test_sharded_candidate_dropped_without_mesh(monkeypatch):
    """No mesh in the context → the sharded candidate silently drops
    out instead of crashing the executor."""
    trace = _adaptive_vs_static("sparse_jit", "sparse_frontier",
                                monkeypatch)
    edges, n = _chain_hub()
    ctx = runners_mod.make_context(edges, _one_hot(n), "bool", 10_000)
    monkeypatch.setattr(adaptive, "ADAPTIVE_COST",
                        _Favor("sparse_sharded"))
    y, iters, tr = runners_mod.adaptive_fixpoint(
        ctx, start="sparse_jit",
        candidates=("sparse_sharded", "sparse_jit"))
    assert tr.switches == []  # infeasible challenger never switched in
    assert trace is not None


def test_trop_handoff_bit_exact(monkeypatch):
    """Hand-offs are exact on the tropical semiring too (⊖ = masked
    keep; weighted shortest paths)."""
    g = datasets.erdos_renyi(60, 3.0, seed=7, weighted=True)
    rel = g.sparse_adjacency(semiring="trop").as_jnp()
    srn = np.full(60, np.inf, np.float32)
    srn[0] = 0.0
    y_ref, it_ref = fx.fixpoint(rel, srn, mode="jit")
    monkeypatch.setattr(adaptive, "ADAPTIVE_COST",
                        _Favor("sparse_frontier"))
    ctx = runners_mod.make_context(rel, srn, "trop", 10_000)
    y, iters, trace = runners_mod.adaptive_fixpoint(
        ctx, start="sparse_jit", candidates=("sparse_frontier",),
        policy=adaptive.ReplanPolicy(chunk_iters=2))
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    assert int(np.asarray(iters)) == int(it_ref)


# --------------------------------------------------------------------------
# ReplanPolicy thrash guards
# --------------------------------------------------------------------------


class _Oscillate:
    """Adversarial pricing: the cheapest runner flips every call, the
    worst case the policy's hysteresis + spacing guards must bound."""

    def __init__(self):
        self.calls = 0

    def round_ns(self, runner, **kw):
        self.calls += 1
        flip = (self.calls // 2) % 2 == 0
        cheap = "sparse_jit" if flip else "sparse_frontier"
        return 1.0 if runner == cheap else 100.0


def test_thrash_guard_bounds_switches(monkeypatch):
    edges, n = _chain_hub(n_chain=60, hub=8)
    init = _one_hot(n)
    y_ref, it_ref = fx.fixpoint(edges, init, mode="jit")
    monkeypatch.setattr(adaptive, "ADAPTIVE_COST", _Oscillate())
    pol = adaptive.ReplanPolicy(chunk_iters=2, max_switches=2,
                                min_chunks_between=2)
    ctx = runners_mod.make_context(edges, init, "bool", 10_000)
    y, iters, trace = runners_mod.adaptive_fixpoint(
        ctx, start="sparse_jit", candidates=("sparse_frontier",),
        policy=pol)
    assert len(trace.switches) <= pol.max_switches
    # spacing guard: consecutive switches are >= min_chunks_between apart
    for a, b in zip(trace.switches, trace.switches[1:]):
        assert b.chunk - a.chunk >= pol.min_chunks_between
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))
    assert int(np.asarray(iters)) == int(it_ref)


def test_should_switch_guards():
    pol = adaptive.ReplanPolicy(chunk_iters=4, hysteresis=2.0,
                                min_chunks_between=2, max_switches=1,
                                warmup_chunks=1)
    ok = dict(chunk_index=3, chunks_since_switch=4, switches=0)
    assert pol.should_switch(100.0, 10.0, **ok)
    # hysteresis: 2× cheaper is the floor
    assert not pol.should_switch(100.0, 60.0, **ok)
    assert pol.should_switch(100.0, 50.0, **ok)
    # warmup: no switch after the first observed chunk
    assert not pol.should_switch(100.0, 10.0, chunk_index=0,
                                 chunks_since_switch=1, switches=0)
    # spacing
    assert not pol.should_switch(100.0, 10.0, chunk_index=3,
                                 chunks_since_switch=1, switches=0)
    # hard cap
    assert not pol.should_switch(100.0, 10.0, chunk_index=9,
                                 chunks_since_switch=5, switches=1)


# --------------------------------------------------------------------------
# Planner integration: PlanHints + adaptive execution + explain
# --------------------------------------------------------------------------


def _bm_db(n=120, avg_deg=3.0, seed=2):
    g = datasets.erdos_renyi(n, avg_deg, seed=seed)
    schema = programs.bm(a=0).original.schema
    return engine.Database(schema, {"id": n},
                           {"E": g.sparse_adjacency(),
                            "V": jnp.ones((n,), bool)})


def test_plan_hints_legacy_dict_warns():
    db = _bm_db()
    prog = programs.bm(a=0).optimized
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p1 = planner.plan_program(prog, db, hints={})
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    p2 = planner.plan_program(prog, db, hints=planner.PlanHints())
    assert p1.signature == p2.signature
    with pytest.raises(TypeError):
        planner.plan_program(prog, db, hints=42)


def test_plan_hints_validation():
    with pytest.raises(TypeError):
        planner.PlanHints(sorts={1: "asc"})
    with pytest.raises(TypeError):
        planner.PlanHints(replan="yes")
    ph = planner.PlanHints(adaptive=True,
                           replan=adaptive.ReplanPolicy(chunk_iters=2))
    assert ph.cache_key()[1] is True


def test_adaptive_execution_matches_static_and_logs():
    db = _bm_db()
    prog = programs.bm(a=0).optimized
    ref, _ = run_program(prog, db, mode="naive")
    plan = planner.plan_program(prog, db,
                                hints=planner.PlanHints(adaptive=True))
    assert plan.adaptive
    out, stats = planner.execute_plan(plan, prog, db)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    sp = plan.strata[0]
    assert sp.switch_log is not None
    assert sp.switch_log.chunks  # at least one chunk observed
    txt = planner.explain(plan)
    assert "adaptive" in txt
    assert f"finished on {sp.switch_log.final_runner}" in txt


def test_adaptive_switch_rendered_in_explain(monkeypatch):
    # the auto plan picks the frontier runner on CPU and keeps the
    # staged runner in `considered` — the adaptive candidates; pricing
    # the staged runner cheapest forces a mid-fixpoint switch
    db = _bm_db()
    prog = programs.bm(a=0).optimized
    ref, _ = run_program(prog, db, mode="naive")
    plan = planner.plan_program(prog, db)
    start = plan.strata[0].runner
    target = next(c for c in plan.strata[0].considered
                  if c != start and runners_mod.get(c).chunkable)
    monkeypatch.setattr(adaptive, "ADAPTIVE_COST", _Favor(target))
    pol = adaptive.ReplanPolicy(chunk_iters=1)
    out, _ = planner.execute_plan(
        plan, prog, db,
        hints=planner.PlanHints(adaptive=True, replan=pol))
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    tr = plan.strata[0].switch_log
    assert tr is not None and tr.policy is pol
    txt = planner.explain(plan)
    if tr.switches:  # the BM fixpoint is deep enough on this seed
        assert "switch" in txt
        assert f"{start} → {target}" in txt


def test_adaptive_forced_plan_still_converges():
    """A forced single-runner plan has no `considered` alternatives —
    the adaptive executor must still chunk it to convergence."""
    db = _bm_db()
    prog = programs.bm(a=0).optimized
    ref, _ = run_program(prog, db, mode="naive")
    plan = planner.plan_program(prog, db, mode="sparse_jit")
    out, _ = planner.execute_plan(
        plan, prog, db, hints=planner.PlanHints(adaptive=True))
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    tr = plan.strata[0].switch_log
    assert tr is not None and tr.switches == []


def test_explain_without_adaptive_run_has_no_switch_lines():
    db = _bm_db()
    prog = programs.bm(a=0).optimized
    plan = planner.plan_program(prog, db)
    txt = planner.explain(plan)
    assert "adaptive " not in txt and "switch " not in txt
