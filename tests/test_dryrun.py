"""Integration: one real AOT dry-run cell via subprocess (512 virtual
devices live only in the child; this process keeps 1 device)."""

import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow  # full AOT lower+compile in a 512-device subprocess
@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles(mesh):
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "decode_32k", "--mesh", mesh],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    rows = [json.loads(l) for l in proc.stdout.splitlines()
            if l.startswith("{")]
    assert rows, proc.stderr[-2000:]
    row = rows[-1]
    assert row["status"] == "ok", row.get("error")
    assert row["flops"] > 0
    assert row["collectives"]["total_bytes"] > 0  # model-sharded decode


def test_hlo_walker_loop_multiplication():
    import jax
    import jax.numpy as jnp
    from repro.launch import hlo_cost

    def body(x, _):
        return x @ x, None

    def f(x):
        return jax.lax.scan(body, x, None, length=7)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flops == pytest.approx(7 * 2 * 256 ** 3, rel=0.01)


def test_skip_rules():
    from repro import configs
    from repro.launch import workloads as wl
    skipped = [a for a in configs.list_archs()
               if wl.skip_reason(configs.get(a), wl.WORKLOADS["long_500k"])]
    assert set(skipped) == {"minicpm-2b", "llama3-405b",
                            "mistral-large-123b", "deepseek-moe-16b",
                            "whisper-base", "llava-next-mistral-7b"}
    for a in configs.list_archs():
        assert wl.skip_reason(configs.get(a),
                              wl.WORKLOADS["train_4k"]) is None
